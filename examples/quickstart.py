"""Quickstart: ZeRO++ training in ~40 lines.

Run (8 simulated devices on CPU):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.data.synthetic import SyntheticLM, make_batch
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.train import trainer
from repro.train.policy import make_policy
from repro.core.compat import make_mesh


def main():
    # 1. mesh: 'data' = slow tier, 'model' = fast tier (paper's intra-node)
    mesh = make_mesh((4, 2), ("data", "model"))

    # 2. architecture + ZeRO++ policy (qwZ INT8 + hpZ + qgZ INT4 by default)
    arch = get_config("gpt-350m").reduced()
    pol = make_policy(arch, mesh.axis_names)       # variant="zeropp"
    model = Model(arch, pol.zcfg, world=8)
    print(f"model: {model.n_params()/1e6:.1f}M params | "
          f"qwZ={pol.zcfg.qwz} hpZ={pol.zcfg.hpz} qgZ={pol.zcfg.qgz}")

    # 3. distributed train step (one shard_map over the mesh)
    opt_cfg = AdamWConfig(lr=3e-3, moments_dtype=pol.moments_dtype)
    step = trainer.build_train_step(model, mesh, opt_cfg, global_batch=16)
    params, opt = trainer.init_state(model, mesh, opt_cfg,
                                     jax.random.PRNGKey(0))

    # 4. deterministic synthetic LM data, train a few steps
    lm = SyntheticLM(vocab=arch.vocab, seq_len=64, seed=0)
    for i in range(10):
        batch = trainer.place_batch(make_batch(arch, lm, i, 16), mesh,
                                    step.in_specs[2])
        params, opt, metrics = step.fn(params, opt, batch)
        print(f"step {i}: loss {float(metrics['loss']):.4f} "
              f"gnorm {float(metrics['grad_norm']):.3f}")
    print(f"(best achievable loss = data entropy bound "
          f"{lm.entropy_bound:.3f})")


if __name__ == "__main__":
    main()
