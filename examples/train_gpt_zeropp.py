"""End-to-end driver: train a ~100M-param GPT with full ZeRO++ for a few
hundred steps, with periodic checkpoints (deliverable (b) end-to-end).

Uses the production launcher (repro.launch.train) — the same code path a
real run would use — on 8 simulated devices.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/train_gpt_zeropp.py [--steps 200]

Takes a while on CPU: a ~100M model at batch 8 x seq 128 is ~5 GFLOP/step.
Pass --tiny for a seconds-scale smoke version.
"""
import argparse
import dataclasses
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config           # noqa: E402
from repro.configs.base import ArchConfig      # noqa: E402
import repro.configs as configs                # noqa: E402
from repro.launch import train as train_mod    # noqa: E402


# ~95M params: a real (if small) transformer, not a toy
GPT_100M = ArchConfig(
    name="gpt-100m", family="dense", n_layers=12, d_model=768, vocab=8192,
    pattern=("attn",), n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/zeropp_gpt100m")
    ap.add_argument("--ckpt-format", default="fp32",
                    choices=["fp32", "int8"],
                    help="per-shard checkpoint payload (int8 = qwZ-style "
                         "block-quantized, ~4x smaller)")
    args = ap.parse_args()

    # register the config so --arch finds it
    configs._R[GPT_100M.name] = GPT_100M

    argv = ["--arch", "gpt-100m", "--mesh", "4x2",
            "--steps", str(args.steps), "--batch", "8", "--seq", "128",
            "--lr", "1e-3", "--ckpt-dir", args.ckpt_dir,
            "--ckpt-format", args.ckpt_format,
            "--ckpt-every", "50", "--log-every", "10"]
    if args.tiny:
        argv += ["--reduced", "--steps", "20", "--batch", "16",
                 "--seq", "64", "--lr", "3e-3"]
    sys.argv = [sys.argv[0]] + argv
    train_mod.main()


if __name__ == "__main__":
    main()
