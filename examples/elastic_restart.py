"""Elastic fault-tolerance demo: the supervisor runtime end to end.

Every phase drives ``repro.launch.train --elastic`` — the supervisor from
train/elastic.py with ASYNC background checkpoints (per-shard files +
checksummed manifest, staged commit + atomic rename), restoring through
``ZeroState.restore_resilient``.

Phase 1  worker death at step 6: the supervisor abandons the in-flight
         write, restores the latest committed async checkpoint and
         replays — post-resume losses are bit-identical to an
         uninterrupted run (the fault suite asserts this).
Phase 2  LIVE resharding mid-run: world 8 -> 4 at step 14 and back 4 -> 8
         at step 17, moving the state through host memory only — no
         checkpoint file is read.
Phase 3  graceful preemption (injected; a real SIGTERM takes the same
         path): the slowed in-flight write is drained within the grace
         window and a final synchronous checkpoint is cut before exit.
Phase 4  corrupt checkpoint on disk: bit-rot is injected into the newest
         checkpoint; the per-shard checksums catch it, the directory is
         quarantined aside (``.corrupt``) and the run falls back to the
         previous intact checkpoint.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import shutil
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_mod    # noqa: E402

CKPT = "/tmp/zeropp_elastic_demo"


def run(argv):
    sys.argv = ["elastic_restart"] + argv
    train_mod.main()


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    common = ["--elastic", "--arch", "gpt-350m", "--reduced", "--batch",
              "16", "--seq", "64", "--ckpt-dir", CKPT, "--ckpt-every", "4"]

    print("=== phase 1: worker death at step 6 -> restore from the "
          "latest async checkpoint, bit-exact replay ===")
    run(common + ["--mesh", "4x2", "--steps", "12", "--fault-die-at", "6"])

    print("\n=== phase 2: LIVE reshard 8 -> 4 -> 8 mid-run "
          "(in-memory, no checkpoint read) ===")
    run(common + ["--mesh", "4x2", "--steps", "20",
                  "--reshard", "14:2x2,17:4x2"])

    print("\n=== phase 3: graceful preemption at step 22 — drain the "
          "slowed in-flight write, cut a final checkpoint ===")
    run(common + ["--mesh", "4x2", "--steps", "26",
                  "--fault-preempt-at", "22",
                  "--fault-slow-write", "1", "--grace", "30"])

    print("\n=== phase 4: bit-rot in the newest checkpoint -> "
          "quarantine and fall back ===")
    from repro.testing.faults import corrupt_shard
    from repro.train.state import latest_checkpoint
    newest = latest_checkpoint(CKPT)
    print(f"corrupting {newest}")
    corrupt_shard(newest)
    run(common + ["--mesh", "4x2", "--steps", "26"])


if __name__ == "__main__":
    main()
