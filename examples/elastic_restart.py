"""Fault tolerance demo: node failure mid-run + ELASTIC restart.

All checkpoints go through the ZeroState subsystem (train/state.py):
per-shard files + a manifest, written atomically (tmp dir + rename).

Phase 1 trains on a 4x2 mesh (8 devices) with periodic checkpoints and a
simulated node failure; the launcher restarts from the latest checkpoint.
Phase 2 restores the same checkpoint onto a 2x2 mesh (4 devices): the flat
ZeRO buffers re-fit onto the new world's padding and training continues —
no layout surgery, loss picks up where it left off.
Phase 3 switches to the INT8 block-quantized checkpoint format (~4x
smaller on disk) and Phase 4 elastically restores THAT onto a 1x2 mesh
(world 4 -> 2, a third padding alignment): loss continues within the
quantization error bound.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import shutil
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_mod    # noqa: E402

CKPT = "/tmp/zeropp_elastic_demo"


def run(argv):
    sys.argv = ["elastic_restart"] + argv
    train_mod.main()


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    common = ["--arch", "gpt-350m", "--reduced", "--batch", "16",
              "--seq", "64", "--ckpt-dir", CKPT, "--ckpt-every", "4",
              "--log-every", "2"]

    print("=== phase 1: 4x2 mesh, failure at step 9, auto-restart ===")
    run(common + ["--mesh", "4x2", "--steps", "12",
                  "--simulate-failure-at", "9"])

    print("\n=== phase 2: ELASTIC restore onto a 2x2 mesh (world 8 -> 4) ===")
    run(common + ["--mesh", "2x2", "--steps", "16"])

    print("\n=== phase 3: INT8 block-quantized per-shard checkpoints ===")
    run(common + ["--mesh", "2x2", "--steps", "20", "--ckpt-format", "int8"])

    print("\n=== phase 4: ELASTIC restore from INT8 onto 1x2 (world 4 -> 2) "
          "===")
    run(common + ["--mesh", "1x2", "--steps", "22"])


if __name__ == "__main__":
    main()
