"""Serving example: batched prefill + autoregressive decode.

Parameters stay ZeRO-sharded (flat buffers over the whole mesh); every
layer group is gathered per step with qwZ INT8 — the serving analogue of
the paper's forward path.  The KV cache shards its sequence dim over the
fast 'model' axis; decode uses the exact 2-pass split-KV softmax.

With --from-ckpt, parameters are written through the ZeroState per-shard
INT8 checkpoint format and loaded back via the serving path
(state.load_serving_params: params only, bf16, no optimizer state) —
the deployment flow for a trained model.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python examples/serve_decode.py --arch qwen3-0.6b
"""
import argparse
import os
import sys
import tempfile

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.models.model import Model
from repro.train import serve
from repro.train.policy import make_policy
from repro.train.state import ZeroState, load_serving_params, param_specs
from repro.core.compat import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--from-ckpt", action="store_true",
                    help="roundtrip params through an INT8 per-shard "
                         "checkpoint and the bf16 serving load path")
    args = ap.parse_args()

    mesh = make_mesh((2, 2), ("data", "model"))
    arch = get_config(args.arch).reduced()
    pol = make_policy(arch, mesh.axis_names)
    model = Model(arch, pol.zcfg, world=4)

    # init + place ZeRO-sharded parameters
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    p_specs = param_specs(model, tuple(mesh.axis_names))
    params = {k: jax.device_put(v, NamedSharding(mesh, p_specs[k]))
              for k, v in params.items()}

    if args.from_ckpt:
        d = tempfile.mkdtemp(prefix="zeropp_serve_ckpt_")
        st = ZeroState(model, mesh, opt_cfg=None, params=params)
        path = st.save(d, 0, fmt="int8")
        params = load_serving_params(model, mesh, d, dtype=jnp.bfloat16)
        print(f"[serve] params <- {path} (INT8 per-shard ckpt, bf16 load)")

    B, P, G = 2, args.prompt_len, args.gen
    cap = P + G
    rng = np.random.default_rng(0)
    toks = rng.integers(0, arch.vocab, size=(B, P)).astype(np.int32)

    batch_axes, kv_axes = ("data",), ("model",)
    ps = serve.build_prefill_step(model, mesh, batch_axes, kv_axes)
    ds = serve.build_decode_step(model, mesh, batch_axes, kv_axes,
                                 donate=False)

    def put(d, specs):
        return {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
                for k, v in d.items()}

    logits, caches = ps.fn(params, put({"tokens": toks}, ps.in_specs[1]))
    caches = serve.pad_prefill_caches(model, caches, cap)
    c_specs = serve.cache_specs(model, batch_axes, kv_axes)
    caches = jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), caches,
        c_specs)

    out = [toks]
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for t in range(P, cap):
        out.append(np.asarray(tok))
        logits, caches = ds.fn(params, caches,
                               put({"tokens": tok}, ds.in_specs[2]),
                               jnp.int32(t))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]

    gen = np.concatenate(out, axis=1)
    for b in range(B):
        print(f"seq {b}: prompt={gen[b, :P].tolist()} "
              f"generated={gen[b, P:].tolist()}")


if __name__ == "__main__":
    main()
