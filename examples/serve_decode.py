"""Serving example: the continuous-batching engine (repro.serve).

Three requests with DIFFERENT prompt lengths run through one engine: they
are admitted into KV-pool slots, prefilled individually (prompt-length
buckets bound the compiled prefill shapes), and decoded TOGETHER by one
jitted decode step with a per-sequence ``cache_pos`` vector.  Tokens
stream per request as they are sampled.  Parameters stay ZeRO-sharded
(flat buffers over the whole mesh); every layer group is gathered per
step with qwZ INT8 — the serving analogue of the paper's forward path.

With --from-ckpt, parameters are written through the ZeroState per-shard
INT8 checkpoint format and the engine boots from it via the bf16 serving
load path (ServeEngine.from_checkpoint) — the deployment flow for a
trained model.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python examples/serve_decode.py --arch qwen3-0.6b \
      --temperature 0.8 --top-k 40 --top-p 0.95 --max-new-tokens 12
"""
import argparse
import os
import sys
import tempfile

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.models.model import Model
from repro.serve import ServeEngine
from repro.train.policy import make_policy
from repro.train.state import ZeroState, param_specs
from repro.core.compat import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--prompt-lens", default="5,12,9",
                    help="comma-separated prompt lengths (mixed in one run)")
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-len", type=int, default=64,
                    help="KV pool capacity per slot")
    ap.add_argument("--slots", type=int, default=2,
                    help="decode batch size (fewer slots than requests "
                         "exercises slot recycling)")
    ap.add_argument("--from-ckpt", action="store_true",
                    help="roundtrip params through an INT8 per-shard "
                         "checkpoint and boot the engine from it")
    ap.add_argument("--prefetch", type=int, default=None,
                    help="weight-gather ring depth for the serving path "
                         "(k>1 pays on slow interconnects; clamps to "
                         "n_layers-1; default: the policy's depth)")
    args = ap.parse_args()

    mesh = make_mesh((2, 2), ("data", "model"))
    arch = get_config(args.arch).reduced()
    pol = make_policy(arch, mesh.axis_names)
    model = Model(arch, pol.zcfg, world=4)

    # init + place ZeRO-sharded parameters
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    p_specs = param_specs(model, tuple(mesh.axis_names))
    params = {k: jax.device_put(v, NamedSharding(mesh, p_specs[k]))
              for k, v in params.items()}

    kw = dict(n_slots=args.slots, kv_len=args.kv_len,
              batch_axes=(), kv_axes=("model",), prefetch=args.prefetch)
    if args.from_ckpt:
        d = tempfile.mkdtemp(prefix="zeropp_serve_ckpt_")
        st = ZeroState(model, mesh, opt_cfg=None, params=params,
                       meta={"arch": arch.name})
        path = st.save(d, 0, fmt="int8")
        engine = ServeEngine.from_checkpoint(model, mesh, d, **kw)
        print(f"[serve] engine <- {path} (INT8 per-shard ckpt, bf16 load)")
    else:
        engine = ServeEngine(model, mesh, params, **kw)

    lens = [int(x) for x in args.prompt_lens.split(",")]
    rng = np.random.default_rng(args.seed)
    streams = {}

    def on_token(uid, tok):
        streams[uid].append(tok)
        print(f"  [stream] req {uid}: +{tok}  ({len(streams[uid])} tokens)")

    uids = []
    for i, P in enumerate(lens):
        prompt = rng.integers(0, arch.vocab, P).astype(np.int32)
        uid = engine.submit(prompt, max_new_tokens=args.max_new_tokens,
                            temperature=args.temperature, top_k=args.top_k,
                            top_p=args.top_p, seed=args.seed + i,
                            on_token=on_token)
        streams[uid] = []
        uids.append((uid, prompt))
        print(f"req {uid}: prompt_len={P} "
              f"bucket={engine.scheduler.bucket_for(P)}")

    results = engine.run(max_steps=1000)
    print(f"\n{args.slots} slots served {len(lens)} requests "
          f"(slot map: {engine.slot_history})")
    for uid, prompt in uids:
        print(f"req {uid}: prompt={prompt.tolist()} "
              f"generated={results[uid]}")

    st = engine.stats()

    def _ms(d):
        return (f"p50 {d['p50']:.1f}ms / p99 {d['p99']:.1f}ms"
                if d.get("p50") is not None else "n/a")

    tps = st["tok_per_s"]
    print(f"\n[serve] stats: admitted={st['admitted']} "
          f"completed={st['completed']} expired={st['expired']} "
          f"steps={st['steps']} occupancy={st['occupancy']:.2f}")
    print(f"[serve] TTFT {_ms(st['ttft_ms'])}  "
          f"per-token {_ms(st['tok_latency_ms'])}  "
          f"throughput {'n/a' if tps is None else f'{tps:.1f} tok/s'}")


if __name__ == "__main__":
    main()
