# Repo CI entry points.  Multi-device semantics run on simulated host CPU
# devices: the pytest main process stays single-device (see
# src/repro/launch/dryrun.py's device-count note) and the multi-device
# checks spawn their own 8-device subprocesses via testing/subproc.py;
# targets that exercise the mesh directly export the XLA flag themselves.

PY       ?= python
MP8       = XLA_FLAGS=--xla_force_host_platform_device_count=8
PYPATH    = PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}

.PHONY: test test-fast bench-smoke bench ckpt-smoke serve-smoke \
        serve-load-smoke moe-smoke ring-smoke fault-smoke kernel-smoke \
        obs-smoke tune-smoke

# tier-1 verify (ROADMAP.md): full suite, stop on first failure
test:
	$(PYPATH) $(PY) -m pytest -x -q

# skip the slow multi-device subprocess groups
test-fast:
	$(PYPATH) $(PY) -m pytest -x -q -m "not slow"

# checkpoint smoke: per-shard fp32 + INT8 save -> ELASTIC restore
# (world 8 -> 4 -> 2) in an 8-device subprocess (testing/subproc.py)
ckpt-smoke:
	$(PYPATH) $(PY) -c "\
	from repro.testing.subproc import run_checks; \
	run_checks(['check_state_elastic_restore', \
	            'check_state_quantized_roundtrip'], n_devices=8, \
	           timeout=1200); \
	print('ckpt smoke OK: per-shard save -> elastic restore verified')"

# serving smoke: continuous-batching engine end-to-end on a 4-device CPU
# mesh — 6 requests with mixed prompt lengths over 4 slots (recycling),
# INT8 per-shard checkpoint boot, greedy output checked bit-identical to
# the raw single-request prefill+decode path (testing/subproc.py)
serve-smoke:
	$(PYPATH) $(PY) -c "\
	from repro.testing.subproc import run_checks; \
	run_checks(['check_serve_engine_continuous_batching'], n_devices=4, \
	           timeout=1200); \
	print('serve smoke OK: continuous batching == per-request decode')"

# paged-serving load smoke (serve/kv_pool.py paged pool, DESIGN.md §10):
# the paged engine booted from an INT8 per-shard checkpoint must emit
# token streams bit-identical to the slab engine on 4- AND 8-device
# meshes (prefix cache hitting, pool fully drained after), the
# speculative self-draft path must stay token-identical with > 1
# accepted token per verify, then the multi-tenant trace bench runs its
# admission / prefix-TTFT / acceptance gates against the committed
# BENCH_serve.json structural snapshot
serve-load-smoke:
	$(PYPATH) $(PY) -c "\
	from repro.testing.subproc import run_checks; \
	run_checks(['check_serve_engine_paged'], n_devices=4, timeout=1200); \
	run_checks(['check_serve_engine_paged', \
	            'check_serve_engine_speculative'], n_devices=8, \
	           timeout=1800); \
	print('serve load smoke OK: paged == slab at 4/8 dev, speculative '\
	      'token-identical with >1 accepted/verify')"
	$(PYPATH) $(PY) -m benchmarks.serve_bench --smoke

# MoE overlap smoke: tiny deepseek-style MoE stack (shared + routed
# experts, chunked) with prefetch=1 — the layer-scan shared gathers and
# the nested expert-chunk gathers/reduces must be schedulable under
# compute (overlap_fraction > 0.5 from compiled HLO; 0.0 synchronous)
moe-smoke:
	$(PYPATH) $(PY) -c "\
	from repro.testing.subproc import run_checks; \
	run_checks(['check_moe_prefetch_overlap_fraction'], n_devices=8, \
	           timeout=1200); \
	print('moe smoke OK: chunk/layer MoE schedule overlap verified from HLO')"

# prefetch-ring smoke: 8-dev depth-2 dense + MoE overlap check from
# compiled HLO — structural overlap_fraction at depth 2 must be no lower
# than the depth-1 measurement, the depth-credited (effective) overlap
# strictly higher, and the MoE nested-remat expert re-gather no longer
# exposed (no gather-only loop)
ring-smoke:
	$(PYPATH) $(PY) -c "\
	from repro.testing.subproc import run_checks; \
	run_checks(['check_ring_overlap_depth'], n_devices=8, timeout=2400); \
	print('ring smoke OK: depth-2 ring beats depth-1 on dense + MoE')"

# elastic fault-tolerance smoke (train/elastic.py + testing/faults.py):
# async writer overlap, worker death -> bit-exact resume, transient-write
# retries, live 8->4->8 in-memory resharding, quarantine-and-fall-back on
# corrupt checkpoints, and REAL SIGKILL/SIGTERM subprocess scenarios
# (crash mid-write leaves only unselectable debris; graceful drain)
fault-smoke:
	$(PYPATH) $(PY) -c "\
	from repro.testing.subproc import run_checks; \
	run_checks(['check_elastic_async_overlap', \
	            'check_elastic_kill_resume', \
	            'check_elastic_flaky_io_retry'], n_devices=8, \
	           timeout=1800); \
	run_checks(['check_elastic_live_reshard', \
	            'check_elastic_corrupt_fallback'], n_devices=8, \
	           timeout=1800); \
	run_checks(['check_elastic_crash_during_write', \
	            'check_elastic_sigterm_grace'], n_devices=8, \
	           timeout=1800); \
	print('fault smoke OK: async ckpt overlap, bit-exact resume, live '\
	      'reshard, corrupt fallback, real-signal crash/drain verified')"

# kernel-backend smoke (kernels/ops.py dispatch seam, DESIGN.md §7):
# interpret-mode parity suite for every Pallas kernel body (quant /
# dequant / fused reorder+quant / dequant-reduce-requant / INT8
# dequant-GEMM vs the pure-jnp oracles), then the schedule- and
# serve-level composition checks with the backend forced to interpret
# (depth sweep bit-exact, fused INT8 serving head == staged head,
# xla-vs-interpret training bit-identity), then a kernel_bench smoke run
kernel-smoke:
	$(PYPATH) $(PY) -m pytest -x -q tests/test_kernels.py \
		-k "not 8dev"
	$(PYPATH) $(PY) -c "\
	from repro.testing.subproc import run_checks; \
	run_checks(['check_kernel_backend_depth_sweep', \
	            'check_qwz_gemm_head_matches_staged', \
	            'check_kernel_backend_train_bitexact'], n_devices=8, \
	           timeout=2400); \
	run_checks(['check_serve_engine_continuous_batching'], n_devices=8, \
	           timeout=1800, \
	           extra_env={'REPRO_KERNEL_BACKEND': 'interpret'}); \
	print('kernel smoke OK: interpret-mode parity + kernel-backed '\
	      'schedule/serve bit-exactness verified')"
	$(PYPATH) $(PY) -m benchmarks.kernel_bench --smoke

# observability smoke (obs/, DESIGN.md §8): measured-vs-projected comm
# crosscheck per collective label (dense + MoE, ring depths 0/1/2),
# telemetry-under-failure jsonl replay (kill/restart -> totals equal the
# uninterrupted oracle), and the runtime gate on a REAL 8-dev train run
# (comm bytes within 1% of the analytic projection, telemetry-disabled
# overhead < 2%), then the telemetry-on train + serve BENCH report with
# the gate in assert mode
obs-smoke:
	$(PYPATH) $(PY) -c "\
	from repro.testing.subproc import run_checks; \
	run_checks(['check_obs_comm_crosscheck'], n_devices=8, timeout=1800); \
	run_checks(['check_obs_comm_crosscheck_moe'], n_devices=8, \
	           timeout=1800); \
	run_checks(['check_obs_telemetry_failure_replay', \
	            'check_obs_runtime_gate'], n_devices=8, timeout=1800); \
	print('obs smoke OK: comm counters match analytics, replay survives '\
	      'kill/restart, runtime gate passes')"
	$(PYPATH) $(PY) -m benchmarks.runtime_report

# tuner smoke (repro/tune, DESIGN.md §9): the (k+1)-ring HBM ledger vs
# the MEASURED live gathered-buffer counts in the traced train step for
# prefetch 0..3, the --tune=static boot path (build_everything resolves
# to the same frozen policy as a direct resolve call and trains), then
# the static resolution sweep checked against the committed
# BENCH_tuner.json snapshot (deterministic by the static-profile
# contract)
tune-smoke:
	$(PYPATH) $(PY) -c "\
	from repro.testing.subproc import run_checks; \
	run_checks(['check_tune_ledger_live_buffers', \
	            'check_tune_static_resolve_boot'], n_devices=8, \
	           timeout=1800); \
	print('tune smoke OK: ledger matches live ring buffers at k=0..3, '\
	      'static boot path resolves deterministically')"
	$(PYPATH) $(PY) -m benchmarks.tuner_report

# overlap benchmark + suite smoke in one command: verifies the prefetched
# schedule from compiled HLO on the 8-device CPU mesh, then prints the
# overlap-aware throughput projection (paper Table 2 analogue)
bench-smoke:
	$(MP8) $(PYPATH) $(PY) -c "\
	from repro.testing.checks import check_prefetch_overlap_fraction; \
	check_prefetch_overlap_fraction(); \
	print('overlap verified: prefetch=1 overlappable, prefetch=0 exposed')"
	$(PYPATH) $(PY) benchmarks/throughput_model.py

# full benchmark battery (paper tables/figures)
bench:
	$(PYPATH) $(PY) -m benchmarks.run
