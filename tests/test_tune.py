"""Tuner subsystem tests (repro/tune): resolver determinism, the
HBM-budget monotonicity contract, static-probe profile round-trips, and
the (k+1) prefetch-ring ledger against a hand-counted oracle.

Live-mesh behaviour (ledger ring counts vs traced scan carries, the
8-device probe) runs in subprocesses via testing/subproc.py from
testing/checks.py; everything here is single-device analytic.
"""
import dataclasses

import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.zeropp import ZeroConfig
from repro.models.model import Model
from repro.testing.subproc import run_checks
from repro.tune import (GB, ProbeProfile, TierProfile, resolve,
                        static_profile, train_ledger)
from repro.tune.memory import ring_lines

AXES2 = ("data", "model")
AXES3 = ("pod", "data", "model")


def _arch():
    return get_config("gpt-350m").reduced()


# ---------------------------------------------------------------------------
# resolver determinism
# ---------------------------------------------------------------------------

def test_resolve_deterministic_under_static_profile():
    """Same inputs -> the same frozen policy, field for field (the static
    profile is committed, so CI resolution is reproducible by contract)."""
    arch = _arch()
    kw = dict(mode="static", mesh_sizes={"data": 4, "model": 2},
              hbm_budget_bytes=16 * GB, tokens_per_device=128)
    a = resolve(arch, AXES2, "zeropp", **kw)
    b = resolve(arch, AXES2, "zeropp", **kw)
    assert a == b                       # frozen dataclass equality
    assert a.zcfg == b.zcfg
    assert a.decisions == b.decisions
    assert a.ledger.as_dict() == b.ledger.as_dict()


def test_resolve_off_matches_make_policy():
    """mode='off' is exactly the preset table make_policy wraps."""
    from repro.train.policy import make_policy
    arch = _arch()
    for variant in ("zeropp", "baseline", "qwz", "hpz", "qgz"):
        for axes in (AXES2, AXES3):
            rp = resolve(arch, axes, variant, mode="off")
            pol = make_policy(arch, axes, variant)
            assert rp.zcfg == pol.zcfg, (variant, axes)
            assert rp.moments_dtype == pol.moments_dtype
            assert rp.n_params == pol.n_params
            assert rp.note == pol.note
            assert rp.train_accum == pol.train_accum


def test_resolve_overrides_win():
    rp = resolve(_arch(), AXES2, "zeropp", mode="static",
                 mesh_sizes={"data": 4, "model": 2},
                 overrides={"prefetch": 3, "qwz_block": 512})
    assert rp.zcfg.prefetch == 3        # pinned, no ledger walk-down
    assert rp.zcfg.qwz_block == 512
    assert any("overrides" in d for d in rp.decisions)


# ---------------------------------------------------------------------------
# budget monotonicity: tighter HBM never RAISES prefetch
# ---------------------------------------------------------------------------

def test_prefetch_monotone_in_budget():
    arch = _arch()
    sizes = {"data": 4, "model": 2}
    depths = []
    for budget_gb in (32, 16, 8, 2, 1):
        rp = resolve(arch, AXES2, "zeropp", mode="static", mesh_sizes=sizes,
                     hbm_budget_bytes=budget_gb * GB,
                     tokens_per_device=2048)
        depths.append(rp.zcfg.prefetch)
    assert depths == sorted(depths, reverse=True), depths
    # and the chosen depth's ledger must fit whenever any depth fits
    rp = resolve(arch, AXES2, "zeropp", mode="static", mesh_sizes=sizes,
                 hbm_budget_bytes=32 * GB)
    assert rp.ledger.fits


def test_ledger_walkdown_hits_zero_on_tiny_budget():
    """A budget smaller than the state itself walks depth to 0 and says so."""
    rp = resolve(_arch(), AXES2, "zeropp", mode="static",
                 mesh_sizes={"data": 4, "model": 2},
                 hbm_budget_bytes=1 << 20)   # 1 MiB: nothing fits
    assert rp.zcfg.prefetch == 0
    assert not rp.ledger.fits
    assert any("walk-down" in d for d in rp.decisions)


# ---------------------------------------------------------------------------
# static probe profile round-trip
# ---------------------------------------------------------------------------

def test_static_profile_roundtrip(tmp_path):
    prof = static_profile(AXES3, (2, 16, 16))
    assert prof.source == "static"
    p = tmp_path / "prof.json"
    prof.save(str(p))
    back = ProbeProfile.load(str(p))
    assert back == prof
    assert back.fast_bw("model") == prof.fast_bw("model")
    assert back.slow_bw(("pod",)) == prof.slow_bw(("pod",))


def test_profile_for_mesh_rekeys_axes():
    """A 3-axis profile re-keyed onto a 2-axis mesh: known axes keep their
    tiers, size-1 axes become free, unknown axes fall back to 'data'."""
    prof = static_profile(AXES3, (2, 16, 16))
    two = prof.for_mesh(AXES2, (16, 16))
    assert set(two.tiers) == {"data", "model"}
    assert two.tiers["model"] == prof.tiers["model"]
    assert two.tiers["data"] == prof.tiers["data"]


# ---------------------------------------------------------------------------
# (k+1) ring ledger vs hand-counted oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [0, 1, 2, 3])
def test_ring_ledger_matches_hand_count(k):
    """Hand-counted live-buffer oracle for a dense model:

      forward/backward weight ring: (k_eff + 1) buffers of the padded
      per-layer flat size, bf16;
      backward gradient ring: k_eff more such buffers.

    k_eff = min(k, n_periods - 1) — a deeper ring would lap itself.
    """
    arch = _arch()
    z = ZeroConfig(dp_axes=AXES2, prefetch=k)
    model = Model(arch, z, world=8)
    lines, rings = ring_lines(model)
    by_name = {l.name: l.bytes for l in lines}

    k_eff = min(k, model.n_periods - 1)
    P = model.period_spec.padded_size
    assert by_name["ring_weights_layers"] == (k_eff + 1) * 2 * P
    assert by_name.get("ring_grads_bwd", 0) == k_eff * 2 * P
    assert dict(rings)["layers"] == k_eff + 1


def test_train_ledger_charges_every_line():
    arch = _arch()
    sizes = {"data": 4, "model": 2}
    z = ZeroConfig(dp_axes=AXES2, hpz=True, hpz_axes=("model",), prefetch=1)
    model = Model(arch, z, world=8)
    led = train_ledger(model, sizes, moments_itemsize=4,
                       tokens_per_device=128, budget_bytes=16 * GB)
    N = model.n_params()
    assert led.line("master_params") == 4 * N // 8
    assert led.line("adam_moments") == 8 * N // 8
    assert led.line("grad_shards") == 4 * N // 8
    assert led.line("hpz_secondary") == 2 * N // 2   # |('model',)| = 2
    assert led.line("ring_weights_layers") > 0
    assert led.line("activations") > 0
    assert led.total == sum(l.bytes for l in led.lines)
    assert led.fits and led.headroom == 16 * GB - led.total


def test_serve_ledger_paged_matches_hand_count():
    """Page-granularity KV charge vs a hand-counted oracle:

      page_bytes = 2 (k+v) * n_layers * page_size * n_kv_heads * d_head
                   * itemsize
      kv_pool    = n_pages * page_bytes / |kv_axes| + n_slots * Pm * 4

    and the full-capacity paged pool over ALL mesh axes must bill exactly
    the slab line plus the page table (same bytes, different granularity).
    """
    from repro.tune import serve_ledger
    arch = _arch()
    z = ZeroConfig(dp_axes=AXES2)
    model = Model(arch, z, world=8)
    sizes = {"data": 4, "model": 2}
    n_slots, kv_len, page = 8, 64, 16
    pm = kv_len // page                      # pages per slot
    page_bytes = 2 * arch.n_layers * page * arch.n_kv_heads * arch.d_head * 2
    table = n_slots * pm * 4

    led = serve_ledger(model, sizes, n_slots=n_slots, kv_len=kv_len,
                       page_size=page, n_pages=12, kv_axes=("model",),
                       budget_bytes=16 * GB)
    assert led.line("kv_pool") == 12 * page_bytes // 2 + table

    # default n_pages = full capacity; kv_axes spanning the whole mesh
    # degenerates to the slab charge + table ints
    slab = serve_ledger(model, sizes, n_slots=n_slots, kv_len=kv_len,
                        budget_bytes=16 * GB)
    full = serve_ledger(model, sizes, n_slots=n_slots, kv_len=kv_len,
                        page_size=page, kv_axes=AXES2,
                        budget_bytes=16 * GB)
    assert full.line("kv_pool") == slab.line("kv_pool") + table

    with pytest.raises(ValueError):
        serve_ledger(model, sizes, n_slots=n_slots, kv_len=kv_len,
                     page_size=24)           # 64 % 24 != 0


def test_moe_ledger_has_expert_ring():
    """MoE models ring the nested expert-chunk scan too."""
    arch = get_config("deepseek-moe-16b").reduced()
    if arch.n_experts == 0:
        pytest.skip("config reduced away MoE")
    z = ZeroConfig(dp_axes=AXES2, prefetch=2)
    model = Model(arch, z, world=8)
    lines, rings = ring_lines(model)
    names = {l.name for l in lines}
    assert "ring_weights_experts" in names
    assert "expert_chunks" in dict(rings)
    kc = z.effective_prefetch(arch.expert_chunks)
    E = model.expert_spec.padded_size
    by_name = {l.name: l.bytes for l in lines}
    assert by_name["ring_weights_experts"] == (kc + 1) * 2 * E


# ---------------------------------------------------------------------------
# probe fitting (no devices: feed synthetic timings through _fit)
# ---------------------------------------------------------------------------

def test_fit_recovers_alpha_beta():
    from repro.tune.probe import _fit
    bw, alpha = 50e9, 20e-6
    pts = [(b, alpha + b / bw) for b in (1 << 13, 1 << 15, 1 << 17)]
    lat, bps = _fit(pts)
    assert abs(bps - bw) / bw < 1e-6
    assert abs(lat - alpha) < 1e-9


def test_fit_clamps_degenerate_inputs():
    from repro.tune.probe import _fit, _MAX_BW, _MIN_BW
    # all-identical byte sizes: slope undefined -> clamped, latency >= 0
    lat, bps = _fit([(4096, 1e-5), (4096, 1e-5), (4096, 1e-5)])
    assert _MIN_BW <= bps <= _MAX_BW
    assert lat >= 0.0


# ---------------------------------------------------------------------------
# multi-device: ledger vs traced scan carries, --tune=static boot path
# (subprocess; see testing/subproc.py)
# ---------------------------------------------------------------------------

def test_tune_ledger_live_buffers():
    """ISSUE 9 acceptance: (k+1) ledger == measured live gathered-buffer
    counts in the traced train step for prefetch 0..3."""
    run_checks(["check_tune_ledger_live_buffers"], n_devices=8, timeout=900)


def test_tune_static_resolve_boot():
    run_checks(["check_tune_static_resolve_boot"], n_devices=8, timeout=900)
