"""Observability subsystem tests (obs/: metrics, trace, report).

Fast tests run in-process: instrument semantics, jsonl tracer round-trip
and kill-safety (truncated final line), replay dedupe, BENCH export /
diff, and the gate math.  The measured-vs-projected comm crosschecks and
the telemetry-under-failure replay run on 8 simulated devices via
testing/subproc.py — the same groups ``make obs-smoke`` drives.
"""
import json
import os

import pytest

from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               count_dispatch, get_registry, set_registry)
from repro.obs.report import (GateFailure, bench_diff, comm_gate,
                              export_snapshot, format_diff, overhead_gate,
                              runtime_gate)
from repro.obs.trace import (Tracer, get_tracer, read_events,
                             replay_counters, set_tracer)
from repro.testing.subproc import run_checks


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    c = Counter("x")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    c.reset()
    assert c.value == 0
    g = Gauge("y")
    assert g.value is None
    g.set(1)
    g.set(7)
    assert g.value == 7


def test_histogram_window_and_percentiles():
    h = Histogram("h", window=4)
    for v in (1, 2, 3, 4, 100):           # 1 falls out of the window
        h.observe(v)
    assert h.count == 5 and h.min == 1 and h.max == 100
    assert h.percentile(50) == 4 and h.percentile(0) == 2
    assert h.percentile(100) == 100
    s = h.summary()
    assert s["count"] == 5 and s["p99"] == 100
    assert Histogram("e").percentile(50) is None
    assert Histogram("e").mean is None


def test_histogram_quantiles_match_numpy():
    """quantiles() is the nearest-rank export ServeEngine.stats() ships:
    with 101 distinct samples every requested quantile must agree with
    numpy's 'nearest' percentile exactly."""
    import numpy as np
    rng = np.random.default_rng(3)
    xs = rng.permutation(101).astype(float)   # 0..100, shuffled
    h = Histogram("q", window=256)
    for v in xs:
        h.observe(v)
    q = h.quantiles((50, 90, 99))
    assert q["n"] == 101
    for p in (50, 90, 99):
        assert q[f"p{p}"] == np.percentile(xs, p, method="nearest")
    # default keys + empty-histogram shape
    assert set(Histogram("e").quantiles()) == {"p50", "p90", "p99", "n"}
    assert Histogram("e").quantiles()["p50"] is None
    # windowing: quantiles see only the last `window` samples
    hw = Histogram("w", window=4)
    for v in (1, 2, 3, 4, 100):
        hw.observe(v)
    assert hw.quantiles((0,))["p0"] == 2 and hw.quantiles((0,))["n"] == 5


def test_registry_create_on_use_and_snapshot():
    r = Registry()
    r.counter("a.n").inc(3)
    r.gauge("b.g").set(1.5)
    r.gauge("b.unset")                    # never set: omitted
    r.histogram("c.h").observe(2.0)
    snap = r.snapshot()
    assert snap["a.n"] == 3 and snap["b.g"] == 1.5
    assert "b.unset" not in snap
    assert snap["c.h"]["count"] == 1 and snap["c.h"]["p50"] == 2.0
    assert r.counter("a.n") is r.counter("a.n")   # same instrument
    r.reset()
    assert r.snapshot() == {}


def test_set_registry_swaps_process_default():
    mine = Registry()
    old = set_registry(mine)
    try:
        count_dispatch("op", "xla")
        assert mine.counter("kernels.dispatch.op.xla").value == 1
        assert get_registry() is mine
    finally:
        set_registry(old)


def test_kernel_dispatch_counts_routing(tmp_path):
    """The ops.py seam counts the EFFECTIVE route once per dispatch."""
    import jax.numpy as jnp
    from repro.core.quant import QuantConfig
    from repro.kernels import ops
    mine = Registry()
    old = set_registry(mine)
    try:
        with ops.use_backend("xla"):
            x = jnp.ones((256,), jnp.float32)
            ops.quantize_blockwise(x, QuantConfig(bits=8, block_size=64))
        assert mine.counter(
            "kernels.dispatch.quantize_blockwise.xla").value == 1
    finally:
        set_registry(old)


# ---------------------------------------------------------------------------
# tracer + replay
# ---------------------------------------------------------------------------

def test_tracer_roundtrip(tmp_path):
    p = str(tmp_path / "ev.jsonl")
    tr = Tracer(p)
    with tr.span("train.step", step=0, layer=3):
        pass
    tr.event("elastic.restart", attempt=1)
    tr.counter("train.steps", 1, step=0)
    tr.counter("bytes", 10)               # unstepped: summed on replay
    tr.counter("bytes", 5)
    tr.flush()
    tr.close()
    recs = read_events(p)
    kinds = [r["kind"] for r in recs]
    assert kinds == ["span", "event", "counter", "counter", "counter"]
    sp = recs[0]
    assert sp["name"] == "train.step" and sp["step"] == 0
    assert sp["layer"] == 3 and sp["dur_ns"] >= 0
    tot = replay_counters(p)
    assert tot == {"train.steps": 1, "bytes": 15}


def test_tracer_disabled_is_noop(tmp_path):
    p = str(tmp_path / "never.jsonl")
    tr = Tracer(p, enabled=False)
    s1 = tr.span("a")
    s2 = tr.span("b", step=1)
    assert s1 is s2                       # one shared nullcontext
    with s1:
        pass
    tr.event("x")
    tr.counter("c", 1, step=0)
    tr.flush()
    assert not os.path.exists(p)          # nothing ever written


def test_tracer_append_mode_extends(tmp_path):
    """A restart re-opens the same log and EXTENDS it (replay contract)."""
    p = str(tmp_path / "ev.jsonl")
    t1 = Tracer(p)
    t1.counter("train.steps", 1, step=0)
    t1.close()
    t2 = Tracer(p)
    t2.counter("train.steps", 1, step=0)   # re-emitted step: dedupes
    t2.counter("train.steps", 1, step=1)
    t2.close()
    assert len(read_events(p)) == 3
    assert replay_counters(p) == {"train.steps": 2}


def test_read_events_skips_truncated_line(tmp_path):
    p = str(tmp_path / "ev.jsonl")
    tr = Tracer(p)
    tr.counter("n", 1, step=0)
    tr.close()
    with open(p, "a") as fh:
        fh.write('{"kind": "counter", "name": "n", "val')   # sheared write
    assert len(read_events(p)) == 1
    assert replay_counters(p) == {"n": 1}


def test_replay_counters_semantics(tmp_path):
    p = str(tmp_path / "ev.jsonl")
    tr = Tracer(p)
    tr.counter("loss", 5.0, step=0)
    tr.counter("loss", 4.0, step=1)
    tr.counter("loss", 9.9, step=1)       # re-emitted: last wins
    tr.counter("loss", 3.0, step=2)
    tr.close()
    assert replay_counters(p) == {"loss": 5.0 + 9.9 + 3.0}
    assert replay_counters(p, up_to_step=1) == {"loss": 5.0 + 9.9}


def test_set_tracer_restores_disabled():
    tr = Tracer(enabled=True)
    old = set_tracer(tr)
    assert get_tracer() is tr
    set_tracer(None)
    assert not get_tracer().enabled
    set_tracer(old)


# ---------------------------------------------------------------------------
# report: export, diff, gate
# ---------------------------------------------------------------------------

def test_export_snapshot_schema(tmp_path):
    r = Registry()
    r.counter("train.steps").inc(4)
    r.histogram("train.step.wall_ms").observe(10.0)
    p = str(tmp_path / "BENCH_runtime.json")
    doc = export_snapshot(p, registry=r, extra={"config": {"mesh": [4, 2]}})
    assert doc["runtime"]["metrics"]["train.steps"] == 4
    assert doc["runtime"]["config"]["mesh"] == [4, 2]
    on_disk = json.load(open(p))
    assert on_disk == doc


def test_bench_diff_and_cli(tmp_path, capsys):
    old = {"runtime": {"metrics": {"a": 100.0, "b": 1.0, "gone": 5}}}
    new = {"runtime": {"metrics": {"a": 103.0, "b": 2.0, "added": 7}}}
    rows = bench_diff(old, new, rel_tol=0.05)
    keys = [r[0] for r in rows]
    assert "runtime.metrics.a" not in keys          # 3% < 5% tol
    assert "runtime.metrics.b" in keys              # 2x drift
    assert "runtime.metrics.gone" in keys and "runtime.metrics.added" in keys
    assert "no drift" == format_diff(bench_diff(old, old))

    from repro.obs import report as report_mod
    po, pn = str(tmp_path / "o.json"), str(tmp_path / "n.json")
    json.dump(old, open(po, "w"))
    json.dump(new, open(pn, "w"))
    assert report_mod.main(["diff", po, pn]) == 0
    assert report_mod.main(["diff", po, pn, "--fail-on-drift"]) == 1
    capsys.readouterr()


def test_comm_gate_tolerance():
    ok = comm_gate({"zero.qwz_gather": 1000.0}, {"zero.qwz_gather": 1005.0})
    assert ok["ok"] and ok["labels"]["zero.qwz_gather"]["pass"]
    bad = comm_gate({"zero.qwz_gather": 1000.0}, {"zero.qwz_gather": 1100.0})
    assert not bad["ok"]
    # 'other' is reported but not gated
    rep = comm_gate({"other": 999.0}, {})
    assert rep["ok"] and not rep["labels"]["other"]["rel"] <= 0.01


def test_comm_gate_missing_label_fails():
    rep = comm_gate({}, {"zero.qgz_reduce": 5000.0})
    assert not rep["ok"]        # projected traffic never measured


def test_overhead_gate_and_runtime_gate_strict():
    ok = overhead_gate([1.0, 1.0, 1.0], [1.01, 1.01, 1.01], tol=0.02)
    assert ok["ok"] and abs(ok["rel_overhead"] - 0.01) < 1e-9
    assert overhead_gate([1.0], [0.9])["ok"]        # faster: trivially ok
    assert not overhead_gate([1.0], [1.5])["ok"]

    with pytest.raises(GateFailure) as ei:
        runtime_gate(measured={"zero.qwz_gather": 1.0},
                     projected={"zero.qwz_gather": 2.0}, strict=True)
    assert "zero.qwz_gather" in str(ei.value)
    rep = runtime_gate(measured={"zero.qwz_gather": 1.0},
                       projected={"zero.qwz_gather": 1.0},
                       enabled_s=[1.0, 1.0], disabled_s=[1.0, 1.0],
                       strict=True)
    assert rep["ok"] and rep["overhead"]["ok"]


# ---------------------------------------------------------------------------
# multi-device: comm crosscheck, failure replay, runtime gate (subprocess)
# ---------------------------------------------------------------------------

def test_obs_comm_crosscheck_dense():
    run_checks(["check_obs_comm_crosscheck"], n_devices=8, timeout=900)


def test_obs_comm_crosscheck_moe():
    run_checks(["check_obs_comm_crosscheck_moe"], n_devices=8, timeout=900)


def test_obs_failure_replay_and_runtime_gate():
    run_checks(["check_obs_telemetry_failure_replay",
                "check_obs_runtime_gate"], n_devices=8, timeout=900)
