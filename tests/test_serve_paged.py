"""Paged KV serving tests: page-table indirection vs the whole-slot slab
engine (token-identical greedy), prefix-cache bit-identity and refcount
safety, LRU eviction, chunked prefill interleaving, and speculative
decoding (token-identical, >1 accepted/verify).  Multi-device variants
run in subprocesses via testing/checks.py.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.core.compat import make_mesh
from repro.models.model import Model
from repro.serve import PagedKVPool, ServeEngine, steps
from repro.testing.subproc import run_checks
from repro.train.policy import make_policy
from repro.train.state import param_specs


@pytest.fixture(scope="module")
def served():
    """(model, mesh, params) — tiny dense arch, f32 for determinism."""
    mesh = make_mesh((1,), ("model",))
    arch = get_config("qwen3-0.6b").reduced()
    pol = make_policy(arch, mesh.axis_names, param_dtype=jnp.float32,
                      compute_dtype=jnp.float32)
    model = Model(arch, pol.zcfg, world=1)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    p_specs = param_specs(model, tuple(mesh.axis_names))
    params = {k: jax.device_put(v, NamedSharding(mesh, p_specs[k]))
              for k, v in params.items()}
    return model, mesh, params


JOBS = [(5, 6), (11, 4), (8, 5), (3, 7)]      # (prompt_len, max_new) x4
KV = 32
PAGE = 8


def _prompts(arch, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, arch.vocab, p).astype(np.int32) for p, _ in JOBS]


def _slab_results(model, mesh, params, prompts, jobs):
    eng = ServeEngine(model, mesh, params, n_slots=3, kv_len=KV)
    uids = [eng.submit(pr, max_new_tokens=n)
            for pr, (_, n) in zip(prompts, jobs)]
    return uids, eng.run(max_steps=200)


def _paged_engine(model, mesh, params, **kw):
    kw.setdefault("n_slots", 3)
    kw.setdefault("kv_len", KV)
    kw.setdefault("pool", "paged")
    kw.setdefault("page_size", PAGE)
    kw.setdefault("chunk_size", PAGE)
    kw.setdefault("cache_dtype", jnp.float32)
    return ServeEngine(model, mesh, params, **kw)


def test_paged_engine_matches_slab_greedy(served):
    """The paged engine (page-table indirection, chunked prefill) must
    emit, per request, exactly the token stream of the slab engine —
    continuous batching, staggered admission and all."""
    model, mesh, params = served
    prompts = _prompts(model.cfg)
    s_uids, s_res = _slab_results(model, mesh, params, prompts, JOBS)
    eng = _paged_engine(model, mesh, params)
    p_uids = [eng.submit(pr, max_new_tokens=n)
              for pr, (_, n) in zip(prompts, JOBS)]
    p_res = eng.run(max_steps=200)
    for su, pu in zip(s_uids, p_uids):
        assert p_res[pu] == s_res[su], (pu, p_res[pu], s_res[su])
    # full drain: every page unpinned (cached pages may park in the LRU)
    assert eng.pool.n_free == 3
    assert (eng.pool.refcount == 0).all()


def _chunked_prefill(pool, step, params, prompt, chunk, max_new=4):
    """Drive pool + jitted paged step directly through a chunked prefill;
    returns (slot, matched, logits_row) with logits_row the last prompt
    token's logits (np.float32, bitwise-comparable)."""
    res = pool.alloc(prompt, max_new, align=chunk)
    assert res is not None
    slot, matched = res
    P = len(prompt)
    done = matched
    last = None
    while done < P:
        end = min(done + chunk, P)
        toks = np.zeros((1, chunk), np.int32)
        toks[0, : end - done] = prompt[done:end]
        logits, pool.caches = step.fn(
            params, pool.caches, {"tokens": jnp.asarray(toks)},
            jnp.asarray(pool.table[slot: slot + 1]),
            jnp.asarray([done], jnp.int32))
        if end >= P:
            last = np.asarray(logits[0, (P - 1) - done])
        done = end
    pool.lengths[slot] = P
    pool.register_prefix(slot, prompt)
    return slot, matched, last


def test_prefix_hit_bitwise_identical_logits(served):
    """A prefix-cache hit skips the matched chunks but must produce the
    SAME memory as the cold prefill — the recomputed final chunk then
    yields bitwise-identical first-token logits (same pages, same chunk
    boundaries, same fixed attention view)."""
    model, mesh, params = served
    pool = PagedKVPool(model, mesh, n_slots=2, kv_len=KV, page_size=PAGE,
                       kv_axes=("model",), dtype=jnp.float32)
    step = steps.build_paged_step(model, mesh, ("model",), donate=False)
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, model.cfg.vocab, 20).astype(np.int32)

    slot, matched, cold = _chunked_prefill(pool, step, params, prompt, PAGE)
    assert matched == 0
    pool.free(slot)            # full prompt pages park in the LRU
    assert pool.counters["prefix_hits"] == 0

    slot2, matched2, warm = _chunked_prefill(pool, step, params, prompt, PAGE)
    # 20 tokens / page 8: pages 0,1 are full prompt pages -> 16 matched
    assert matched2 == 16
    assert pool.counters["prefix_hits"] == 1
    assert pool.counters["prefix_tokens_reused"] == 16
    np.testing.assert_array_equal(cold, warm)


def test_refcounted_pages_never_reclaimed_while_referenced(served):
    """Two live slots sharing prefix pages: freeing one must keep the
    shared pages out of the free list AND out of the LRU until the last
    reference drops; eviction only ever claims refcount-0 pages."""
    model, mesh, params = served
    # 4 slots x 4 pages capacity but only 8 physical pages: real pressure
    pool = PagedKVPool(model, mesh, n_slots=4, kv_len=KV, page_size=PAGE,
                       n_pages=8, kv_axes=("model",), dtype=jnp.float32)
    step = steps.build_paged_step(model, mesh, ("model",), donate=False)
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, model.cfg.vocab, 17).astype(np.int32)

    a, _, _ = _chunked_prefill(pool, step, params, prompt, PAGE, max_new=4)
    b, matched, _ = _chunked_prefill(pool, step, params, prompt, PAGE,
                                     max_new=4)
    shared = [int(pg) for pg in pool.table[a][:2]]
    assert matched == 16 and list(pool.table[b][:2]) == shared
    assert all(pool.refcount[pg] == 2 for pg in shared)

    pool.free(a)
    # still referenced by b: active, not cached, not free
    assert all(pool.refcount[pg] == 1 for pg in shared)
    assert all(pg not in pool._free_pages for pg in shared)
    assert all(pg not in pool._lru.values() for pg in shared)

    # a third request needing more pages than the free list holds must
    # evict — but only refcount-0 (LRU) pages, never b's live pages.
    # b holds 3 pages; a's free dropped its non-shared page to the free
    # list (unregistered 3rd page) — force eviction pressure:
    other = rng.integers(0, model.cfg.vocab, 24).astype(np.int32)
    res = pool.alloc(other, max_new=8, align=PAGE)   # needs 4 pages
    assert res is not None
    c = res[0]
    assert set(int(p) for p in pool.table[c]) \
        .isdisjoint({pg for pg in shared})
    assert all(pool.refcount[pg] == 1 for pg in shared)

    # with every page now referenced, a further admission must refuse
    # (all-or-nothing) rather than steal a live page
    assert pool.free_pages + pool.n_free >= 0
    assert pool.alloc(other, max_new=8, align=PAGE) is None
    assert (pool.refcount[[int(p) for p in pool.table[b] if p >= 0]]
            >= 1).all()


def test_lru_eviction_frees_only_refcount_zero(served):
    """Park two prompts' pages in the LRU, then admit a request that
    needs them back: eviction claims the OLDEST parked pages first and
    the evicted hashes stop matching."""
    model, mesh, params = served
    pool = PagedKVPool(model, mesh, n_slots=2, kv_len=KV, page_size=PAGE,
                       n_pages=4, kv_axes=("model",), dtype=jnp.float32)
    step = steps.build_paged_step(model, mesh, ("model",), donate=False)
    rng = np.random.default_rng(9)
    p1 = rng.integers(0, model.cfg.vocab, 9).astype(np.int32)
    p2 = rng.integers(0, model.cfg.vocab, 9).astype(np.int32)

    s1, _, _ = _chunked_prefill(pool, step, params, p1, PAGE, max_new=4)
    pool.free(s1)                                    # 1 page -> LRU
    s2, _, _ = _chunked_prefill(pool, step, params, p2, PAGE, max_new=4)
    pool.free(s2)                                    # 1 more page -> LRU
    assert pool.utilization()["pages_cached"] == 2

    big = rng.integers(0, model.cfg.vocab, 25).astype(np.int32)
    res = pool.alloc(big, max_new=4, align=PAGE)     # needs all 4 pages
    assert res is not None
    u = pool.utilization()
    assert u["evicted"] == 2 and u["pages_cached"] == 0
    # both parked prefixes are gone from the cache
    assert pool.match_prefix(p1)[0] == 0
    assert pool.match_prefix(p2)[0] == 0


def test_chunked_prefill_interleaves_decode(served):
    """A long prompt prefills in fixed chunks WHILE an already-active
    request keeps decoding: some step must emit a token for the short
    request while the long one is still mid-prefill."""
    model, mesh, params = served
    eng = _paged_engine(model, mesh, params, n_slots=2)
    rng = np.random.default_rng(10)
    short = rng.integers(0, model.cfg.vocab, 4).astype(np.int32)
    long = rng.integers(0, model.cfg.vocab, 24).astype(np.int32)
    u_short = eng.submit(short, max_new_tokens=8)
    eng.step()                                       # short goes active
    u_long = eng.submit(long, max_new_tokens=4)
    interleaved = False
    for _ in range(50):
        if eng.done:
            break
        emitted = eng.step()
        if eng._prefilling and any(u == u_short for u, _ in emitted):
            interleaved = True
    assert interleaved, "no decode tick overlapped the chunked prefill"
    # 24-token prompt / 8-token chunks = 3 chunks; short took 1
    assert eng.stats()["prefill_chunks"] == 4
    # both streams still exactly the solo greedy references
    for uid, pr, n in ((u_short, short, 8), (u_long, long, 4)):
        solo = ServeEngine(model, mesh, params, n_slots=1, kv_len=KV)
        su = solo.submit(pr, max_new_tokens=n)
        assert eng.results[uid] == solo.run(max_steps=100)[su]


def test_speculative_greedy_token_identical(served):
    """Self-draft speculative decoding (drafter == target) must emit
    exactly the plain greedy streams while accepting >1 token per verify
    step (a perfect drafter accepts the g-1 cap every round)."""
    model, mesh, params = served
    prompts = _prompts(model.cfg, seed=12)
    s_uids, s_res = _slab_results(model, mesh, params, prompts, JOBS)
    eng = _paged_engine(model, mesh, params, draft=(model, params),
                        spec_tokens=4)
    p_uids = [eng.submit(pr, max_new_tokens=n)
              for pr, (_, n) in zip(prompts, JOBS)]
    p_res = eng.run(max_steps=200)
    for su, pu in zip(s_uids, p_uids):
        assert p_res[pu] == s_res[su], (pu, p_res[pu], s_res[su])
    acc = eng.stats()["spec_accepted"]
    assert acc["n"] > 0 and acc["mean"] > 1.0, acc


def test_speculative_rejects_sampling(served):
    model, mesh, params = served
    eng = _paged_engine(model, mesh, params, draft=(model, params),
                        spec_tokens=2)
    with pytest.raises(ValueError, match="greedily"):
        eng.submit(np.zeros(4, np.int32), max_new_tokens=2, temperature=0.7)


def test_paged_engine_rejects_bad_configs(served):
    model, mesh, params = served
    with pytest.raises(ValueError, match="chunk_size"):
        _paged_engine(model, mesh, params, chunk_size=12)
    with pytest.raises(ValueError, match="pool"):
        ServeEngine(model, mesh, params, n_slots=1, kv_len=KV, pool="heap")
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, mesh, params, n_slots=1, kv_len=KV,
                    draft=(model, params))
    with pytest.raises(ValueError, match="spec_tokens"):
        _paged_engine(model, mesh, params, draft=(model, params),
                      spec_tokens=1)


# ---------------------------------------------------------------------------
# multi-device engine checks (subprocess; see testing/checks.py)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("n", [4, 8])
def test_paged_engine_sharded_int8_boot(n):
    run_checks(["check_serve_engine_paged"], n_devices=n, timeout=900)


@pytest.mark.slow
def test_speculative_engine_sharded(n=8):
    run_checks(["check_serve_engine_speculative"], n_devices=n, timeout=900)
