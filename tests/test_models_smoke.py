"""Per-architecture smoke tests: reduced config, one fwd/train step on CPU.

Asserts output shapes, finiteness (no NaN), and that a gradient step moves
the loss.  The FULL configs are exercised only by the dry-run.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.core.zeropp import ZeroConfig
from repro.models.model import Model
from repro.models.transformer import RunSpec

Z = ZeroConfig.local(param_dtype=jnp.float32, compute_dtype=jnp.float32)


def _batch_for(model, B, S, key):
    cfg = model.cfg
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {"targets": jax.random.randint(k1, (B, S), 0, cfg.vocab)}
    if cfg.embed_inputs:
        batch["embeds"] = jax.random.normal(k2, (B, S, cfg.d_model),
                                            jnp.float32) * 0.1
    else:
        batch["tokens"] = jax.random.randint(k3, (B, S), 0, cfg.vocab)
    if cfg.mrope:
        p = jnp.arange(S)[None].repeat(B, 0)
        batch["positions"] = jnp.stack([p, p, p])  # text-like stub positions
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg, Z)
    B, S = 2, 16
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, dtype=jnp.float32)
    batch = _batch_for(model, B, S, key)
    rs = RunSpec(mode="train")

    @jax.jit
    def step(params):
        def lf(p):
            loss, m = model.loss_fn(p, batch, rs, dp_world=1)
            return loss, m
        (loss, m), g = jax.value_and_grad(lf, has_aux=True)(params)
        new = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
        return loss, m, new, g

    loss0, m, params1, g = step(params)
    assert np.isfinite(float(loss0)), f"{arch} loss NaN"
    # plausible initial loss for uniform-ish predictions
    assert 0 < float(loss0) < 3 * np.log(cfg.vocab) + 5
    for k, v in g.items():
        assert np.isfinite(np.asarray(v)).all(), f"{arch} grad {k} NaN"
        assert np.abs(np.asarray(v)).max() > 0, f"{arch} grad {k} all-zero"
    loss1, *_ = step(params1)
    assert float(loss1) < float(loss0), f"{arch} SGD step did not reduce loss"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg, Z)
    B, S = 2, 8
    key = jax.random.PRNGKey(1)
    params = model.init_params(key, dtype=jnp.float32)
    batch = _batch_for(model, B, S, key)
    rs_p = RunSpec(mode="prefill")
    rs_d = RunSpec(mode="decode", kv_len=S + 4)

    logits, caches_p = jax.jit(
        lambda p, b: model.prefill_fn(p, b, rs_p))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch} prefill NaN"

    # decode a few tokens from scratch caches
    caches = model.init_caches(B, S + 4, dtype=jnp.float32)

    @jax.jit
    def dstep(p, c, tok, pos):
        db = {"tokens": tok} if not cfg.embed_inputs else \
            {"embeds": jax.random.normal(jax.random.PRNGKey(7),
                                         (B, 1, cfg.d_model)) * 0.1}
        if cfg.mrope:
            pp = jnp.full((B, 1), pos)
            db["positions"] = jnp.stack([pp, pp, pp])
        return model.decode_fn(p, c, db, pos, rs_d)

    tok = jnp.zeros((B, 1), jnp.int32)
    for t in range(3):
        logits_d, caches = dstep(params, caches, tok, jnp.int32(t))
        assert logits_d.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits_d)).all(), f"{arch} decode NaN"
        tok = jnp.argmax(logits_d[:, :, :], axis=-1).astype(jnp.int32)


def test_decode_matches_forward_dense():
    """Token-by-token decode logits == teacher-forced forward logits."""
    cfg = get_config("qwen3-0.6b").reduced()
    model = Model(cfg, Z)
    B, S = 1, 6
    key = jax.random.PRNGKey(2)
    params = model.init_params(key, dtype=jnp.float32)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)

    # teacher-forced: prefill over the first t tokens gives logits at t-1
    rs_d = RunSpec(mode="decode", kv_len=S)
    caches = model.init_caches(B, S, dtype=jnp.float32)
    dec_logits = []
    for t in range(S):
        lg, caches = jax.jit(lambda p, c, tk, pos: model.decode_fn(
            p, c, {"tokens": tk}, pos, rs_d))(
            params, caches, toks[:, t:t + 1], jnp.int32(t))
        dec_logits.append(np.asarray(lg)[:, 0])
    dec_logits = np.stack(dec_logits, axis=1)  # (B, S, V)

    rs_p = RunSpec(mode="prefill")
    last, _ = jax.jit(lambda p, b: model.prefill_fn(p, b, rs_p))(
        params, {"tokens": toks})
    np.testing.assert_allclose(dec_logits[:, -1], np.asarray(last)[:, 0],
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_forward_hybrid():
    """Same consistency check for the rec/local hybrid (state + ring cache)."""
    cfg = get_config("recurrentgemma-2b").reduced(window=4)
    model = Model(cfg, Z)
    B, S = 1, 6
    key = jax.random.PRNGKey(3)
    params = model.init_params(key, dtype=jnp.float32)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)

    rs_d = RunSpec(mode="decode", kv_len=S)
    caches = model.init_caches(B, S, dtype=jnp.float32)
    for t in range(S):
        lg, caches = jax.jit(lambda p, c, tk, pos: model.decode_fn(
            p, c, {"tokens": tk}, pos, rs_d))(
            params, caches, toks[:, t:t + 1], jnp.int32(t))
    rs_p = RunSpec(mode="prefill")
    last, _ = jax.jit(lambda p, b: model.prefill_fn(p, b, rs_p))(
        params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg)[:, 0], np.asarray(last)[:, 0],
                               rtol=2e-3, atol=2e-3)
