"""End-to-end system tests: trainer, serving, checkpoints, dry-run.

All multi-device behaviour runs in subprocesses with 8 simulated host
devices (see testing/subproc.py for why).
"""
import pytest

from repro.testing.subproc import run_checks


@pytest.mark.slow
def test_trainer_group():
    run_checks([
        "check_trainer_loss_decreases",
        "check_trainer_zeropp_tracks_baseline",
    ], n_devices=8, timeout=900)


@pytest.mark.slow
def test_trainer_accum():
    run_checks(["check_trainer_grad_accumulation"], n_devices=8, timeout=900)


@pytest.mark.slow
def test_checkpoint_elastic():
    run_checks(["check_checkpoint_elastic_restart"], n_devices=8,
               timeout=900)


@pytest.mark.slow
def test_serve_consistency_dense():
    run_checks(["check_serve_prefill_decode_consistency"], n_devices=4,
               timeout=900)


@pytest.mark.slow
def test_serve_consistency_families():
    run_checks([
        "check_serve_consistency_ssm",
        "check_serve_consistency_hybrid",
        "check_serve_consistency_moe",
    ], n_devices=4, timeout=1200)


@pytest.mark.slow
def test_dryrun_machinery():
    run_checks(["check_dryrun_smoke_cell"], n_devices=8, timeout=900)
