"""Multi-device collective equivalence tests (subprocess, 8 simulated devices).

The actual assertions live in repro.testing.checks; see subproc.py for why
these run out-of-process.  Grouped to amortize jax startup cost.
"""
import pytest

from repro.testing.subproc import run_checks


@pytest.mark.slow
def test_qgz_group():
    run_checks([
        "check_qgz_matches_reduce_scatter",
        "check_qgz_exact_when_representable",
        "check_qgz_multipod",
    ], n_devices=8)


@pytest.mark.slow
def test_qgz_variants_group():
    run_checks(["check_qgz_1hop_and_ring"], n_devices=8)


@pytest.mark.slow
def test_qwz_hpz_group():
    run_checks(["check_qwz_all_gather", "check_hpz_roundtrip"], n_devices=8)


@pytest.mark.slow
def test_engine_group():
    run_checks([
        "check_engine_baseline_matches_local",
        "check_engine_zeropp_close_to_local",
        "check_engine_hpz_consistency",
    ], n_devices=8)
