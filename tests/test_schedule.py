"""Prefetched-schedule tests (core/schedule.py + launch overlap analysis).

Multi-device assertions live in repro.testing.checks and run in
subprocesses (see testing/subproc.py); the analyze_overlap unit tests run
in-process on synthetic HLO text.
"""
import pytest

from repro.launch.hlo_analysis import analyze_overlap
from repro.testing.subproc import run_checks


@pytest.mark.slow
def test_prefetch_loss_equality():
    """prefetch=1 == prefetch=0 losses, bit-exact, on the smoke model."""
    run_checks(["check_prefetch_matches_sync"], n_devices=8, timeout=900)


@pytest.mark.slow
def test_prefetch_jaxpr_ordering():
    """Layer i+1's gather is issued before layer i's matmuls and is not
    consumed by them (prefetch=1); prefetch=0 is synchronous."""
    run_checks(["check_prefetch_jaxpr_ordering"], n_devices=8, timeout=900)


@pytest.mark.slow
def test_prefetch_overlap_hlo():
    """Compiled HLO: overlap_fraction > 0 with prefetch=1, == 0 without."""
    run_checks(["check_prefetch_overlap_fraction"], n_devices=8, timeout=900)


@pytest.mark.slow
def test_qgz_1hop_validates_input():
    run_checks(["check_qgz_1hop_rejects_misaligned"], n_devices=8,
               timeout=900)


# ---------------------------------------------------------------------------
# MoE chunk/layer schedule (the prefetched expert path)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_moe_prefetch_loss_and_grads_8dev():
    """MoE prefetch=1 (layer gathers + chunk pipeline double-buffered) ==
    prefetch=0 (synchronous): bit-exact losses AND gradients, 8 devices."""
    run_checks(["check_moe_prefetch_matches_sync"], n_devices=8,
               timeout=1200)


@pytest.mark.slow
def test_moe_prefetch_loss_and_grads_4dev():
    """Same bit-exactness on the smaller 2x2 mesh (different shard and
    secondary-group sizes exercise the alignment paths)."""
    run_checks(["check_moe_prefetch_matches_sync"], n_devices=4,
               timeout=1200)


@pytest.mark.slow
def test_moe_prefetch_overlap_hlo():
    """Compiled HLO: MoE overlap_fraction > 0.7 with prefetch=1 (layer
    scan + nested chunk scans, no gather-only remat loop left), == 0 with
    prefetch=0."""
    run_checks(["check_moe_prefetch_overlap_fraction"], n_devices=8,
               timeout=1200)


# ---------------------------------------------------------------------------
# depth-k prefetch ring (ring schedule, routing-ahead, hpZ nested recompute)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_depth_sweep_dense_8dev():
    """Dense 4-layer stack on 8 devices: losses AND gradients bit-exact
    to the synchronous reference at prefetch ∈ {1,2,3} and at 8 >
    n_layers (ring clamp)."""
    run_checks(["check_prefetch_depth_sweep"], n_devices=8, timeout=2400)


@pytest.mark.slow
def test_depth_sweep_dense_4dev():
    run_checks(["check_prefetch_depth_sweep"], n_devices=4, timeout=2400)


@pytest.mark.slow
def test_depth_sweep_moe_8dev():
    """MoE 4-layer stack (chunk+layer rings, speculative chunk-0 gather,
    hpZ-residual nested recompute): bit-exact across the same sweep."""
    run_checks(["check_moe_prefetch_depth_sweep"], n_devices=8,
               timeout=3600)


@pytest.mark.slow
def test_depth_sweep_moe_4dev():
    run_checks(["check_moe_prefetch_depth_sweep"], n_devices=4,
               timeout=3600)


@pytest.mark.slow
def test_ring_overlap_depth():
    """Acceptance: prefetch=2 strictly beats prefetch=1 in depth-credited
    overlap on dense AND MoE stacks; the MoE nested-remat re-gather is no
    longer exposed."""
    run_checks(["check_ring_overlap_depth"], n_devices=8, timeout=2400)


def test_zeroconfig_prefetch_validation():
    """Negative ring depths are rejected; effective_prefetch clamps to
    n-1 and degenerates to synchronous for local/single-layer scans."""
    import jax.numpy as jnp
    from repro.core.zeropp import ZeroConfig

    with pytest.raises(ValueError):
        ZeroConfig(prefetch=-1)
    z = ZeroConfig(prefetch=3)
    assert z.effective_prefetch(8) == 3
    assert z.effective_prefetch(4) == 3
    assert z.effective_prefetch(2) == 1      # clamp to n-1
    assert z.effective_prefetch(1) == 0      # single step: synchronous
    assert ZeroConfig.local(prefetch=3).effective_prefetch(8) == 0
    assert ZeroConfig(prefetch=0).effective_prefetch(8) == 0


# ---------------------------------------------------------------------------
# analyze_overlap unit tests (synthetic HLO, no devices)
# ---------------------------------------------------------------------------

_SYNC_HLO = """
HloModule sync

%cond (p: (s32[], f32[8], f32[64])) -> pred[] {
  %p = (s32[], f32[8], f32[64]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8], f32[64]) %p), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %n), direction=LT
}

%body (p: (s32[], f32[8], f32[64])) -> (s32[], f32[8], f32[64]) {
  %p = (s32[], f32[8], f32[64]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8], f32[64]) %p), index=0
  %w = f32[8]{0} get-tuple-element((s32[], f32[8], f32[64]) %p), index=1
  %h = f32[64]{0} get-tuple-element((s32[], f32[8], f32[64]) %p), index=2
  %g = f32[64]{0} all-gather(f32[8]{0} %w), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %wm = f32[8,8]{1,0} reshape(f32[64]{0} %g)
  %hm = f32[8,8]{1,0} reshape(f32[64]{0} %h)
  %mm = f32[8,8]{1,0} dot(f32[8,8]{1,0} %hm, f32[8,8]{1,0} %wm), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %h2 = f32[64]{0} reshape(f32[8,8]{1,0} %mm)
  %one = s32[] constant(1)
  %i2 = s32[] add(s32[] %i, s32[] %one)
  ROOT %out = (s32[], f32[8], f32[64]) tuple(s32[] %i2, f32[8]{0} %w, f32[64]{0} %h2)
}

ENTRY %main (a: (s32[], f32[8], f32[64])) -> (s32[], f32[8], f32[64]) {
  %a = (s32[], f32[8], f32[64]) parameter(0)
  ROOT %w0 = (s32[], f32[8], f32[64]) while((s32[], f32[8], f32[64]) %a), condition=%cond, body=%body
}
"""

# prefetched: the gather consumes a carried shard and feeds only the carry;
# the dot consumes the PREVIOUS iteration's gathered weights (also carried)
_PREFETCH_HLO = _SYNC_HLO.replace("HloModule sync", "HloModule prefetch") \
    .replace(
        "%g = f32[64]{0} all-gather(f32[8]{0} %w), "
        "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}\n"
        "  %wm = f32[8,8]{1,0} reshape(f32[64]{0} %g)\n"
        "  %hm = f32[8,8]{1,0} reshape(f32[64]{0} %h)",
        "%g = f32[64]{0} all-gather(f32[8]{0} %w), "
        "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}\n"
        "  %wm = f32[8,8]{1,0} reshape(f32[64]{0} %h)\n"
        "  %hm = f32[8,8]{1,0} reshape(f32[64]{0} %h)") \
    .replace(
        "ROOT %out = (s32[], f32[8], f32[64]) tuple(s32[] %i2, "
        "f32[8]{0} %w, f32[64]{0} %h2)",
        "%keep = f32[8]{0} slice(f32[64]{0} %g), slice={[0:8]}\n"
        "  ROOT %out = (s32[], f32[8], f32[64]) tuple(s32[] %i2, "
        "f32[8]{0} %keep, f32[64]{0} %h2)")


def test_analyze_overlap_sync_exposed():
    ov = analyze_overlap(_SYNC_HLO)
    assert ov["in_loop_collectives"] == 1
    assert ov["overlappable_collectives"] == 0
    assert ov["overlap_fraction"] == 0.0


def test_analyze_overlap_prefetch_detected():
    ov = analyze_overlap(_PREFETCH_HLO)
    assert ov["in_loop_collectives"] == 1
    assert ov["overlappable_collectives"] == 1
    assert ov["overlap_fraction"] == 1.0
    # trip count parsed from the loop condition constant
    (loop,) = ov["per_loop"].values()
    assert loop["trip_count"] == 4


# nested loops: a 3-trip inner (chunk) loop inside a 4-trip outer (layer)
# loop — the inner loop's wire bytes must be weighted by the outer trips
_NESTED_HLO = """
HloModule nested

%icond (p: (s32[], f32[8], f32[64])) -> pred[] {
  %p = (s32[], f32[8], f32[64]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8], f32[64]) %p), index=0
  %n = s32[] constant(3)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %n), direction=LT
}

%ibody (p: (s32[], f32[8], f32[64])) -> (s32[], f32[8], f32[64]) {
  %p = (s32[], f32[8], f32[64]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8], f32[64]) %p), index=0
  %w = f32[8]{0} get-tuple-element((s32[], f32[8], f32[64]) %p), index=1
  %h = f32[64]{0} get-tuple-element((s32[], f32[8], f32[64]) %p), index=2
  %g = f32[64]{0} all-gather(f32[8]{0} %w), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %wm = f32[8,8]{1,0} reshape(f32[64]{0} %g)
  %hm = f32[8,8]{1,0} reshape(f32[64]{0} %h)
  %mm = f32[8,8]{1,0} dot(f32[8,8]{1,0} %hm, f32[8,8]{1,0} %wm), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %h2 = f32[64]{0} reshape(f32[8,8]{1,0} %mm)
  %one = s32[] constant(1)
  %i2 = s32[] add(s32[] %i, s32[] %one)
  ROOT %out = (s32[], f32[8], f32[64]) tuple(s32[] %i2, f32[8]{0} %w, f32[64]{0} %h2)
}

%ocond (p: (s32[], f32[8], f32[64])) -> pred[] {
  %p = (s32[], f32[8], f32[64]) parameter(0)
  %j = s32[] get-tuple-element((s32[], f32[8], f32[64]) %p), index=0
  %m = s32[] constant(4)
  ROOT %lt = pred[] compare(s32[] %j, s32[] %m), direction=LT
}

%obody (p: (s32[], f32[8], f32[64])) -> (s32[], f32[8], f32[64]) {
  %p = (s32[], f32[8], f32[64]) parameter(0)
  %j = s32[] get-tuple-element((s32[], f32[8], f32[64]) %p), index=0
  %w = f32[8]{0} get-tuple-element((s32[], f32[8], f32[64]) %p), index=1
  %h = f32[64]{0} get-tuple-element((s32[], f32[8], f32[64]) %p), index=2
  %zero = s32[] constant(0)
  %it = (s32[], f32[8], f32[64]) tuple(s32[] %zero, f32[8]{0} %w, f32[64]{0} %h)
  %iw = (s32[], f32[8], f32[64]) while((s32[], f32[8], f32[64]) %it), condition=%icond, body=%ibody
  %h3 = f32[64]{0} get-tuple-element((s32[], f32[8], f32[64]) %iw), index=2
  %one = s32[] constant(1)
  %j2 = s32[] add(s32[] %j, s32[] %one)
  ROOT %out = (s32[], f32[8], f32[64]) tuple(s32[] %j2, f32[8]{0} %w, f32[64]{0} %h3)
}

ENTRY %main (a: (s32[], f32[8], f32[64])) -> (s32[], f32[8], f32[64]) {
  %a = (s32[], f32[8], f32[64]) parameter(0)
  ROOT %w0 = (s32[], f32[8], f32[64]) while((s32[], f32[8], f32[64]) %a), condition=%ocond, body=%obody
}
"""


def test_analyze_overlap_nested_loop_multiplier():
    ov = analyze_overlap(_NESTED_HLO)
    (loop,) = ov["per_loop"].values()          # only the inner body gathers
    assert loop["trip_count"] == 3
    assert loop["outer_mult"] == 4.0
    # gather moves 64-8=56 f32 = 224 bytes, x3 trips x4 outer iterations
    assert ov["in_loop_wire_bytes"] == 224 * 3 * 4
    assert ov["overlap_fraction"] == 0.0       # sync: gather feeds the dot


# a gather-only loop (what XLA leaves of a remat whose recomputed GEMMs are
# dead): nothing to overlap with inside the iteration -> exposed
_GATHER_ONLY_HLO = """
HloModule gatheronly

%cond (p: (s32[], f32[8], f32[64])) -> pred[] {
  %p = (s32[], f32[8], f32[64]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8], f32[64]) %p), index=0
  %n = s32[] constant(2)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %n), direction=LT
}

%body (p: (s32[], f32[8], f32[64])) -> (s32[], f32[8], f32[64]) {
  %p = (s32[], f32[8], f32[64]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8], f32[64]) %p), index=0
  %w = f32[8]{0} get-tuple-element((s32[], f32[8], f32[64]) %p), index=1
  %g = f32[64]{0} all-gather(f32[8]{0} %w), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(s32[] %i, s32[] %one)
  ROOT %out = (s32[], f32[8], f32[64]) tuple(s32[] %i2, f32[8]{0} %w, f32[64]{0} %g)
}

ENTRY %main (a: (s32[], f32[8], f32[64])) -> (s32[], f32[8], f32[64]) {
  %a = (s32[], f32[8], f32[64]) parameter(0)
  ROOT %w0 = (s32[], f32[8], f32[64]) while((s32[], f32[8], f32[64]) %a), condition=%cond, body=%body
}
"""


def test_analyze_overlap_gather_only_loop_exposed():
    ov = analyze_overlap(_GATHER_ONLY_HLO)
    assert ov["in_loop_collectives"] == 1
    assert ov["overlappable_collectives"] == 0
    assert ov["overlap_fraction"] == 0.0


_ASYNC_HLO = """
HloModule asyncpair

ENTRY %main (w: f32[8], h: f32[8,8]) -> f32[8,8] {
  %w = f32[8]{0} parameter(0)
  %h = f32[8,8]{1,0} parameter(1)
  %ags = (f32[8], f32[64]) all-gather-start(f32[8]{0} %w), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %mm = f32[8,8]{1,0} dot(f32[8,8]{1,0} %h, f32[8,8]{1,0} %h), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %agd = f32[64]{0} all-gather-done((f32[8], f32[64]) %ags)
  %wm = f32[8,8]{1,0} reshape(f32[64]{0} %agd)
  ROOT %o = f32[8,8]{1,0} add(f32[8,8]{1,0} %mm, f32[8,8]{1,0} %wm)
}
"""


def test_analyze_overlap_async_pairs():
    ov = analyze_overlap(_ASYNC_HLO)
    assert ov["async_pairs"] == 1
    assert ov["async_pairs_enclosing_compute"] == 1


# ring-carried gather: the result is dynamic-update-sliced into a (2,64)
# ring buffer in the carry, so it is consumed two iterations later —
# slack_iters must read the ring depth off the buffer's leading dim
_RING2_HLO = """
HloModule ring2

%cond (p: (s32[], f32[8], f32[2,64], f32[64])) -> pred[] {
  %p = (s32[], f32[8], f32[2,64], f32[64]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8], f32[2,64], f32[64]) %p), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %n), direction=LT
}

%body (p: (s32[], f32[8], f32[2,64], f32[64])) -> (s32[], f32[8], f32[2,64], f32[64]) {
  %p = (s32[], f32[8], f32[2,64], f32[64]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8], f32[2,64], f32[64]) %p), index=0
  %w = f32[8]{0} get-tuple-element((s32[], f32[8], f32[2,64], f32[64]) %p), index=1
  %r = f32[2,64]{1,0} get-tuple-element((s32[], f32[8], f32[2,64], f32[64]) %p), index=2
  %h = f32[64]{0} get-tuple-element((s32[], f32[8], f32[2,64], f32[64]) %p), index=3
  %g = f32[64]{0} all-gather(f32[8]{0} %w), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %gu = f32[1,64]{1,0} reshape(f32[64]{0} %g)
  %z = s32[] constant(0)
  %r2 = f32[2,64]{1,0} dynamic-update-slice(f32[2,64]{1,0} %r, f32[1,64]{1,0} %gu, s32[] %z, s32[] %z)
  %hm = f32[8,8]{1,0} reshape(f32[64]{0} %h)
  %mm = f32[8,8]{1,0} dot(f32[8,8]{1,0} %hm, f32[8,8]{1,0} %hm), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %h2 = f32[64]{0} reshape(f32[8,8]{1,0} %mm)
  %one = s32[] constant(1)
  %i2 = s32[] add(s32[] %i, s32[] %one)
  ROOT %out = (s32[], f32[8], f32[2,64], f32[64]) tuple(s32[] %i2, f32[8]{0} %w, f32[2,64]{1,0} %r2, f32[64]{0} %h2)
}

ENTRY %main (a: (s32[], f32[8], f32[2,64], f32[64])) -> (s32[], f32[8], f32[2,64], f32[64]) {
  %a = (s32[], f32[8], f32[2,64], f32[64]) parameter(0)
  ROOT %w0 = (s32[], f32[8], f32[2,64], f32[64]) while((s32[], f32[8], f32[2,64], f32[64]) %a), condition=%cond, body=%body
}
"""

# the same schedule with a one-slot ring (the classic double buffer)
_RING1_HLO = _RING2_HLO.replace("ring2", "ring1").replace("2,64", "1,64")


def test_ring_slack_detected():
    ov = analyze_overlap(_RING2_HLO)
    (loop,) = ov["per_loop"].values()
    assert loop["has_compute"]
    assert loop["max_slack_iters"] == 2
    (coll,) = loop["colls"]
    assert coll["overlappable"] and coll["slack_iters"] == 2
    ov1 = analyze_overlap(_RING1_HLO)
    (loop1,) = ov1["per_loop"].values()
    assert loop1["max_slack_iters"] == 1


def test_effective_overlap_depth_credit():
    """A gather issued d iterations early is credited against d iterations
    of compute: at a bandwidth where one iteration cannot cover it, the
    2-slot ring strictly beats the 1-slot ring; at a fast operating point
    both saturate to the structural fraction."""
    from repro.launch.hlo_analysis import effective_overlap

    ov1 = analyze_overlap(_RING1_HLO)
    ov2 = analyze_overlap(_RING2_HLO)
    assert ov1["overlap_fraction"] == ov2["overlap_fraction"] == 1.0
    slow = dict(peak_flops=1e9,
                tier_bw={"model": 1e6, "data": 1e6, "pod": 1e6},
                coll_latency_s=0.0)
    e1 = effective_overlap(ov1, **slow)["effective_overlap_fraction"]
    e2 = effective_overlap(ov2, **slow)["effective_overlap_fraction"]
    assert 0.0 < e1 < e2 <= 1.0, (e1, e2)
    fast = dict(peak_flops=1e9,
                tier_bw={"model": 1e12, "data": 1e12, "pod": 1e12},
                coll_latency_s=0.0)
    for ov in (ov1, ov2):
        eff = effective_overlap(ov, **fast)["effective_overlap_fraction"]
        assert eff == ov["overlap_fraction"], (eff, ov["overlap_fraction"])
