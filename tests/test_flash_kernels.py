"""Pallas flash-attention kernels vs the jnp flash/dense oracles.

Sweeps GQA ratios, window, softcap, tile sizes — forward and backward.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.kernels.flash_attention import flash_bwd_pallas, flash_fwd_pallas


def _mk(B, Sq, S, H, K, hd, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, K, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, K, hd)), dtype)
    g = jnp.asarray(rng.standard_normal((B, Sq, H, hd)), dtype)
    return q, k, v, g


SWEEP = [
    # B, Sq, H, K, hd, window, softcap, bq, bk
    (2, 64, 4, 4, 32, 0, 0.0, 16, 16),
    (1, 128, 8, 2, 64, 0, 0.0, 32, 64),
    (2, 64, 4, 1, 32, 16, 0.0, 16, 16),
    (1, 64, 4, 2, 64, 0, 30.0, 32, 32),
    (1, 128, 2, 2, 32, 32, 20.0, 64, 32),
]


@pytest.mark.parametrize("B,Sq,H,K,hd,window,softcap,bq,bk", SWEEP)
def test_flash_fwd_matches_ref(B, Sq, H, K, hd, window, softcap, bq, bk):
    q, k, v, _ = _mk(B, Sq, Sq, H, K, hd, seed=B * Sq)
    scale = hd ** -0.5
    want = A.flash_attention(q, k, v, jnp.arange(Sq), scale, True, window,
                             softcap, min(32, Sq))
    got, m, l = flash_fwd_pallas(q, k, v, scale=scale, causal=True,
                                 window=window, softcap=softcap, bq=bq,
                                 bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,Sq,H,K,hd,window,softcap,bq,bk", SWEEP)
def test_flash_bwd_matches_ref(B, Sq, H, K, hd, window, softcap, bq, bk):
    q, k, v, g = _mk(B, Sq, Sq, H, K, hd, seed=B + Sq)
    scale = hd ** -0.5

    def ref(q, k, v):
        return A.flash_attention(q, k, v, jnp.arange(Sq), scale, True,
                                 window, softcap, min(32, Sq))

    want = jax.grad(lambda *a: jnp.sum(ref(*a) * g), argnums=(0, 1, 2))(
        q, k, v)
    out, m, l = flash_fwd_pallas(q, k, v, scale=scale, causal=True,
                                 window=window, softcap=softcap, bq=bq,
                                 bk=bk, interpret=True)
    got = flash_bwd_pallas(q, k, v, out, m, l, g, scale=scale, causal=True,
                           window=window, softcap=softcap, bq=bq, bk=bk,
                           interpret=True)
    for a, b, n in zip(want, got, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=3e-5, atol=3e-5, err_msg=n)


def test_mha_pallas_impl_matches_xla():
    """mha(impl='pallas') == mha(impl='xla') end to end (incl. grads)."""
    B, S, H, K, hd = 1, 512, 4, 2, 32
    q, k, v, g = _mk(B, S, S, H, K, hd, seed=3)

    def run(impl):
        def f(q, k, v):
            return A.mha(q, k, v, causal=True, impl=impl, kv_chunk=128)
        o = f(q, k, v)
        d = jax.grad(lambda *a: jnp.sum(f(*a) * g), argnums=(0, 1, 2))(
            q, k, v)
        return o, d

    o1, d1 = run("xla")
    o2, d2 = run("pallas")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=3e-5,
                               atol=3e-5)
    for a, b in zip(d1, d2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5,
                                   atol=3e-5)
