"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracles.

Per the spec, each kernel is swept over shapes/dtypes and checked with
assert_allclose against ref.py.  Round-to-nearest ties are the only
permitted divergence source (jnp.round is ties-to-even in both paths, so in
practice the match is exact).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # only the @given property tests need hypothesis
    from repro.testing.hypothesis_stub import given, settings, st

from repro.core.quant import QuantConfig, dequantize_blockwise
from repro.kernels import ref
from repro.kernels.quant_block import (
    dequantize_pallas,
    pick_tiles,
    quantize_pallas,
    quantize_reordered_pallas,
)
from repro.kernels.fused_dequant_reduce_quant import (
    dequant_reduce_pallas,
    dequant_reduce_quant_pallas,
)

INTERP = dict(interpret=True)


def _jit(fn, **kw):
    """Jit with static kwargs.  Kernel and ref are BOTH compared under jit:
    eager XLA and jitted XLA may differ by 1 ulp in division fusion, which
    flips round-to-nearest ties; inside jit the two paths are bit-equal."""
    import functools
    return jax.jit(functools.partial(fn, **kw))


def _rand(shape, dtype, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(shape) * scale).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


SWEEP = [
    # (rows, cols, block, bits, dtype)
    (1, 256, 256, 8, jnp.float32),
    (8, 512, 128, 8, jnp.float32),
    (16, 1024, 256, 4, jnp.float32),
    (3, 384, 128, 4, jnp.bfloat16),
    (7, 768, 256, 8, jnp.bfloat16),
    (32, 2048, 512, 4, jnp.float32),
    (2, 8192, 1024, 8, jnp.bfloat16),
]


@pytest.mark.parametrize("rows,cols,block,bits,dtype", SWEEP)
def test_quantize_matches_ref(rows, cols, block, bits, dtype):
    cfg = QuantConfig(bits=bits, block_size=block)
    x = _rand((rows, cols), dtype, seed=rows * cols)
    p_k, s_k = _jit(quantize_pallas, cfg=cfg, **INTERP)(x)
    p_r, s_r = _jit(ref.quantize_ref, cfg=cfg)(x)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


@pytest.mark.parametrize("rows,cols,block,bits,dtype", SWEEP)
def test_dequantize_matches_ref(rows, cols, block, bits, dtype):
    cfg = QuantConfig(bits=bits, block_size=block)
    x = _rand((rows, cols), dtype, seed=rows + cols)
    p, s = ref.quantize_ref(x, cfg)
    got = _jit(dequantize_pallas, cfg=cfg, out_dtype=jnp.float32, **INTERP)(p, s)
    want = _jit(ref.dequantize_ref, cfg=cfg, out_dtype=jnp.float32)(p, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("rows,cols,block,bits,dtype", SWEEP[:4])
def test_quant_roundtrip_error_bound(rows, cols, block, bits, dtype):
    """|dequant(quant(x)) - x| <= scale/2 per block (symmetric quant)."""
    cfg = QuantConfig(bits=bits, block_size=block)
    x = _rand((rows, cols), dtype, seed=5)
    p, s = _jit(quantize_pallas, cfg=cfg, **INTERP)(x)
    rt = _jit(dequantize_pallas, cfg=cfg, out_dtype=jnp.float32, **INTERP)(p, s)
    err = np.abs(np.asarray(rt) - np.asarray(x, dtype=np.float32))
    bound = np.repeat(np.asarray(s), block, axis=-1) / 2 + 1e-7
    assert (err <= bound * 1.001).all()


@pytest.mark.parametrize("Y,X,L,block,bits", [
    (2, 2, 256, 128, 4),
    (4, 2, 512, 256, 4),
    (3, 5, 1024, 256, 8),
    (16, 2, 256, 128, 4),
])
def test_quantize_reordered_matches_ref(Y, X, L, block, bits):
    cfg = QuantConfig(bits=bits, block_size=block)
    x = _rand((Y, X, L), jnp.float32, seed=Y * X)
    p_k, s_k = _jit(quantize_reordered_pallas, cfg=cfg, **INTERP)(x)
    p_r, s_r = _jit(ref.quantize_reordered_ref, cfg=cfg)(x)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


@pytest.mark.parametrize("N,C,block,bits", [
    (2, 256, 128, 4),
    (8, 512, 256, 4),
    (16, 1024, 256, 8),
    (4, 4096, 512, 4),
])
def test_dequant_reduce_matches_ref(N, C, block, bits):
    cfg = QuantConfig(bits=bits, block_size=block)
    x = _rand((N, C), jnp.float32, seed=N * C)
    p, s = ref.quantize_ref(x, cfg)
    got = _jit(dequant_reduce_pallas, cfg=cfg, **INTERP)(p, s)
    want = _jit(ref.dequant_reduce_ref, cfg=cfg)(p, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("N,C,block,bits_in,bits_out", [
    (2, 256, 128, 4, 4),
    (8, 512, 256, 4, 4),
    (4, 1024, 256, 8, 4),
    (16, 512, 128, 4, 8),
])
def test_dequant_reduce_quant_matches_ref(N, C, block, bits_in, bits_out):
    cfg_in = QuantConfig(bits=bits_in, block_size=block)
    cfg_out = QuantConfig(bits=bits_out, block_size=block)
    x = _rand((N, C), jnp.float32, seed=N + C)
    p, s = ref.quantize_ref(x, cfg_in)
    p_k, s_k = _jit(dequant_reduce_quant_pallas, cfg_in=cfg_in, cfg_out=cfg_out, **INTERP)(p, s)
    p_r, s_r = _jit(ref.dequant_reduce_quant_ref, cfg_in=cfg_in, cfg_out=cfg_out)(p, s)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


# ---------------------------------------------------------------------------
# property-based sweeps (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 12),
    nblocks=st.integers(1, 6),
    block_pow=st.integers(5, 9),        # block 32..512
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2 ** 16),
)
def test_prop_kernel_equals_ref(rows, nblocks, block_pow, bits, seed):
    block = 2 ** block_pow
    cfg = QuantConfig(bits=bits, block_size=block)
    x = _rand((rows, nblocks * block), jnp.float32, seed=seed, scale=3.0)
    p_k, s_k = _jit(quantize_pallas, cfg=cfg, **INTERP)(x)
    p_r, s_r = _jit(ref.quantize_ref, cfg=cfg)(x)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    got = _jit(dequantize_pallas, cfg=cfg, out_dtype=jnp.float32, **INTERP)(p_k, s_k)
    want = _jit(ref.dequantize_ref, cfg=cfg, out_dtype=jnp.float32)(p_r, s_r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 16),
    nblocks=st.integers(1, 4),
    seed=st.integers(0, 2 ** 16),
)
def test_prop_fused_reduce_is_fp32_exact(n, nblocks, seed):
    """The fused kernel's reduction must be bit-identical to an fp32 sum of
    the individually dequantized contributions (the paper's accuracy
    argument hinges on full-precision reduction)."""
    cfg = QuantConfig(bits=4, block_size=128)
    x = _rand((n, nblocks * 128), jnp.float32, seed=seed)
    p, s = ref.quantize_ref(x, cfg)
    got = _jit(dequant_reduce_pallas, cfg=cfg, **INTERP)(p, s)
    want = _jit(lambda p, s: jnp.sum(dequantize_blockwise(p, s, cfg, jnp.float32), axis=0))(p, s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pick_tiles_divides():
    for rows, cols, block in [(1, 256, 256), (13, 13 * 512, 128),
                              (64, 8192, 1024), (5, 640, 64)]:
        rt, ct = pick_tiles(rows, cols, block)
        assert rows % rt == 0 and cols % ct == 0 and ct % block == 0


def test_ops_dispatch_ref_equals_interpret():
    """ops.py must produce identical results whichever path it picks."""
    from repro.kernels import ops
    cfg = QuantConfig(bits=4, block_size=128)
    x = _rand((4, 512), jnp.float32, seed=11)
    old = ops.FORCE
    try:
        ops.FORCE = "ref"
        p1, s1 = ops.quantize_blockwise(x, cfg)
        ops.FORCE = "interpret"
        p2, s2 = ops.quantize_blockwise(x, cfg)
    finally:
        ops.FORCE = old
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
