"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracles.

Per the spec, each kernel is swept over shapes/dtypes and checked with
assert_allclose against ref.py.  Round-to-nearest ties are the only
permitted divergence source (jnp.round is ties-to-even in both paths, so in
practice the match is exact).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # only the @given property tests need hypothesis
    from repro.testing.hypothesis_stub import given, settings, st

from repro.core.quant import QuantConfig, dequantize_blockwise
from repro.kernels import ref
from repro.kernels.quant_block import (
    dequantize_pallas,
    pick_tiles,
    quantize_pallas,
    quantize_reordered_pallas,
)
from repro.kernels.fused_dequant_reduce_quant import (
    dequant_reduce_pallas,
    dequant_reduce_quant_pallas,
)

INTERP = dict(interpret=True)


def _jit(fn, **kw):
    """Jit with static kwargs.  Kernel and ref are BOTH compared under jit:
    eager XLA and jitted XLA may differ by 1 ulp in division fusion, which
    flips round-to-nearest ties; inside jit the two paths are bit-equal."""
    import functools
    return jax.jit(functools.partial(fn, **kw))


def _rand(shape, dtype, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(shape) * scale).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


SWEEP = [
    # (rows, cols, block, bits, dtype)
    (1, 256, 256, 8, jnp.float32),
    (8, 512, 128, 8, jnp.float32),
    (16, 1024, 256, 4, jnp.float32),
    (3, 384, 128, 4, jnp.bfloat16),
    (7, 768, 256, 8, jnp.bfloat16),
    (32, 2048, 512, 4, jnp.float32),
    (2, 8192, 1024, 8, jnp.bfloat16),
]


@pytest.mark.parametrize("rows,cols,block,bits,dtype", SWEEP)
def test_quantize_matches_ref(rows, cols, block, bits, dtype):
    cfg = QuantConfig(bits=bits, block_size=block)
    x = _rand((rows, cols), dtype, seed=rows * cols)
    p_k, s_k = _jit(quantize_pallas, cfg=cfg, **INTERP)(x)
    p_r, s_r = _jit(ref.quantize_ref, cfg=cfg)(x)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


@pytest.mark.parametrize("rows,cols,block,bits,dtype", SWEEP)
def test_dequantize_matches_ref(rows, cols, block, bits, dtype):
    cfg = QuantConfig(bits=bits, block_size=block)
    x = _rand((rows, cols), dtype, seed=rows + cols)
    p, s = ref.quantize_ref(x, cfg)
    got = _jit(dequantize_pallas, cfg=cfg, out_dtype=jnp.float32, **INTERP)(p, s)
    want = _jit(ref.dequantize_ref, cfg=cfg, out_dtype=jnp.float32)(p, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("rows,cols,block,bits,dtype", SWEEP[:4])
def test_quant_roundtrip_error_bound(rows, cols, block, bits, dtype):
    """|dequant(quant(x)) - x| <= scale/2 per block (symmetric quant)."""
    cfg = QuantConfig(bits=bits, block_size=block)
    x = _rand((rows, cols), dtype, seed=5)
    p, s = _jit(quantize_pallas, cfg=cfg, **INTERP)(x)
    rt = _jit(dequantize_pallas, cfg=cfg, out_dtype=jnp.float32, **INTERP)(p, s)
    err = np.abs(np.asarray(rt) - np.asarray(x, dtype=np.float32))
    bound = np.repeat(np.asarray(s), block, axis=-1) / 2 + 1e-7
    assert (err <= bound * 1.001).all()


@pytest.mark.parametrize("Y,X,L,block,bits", [
    (2, 2, 256, 128, 4),
    (4, 2, 512, 256, 4),
    (3, 5, 1024, 256, 8),
    (16, 2, 256, 128, 4),
])
def test_quantize_reordered_matches_ref(Y, X, L, block, bits):
    cfg = QuantConfig(bits=bits, block_size=block)
    x = _rand((Y, X, L), jnp.float32, seed=Y * X)
    p_k, s_k = _jit(quantize_reordered_pallas, cfg=cfg, **INTERP)(x)
    p_r, s_r = _jit(ref.quantize_reordered_ref, cfg=cfg)(x)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


@pytest.mark.parametrize("N,C,block,bits", [
    (2, 256, 128, 4),
    (8, 512, 256, 4),
    (16, 1024, 256, 8),
    (4, 4096, 512, 4),
])
def test_dequant_reduce_matches_ref(N, C, block, bits):
    cfg = QuantConfig(bits=bits, block_size=block)
    x = _rand((N, C), jnp.float32, seed=N * C)
    p, s = ref.quantize_ref(x, cfg)
    got = _jit(dequant_reduce_pallas, cfg=cfg, **INTERP)(p, s)
    want = _jit(ref.dequant_reduce_ref, cfg=cfg)(p, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("N,C,block,bits_in,bits_out", [
    (2, 256, 128, 4, 4),
    (8, 512, 256, 4, 4),
    (4, 1024, 256, 8, 4),
    (16, 512, 128, 4, 8),
])
def test_dequant_reduce_quant_matches_ref(N, C, block, bits_in, bits_out):
    cfg_in = QuantConfig(bits=bits_in, block_size=block)
    cfg_out = QuantConfig(bits=bits_out, block_size=block)
    x = _rand((N, C), jnp.float32, seed=N + C)
    p, s = ref.quantize_ref(x, cfg_in)
    p_k, s_k = _jit(dequant_reduce_quant_pallas, cfg_in=cfg_in, cfg_out=cfg_out, **INTERP)(p, s)
    p_r, s_r = _jit(ref.dequant_reduce_quant_ref, cfg_in=cfg_in, cfg_out=cfg_out)(p, s)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


# ---------------------------------------------------------------------------
# property-based sweeps (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 12),
    nblocks=st.integers(1, 6),
    block_pow=st.integers(5, 9),        # block 32..512
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2 ** 16),
)
def test_prop_kernel_equals_ref(rows, nblocks, block_pow, bits, seed):
    block = 2 ** block_pow
    cfg = QuantConfig(bits=bits, block_size=block)
    x = _rand((rows, nblocks * block), jnp.float32, seed=seed, scale=3.0)
    p_k, s_k = _jit(quantize_pallas, cfg=cfg, **INTERP)(x)
    p_r, s_r = _jit(ref.quantize_ref, cfg=cfg)(x)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    got = _jit(dequantize_pallas, cfg=cfg, out_dtype=jnp.float32, **INTERP)(p_k, s_k)
    want = _jit(ref.dequantize_ref, cfg=cfg, out_dtype=jnp.float32)(p_r, s_r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 16),
    nblocks=st.integers(1, 4),
    seed=st.integers(0, 2 ** 16),
)
def test_prop_fused_reduce_is_fp32_exact(n, nblocks, seed):
    """The fused kernel's reduction must be bit-identical to an fp32 sum of
    the individually dequantized contributions (the paper's accuracy
    argument hinges on full-precision reduction)."""
    cfg = QuantConfig(bits=4, block_size=128)
    x = _rand((n, nblocks * 128), jnp.float32, seed=seed)
    p, s = ref.quantize_ref(x, cfg)
    got = _jit(dequant_reduce_pallas, cfg=cfg, **INTERP)(p, s)
    want = _jit(lambda p, s: jnp.sum(dequantize_blockwise(p, s, cfg, jnp.float32), axis=0))(p, s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pick_tiles_divides():
    for rows, cols, block in [(1, 256, 256), (13, 13 * 512, 128),
                              (64, 8192, 1024), (5, 640, 64)]:
        rt, ct = pick_tiles(rows, cols, block)
        assert rows % rt == 0 and cols % ct == 0 and ct % block == 0


def test_ops_dispatch_ref_equals_interpret():
    """ops.py must produce identical results whichever path it picks."""
    from repro.kernels import ops
    cfg = QuantConfig(bits=4, block_size=128)
    x = _rand((4, 512), jnp.float32, seed=11)
    old = ops.FORCE
    try:
        ops.FORCE = "ref"
        p1, s1 = ops.quantize_blockwise(x, cfg)
        ops.FORCE = "interpret"
        p2, s2 = ops.quantize_blockwise(x, cfg)
    finally:
        ops.FORCE = old
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


# ---------------------------------------------------------------------------
# fused INT8 dequant-GEMM (kernels/dequant_matmul.py)
# ---------------------------------------------------------------------------

from repro.kernels.dequant_matmul import dequant_matmul_pallas

GEMM_SWEEP = [
    # (T, N, K, n_scale_groups, x_dtype) — single- and multi-k-tile,
    # per-row scale groups and the one-scale-per-row broadcast layout
    (8, 64, 256, 2, jnp.float32),
    (4, 128, 64, 1, jnp.bfloat16),
    (16, 96, 384, 3, jnp.float32),
    (3, 40, 512, 4, jnp.bfloat16),
    (16, 128, 2048, 8, jnp.float32),   # k-tiled: 4 accumulation steps
    (5, 64, 1536, 12, jnp.float32),    # odd row count, k-tiled
]


@pytest.mark.parametrize("T,N,K,nb,xdtype", GEMM_SWEEP)
def test_dequant_matmul_matches_ref(T, N, K, nb, xdtype):
    """Kernel vs staged oracle.  Tolerance is fp32 accumulation ORDER only
    (k-tiled partial sums); the elementwise dequant math is identical."""
    cfg = QuantConfig(bits=8, block_size=K // nb)
    x = _rand((T, K), xdtype, seed=T * K)
    w = _rand((N, K), jnp.float32, seed=N + K)
    p, s = ref.quantize_ref(w, cfg)
    got = _jit(dequant_matmul_pallas, **INTERP)(x, p, s)
    want = _jit(ref.dequant_matmul_ref)(x, p, s)
    scale = np.abs(np.asarray(want)).max() + 1e-9
    np.testing.assert_allclose(np.asarray(got) / scale,
                               np.asarray(want) / scale, atol=2e-6)


def test_dequant_matmul_ref_is_staged_math():
    """The oracle == dequantize_blockwise + einsum, bit for bit: the `xla`
    dispatch path must be indistinguishable from the pre-fusion staged
    serving head."""
    cfg = QuantConfig(bits=8, block_size=128)
    x = _rand((6, 512), jnp.float32, seed=3)
    w = _rand((32, 512), jnp.float32, seed=4)
    p, s = ref.quantize_ref(w, cfg)

    def staged(x, p, s):
        wd = dequantize_blockwise(p, s, cfg, jnp.bfloat16)
        return jnp.einsum("tk,nk->tn", x, wd,
                          preferred_element_type=jnp.float32)

    got = _jit(ref.dequant_matmul_ref)(x, p, s)
    want = _jit(staged)(x, p, s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ops_dequant_matmul_dispatch():
    from repro.kernels import ops
    cfg = QuantConfig(bits=8, block_size=256)
    x = _rand((4, 1024), jnp.float32, seed=9)
    w = _rand((16, 1024), jnp.float32, seed=10)
    p, s = ref.quantize_ref(w, cfg)
    with ops.use_backend("xla"):
        a = ops.dequant_matmul(x, p, s)
    with ops.use_backend("interpret"):
        b = ops.dequant_matmul(x, p, s)
    scale = np.abs(np.asarray(a)).max() + 1e-9
    np.testing.assert_allclose(np.asarray(a) / scale, np.asarray(b) / scale,
                               atol=2e-6)


# ---------------------------------------------------------------------------
# backend seam (kernels/platform.py + ops.py resolution)
# ---------------------------------------------------------------------------

def test_platform_resolution_order(monkeypatch):
    from repro.kernels import platform
    monkeypatch.delenv(platform.ENV_VAR, raising=False)
    assert platform.resolve() == "xla"                 # CPU default
    monkeypatch.setenv(platform.ENV_VAR, "interpret")
    assert platform.resolve() == "interpret"           # env beats default
    assert platform.resolve("xla") == "xla"            # force beats env
    assert platform.resolve("ref") == "xla"            # alias
    with pytest.raises(ValueError):
        platform.resolve("cuda")


def test_platform_pallas_off_tpu_raises(monkeypatch):
    """'pallas' off-TPU is a hard error at every entry point — forced,
    via env, and at ops.set_backend configuration time."""
    from repro.kernels import ops, platform
    assert not platform.is_tpu()
    with pytest.raises(RuntimeError, match="requires a TPU"):
        platform.resolve("pallas")
    monkeypatch.setenv(platform.ENV_VAR, "pallas")
    with pytest.raises(RuntimeError, match="requires a TPU"):
        platform.resolve()
    monkeypatch.delenv(platform.ENV_VAR)
    with pytest.raises(RuntimeError, match="requires a TPU"):
        ops.set_backend("pallas")
    assert ops.FORCE is None                           # rejected, not stored


def test_use_backend_scoping():
    from repro.kernels import ops
    assert ops.FORCE is None
    with ops.use_backend("interpret"):
        assert ops.backend() == "interpret"
        with ops.use_backend("ref"):
            assert ops.backend() == "xla"
        assert ops.backend() == "interpret"
    assert ops.FORCE is None


def test_flash_ops_shares_platform_probe(monkeypatch):
    """flash_ops and ops must answer 'interpret?' through the SAME probe:
    env settings reach both, and a bad env fails loudly in both."""
    from repro.kernels import flash_ops, platform
    monkeypatch.delenv(platform.ENV_VAR, raising=False)
    assert flash_ops._interpret() is True              # CPU: never compile
    monkeypatch.setenv(platform.ENV_VAR, "pallas")
    with pytest.raises(RuntimeError, match="requires a TPU"):
        flash_ops._interpret()


# ---------------------------------------------------------------------------
# stochastic rounding through the dispatch seam
# ---------------------------------------------------------------------------

def test_stochastic_dispatch_determinism():
    """Stochastic rounding now THREADS the PRNG key through the kernel
    path: the uniform field is drawn outside the pallas_call (exactly as
    the reference draws it) and compared inside the kernel, so a fixed
    key gives bit-identical payloads on every backend — with the
    interpret backend actually running the kernel, not the xla ref."""
    from repro.kernels import ops
    from repro.obs.metrics import get_registry
    cfg = QuantConfig(bits=4, block_size=128, stochastic=True)
    x = _rand((4, 512), jnp.float32, seed=21)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    outs = {}
    for be in ("xla", "interpret"):
        with ops.use_backend(be):
            before = get_registry().counter(
                f"kernels.dispatch.quantize_blockwise.{be}").value
            # jit both backends: eager vs traced XLA differ by 1 ulp in
            # the scale division (see _jit's note), which is not what
            # this test is about
            outs[be] = jax.jit(
                lambda a, k: ops.quantize_blockwise(a, cfg, k))(x, k1)
            after = get_registry().counter(
                f"kernels.dispatch.quantize_blockwise.{be}").value
            assert after == before + 1, (be, before, after)
    np.testing.assert_array_equal(np.asarray(outs["xla"][0]),
                                  np.asarray(outs["interpret"][0]))
    np.testing.assert_array_equal(np.asarray(outs["xla"][1]),
                                  np.asarray(outs["interpret"][1]))
    with ops.use_backend("interpret"):
        again = jax.jit(
            lambda a, k: ops.quantize_blockwise(a, cfg, k))(x, k1)
        other = jax.jit(
            lambda a, k: ops.quantize_blockwise(a, cfg, k))(x, k2)
    np.testing.assert_array_equal(np.asarray(outs["interpret"][0]),
                                  np.asarray(again[0]))
    assert not np.array_equal(np.asarray(again[0]), np.asarray(other[0]))


@pytest.mark.parametrize("bits_in,bits_out", [(4, 4), (4, 8), (8, 4)])
def test_stochastic_fused_dequant_reduce_quant(bits_in, bits_out):
    """The fused qgZ intra-hop op now threads stochastic rounding through
    the kernel path too: the uniform field is drawn on the reference's
    flat (C,) segmentation and requantization happens in-kernel, so a
    fixed key gives bit-identical payloads AND scales across backends —
    this closed the last stochastic xla fallback."""
    from repro.kernels import ops
    from repro.obs.metrics import get_registry
    cfg_in = QuantConfig(bits=bits_in, block_size=64)
    cfg_out = QuantConfig(bits=bits_out, block_size=64, stochastic=True)
    x = _rand((4, 512), jnp.float32, seed=11)
    p, s = ref.quantize_ref(x, cfg_in)
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    outs = {}
    for be in ("xla", "interpret"):
        with ops.use_backend(be):
            before = get_registry().counter(
                f"kernels.dispatch.dequant_reduce_quant.{be}").value
            outs[be] = jax.jit(lambda pp, ss, k: ops.dequant_reduce_quant(
                pp, ss, cfg_in, cfg_out, k))(p, s, k1)
            after = get_registry().counter(
                f"kernels.dispatch.dequant_reduce_quant.{be}").value
            # the interpret dispatch must NOT fall back to xla any more
            assert after == before + 1, (be, before, after)
    np.testing.assert_array_equal(np.asarray(outs["xla"][0]),
                                  np.asarray(outs["interpret"][0]))
    np.testing.assert_array_equal(np.asarray(outs["xla"][1]),
                                  np.asarray(outs["interpret"][1]))
    with ops.use_backend("interpret"):
        again = jax.jit(lambda pp, ss, k: ops.dequant_reduce_quant(
            pp, ss, cfg_in, cfg_out, k))(p, s, k1)
        other = jax.jit(lambda pp, ss, k: ops.dequant_reduce_quant(
            pp, ss, cfg_in, cfg_out, k))(p, s, k2)
    np.testing.assert_array_equal(np.asarray(outs["interpret"][0]),
                                  np.asarray(again[0]))
    assert not np.array_equal(np.asarray(again[0]), np.asarray(other[0]))


# ---------------------------------------------------------------------------
# multi-segment shapes + tile-boundary-crossing blocks
# ---------------------------------------------------------------------------

def test_multiseg_ref_parity(monkeypatch):
    """Force the reference onto its lax.map segmentation path and check
    the (unsegmented, tile-streaming) kernel still matches bit-for-bit —
    segmentation is a memory layout choice, never a numerics one."""
    from repro.core import quant as quant_mod
    monkeypatch.setattr(quant_mod, "_SEG_ELEMS", 1 << 10)
    cfg = QuantConfig(bits=8, block_size=128)
    x = _rand((4, 2048), jnp.float32, seed=13)         # 8192 elems > 1024
    p_r, s_r = _jit(ref.quantize_ref, cfg=cfg)(x)
    p_k, s_k = _jit(quantize_pallas, cfg=cfg, **INTERP)(x)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


@pytest.mark.parametrize("rows,cols,block,bits", [
    (2, 16384, 8192, 8),    # block > the 4096-col VMEM tile cap
    (5, 8192, 4096, 4),     # block == cap, odd rows, int4 packing
])
def test_block_crossing_tile_cap(rows, cols, block, bits):
    cfg = QuantConfig(bits=bits, block_size=block)
    x = _rand((rows, cols), jnp.float32, seed=rows)
    p_k, s_k = _jit(quantize_pallas, cfg=cfg, **INTERP)(x)
    p_r, s_r = _jit(ref.quantize_ref, cfg=cfg)(x)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    got = _jit(dequantize_pallas, cfg=cfg, out_dtype=jnp.bfloat16, **INTERP)(p_k, s_k)
    want = _jit(ref.dequantize_ref, cfg=cfg, out_dtype=jnp.bfloat16)(p_r, s_r)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# schedule/serve composition with the kernel backend on (8-dev subprocess)
# ---------------------------------------------------------------------------

from repro.testing.subproc import run_checks

_INTERP_ENV = {"REPRO_KERNEL_BACKEND": "interpret"}


def test_depth_sweep_kernel_backend_8dev():
    """The dense depth sweep stays bit-exact with the kernel backend
    forced to interpret (same assertions as check_prefetch_depth_sweep;
    `make kernel-smoke` additionally runs that check unchanged under
    $REPRO_KERNEL_BACKEND=interpret)."""
    run_checks(["check_kernel_backend_depth_sweep"], n_devices=8,
               timeout=2400)


def test_serve_engine_kernel_backend_8dev():
    """Acceptance: the serve-engine bit-identity check passes unchanged
    with the kernel backend forced to interpret (fused INT8 head active)."""
    run_checks(["check_serve_engine_continuous_batching"], n_devices=8,
               timeout=1800, extra_env=_INTERP_ENV)


def test_train_bitexact_across_backends_8dev():
    run_checks(["check_kernel_backend_train_bitexact"], n_devices=8,
               timeout=1800)


def test_qwz_gemm_head_matches_staged_8dev():
    run_checks(["check_qwz_gemm_head_matches_staged"], n_devices=8,
               timeout=1800)


def test_kernels_first_import_order():
    """Regression: importing repro.kernels.ops BEFORE repro.core (the
    --kernel-backend CLI path does exactly this) must not trip the
    kernels<->core import cycle.  core.collectives binds the ops module,
    not its names, so resolution happens at call time."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    for first in ("repro.kernels.ops", "repro.kernels.ref"):
        r = subprocess.run(
            [sys.executable, "-c",
             f"import {first}; import repro.core.collectives as c; "
             "import repro.kernels.ops as o; "
             "assert callable(c.quantize_blockwise); print(o.backend())"],
            env=env, capture_output=True, text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        assert r.returncode == 0, f"{first} first: {r.stderr}"
