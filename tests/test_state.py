"""ZeroState subsystem tests (train/state.py).

Fast tests run in the main single-device pytest process: spec ownership,
checkpoint discovery hardening, the quantized payload math, and a full
world=1 save/restore roundtrip in both formats.  Multi-device elastic
behaviour (8 -> 4 -> 2, bit-exactness, quantization bounds, serving load)
runs in 8-device subprocesses via testing/subproc.py.
"""
import json
import os

import numpy as np
import pytest

from repro.testing.subproc import run_checks


# ---------------------------------------------------------------------------
# fast: ParamSpec.offsets memoization
# ---------------------------------------------------------------------------

def test_param_spec_offsets_memoized():
    from repro.core.partition import ParamSpec

    spec = ParamSpec((("a", (4, 8)), ("b", (16,)), ("c", ())), align=64)
    first = spec.offsets
    assert spec.offsets is first          # cached per instance
    assert first == {"a": (0, 32), "b": (32, 16), "c": (48, 1)}
    # a derived instance gets a fresh (and different) cache
    spec2 = spec.with_align(128)
    assert spec2.offsets is not first
    assert spec2.offsets == first
    # pack/unpack still roundtrip through the cached offsets
    import jax.numpy as jnp
    tensors = {"a": jnp.arange(32.0).reshape(4, 8),
               "b": jnp.arange(16.0), "c": jnp.float32(7)}
    flat = spec.pack(tensors)
    assert flat.shape == (spec.padded_size,)
    out = spec.unpack(flat)
    for k in tensors:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(tensors[k]))


# ---------------------------------------------------------------------------
# fast: checkpoint discovery hardening
# ---------------------------------------------------------------------------

def test_latest_skips_foreign_files(tmp_path):
    from repro.train import checkpoint as ckpt

    d = tmp_path / "ckpts"
    d.mkdir()
    # foreign / malformed names that used to crash the int() sort
    for name in ("ckpt_final.npz", "ckpt_.npz", "ckpt_12abc.npz",
                 "notes.txt", "ckpt_5.tmp"):
        (d / name).write_bytes(b"x")
    (d / "ckpt_3.npz").write_bytes(b"x")
    (d / "ckpt_10.npz").write_bytes(b"x")
    assert ckpt.latest(str(d)) == str(d / "ckpt_10.npz")

    # a per-shard dir wins if newer; one without a manifest is incomplete
    (d / "ckpt_11").mkdir()
    assert ckpt.latest(str(d)) == str(d / "ckpt_10.npz")
    (d / "ckpt_11" / "manifest.json").write_text("{}")
    assert ckpt.latest(str(d)) == str(d / "ckpt_11")

    assert ckpt.latest(str(tmp_path / "missing")) is None


def test_latest_ignores_staging_and_quarantine(tmp_path):
    """A crash can leave a fully-populated staging dir — shards AND
    manifest, killed between the manifest fsync and the atomic rename.
    ``latest()`` must never select it (nor a quarantined checkpoint),
    even when its step number is the highest in the directory."""
    from repro.train import checkpoint as ckpt

    d = tmp_path / "c"
    d.mkdir()
    good = d / "ckpt_4"
    good.mkdir()
    (good / "manifest.json").write_text("{}")
    staging = d / "ckpt_9.tmp"            # crash-left, manifest included
    staging.mkdir()
    (staging / "shard_00000.npz").write_bytes(b"x")
    (staging / "manifest.json").write_text("{}")
    quarantined = d / "ckpt_12.corrupt"
    quarantined.mkdir()
    (quarantined / "manifest.json").write_text("{}")
    assert ckpt.latest(str(d)) == str(good)


# ---------------------------------------------------------------------------
# fast: quantized payload math
# ---------------------------------------------------------------------------

def test_quantize_shard_roundtrip_bound():
    from repro.train.state import dequantize_shard, quantize_shard

    rng = np.random.default_rng(0)
    block = 64
    x = (rng.normal(size=(3, 8 * block)) * 0.05).astype(np.float32)
    x[0, :block] *= 100.0          # outlier block must not poison others
    q, s = quantize_shard(x, block)
    assert q.dtype == np.int8 and s.dtype == np.float16
    assert q.shape == x.shape and s.shape == (3, 8)
    back = dequantize_shard(q, s, block)
    xb = x.reshape(3, 8, block)
    bound = np.abs(xb).max(axis=-1, keepdims=True) / 127.0 * 0.6 + 1e-8
    assert (np.abs(back.reshape(xb.shape) - xb) <= bound).all()
    # zero blocks (checkpoint padding) roundtrip to exact zeros
    q0, s0 = quantize_shard(np.zeros((2 * block,), np.float32), block)
    assert not q0.any() and not s0.astype(np.float32).any()
    assert not dequantize_shard(q0, s0, block).any()


def test_quantize_shard_sqrt_never_underestimates():
    """The second-moment encoder's core invariant: v_hat >= v for every
    element, at EVERY magnitude — including blocks whose fp32 scale is
    below the fp16 normal/subnormal range (a plain fp16 cast flushes the
    scale to zero and restores v_hat = 0, detonating Adam's division)."""
    from repro.train.state import dequantize_shard_sqrt, quantize_shard_sqrt

    rng = np.random.default_rng(1)
    block = 64
    for mag in (1.0, 1e-4, 1e-8, 1e-12, 1e-16):
        v = (rng.uniform(0, 1, size=(4 * block,)) * mag).astype(np.float32)
        q, s = quantize_shard_sqrt(v, block)
        assert q.dtype == np.uint8 and s.dtype == np.float16
        back = dequantize_shard_sqrt(q, s, block)
        assert (back >= v).all(), (mag, float((v - back).max()))
        nonzero = v > 0
        assert back[nonzero].min() > 0, mag   # no flush-to-zero
    # symmetric encoder: tiny blocks must not flush to zero scale either
    from repro.train.state import dequantize_shard, quantize_shard
    x = (rng.normal(size=(2 * block,)) * 1e-7).astype(np.float32)
    q, s = quantize_shard(x, block)
    assert s.astype(np.float32).min() > 0
    back = dequantize_shard(q, s, block)
    assert np.isfinite(back).all()
    # stored-scale bound: |x - x_hat| <= s/2 everywhere
    s32 = np.repeat(s.astype(np.float32), block)
    assert (np.abs(back - x) <= s32 / 2 + 1e-12).all()


# ---------------------------------------------------------------------------
# fast: world=1 end-to-end roundtrip (both formats + legacy fallback)
# ---------------------------------------------------------------------------

def _tiny_state():
    import jax
    from repro.configs import get_config
    from repro.core.compat import auto_axis_types, make_mesh
    from repro.models.model import Model
    from repro.optim.adamw import AdamWConfig
    from repro.train.policy import make_policy
    from repro.train.state import ZeroState

    mesh = make_mesh((1, 1), ("data", "model"),
                     axis_types=auto_axis_types(2))
    arch = get_config("gpt-350m").reduced()
    pol = make_policy(arch, tuple(mesh.axis_names))
    model = Model(arch, pol.zcfg, world=1)
    opt_cfg = AdamWConfig()
    st = ZeroState(model, mesh, opt_cfg).init(jax.random.PRNGKey(0))
    return mesh, model, opt_cfg, st


def test_zero_state_roundtrip_single_device(tmp_path):
    import jax
    from repro.train.state import MANIFEST, ZeroState, read_manifest

    mesh, model, opt_cfg, st = _tiny_state()
    p_host = jax.device_get(st.params)

    path = st.save(str(tmp_path), 7, meta={"arch": "tiny"})
    assert os.path.basename(path) == "ckpt_7"
    man = read_manifest(path)
    assert man["world"] == 1 and man["step"] == 7
    assert man["meta"]["arch"] == "tiny"
    assert set(man["param_layout"]) >= {"blocks", "head", "unemb"}

    st2 = ZeroState.restore(model, mesh, opt_cfg, str(tmp_path))
    assert st2 is not None and st2.step == 7
    for k, v in st2.params.items():
        np.testing.assert_array_equal(np.asarray(jax.device_get(v)),
                                      np.asarray(p_host[k]))

    # INT8 format: bounded error, strictly smaller files
    path8 = st.save(str(tmp_path / "q"), 7, fmt="int8")
    st3 = ZeroState.restore(model, mesh, opt_cfg, str(tmp_path / "q"))
    for k, v in st3.params.items():
        want = np.asarray(p_host[k])
        err = np.abs(np.asarray(jax.device_get(v)) - want).max()
        assert err <= np.abs(want).max() / 127.0 * 0.6 + 1e-8, (k, err)

    def size(p):
        return sum(os.path.getsize(os.path.join(p, f))
                   for f in os.listdir(p) if f != MANIFEST)
    assert size(path8) < 0.35 * size(path)


def test_legacy_npz_compat(tmp_path):
    """checkpoint.py's legacy single-file API still works and ZeroState
    restores from it transparently."""
    import jax
    from repro.train import checkpoint as ckpt
    from repro.train.state import ZeroState

    mesh, model, opt_cfg, st = _tiny_state()
    p_host = jax.device_get(st.params)
    path = str(tmp_path / "ckpt_4.npz")
    ckpt.save(path, 4, {"params": jax.device_get(st.params),
                        "opt": jax.device_get(st.opt)}, {"world": 1})
    step, tree, meta = ckpt.load(path)
    assert step == 4 and meta["world"] == 1
    st2 = ZeroState.restore(model, mesh, opt_cfg, str(tmp_path))
    assert st2 is not None and st2.step == 4
    for k, v in st2.params.items():
        np.testing.assert_array_equal(np.asarray(jax.device_get(v)),
                                      np.asarray(p_host[k]))


def test_restore_corrupt_checkpoint_fallback(tmp_path):
    """Bit-rot in the newest checkpoint: the checksum catches it with a
    clear error, and ``restore_resilient`` quarantines the damaged dir
    and falls back to the previous intact one."""
    import jax
    from repro.testing.faults import corrupt_shard
    from repro.train.state import (CheckpointCorruptError, ZeroState,
                                   load_global)

    mesh, model, opt_cfg, st = _tiny_state()
    p_host = jax.device_get(st.params)
    st.save(str(tmp_path), 1)
    st.save(str(tmp_path), 2)
    corrupt_shard(str(tmp_path / "ckpt_2"))
    with pytest.raises(CheckpointCorruptError, match="checksum mismatch"):
        load_global(str(tmp_path / "ckpt_2"))
    st2 = ZeroState.restore_resilient(model, mesh, opt_cfg, str(tmp_path))
    assert st2 is not None and st2.step == 1
    assert (tmp_path / "ckpt_2.corrupt").is_dir()   # quarantined aside
    for k, v in st2.params.items():
        np.testing.assert_array_equal(np.asarray(jax.device_get(v)),
                                      np.asarray(p_host[k]))


def test_restore_truncated_shard_exhausts_to_none(tmp_path):
    """A truncated shard (interrupted write) raises a clear corrupt error
    rather than a numpy stack trace; with EVERY checkpoint damaged,
    ``restore_resilient`` returns None (fresh start) instead of raising."""
    from repro.testing.faults import truncate_shard
    from repro.train.state import (CheckpointCorruptError, ZeroState,
                                   load_global)

    mesh, model, opt_cfg, st = _tiny_state()
    st.save(str(tmp_path), 3)
    truncate_shard(str(tmp_path / "ckpt_3"))
    with pytest.raises(CheckpointCorruptError):
        load_global(str(tmp_path / "ckpt_3"))
    assert ZeroState.restore_resilient(model, mesh, opt_cfg,
                                       str(tmp_path)) is None
    assert (tmp_path / "ckpt_3.corrupt").is_dir()
    # plain restore on the now-empty dir is also a clean None
    assert ZeroState.restore(model, mesh, opt_cfg, str(tmp_path)) is None


def test_serve_imports_nothing_from_trainer():
    """The layering fix: serving must not depend on the training stack."""
    import inspect
    from repro.train import serve

    src = inspect.getsource(serve)
    assert "from repro.train.trainer" not in src
    assert "import trainer" not in src


# ---------------------------------------------------------------------------
# slow: multi-device elastic / quantized / serving behaviour
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_state_elastic_restore_multidevice():
    run_checks(["check_state_elastic_restore"], n_devices=8, timeout=1200)


@pytest.mark.slow
def test_state_quantized_and_serving():
    run_checks(["check_state_quantized_roundtrip",
                "check_state_serving_load"], n_devices=8, timeout=1200)
