"""Elastic fault-tolerant runtime tests (train/elastic.py, testing/faults.py).

Fast tests run world=1 in the main pytest process: async writer overlap /
backpressure / abandon semantics, the save retry-with-backoff path, stale
staging sweeps, and the crash-before-manifest invariant.  The full fault
suite (worker death + bit-exact resume, live 8->4->8 resharding, REAL
SIGKILL/SIGTERM subprocess scenarios) runs on 8 simulated devices via
testing/subproc.py — same groups as ``make fault-smoke``.
"""
import os
import time

import numpy as np
import pytest

from repro.testing.subproc import run_checks


def _tiny_state():
    import jax
    from repro.configs import get_config
    from repro.core.compat import auto_axis_types, make_mesh
    from repro.models.model import Model
    from repro.optim.adamw import AdamWConfig
    from repro.train.policy import make_policy
    from repro.train.state import ZeroState

    mesh = make_mesh((1, 1), ("data", "model"),
                     axis_types=auto_axis_types(2))
    arch = get_config("gpt-350m").reduced()
    pol = make_policy(arch, tuple(mesh.axis_names))
    model = Model(arch, pol.zcfg, world=1)
    opt_cfg = AdamWConfig()
    st = ZeroState(model, mesh, opt_cfg).init(jax.random.PRNGKey(0))
    return mesh, model, opt_cfg, st


# ---------------------------------------------------------------------------
# fast: async writer semantics
# ---------------------------------------------------------------------------

def test_async_writer_overlap_and_snapshot_isolation(tmp_path):
    """The write happens on the background thread (steps keep running:
    steps_overlapped counts them) and commits a checkpoint identical to
    the submitted state — the on-device snapshot means later mutation of
    the live buffers cannot leak into the file."""
    import jax
    from repro.testing.faults import SlowIO
    from repro.train.elastic import AsyncCheckpointWriter
    from repro.train.state import ZeroState, read_manifest

    mesh, model, opt_cfg, st = _tiny_state()
    p_host = jax.device_get(st.params)
    w = AsyncCheckpointWriter(model, mesh, opt_cfg, str(tmp_path),
                              io_hooks=SlowIO(0.3))
    w.submit(1, st.params, st.opt, {"world": 1})
    while w.in_flight():              # the "train loop" keeps stepping
        w.note_step()
        time.sleep(0.02)
    path = w.drain()
    w.close()
    assert w.stats.completed == 1 and w.stats.failed == 0
    assert w.stats.steps_overlapped > 0
    man = read_manifest(path)
    assert man["step"] == 1 and man["checksums"]
    st2 = ZeroState.restore(model, mesh, opt_cfg, str(tmp_path))
    for k, v in st2.params.items():
        np.testing.assert_array_equal(np.asarray(jax.device_get(v)),
                                      np.asarray(p_host[k]))


def test_async_writer_backpressure_single_flight(tmp_path):
    """Never more than one write in flight: a second submit blocks until
    the first (slowed) write commits."""
    from repro.testing.faults import SlowIO
    from repro.train.elastic import AsyncCheckpointWriter

    mesh, model, opt_cfg, st = _tiny_state()
    w = AsyncCheckpointWriter(model, mesh, opt_cfg, str(tmp_path),
                              io_hooks=SlowIO(0.6))
    w.submit(1, st.params, st.opt)
    t0 = time.monotonic()
    w.submit(2, st.params, st.opt)    # must wait out write #1
    assert time.monotonic() - t0 > 0.4
    w.drain()
    w.close()
    assert w.stats.submitted == 2 and w.stats.completed == 2


def test_async_writer_abandon_publishes_nothing(tmp_path):
    """Abandoning an in-flight write (grace expired) cancels it before
    the manifest commit: no checkpoint is ever published."""
    from repro.testing.faults import SlowIO
    from repro.train.elastic import AsyncCheckpointWriter
    from repro.train.state import latest_checkpoint

    mesh, model, opt_cfg, st = _tiny_state()
    w = AsyncCheckpointWriter(model, mesh, opt_cfg, str(tmp_path),
                              io_hooks=SlowIO(1.0))
    w.submit(1, st.params, st.opt)
    assert w.abandon() is True
    w.close()
    assert w.stats.abandoned == 1 and w.stats.completed == 0
    assert latest_checkpoint(str(tmp_path)) is None
    assert not [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]
    # abandoning while idle is a no-op
    assert w.abandon() is False


# ---------------------------------------------------------------------------
# fast: save retry / staging hygiene
# ---------------------------------------------------------------------------

def test_save_retries_transient_errors(tmp_path):
    from repro.testing.faults import FlakyIO
    from repro.train.state import latest_checkpoint, read_manifest

    mesh, model, opt_cfg, st = _tiny_state()
    flaky = FlakyIO(2)
    path = st.save(str(tmp_path), 1, io_hooks=flaky, retries=3,
                   backoff=0.01)
    assert flaky.calls == 3 and flaky.remaining == 0   # 2 fails + 1 ok
    assert os.path.basename(latest_checkpoint(str(tmp_path))) == "ckpt_1"
    assert read_manifest(path)["checksums"]


def test_save_retry_exhaustion_raises_and_sweeps(tmp_path):
    from repro.testing.faults import FlakyIO
    from repro.train.state import CheckpointError, latest_checkpoint

    mesh, model, opt_cfg, st = _tiny_state()
    st.save(str(tmp_path), 1)                       # a good one to keep
    with pytest.raises(CheckpointError, match="injected transient"):
        st.save(str(tmp_path), 2, io_hooks=FlakyIO(5), retries=1,
                backoff=0.01)
    # the failed attempt left no debris and the good checkpoint survives
    assert not [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]
    assert os.path.basename(latest_checkpoint(str(tmp_path))) == "ckpt_1"


def test_save_sweeps_stale_staging(tmp_path):
    """A crash-left staging dir for the SAME step must not poison the
    re-save after resume."""
    from repro.testing.faults import make_stale_staging
    from repro.train.state import latest_checkpoint, read_manifest

    mesh, model, opt_cfg, st = _tiny_state()
    staging = make_stale_staging(str(tmp_path), 5)
    assert os.path.isdir(staging)
    path = st.save(str(tmp_path), 5)
    assert not os.path.isdir(staging)
    assert os.path.basename(latest_checkpoint(str(tmp_path))) == "ckpt_5"
    assert read_manifest(path)["step"] == 5


def test_crash_before_manifest_never_selectable(tmp_path):
    """The commit-protocol invariant from the I/O side: failing between
    the shard write and the manifest commit publishes nothing."""
    from repro.testing.faults import CrashBeforeManifest
    from repro.train.state import CheckpointError, latest_checkpoint

    mesh, model, opt_cfg, st = _tiny_state()
    with pytest.raises(CheckpointError):
        st.save(str(tmp_path), 3, io_hooks=CrashBeforeManifest())
    assert latest_checkpoint(str(tmp_path)) is None


# ---------------------------------------------------------------------------
# slow: the 8-device fault suite (same groups as `make fault-smoke`)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_elastic_overlap_kill_flaky():
    run_checks(["check_elastic_async_overlap", "check_elastic_kill_resume",
                "check_elastic_flaky_io_retry"], n_devices=8, timeout=1800)


@pytest.mark.slow
def test_elastic_reshard_and_corrupt():
    run_checks(["check_elastic_live_reshard",
                "check_elastic_corrupt_fallback"], n_devices=8,
               timeout=1800)


@pytest.mark.slow
def test_elastic_real_signals():
    run_checks(["check_elastic_crash_during_write",
                "check_elastic_sigterm_grace"], n_devices=8, timeout=1800)
