"""SSD (Mamba-2) and RG-LRU vs naive sequential recurrence oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.ssm import ssd_scan, ssd_step, rglru_scan, rglru_step


def naive_ssd(x, dt, A, Bm, Cm, h0=None):
    """O(S) sequential oracle: h_t = exp(dt_t A) h + dt_t B_t x_t^T; y=C·h."""
    B, S, nh, hp = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hg = nh // G
    h = np.zeros((B, nh, N, hp), np.float64) if h0 is None else h0.astype(np.float64).copy()
    ys = np.zeros((B, S, nh, hp), np.float64)
    for t in range(S):
        for g in range(G):
            for hh in range(g * hg, (g + 1) * hg):
                decay = np.exp(dt[:, t, hh] * A[hh])  # (B,)
                outer = (dt[:, t, hh, None, None]
                         * Bm[:, t, g, :, None] * x[:, t, hh, None, :])
                h[:, hh] = decay[:, None, None] * h[:, hh] + outer
                ys[:, t, hh] = np.einsum("bn,bnp->bp", Cm[:, t, g], h[:, hh])
    return ys, h


@pytest.mark.parametrize("chunk", [4, 8])
def test_ssd_matches_naive(chunk):
    rng = np.random.default_rng(0)
    B, S, nh, hp, G, N = 2, 16, 4, 8, 2, 6
    x = rng.normal(size=(B, S, nh, hp)).astype(np.float32)
    dt = rng.uniform(0.1, 0.9, size=(B, S, nh)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, size=(nh,)).astype(np.float32)
    Bm = rng.normal(size=(B, S, G, N)).astype(np.float32)
    Cm = rng.normal(size=(B, S, G, N)).astype(np.float32)

    y, hf = ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                     jnp.asarray(Bm), jnp.asarray(Cm), chunk=chunk)
    y_ref, h_ref = naive_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), h_ref, rtol=2e-4, atol=2e-4)


def test_ssd_carried_state():
    rng = np.random.default_rng(1)
    B, S, nh, hp, G, N = 1, 8, 2, 4, 1, 3
    x = rng.normal(size=(B, S, nh, hp)).astype(np.float32)
    dt = rng.uniform(0.1, 0.9, size=(B, S, nh)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, size=(nh,)).astype(np.float32)
    Bm = rng.normal(size=(B, S, G, N)).astype(np.float32)
    Cm = rng.normal(size=(B, S, G, N)).astype(np.float32)
    h0 = rng.normal(size=(B, nh, N, hp)).astype(np.float32)

    y, hf = ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                     jnp.asarray(Bm), jnp.asarray(Cm), chunk=4,
                     h0=jnp.asarray(h0))
    y_ref, h_ref = naive_ssd(x, dt, A, Bm, Cm, h0=h0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), h_ref, rtol=2e-4, atol=2e-4)


def test_ssd_step_matches_scan():
    """Decoding token-by-token must equal the chunked scan."""
    rng = np.random.default_rng(2)
    B, S, nh, hp, G, N = 2, 8, 4, 4, 1, 5
    x = rng.normal(size=(B, S, nh, hp)).astype(np.float32)
    dt = rng.uniform(0.1, 0.9, size=(B, S, nh)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, size=(nh,)).astype(np.float32)
    Bm = rng.normal(size=(B, S, G, N)).astype(np.float32)
    Cm = rng.normal(size=(B, S, G, N)).astype(np.float32)

    y_scan, hf = ssd_scan(*map(jnp.asarray, (x, dt, A, Bm, Cm)), chunk=4)
    h = jnp.zeros((B, nh, N, hp), jnp.float32)
    ys = []
    for t in range(S):
        y, h = ssd_step(jnp.asarray(x[:, t]), jnp.asarray(dt[:, t]),
                        jnp.asarray(A), jnp.asarray(Bm[:, t]),
                        jnp.asarray(Cm[:, t]), h)
        ys.append(np.asarray(y))
    np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_scan),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hf), rtol=2e-4, atol=2e-4)


def naive_rglru(x, r, i, log_a, h0=None):
    B, S, D = x.shape
    h = np.zeros((B, D), np.float64) if h0 is None else h0.astype(np.float64).copy()
    ys = np.zeros((B, S, D), np.float64)
    for t in range(S):
        a = np.exp(log_a[None] * r[:, t])
        b = np.sqrt(np.clip(1 - a ** 2, 0, 1)) * (i[:, t] * x[:, t])
        h = a * h + b
        ys[:, t] = h
    return ys, h


def test_rglru_matches_naive():
    rng = np.random.default_rng(3)
    B, S, D = 2, 12, 8
    x = rng.normal(size=(B, S, D)).astype(np.float32)
    r = rng.uniform(0, 1, size=(B, S, D)).astype(np.float32)
    i = rng.uniform(0, 1, size=(B, S, D)).astype(np.float32)
    log_a = -rng.uniform(0.1, 3.0, size=(D,)).astype(np.float32)
    h0 = rng.normal(size=(B, D)).astype(np.float32)

    y, hf = rglru_scan(*map(jnp.asarray, (x, r, i)), jnp.asarray(log_a),
                       h0=jnp.asarray(h0))
    y_ref, h_ref = naive_rglru(x, r, i, log_a, h0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), h_ref, rtol=2e-4, atol=2e-4)

    # decode path
    h = jnp.asarray(h0)
    ys = []
    for t in range(S):
        yt, h = rglru_step(jnp.asarray(x[:, t]), jnp.asarray(r[:, t]),
                           jnp.asarray(i[:, t]), jnp.asarray(log_a), h)
        ys.append(np.asarray(yt))
    np.testing.assert_allclose(np.stack(ys, 1), y_ref, rtol=2e-4, atol=2e-4)
