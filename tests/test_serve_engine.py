"""Continuous-batching engine tests: slot recycling, per-sequence
cache_pos batched decode == per-request sequential decode (bit-identical
greedy), sampling invariants, scheduler admission, and an 8-device
shard_map engine smoke (subprocess).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.core.compat import make_mesh
from repro.models.model import Model
from repro.serve import FIFOScheduler, Request, ServeEngine, steps
from repro.serve import sampling
from repro.testing.subproc import run_checks
from repro.train.policy import make_policy
from repro.train.state import param_specs


@pytest.fixture(scope="module")
def served():
    """(model, mesh, params) — tiny dense arch, f32 for determinism."""
    mesh = make_mesh((1,), ("model",))
    arch = get_config("qwen3-0.6b").reduced()
    pol = make_policy(arch, mesh.axis_names, param_dtype=jnp.float32,
                      compute_dtype=jnp.float32)
    model = Model(arch, pol.zcfg, world=1)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    p_specs = param_specs(model, tuple(mesh.axis_names))
    params = {k: jax.device_put(v, NamedSharding(mesh, p_specs[k]))
              for k, v in params.items()}
    return model, mesh, params


JOBS = [(5, 6), (11, 4), (8, 5), (3, 7)]      # (prompt_len, max_new) x4
KV = 32


def _prompts(arch, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, arch.vocab, p).astype(np.int32) for p, _ in JOBS]


def _reference_greedy(model, mesh, params, prompt, n, kv_len=KV):
    """One request alone through the raw prefill+decode path."""
    ps = steps.build_prefill_step(model, mesh, (), ())
    ds = steps.build_decode_step(model, mesh, (), ("model",), donate=False)
    logits, caches = ps.fn(params, {"tokens": prompt[None, :]})
    caches = steps.pad_prefill_caches(model, caches, kv_len)
    toks = [int(jnp.argmax(logits[0, -1]))]
    for i in range(1, n):
        logits, caches = ds.fn(
            params, caches, {"tokens": jnp.array([[toks[-1]]], jnp.int32)},
            jnp.full((1,), len(prompt) + i - 1, jnp.int32))
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks


def test_engine_batched_greedy_bit_identical(served):
    """4 requests with mixed prompt lengths over 3 slots: the continuously
    batched decode (rows at different positions, staggered admission) must
    emit, per request, exactly the tokens of that request run alone."""
    model, mesh, params = served
    eng = ServeEngine(model, mesh, params, n_slots=3, kv_len=KV)
    prompts = _prompts(model.cfg)
    uids = [eng.submit(pr, max_new_tokens=n)
            for pr, (_, n) in zip(prompts, JOBS)]
    res = eng.run(max_steps=100)
    for uid, pr, (_, n) in zip(uids, prompts, JOBS):
        want = _reference_greedy(model, mesh, params, pr, n)
        assert res[uid] == want, (uid, res[uid], want)


def test_engine_slot_recycling(served):
    """More requests than slots: a retired slot must be reused, and the
    recycled request's output must be unpolluted (checked above)."""
    model, mesh, params = served
    eng = ServeEngine(model, mesh, params, n_slots=2, kv_len=KV)
    prompts = _prompts(model.cfg, seed=1)
    for pr, (_, n) in zip(prompts, JOBS):
        eng.submit(pr, max_new_tokens=n)
    eng.run(max_steps=100)
    slots = list(eng.slot_history.values())
    assert len(slots) == 4
    assert set(slots) == {0, 1}            # both slots used...
    assert len(slots) > len(set(slots))    # ...and reused after retirement
    assert eng.pool.n_free == 2            # everything released at the end
    assert (eng.pool.lengths == 0).all()


def test_engine_streaming_and_eos(served):
    model, mesh, params = served
    eng = ServeEngine(model, mesh, params, n_slots=2, kv_len=KV)
    pr = _prompts(model.cfg, seed=2)[0]
    first = _reference_greedy(model, mesh, params, pr, 1)[0]
    streamed = []
    uid = eng.submit(pr, max_new_tokens=10, eos_id=first,
                     on_token=lambda u, t: streamed.append((u, t)))
    res = eng.run(max_steps=50)
    # the very first sampled token is the EOS -> request retires at length 1
    assert res[uid] == [first]
    assert streamed == [(uid, first)]


def test_engine_temperature_zero_equals_argmax(served):
    """A sampled run at temperature -> 0 converges to the greedy run."""
    model, mesh, params = served
    pr = _prompts(model.cfg, seed=3)[1]
    want = _reference_greedy(model, mesh, params, pr, 5)
    eng = ServeEngine(model, mesh, params, n_slots=1, kv_len=KV)
    uid = eng.submit(pr, max_new_tokens=5, temperature=1e-6, seed=11)
    assert eng.run(max_steps=50)[uid] == want


def test_engine_seeded_sampling_deterministic(served):
    model, mesh, params = served
    pr = _prompts(model.cfg, seed=4)[2]

    def run_once(seed):
        eng = ServeEngine(model, mesh, params, n_slots=1, kv_len=KV)
        uid = eng.submit(pr, max_new_tokens=8, temperature=1.0, top_k=20,
                         top_p=0.95, seed=seed)
        return eng.run(max_steps=50)[uid]

    a, b, c = run_once(5), run_once(5), run_once(6)
    assert a == b                      # same seed -> same stream
    assert a != c                      # (overwhelmingly) different seed


# ---------------------------------------------------------------------------
# sampling invariants
# ---------------------------------------------------------------------------

def test_top_k_masks_exactly_k():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(3, 64)).astype(np.float32))
    for k in (1, 5, 63):
        m = np.asarray(sampling.top_k_mask(logits, k))
        assert (m.sum(-1) == k).all()
        # the kept set IS the top-k: min kept > max dropped (no ties here)
        kept = np.where(m, np.asarray(logits), np.inf).min(-1)
        drop = np.where(~m, np.asarray(logits), -np.inf).max(-1)
        assert (kept > drop).all()
    # ties: still exactly k kept
    tied = jnp.zeros((1, 16), jnp.float32)
    assert np.asarray(sampling.top_k_mask(tied, 4)).sum() == 4


def test_top_p_mask_smallest_covering_set():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32) * 3)
    p = 0.7
    m = np.asarray(sampling.top_p_mask(logits, p))
    probs = np.asarray(jax.nn.softmax(logits, axis=-1))
    for row in range(4):
        keep = m[row]
        # argmax always kept; kept mass reaches p; minimal: dropping the
        # smallest kept token would fall below p
        assert keep[probs[row].argmax()]
        assert probs[row][keep].sum() >= p - 1e-6
        smallest = probs[row][keep].min()
        assert probs[row][keep].sum() - smallest < p + 1e-6
    assert np.asarray(sampling.top_p_mask(logits, 1.0)).all()


def test_sample_logits_temperature_to_zero_is_argmax():
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(8, 50)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    greedy = np.asarray(sampling.sample_logits(logits, key, temperature=0.0))
    cold = np.asarray(sampling.sample_logits(logits, key, temperature=1e-5))
    assert (greedy == np.asarray(logits).argmax(-1)).all()
    assert (cold == greedy).all()


# ---------------------------------------------------------------------------
# scheduler admission
# ---------------------------------------------------------------------------

def test_scheduler_buckets_and_admission():
    s = FIFOScheduler(kv_len=64)
    assert s.buckets[-1] == 64
    assert s.bucket_for(5) == 8 and s.bucket_for(8) == 8
    assert s.bucket_for(33) == 64
    for plen in (3, 9, 17):
        s.submit(Request(prompt=np.zeros(plen, np.int32)))
    assert len(s) == 3
    adm = s.admit(2)                   # keyed on free slots
    assert [len(r.prompt) for r, _ in adm] == [3, 9]   # FIFO
    assert [b for _, b in adm] == [8, 16]              # padded lengths
    assert len(s) == 1
    assert s.admit(0) == []


def test_default_buckets_never_degenerate():
    """start >= kv_len used to collapse the ladder to (kv_len,), silently
    padding every short prompt to full KV capacity in prefill."""
    from repro.serve.scheduler import default_buckets

    assert default_buckets(64) == (8, 16, 32, 64)
    # start clamped to kv_len // 2: a sub-capacity bucket always exists
    assert default_buckets(16, start=32) == (8, 16)
    assert default_buckets(12, start=100) == (6, 12)
    assert default_buckets(4) == (2, 4)
    s = FIFOScheduler(kv_len=16, buckets=default_buckets(16, start=64))
    assert s.bucket_for(3) < 16
    with pytest.raises(ValueError, match="degenerate"):
        default_buckets(1)
    with pytest.raises(ValueError, match="start must be >= 1"):
        default_buckets(64, start=0)


def test_scheduler_rejects_oversized_prompt():
    s = FIFOScheduler(kv_len=16)
    with pytest.raises(ValueError, match="no room to generate"):
        s.submit(Request(prompt=np.zeros(16, np.int32)))
    capped = FIFOScheduler(kv_len=32, buckets=(8,))
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        capped.submit(Request(prompt=np.zeros(9, np.int32)))
    with pytest.raises(ValueError, match="exceeds KV capacity"):
        FIFOScheduler(kv_len=8, buckets=(16,))
    with pytest.raises(ValueError, match="max_new_tokens"):
        s.submit(Request(prompt=np.zeros(4, np.int32), max_new_tokens=0))
    # multi-row prompts validate at their FLAT length, at submit time
    with pytest.raises(ValueError, match="no room to generate"):
        FIFOScheduler(kv_len=16).submit(
            Request(prompt=np.zeros((2, 8), np.int32)))


def test_engine_run_exact_step_budget(served):
    """Draining in exactly max_steps is success, not a timeout."""
    model, mesh, params = served
    pr = _prompts(model.cfg, seed=5)[3]
    probe = ServeEngine(model, mesh, params, n_slots=1, kv_len=KV)
    probe.submit(pr, max_new_tokens=3)
    needed = 0
    while not probe.done:
        probe.step()
        needed += 1
    eng = ServeEngine(model, mesh, params, n_slots=1, kv_len=KV)
    uid = eng.submit(pr, max_new_tokens=3)
    assert len(eng.run(max_steps=needed)[uid]) == 3


class _FakeClock:
    """Injectable engine clock: tests advance time explicitly."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_engine_request_deadline_timeout(served):
    """A request past its deadline retires with status 'timeout' and its
    KV slot returns to the pool; a request that expires while queued never
    takes a slot.  Requests without deadlines are untouched."""
    model, mesh, params = served
    clk = _FakeClock()
    eng = ServeEngine(model, mesh, params, n_slots=1, kv_len=KV, clock=clk)
    pr = _prompts(model.cfg, seed=6)[0]
    # greedy, no EOS: would decode until KV capacity if never timed out
    slow = eng.submit(pr, max_new_tokens=1000, deadline=5.0)
    queued = eng.submit(pr, max_new_tokens=4, deadline=5.0)
    ok = eng.submit(pr, max_new_tokens=4)          # no deadline
    eng.step()
    eng.step()
    assert eng.status[slow] == "active"
    assert eng.status[queued] == "queued"
    assert eng.pool.n_free == 0
    n_before = len(eng.results[slow])
    assert n_before >= 2                           # made progress first
    clk.t = 10.0                                   # past both deadlines
    eng.step()
    assert eng.status[slow] == "timeout"           # slot reclaimed...
    assert eng.status[queued] == "timeout"         # ...queue never admitted
    assert eng.status[ok] == "active"              # freed slot reused NOW
    assert len(eng.results[slow]) == n_before      # no tokens after timeout
    assert eng.slot_history[ok] == eng.slot_history[slow]
    res = eng.run(max_steps=50)
    assert eng.status[ok] == "done"
    assert len(res[ok]) == 4
    assert res[ok] == _reference_greedy(model, mesh, params, pr, 4)
    assert eng.pool.n_free == 1                    # everything released


def test_scheduler_queue_expiry():
    s = FIFOScheduler(kv_len=64)
    a = Request(prompt=np.zeros(4, np.int32), deadline=1.0)
    b = Request(prompt=np.zeros(4, np.int32))
    c = Request(prompt=np.zeros(4, np.int32), deadline=9.0)
    for r in (a, b, c):
        s.submit(r)
    assert s.expire(0.5) == []
    dropped = s.expire(2.0)
    assert [r.uid for r in dropped] == [a.uid]
    assert len(s) == 2                 # b (no deadline) and c survive
    adm = s.admit(4)
    assert [r.uid for r, _ in adm] == [b.uid, c.uid]


def test_engine_keeps_custom_scheduler(served):
    """An (empty, hence falsy) user-supplied scheduler must not be
    silently replaced by the default one."""
    model, mesh, params = served
    sched = FIFOScheduler(kv_len=KV, buckets=(16,))
    eng = ServeEngine(model, mesh, params, n_slots=1, kv_len=KV,
                      scheduler=sched)
    assert eng.scheduler is sched


def test_serve_shape_policy_validation():
    """The shape policy refuses unknown/non-serving shapes and bad meshes
    instead of silently falling through to the default layout."""
    pol = steps.serve_shape_policy
    assert pol("decode_32k", ("pod", "data", "model")) == \
        (("pod", "data"), ("model",))
    assert pol("long_500k", ("data", "model")) == ((), ("data", "model"))
    with pytest.raises(ValueError, match="unknown inference shape"):
        pol("decode_64k", ("data", "model"))
    with pytest.raises(ValueError, match="train shape"):
        pol("train_4k", ("data", "model"))
    with pytest.raises(ValueError, match="'model'"):
        pol("decode_32k", ("data", "mdl"))
    with pytest.raises(ValueError, match="duplicate"):
        pol("decode_32k", ("data", "data", "model"))


# ---------------------------------------------------------------------------
# multi-device engine smoke (subprocess; see testing/subproc.py)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_8dev_sharded_int8_boot():
    run_checks(["check_serve_engine_continuous_batching"], n_devices=8, timeout=900)
