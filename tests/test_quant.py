"""Unit + property tests for blockwise quantization (single device)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # only the @given property tests need hypothesis
    from repro.testing.hypothesis_stub import given, settings, st

from repro.core.quant import (
    QuantConfig,
    dequantize_blockwise,
    dequantize_global,
    pack_int4,
    pad_to_block,
    quantization_error,
    quantize_blockwise,
    quantize_global,
    unpack_int4,
)


@pytest.mark.parametrize("bits,block", [(8, 32), (8, 256), (4, 32), (4, 256)])
def test_roundtrip_error_bound(bits, block):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(4, block * 8)).astype(np.float32)
    cfg = QuantConfig(bits=bits, block_size=block)
    q, s = quantize_blockwise(jnp.asarray(x), cfg)
    y = np.asarray(dequantize_blockwise(q, s, cfg))
    # per-block error <= scale/2 = blockmax/qmax/2
    xb = x.reshape(4, 8, block)
    bound = np.abs(xb).max(-1) / cfg.qmax / 2
    err = np.abs((y.reshape(4, 8, block) - xb)).max(-1)
    assert (err <= bound + 1e-7).all()


def test_int4_pack_unpack_exhaustive():
    q = jnp.arange(-8, 8, dtype=jnp.int8)
    assert np.array_equal(np.asarray(unpack_int4(pack_int4(q))), np.asarray(q))


def test_payload_shapes_and_dtypes():
    x = jnp.ones((512,), jnp.bfloat16)
    q8, s8 = quantize_blockwise(x, QuantConfig(bits=8, block_size=128))
    assert q8.shape == (512,) and q8.dtype == jnp.int8
    assert s8.shape == (4,) and s8.dtype == jnp.float32
    q4, s4 = quantize_blockwise(x, QuantConfig(bits=4, block_size=128))
    assert q4.shape == (256,) and q4.dtype == jnp.int8  # packed 2/byte


def test_zero_block_is_exact():
    x = jnp.zeros((256,), jnp.float32)
    cfg = QuantConfig(bits=4, block_size=64)
    q, s = quantize_blockwise(x, cfg)
    assert np.asarray(dequantize_blockwise(q, s, cfg)).max() == 0.0


def test_blocked_beats_global_on_outliers():
    """Paper Fig. 2: block quantization reduces error ~3x on real weights."""
    rng = np.random.default_rng(1)
    x = (rng.normal(size=(4096,)) * 0.02).astype(np.float32)
    x[:16] = 3.0  # outlier channel
    cfg = QuantConfig(bits=8, block_size=64)
    qb, sb = quantize_blockwise(jnp.asarray(x), cfg)
    e_block = float(np.abs(np.asarray(dequantize_blockwise(qb, sb, cfg)) - x).mean())
    qg, sg = quantize_global(jnp.asarray(x), 8)
    e_glob = float(np.abs(np.asarray(dequantize_global(qg, sg, 8)) - x).mean())
    assert e_block < e_glob / 3


def test_stochastic_rounding_unbiased():
    cfg = QuantConfig(bits=8, block_size=128, stochastic=True)
    x = jnp.full((128,), 0.3) * (0.5 / 127 * 127)  # value between grid points
    keys = jax.random.split(jax.random.PRNGKey(0), 256)
    outs = []
    for k in keys:
        q, s = quantize_blockwise(x, cfg, key=k)
        outs.append(np.asarray(dequantize_blockwise(q, s, cfg)).mean())
    assert abs(np.mean(outs) - 0.3 * 0.5) / (0.3 * 0.5) < 0.05


def test_pad_to_block():
    assert pad_to_block(jnp.ones((100,)), 64).shape == (128,)
    assert pad_to_block(jnp.ones((128,)), 64).shape == (128,)


@settings(max_examples=50, deadline=None)
@given(
    bits=st.sampled_from([4, 8]),
    nblocks=st.integers(1, 8),
    block=st.sampled_from([32, 64, 128]),
    scale=st.floats(1e-4, 1e4),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_roundtrip(bits, nblocks, block, scale, seed):
    """Property: dequant(quant(x)) is within half a quantization step of x,
    for arbitrary scales and shapes; int4 packing round-trips losslessly."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(nblocks * block,)) * scale).astype(np.float32)
    cfg = QuantConfig(bits=bits, block_size=block)
    q, s = quantize_blockwise(jnp.asarray(x), cfg)
    y = np.asarray(dequantize_blockwise(q, s, cfg))
    xb = x.reshape(nblocks, block)
    bound = np.abs(xb).max(-1, keepdims=True) / cfg.qmax / 2 + 1e-12
    assert (np.abs(y.reshape(nblocks, block) - xb) <= bound * 1.001).all()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 64))
def test_property_int4_pack(seed, n):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(-8, 8, size=(2 * n,)), dtype=jnp.int8)
    assert np.array_equal(np.asarray(unpack_int4(pack_int4(q))), np.asarray(q))


def test_wire_bytes_accounting():
    cfg8 = QuantConfig(bits=8, block_size=256)
    cfg4 = QuantConfig(bits=4, block_size=256)
    n = 1 << 20
    assert cfg8.payload_bytes(n) == n          # 2x reduction vs bf16 (2n)
    assert cfg4.payload_bytes(n) == n // 2     # 4x reduction vs bf16
    # scales are fp32 on the wire (quantize_blockwise emits float32 and the
    # collectives move them as-is): 4 bytes per block, not 2.  The old
    # 2-byte default silently undercounted every analytic comm number;
    # caught by the measured-vs-projected runtime gate (obs/report.py).
    assert cfg8.wire_bytes(n) == n + (n // 256) * 4
    assert cfg8.wire_bytes(n, scale_bytes=2) == n + (n // 256) * 2


def test_payload_bytes_odd_int4_ceil():
    """An odd int4 payload still moves ceil(n/2) bytes on the wire —
    floor division used to undercount by a byte."""
    cfg4 = QuantConfig(bits=4, block_size=256)
    for n in (1, 3, 255, 1001):
        assert cfg4.payload_bytes(n) == (n + 1) // 2, n
        nblocks = -(-n // 256)
        assert cfg4.wire_bytes(n) == (n + 1) // 2 + nblocks * 4, n
    assert cfg4.payload_bytes(256) == 128
    assert QuantConfig(bits=8, block_size=256).payload_bytes(1001) == 1001


# ---------------------------------------------------------------------------
# segmented stochastic quantization (large-buffer peak-memory regression)
# ---------------------------------------------------------------------------


def _scan_eqns(jaxpr):
    """All scan (lax.map) eqns reachable in a closed jaxpr, recursively."""
    out = []
    todo = [jaxpr.jaxpr]
    while todo:
        j = todo.pop()
        for eqn in j.eqns:
            if eqn.primitive.name == "scan":
                out.append(eqn)
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    todo.append(v.jaxpr if hasattr(v.jaxpr, "eqns")
                                else v.jaxpr.jaxpr)
    return out


def test_stochastic_quantization_stays_segmented(monkeypatch):
    """Stochastic rounding must NOT disable lax.map segmentation of large
    flat buffers (the full-buffer fp32 temporary is the peak-memory spike
    _SEG_ELEMS exists to prevent): the key is split per segment instead."""
    from repro.core import quant
    monkeypatch.setattr(quant, "_SEG_ELEMS", 1024)
    cfg = QuantConfig(bits=8, block_size=128, stochastic=True)
    n, nseg = 4096, 4
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    key = jax.random.PRNGKey(11)

    # determinism given a fixed key
    q1, s1 = quantize_blockwise(x, cfg, key=key)
    q2, s2 = quantize_blockwise(x, cfg, key=key)
    assert np.array_equal(np.asarray(q1), np.asarray(q2))
    assert np.array_equal(np.asarray(s1), np.asarray(s2))

    # the traced program segments: a scan whose body intermediates are
    # segment-sized, never the full n-element fp32 buffer
    jaxpr = jax.make_jaxpr(
        lambda xx, kk: quantize_blockwise(xx, cfg, key=kk))(x, key)
    scans = _scan_eqns(jaxpr)
    assert scans, "expected a lax.map scan over segments"
    body = scans[0].params["jaxpr"].jaxpr
    peak = max(int(np.prod(v.aval.shape)) for eqn in body.eqns
               for v in eqn.outvars)
    assert peak <= n // nseg, (peak, n // nseg)

    # matches per-segment quantization with per-segment split keys: the
    # payload (and hence the wire traffic) is identical; scales may differ
    # by 1 ulp between the fused map body and the eager division
    keys = jax.random.split(key, nseg)
    parts = [quantize_blockwise(x.reshape(nseg, -1)[i], cfg, key=keys[i])
             for i in range(nseg)]
    np.testing.assert_array_equal(
        np.asarray(q1), np.concatenate([np.asarray(p) for p, _ in parts]))
    np.testing.assert_allclose(
        np.asarray(s1), np.concatenate([np.asarray(s) for _, s in parts]),
        rtol=3e-7)

    # roundtrip error bound still holds on the segmented stochastic path
    y = np.asarray(dequantize_blockwise(q1, s1, cfg))
    xb = np.asarray(x).reshape(-1, 128)
    bound = np.abs(xb).max(-1, keepdims=True) / cfg.qmax  # SR: one full step
    assert (np.abs(y.reshape(-1, 128) - xb) <= bound + 1e-7).all()


def test_stochastic_quantization_segments_rows(monkeypatch):
    """Multi-dim stochastic path: row-mapped segmentation with split keys
    (same regression as the flat path, for qgZ's (Y, X, L) slices)."""
    from repro.core import quant
    monkeypatch.setattr(quant, "_SEG_ELEMS", 512)
    cfg = QuantConfig(bits=8, block_size=64, stochastic=True)
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(8, 256)).astype(np.float32))  # 2k elems
    key = jax.random.PRNGKey(3)
    q, s = quantize_blockwise(x, cfg, key=key)
    assert q.shape == (8, 256) and s.shape == (8, 4)
    q2, s2 = quantize_blockwise(x, cfg, key=key)
    assert np.array_equal(np.asarray(q), np.asarray(q2))
    keys = jax.random.split(key, 8)
    rows = [quantize_blockwise(x[i], cfg, key=keys[i]) for i in range(8)]
    np.testing.assert_array_equal(
        np.asarray(q), np.stack([np.asarray(p) for p, _ in rows]))
    np.testing.assert_allclose(
        np.asarray(s), np.stack([np.asarray(sc) for _, sc in rows]),
        rtol=3e-7)
