"""Deterministic synthetic LM data pipeline.

An order-1 Markov chain with a low-entropy, seeded transition table: the
conditional distribution is learnable, so convergence benchmarks show real
loss curves (down to the chain's conditional entropy), and everything is a
pure function of (seed, step, shard) — which is what makes checkpoint/
restart and elastic re-sharding exactly reproducible: the data cursor IS
the step counter.

Frontend stubs for [audio]/[vlm] archs live here too: embeddings are a
fixed seeded projection of the token stream (the assignment's "precomputed
frame/patch embeddings"), and M-RoPE gets synthetic (t, h, w) positions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    seed: int = 0
    branching: int = 4      # candidate next-tokens per state (entropy knob)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab
        k = min(self.branching, v)
        self._succ = rng.integers(0, v, size=(v, k))          # successor table
        p = rng.dirichlet(np.full(k, 0.6), size=v)            # skewed probs
        self._cum = np.cumsum(p, axis=1).astype(np.float64)

    @property
    def entropy_bound(self) -> float:
        """Mean conditional entropy (nats) — the best achievable LM loss."""
        p = np.diff(np.concatenate([np.zeros((self.vocab, 1)), self._cum], 1))
        p = np.clip(p, 1e-12, 1)
        return float(-(p * np.log(p)).sum(1).mean())

    def batch(self, step: int, batch_size: int,
              shard: int = 0, n_shards: int = 1) -> np.ndarray:
        """(batch_size, seq_len+1) tokens; pure function of its arguments."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard * 257)
        assert batch_size % n_shards == 0
        b = batch_size // n_shards
        out = np.empty((b, self.seq_len + 1), np.int32)
        state = rng.integers(0, self.vocab, size=b)
        u = rng.random((b, self.seq_len + 1))
        for t in range(self.seq_len + 1):
            out[:, t] = state
            nxt = (u[:, t, None] < self._cum[state]).argmax(axis=1)
            state = self._succ[state, nxt]
        return out


def make_batch(arch: ArchConfig, lm: SyntheticLM, step: int,
               global_batch: int, np_dtype=np.float32) -> Dict[str, np.ndarray]:
    """GLOBAL batch dict for one train step (trainer shards it)."""
    toks = lm.batch(step, global_batch)
    batch = {"targets": toks[:, 1:].astype(np.int32)}
    B, S = batch["targets"].shape
    if arch.embed_inputs:
        # frontend stub: fixed seeded projection table token -> d_model
        rng = np.random.default_rng(arch.vocab * 7 + 13)
        table = (rng.standard_normal((arch.vocab, arch.d_model)) * 0.05
                 ).astype(np_dtype)
        batch["embeds"] = table[toks[:, :-1]]
    else:
        batch["tokens"] = toks[:, :-1].astype(np.int32)
    if arch.mrope:
        # synthetic (t,h,w): text-like ramp on t, coarse grid on h/w
        t = np.tile(np.arange(S, dtype=np.int32), (B, 1))
        h = t // 16
        w = t % 16
        batch["positions"] = np.stack([t, h, w]).astype(np.int32)
    return batch
