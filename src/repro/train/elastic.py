"""Elastic fault-tolerant training runtime (DESIGN.md §6).

Two pieces on top of the ZeroState subsystem:

  * :class:`AsyncCheckpointWriter` — snapshots (params, opt) with an
    on-device donated copy (the train step donates its inputs, so the
    snapshot must not alias them) and writes the per-shard checkpoint on a
    background thread, overlapped with subsequent train steps.  Bounded to
    ONE write in flight: a second ``submit`` blocks until the first
    commits (slow-writer backpressure).  The write itself is
    ``ZeroState.save``'s staged commit protocol — shards + fsync, then
    manifest + fsync, then atomic rename — so a crash at any point during
    the write can never produce a checkpoint ``latest_checkpoint`` would
    select.  An in-flight write can be abandoned (preemption with an
    expired grace deadline): the staging dir is swept and no manifest is
    ever published.

  * :class:`Supervisor` — the preempt/reshard/resume state machine around
    the step loop.  It restores via ``ZeroState.restore_resilient``
    (quarantine-and-fall-back on corrupt checkpoints), catches injected
    :class:`WorkerDeath` and restarts from the latest committed
    checkpoint, drains or abandons the in-flight write on SIGTERM within
    a grace deadline (final synchronous checkpoint before exit), and
    performs LIVE world-size resharding mid-run: device_get the global
    buffers, rebuild model/mesh/train-step at the new world, and re-place
    via ``ZeroState.place_global`` — no checkpoint file is read.

Fault injection lives in ``repro.testing.faults``; this module only
defines the exception type it raises so production code never imports the
test harness.
"""
from __future__ import annotations

import dataclasses
import math
import os
import queue
import signal
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.obs.metrics import get_registry
from repro.obs.trace import Tracer, get_tracer
from repro.train.state import CheckpointError, ZeroState, _call_hook

__all__ = ["WorkerDeath", "WriterStats", "AsyncCheckpointWriter",
           "ElasticConfig", "Supervisor"]


class WorkerDeath(RuntimeError):
    """A worker died mid-step (injected by the fault harness): whatever
    was in device memory is lost; the supervisor restores from the latest
    committed checkpoint and replays."""


class _Abandoned(Exception):
    """Internal: the in-flight write was cancelled between I/O stages."""


# ---------------------------------------------------------------------------
# async checkpoint writer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WriterStats:
    submitted: int = 0
    completed: int = 0
    abandoned: int = 0
    failed: int = 0
    steps_overlapped: int = 0    # train steps finished while a write ran
    last_step: Optional[int] = None
    last_path: Optional[str] = None


class _CancellableHooks:
    """Wrap user io_hooks with a cancellation check at every stage, so an
    ``abandon()`` lands before the manifest commit even when the inner
    hook (e.g. a SlowIO sleep) is what's eating the time."""

    def __init__(self, cancel: threading.Event, inner: Any):
        self._cancel = cancel
        self._inner = inner

    def _stage(self, name: str, *args) -> None:
        if self._cancel.is_set():
            raise _Abandoned(name)
        _call_hook(self._inner, name, *args)
        if self._cancel.is_set():
            raise _Abandoned(name)

    def post_shard(self, path: str) -> None:
        self._stage("post_shard", path)

    def pre_manifest(self, staging: str) -> None:
        self._stage("pre_manifest", staging)

    def pre_publish(self, staging: str, final: str) -> None:
        self._stage("pre_publish", staging, final)


class AsyncCheckpointWriter:
    """Background per-shard checkpoint writer, never more than one write
    in flight.

    ``submit`` makes an on-device copy of (params, opt) — a cheap jitted
    ``jnp.copy`` per buffer, required because the train step DONATES its
    (params, opt) arguments and would otherwise overwrite the snapshot's
    buffers mid-write — then hands it to a daemon thread that runs
    ``ZeroState.save``.  ``note_step()`` (called by the step loop after
    each completed step) counts overlap; ``drain()`` blocks until idle;
    ``abandon()`` cancels the in-flight write before its manifest commit.
    """

    def __init__(self, model, mesh, opt_cfg, ckpt_dir: str, *,
                 fmt: str = "fp32", io_hooks: Any = None,
                 retries: int = 0, backoff: float = 0.05,
                 on_commit: Optional[Callable[[int, str], None]] = None):
        self.model, self.mesh, self.opt_cfg = model, mesh, opt_cfg
        self.ckpt_dir, self.fmt = ckpt_dir, fmt
        self.retries, self.backoff = retries, backoff
        self.on_commit = on_commit
        self.stats = WriterStats()
        self._copy = jax.jit(lambda t: jax.tree.map(jnp.copy, t))
        self._queue: "queue.Queue" = queue.Queue(maxsize=1)
        self._idle = threading.Event()
        self._idle.set()
        self._cancel = threading.Event()
        self._hooks = _CancellableHooks(self._cancel, io_hooks)
        self._lock = threading.Lock()
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._worker, name="ckpt-writer", daemon=True)
        self._thread.start()

    # -------------------------------------------------------- public API

    def in_flight(self) -> bool:
        return not self._idle.is_set()

    def note_step(self) -> None:
        with self._lock:
            if not self._idle.is_set():
                self.stats.steps_overlapped += 1

    def submit(self, step: int, params, opt,
               meta: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot on device and enqueue the write.  Blocks while a
        previous write is still in flight (backpressure — bounded queue of
        one), never blocks on the disk write itself."""
        self._idle.wait()
        self._raise_pending()
        snap = self._copy((params, opt))
        jax.block_until_ready(snap)     # copy done BEFORE donation reuses
        with self._lock:
            self.stats.submitted += 1
            self._idle.clear()
        self._queue.put((int(step), snap, dict(meta or {})))

    def drain(self, timeout: Optional[float] = None) -> Optional[str]:
        """Wait for the in-flight write (if any) to commit; re-raises a
        write failure.  Returns the last committed checkpoint path."""
        if not self._idle.wait(timeout):
            raise TimeoutError("async checkpoint write did not finish "
                               f"within {timeout}s")
        self._raise_pending()
        return self.stats.last_path

    def abandon(self) -> bool:
        """Cancel the in-flight write (no manifest is published; the
        staging dir is swept).  Returns True if a write was cancelled."""
        if self._idle.is_set():
            return False
        self._cancel.set()
        self._idle.wait()
        self._cancel.clear()
        return True

    def close(self) -> None:
        self._queue.put(None)
        self._thread.join(timeout=120)

    # ---------------------------------------------------------- internal

    def _raise_pending(self) -> None:
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    def _worker(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                return
            step, (params, opt), meta = item
            try:
                t0 = time.monotonic()
                st = ZeroState(self.model, self.mesh, self.opt_cfg,
                               params=params, opt=opt, step=step)
                path = st.save(self.ckpt_dir, step, meta=meta, fmt=self.fmt,
                               io_hooks=self._hooks, retries=self.retries,
                               backoff=self.backoff)
                get_registry().histogram("elastic.ckpt.write_ms").observe(
                    (time.monotonic() - t0) * 1e3)
                with self._lock:
                    self.stats.completed += 1
                    self.stats.last_step, self.stats.last_path = step, path
                if self.on_commit is not None:
                    self.on_commit(step, path)
            except _Abandoned:
                with self._lock:
                    self.stats.abandoned += 1
            except CheckpointError as e:
                # retries exhausted inside save() can surface an injected
                # _Abandoned as the root cause — classify it as such
                if isinstance(e.__cause__, _Abandoned):
                    with self._lock:
                        self.stats.abandoned += 1
                else:
                    with self._lock:
                        self.stats.failed += 1
                        self._error = e
            except BaseException as e:   # surfaced on next submit/drain
                with self._lock:
                    self.stats.failed += 1
                    self._error = e
            finally:
                self._idle.set()


# ---------------------------------------------------------------------------
# supervisor
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ElasticConfig:
    """One elastic training run (mirrors ``launch/train`` CLI args)."""
    arch: str = "gpt-350m"
    reduced: bool = True
    mesh: Tuple[int, ...] = (4, 2)
    variant: str = "zeropp"
    steps: int = 10
    batch: int = 16
    seq: int = 64
    lr: float = 3e-3
    accum: int = 1
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    ckpt_format: str = "fp32"
    async_ckpt: bool = True
    retries: int = 0
    backoff: float = 0.05
    grace: float = 30.0          # seconds between preempt signal and exit
    max_restarts: int = 3
    log: bool = True
    metrics_dir: Optional[str] = None   # jsonl event log + BENCH export


class Supervisor:
    """Preempt/reshard/resume state machine around the train loop.

    ::

        RUN --WorkerDeath--> RESTORE (restore_resilient) --> RUN
        RUN --SIGTERM/preempt--> DRAIN|ABANDON --> final sync ckpt --> EXIT
        RUN --reshard@step--> device_get -> rebuild -> place_global --> RUN

    ``reshard_plan`` maps step -> new mesh shape; the transition moves the
    global buffers through host memory only (``ZeroState.place_global``),
    never through a checkpoint file, so it works with ``ckpt_dir=None``.
    ``faults`` is a ``testing.faults.StepFaults`` plan (or None) and
    ``io_hooks`` plugs into every checkpoint write this supervisor makes.

    Step markers are printed with full float repr so a subprocess harness
    can compare post-resume losses bit-for-bit against an oracle run.
    """

    def __init__(self, cfg: ElasticConfig, *, faults: Any = None,
                 reshard_plan: Optional[Dict[int, Tuple[int, ...]]] = None,
                 io_hooks: Any = None):
        self.cfg = cfg
        self.faults = faults
        self.reshard_plan = dict(reshard_plan or {})
        self.io_hooks = io_hooks
        self.writer: Optional[AsyncCheckpointWriter] = None
        self.losses: Dict[int, float] = {}
        self.restarts = 0
        self.resharded: List[Tuple[int, int, int]] = []
        self._preempt = threading.Event()
        self._deadline: Optional[float] = None
        # Per-step counter records go to an append-mode jsonl log so an
        # in-process restart EXTENDS the history; replay_counters dedupes
        # re-emitted steps (resume from an earlier checkpoint) per
        # (name, step), which is the telemetry-under-failure invariant the
        # fault harness asserts.  Without metrics_dir, the process tracer
        # (usually the disabled singleton) is used and owns its own life.
        if cfg.metrics_dir:
            self.tracer: Tracer = Tracer(
                os.path.join(cfg.metrics_dir, "events.jsonl"))
            self._own_tracer = True
        else:
            self.tracer = get_tracer()
            self._own_tracer = False

    # ------------------------------------------------------------ events

    def _log(self, msg: str) -> None:
        if self.cfg.log:
            print(f"[elastic] {msg}", flush=True)

    def request_preempt(self, grace: Optional[float] = None) -> None:
        if grace is not None:
            self._deadline = time.monotonic() + grace
        self._preempt.set()

    def install_signal_handlers(self) -> None:
        """Route SIGTERM into a graceful preemption (main thread only)."""
        def handler(signum, frame):
            self._log(f"signal {signum}: preemption requested "
                      f"(grace {self.cfg.grace}s)")
            self.request_preempt(self.cfg.grace)
        signal.signal(signal.SIGTERM, handler)

    def _on_commit(self, step: int, path: str) -> None:
        # runs on the writer thread: emit only (GIL-atomic list append);
        # the step loop's per-step flush carries it to disk
        self.tracer.event("elastic.ckpt.commit", step=step)
        self._log(f"committed step {step} -> {os.path.basename(path)}")

    def _make_writer(self, model, mesh, opt_cfg
                     ) -> Optional[AsyncCheckpointWriter]:
        cfg = self.cfg
        if not (cfg.ckpt_dir and cfg.ckpt_every and cfg.async_ckpt):
            return None
        return AsyncCheckpointWriter(
            model, mesh, opt_cfg, cfg.ckpt_dir, fmt=cfg.ckpt_format,
            io_hooks=self.io_hooks, retries=cfg.retries,
            backoff=cfg.backoff, on_commit=self._on_commit)

    # ------------------------------------------------------------- drive

    def run_supervised(self) -> Dict[str, Any]:
        """:meth:`run` under the restart policy: a worker death tears the
        run down (abandoning any in-flight write — the process "died")
        and re-enters, which restores from the latest committed
        checkpoint."""
        attempt = 0
        while True:
            try:
                return self.run()
            except WorkerDeath as e:
                if self.writer is not None:
                    self.writer.abandon()
                    self.writer.close()
                    self.writer = None
                attempt += 1
                if attempt > self.cfg.max_restarts or not self.cfg.ckpt_dir:
                    raise
                self.restarts += 1
                get_registry().counter("elastic.restarts").inc()
                self.tracer.event("elastic.restart", attempt=attempt,
                                  reason=str(e))
                self.tracer.flush()
                self._log(f"restarting after worker death "
                          f"({attempt}/{self.cfg.max_restarts}): {e}")

    def run(self) -> Dict[str, Any]:
        cfg = self.cfg
        from repro.data.synthetic import make_batch
        from repro.launch.train import build_everything
        from repro.train.trainer import place_batch

        mesh_shape = tuple(cfg.mesh)
        mesh, arch, model, opt_cfg, ts, lm = build_everything(
            cfg.arch, mesh_shape, cfg.variant, cfg.reduced, cfg.batch,
            cfg.seq, cfg.lr, cfg.accum)

        st = None
        if cfg.ckpt_dir:
            st = ZeroState.restore_resilient(model, mesh, opt_cfg,
                                             cfg.ckpt_dir)
        if st is not None:
            start = int(st.step)
            params, opt = st.params, st.opt
            self._log(f"resumed from step {start} "
                      f"(saved world={st.meta.get('world')}, "
                      f"now={ts.world})")
        else:
            start = 0
            st0 = ZeroState(model, mesh, opt_cfg).init(
                jax.random.PRNGKey(cfg.seed))
            params, opt = st0.params, st0.opt

        writer = self._make_writer(model, mesh, opt_cfg)
        self.writer = writer
        b_specs = ts.in_specs[2]
        i = start
        status = "complete"
        while i < cfg.steps:
            if self._preempt.is_set():
                status = "preempted"
                break
            new_shape = self.reshard_plan.pop(i, None)
            if new_shape is not None and tuple(new_shape) != mesh_shape:
                if writer is not None:     # quiesce I/O, then move state
                    writer.drain()
                    writer.close()
                old_world = ts.world
                p_host = jax.device_get(params)
                o_host = jax.device_get(opt)
                mesh_shape = tuple(new_shape)
                mesh, arch, model, opt_cfg, ts, lm = build_everything(
                    cfg.arch, mesh_shape, cfg.variant, cfg.reduced,
                    cfg.batch, cfg.seq, cfg.lr, cfg.accum)
                placed = ZeroState(model, mesh, opt_cfg).place_global(
                    p_host, o_host)
                params, opt = placed.params, placed.opt
                b_specs = ts.in_specs[2]
                writer = self._make_writer(model, mesh, opt_cfg)
                self.writer = writer
                self.resharded.append((i, old_world, ts.world))
                get_registry().counter("elastic.reshards").inc()
                self.tracer.event("elastic.reshard", step=i,
                                  old_world=old_world, new_world=ts.world)
                self._log(f"reshard step {i} world {old_world}->{ts.world}"
                          f" (in-memory, no disk)")
            if self.faults is not None:
                action = self.faults.take(i)
                if action == "die":
                    self._log(f"injected worker death at step {i}")
                    raise WorkerDeath(f"injected death at step {i}")
                if action == "preempt":
                    self._log(f"injected preemption at step {i} "
                              f"(grace {cfg.grace}s)")
                    self.request_preempt(cfg.grace)
                    continue
            host = make_batch(arch, lm, i, cfg.batch)
            if cfg.accum > 1:
                host = {k: v.reshape((cfg.accum, -1) + v.shape[1:])
                        for k, v in host.items()}
            batch = place_batch(host, mesh, b_specs)
            t_step = time.monotonic()
            with self.tracer.span("train.step", step=i):
                params, opt, metrics = ts.fn(params, opt, batch)
                loss = float(metrics["loss"])
            get_registry().histogram("train.step.wall_ms").observe(
                (time.monotonic() - t_step) * 1e3)
            self.losses[i] = loss
            if writer is not None:
                writer.note_step()
            # stepped counter records: replay-safe across restarts (dedupe
            # per (name, step)); flushed+fsynced every step so a SIGKILL
            # loses at most the line it sheared
            self.tracer.counter("train.steps", 1, step=i)
            self.tracer.counter("train.tokens", float(metrics["tokens"]),
                                step=i)
            self.tracer.counter("train.loss", loss, step=i)
            self.tracer.flush()
            self._log(f"step {i} loss {loss!r}")
            i += 1
            if cfg.ckpt_dir and cfg.ckpt_every and i % cfg.ckpt_every == 0:
                meta = {"world": ts.world, "arch": arch.name,
                        "data_cursor": i}
                if writer is not None:
                    self._log(f"snapshot step {i} submitted")
                    self.tracer.event("elastic.ckpt.submit", step=i)
                    writer.submit(i, params, opt, meta)
                else:
                    t0 = time.monotonic()
                    with self.tracer.span("elastic.ckpt.sync_write", step=i):
                        ZeroState(model, mesh, opt_cfg, params=params,
                                  opt=opt).save(
                            cfg.ckpt_dir, i, meta=meta, fmt=cfg.ckpt_format,
                            io_hooks=self.io_hooks, retries=cfg.retries,
                            backoff=cfg.backoff)
                    get_registry().histogram(
                        "elastic.ckpt.write_ms").observe(
                        (time.monotonic() - t0) * 1e3)
                    self._log(f"committed step {i} (sync)")

        if status == "preempted":
            self._finish_preempt(writer, model, mesh, opt_cfg, params, opt,
                                 i, ts, arch)
        elif writer is not None:
            writer.drain()
            self._log(f"complete at step {i}")
        if writer is not None:
            writer.close()
        stats = writer.stats if writer is not None else None
        reg = get_registry()
        if stats is not None and stats.submitted:
            reg.gauge("elastic.ckpt.overlap_fraction").set(
                stats.steps_overlapped / stats.submitted)
        self.tracer.event("elastic.run_end", status=status, final_step=i)
        if self._own_tracer:
            self.tracer.close()   # append-mode: a restart re-opens cleanly
        else:
            self.tracer.flush()
        return {"status": status, "final_step": i,
                "losses": dict(self.losses), "restarts": self.restarts,
                "resharded": list(self.resharded),
                "writer_stats": dataclasses.asdict(stats) if stats else None,
                "fired": list(self.faults.fired) if self.faults else []}

    def _finish_preempt(self, writer, model, mesh, opt_cfg, params, opt,
                        i, ts, arch) -> None:
        cfg = self.cfg
        remaining = math.inf if self._deadline is None \
            else self._deadline - time.monotonic()
        if writer is not None and writer.in_flight():
            if remaining > 1.0:
                writer.drain()
                self._log("preempt: drained in-flight write")
            else:
                writer.abandon()
                self._log("preempt: abandoned in-flight write "
                          "(grace expired)")
        if cfg.ckpt_dir:
            st = ZeroState(model, mesh, opt_cfg, params=params, opt=opt)
            path = st.save(cfg.ckpt_dir, i,
                           meta={"world": ts.world, "arch": arch.name,
                                 "data_cursor": i, "preempted": True},
                           fmt=cfg.ckpt_format, io_hooks=self.io_hooks,
                           retries=cfg.retries, backoff=cfg.backoff)
            self._log(f"preempted at step {i}: final checkpoint "
                      f"{os.path.basename(path)}")
        else:
            self._log(f"preempted at step {i} (no checkpoint dir)")
