"""shard_map train-step builder: the distributed runtime around the Model.

One ``shard_map`` over the full mesh wraps loss + backward + optimizer.
All ZeRO++ collectives (qwZ gathers, hpZ secondary gathers, qgZ all-to-all
reduce-scatter) happen *inside*, per layer group, via the engine; the only
things sharded at the jit boundary are the flat parameter/optimizer buffers
(over every mesh axis) and the batch (batch dims over the slow axes,
sequence over the fast ``model`` axis = sequence parallelism).

Also provides gradient accumulation (microbatching) — at very small
per-device batch the paper's regime — and metric reduction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map
from repro.models.model import Model
from repro.models.transformer import RunSpec
from repro.optim.adamw import AdamWConfig, apply_update
# State specs/init/shapes are owned by the ZeroState subsystem
# (train/state.py); re-exported here for existing callers.
from repro.train.state import (ZeroState, opt_specs,  # noqa: F401
                               param_specs, state_shapes)

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# batch partition specs (model-state specs live in train/state.py)
# ---------------------------------------------------------------------------

def batch_specs(model: Model, axes: Tuple[str, ...],
                batch_axes: Tuple[str, ...], seq_axes: Tuple[str, ...],
                ) -> Dict[str, P]:
    """Specs for a train batch dict (tokens/targets/embeds/positions)."""
    b = tuple(batch_axes) or None
    s = tuple(seq_axes) or None
    cfg = model.cfg
    out = {"targets": P(b, s)}
    if cfg.embed_inputs:
        out["embeds"] = P(b, s, None)
    else:
        out["tokens"] = P(b, s)
    if cfg.mrope:
        out["positions"] = P(None, b, s)
    return out


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainStep:
    """A built (but not yet lowered) distributed train step."""
    fn: Callable                       # jitted (params, opt, batch) -> ...
    mesh: Any
    in_specs: Tuple[Any, ...]
    out_specs: Tuple[Any, ...]
    run_spec: RunSpec
    world: int


def choose_batch_seq_axes(global_batch: int, mesh
                          ) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    """Greedy activation layout: shard batch over as many (slowest-first)
    axes as it divides into; remaining axes carry the sequence dim.

    Pure-DP (batch over every axis, no sequence sharding — the paper's own
    ZeRO layout, zero attention-KV gathers) whenever global_batch covers the
    world; sequence parallelism only absorbs the axes batch can't fill.
    """
    batch_axes, rem = [], global_batch
    for ax in mesh.axis_names:
        n = mesh.shape[ax]
        if rem % n == 0 and rem >= n:
            batch_axes.append(ax)
            rem //= n
        else:
            break
    seq_axes = tuple(a for a in mesh.axis_names if a not in batch_axes)
    return tuple(batch_axes), seq_axes


def build_train_step(
    model: Model,
    mesh,
    opt_cfg: AdamWConfig,
    accum: int = 1,
    donate: bool = True,
    global_batch: Optional[int] = None,
    seq_shard: str = "auto",     # auto | force (always seq-shard on model)
    attn_impl: str = "xla",      # xla | pallas (flash kernel, §Perf)
) -> TrainStep:
    """Build the jitted ZeRO++ train step for ``mesh``.

    Batch layout: every leaf has GLOBAL shape; with ``accum > 1`` a leading
    microbatch axis (accum, B, S, ...) is scanned with gradient summation.
    """
    z = model.zcfg
    axes = tuple(mesh.axis_names)
    assert tuple(z.dp_axes) == axes, (z.dp_axes, axes)
    if seq_shard == "auto" and global_batch is not None:
        batch_axes, seq_axes = choose_batch_seq_axes(global_batch, mesh)
    else:
        batch_axes = tuple(a for a in axes if a != z.intra_axis)
        seq_axes = (z.intra_axis,)
    world = int(np.prod(list(mesh.shape.values())))
    rs = RunSpec(mode="train", seq_axes=seq_axes, attn_impl=attn_impl)

    p_specs = param_specs(model, axes)
    o_specs = opt_specs(model, axes)
    b_specs = batch_specs(model, axes, batch_axes, seq_axes)
    if accum > 1:
        b_specs = {k: P(None, *v) for k, v in b_specs.items()}

    m_specs = {"loss": P(), "nll": P(), "tokens": P(), "grad_norm": P(),
               "lr": P()}
    if model.n_moe_layers:
        m_specs["moe_aux"] = P()

    def local_step(params, opt, batch):
        def loss_of(p, b):
            return model.loss_fn(p, b, rs, world)

        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                (l, mts), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, mb)
                loss_a, grads_a, m_a = carry
                grads_a = jax.tree.map(jnp.add, grads_a, g)
                m_a = jax.tree.map(jnp.add, m_a, mts)
                return (loss_a + l, grads_a, m_a), ()

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zero_m = {"nll_sum": jnp.float32(0), "tokens": jnp.float32(0)}
            if model.n_moe_layers:
                zero_m["moe_aux"] = jnp.float32(0)
            (loss, grads, metrics), _ = lax.scan(
                micro, (jnp.float32(0), zero_g, zero_m), batch)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        new_params, new_opt, stats = apply_update(
            grads, params, opt, opt_cfg, dp_axes=z.dp_axes)

        gl = lax.psum(loss, z.dp_axes)
        nll = lax.psum(metrics["nll_sum"], z.dp_axes)
        toks = lax.psum(metrics["tokens"], z.dp_axes)
        out_m = {"loss": gl, "nll": nll / toks, "tokens": toks,
                 "grad_norm": stats["grad_norm"], "lr": stats["lr"]}
        if model.n_moe_layers:
            out_m["moe_aux"] = lax.psum(metrics["moe_aux"], z.dp_axes) \
                / (model.n_moe_layers * world * max(accum, 1))
        return new_params, new_opt, out_m

    sm = shard_map(
        local_step, mesh=mesh,
        in_specs=(p_specs, o_specs, b_specs),
        out_specs=(p_specs, o_specs, m_specs),
        check_vma=False,
    )
    fn = jax.jit(sm, donate_argnums=(0, 1) if donate else ())
    return TrainStep(fn=fn, mesh=mesh,
                     in_specs=(p_specs, o_specs, b_specs),
                     out_specs=(p_specs, o_specs, m_specs),
                     run_spec=rs, world=world)


# ---------------------------------------------------------------------------
# state construction / placement
# ---------------------------------------------------------------------------

def init_state(model: Model, mesh, opt_cfg: AdamWConfig, key,
               ) -> Tuple[PyTree, PyTree]:
    """Initialize (params fp32, opt) sharded over the mesh.

    Thin wrapper over :meth:`repro.train.state.ZeroState.init` for callers
    that want bare pytrees rather than the state object.
    """
    st = ZeroState(model, mesh, opt_cfg).init(key)
    return st.params, st.opt


def place_batch(batch: Dict[str, np.ndarray], mesh, b_specs) -> Dict:
    """Device_put a host batch dict with the trainer's shardings."""
    return {k: jax.device_put(v, NamedSharding(mesh, b_specs[k]))
            for k, v in batch.items()}
