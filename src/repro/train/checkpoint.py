"""Atomic, elastic checkpointing of the flat ZeRO state.

Checkpoints store the GLOBAL flat buffers (params + optimizer + step + data
cursor) as an npz written via tmp-file + rename (crash-safe).  Because all
model state is flat 1-D per group, restoring onto a different device count
is a re-pad + re-split — elastic restart needs no layout surgery.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "::"


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            key = f"{prefix}{_SEP}{k}" if prefix else str(k)
            out.update(_flatten(v, key))
    else:
        out[prefix] = np.asarray(jax.device_get(tree))
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Dict[str, Any]:
    tree: Dict[str, Any] = {}
    for key, v in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save(path: str, step: int, state: Dict[str, Any],
         meta: Optional[Dict[str, Any]] = None) -> str:
    """Atomic save.  ``state`` is a pytree-of-dicts of (global) arrays."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(state)
    flat["__step__"] = np.asarray(step, np.int64)
    if meta:
        flat["__meta__"] = np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)   # atomic on POSIX
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load(path: str) -> Tuple[int, Dict[str, Any], Dict[str, Any]]:
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    step = int(flat.pop("__step__"))
    meta = {}
    if "__meta__" in flat:
        meta = json.loads(flat.pop("__meta__").tobytes().decode())
    return step, _unflatten(flat), meta


def latest(directory: str, prefix: str = "ckpt_") -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    cands = [f for f in os.listdir(directory)
             if f.startswith(prefix) and f.endswith(".npz")]
    if not cands:
        return None
    cands.sort(key=lambda f: int(f[len(prefix):-4]))
    return os.path.join(directory, cands[-1])


def fit_to(arr: np.ndarray, target_shape) -> np.ndarray:
    """Re-fit a flat (…, padded) buffer onto a different padding length.

    Elastic restart: world sizes differ between save and restore, so the
    trailing padded dim differs.  Real parameters occupy the leading
    ``spec.size`` elements and padding is zeros, so truncating or
    zero-extending the trailing dim is exact as long as the new padding is
    not smaller than the logical size (guaranteed: padding >= size for any
    world).
    """
    tgt = tuple(target_shape)
    assert arr.shape[:-1] == tgt[:-1], (arr.shape, tgt)
    cur, new = arr.shape[-1], tgt[-1]
    if cur == new:
        return arr
    if cur > new:
        return np.ascontiguousarray(arr[..., :new])
    pad = [(0, 0)] * (arr.ndim - 1) + [(0, new - cur)]
    return np.pad(arr, pad)
