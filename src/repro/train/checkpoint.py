"""Thin compat shim over the state subsystem (see train/state.py).

Checkpoint I/O is owned by ``repro.train.state``: per-shard files + a
manifest (with an optional INT8 block-quantized payload) and elastic
restore live there.  This module keeps the original API alive for old
callers and tools:

  * ``save``/``load`` — the legacy single-file GLOBAL npz format (every
    buffer gathered to one host; O(model) host RAM — use
    ``ZeroState.save``/``ZeroState.restore`` for anything past toy scale).
  * ``latest`` — checkpoint discovery, now recognizing both the per-shard
    manifest dirs and legacy ``.npz`` files, and skipping foreign names
    instead of crashing on non-integer suffixes.
  * ``fit_to`` — elastic re-pad of a flat buffer (re-exported).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

from repro.train.state import (CheckpointCorruptError,  # noqa: F401
                               CheckpointError, fit_to, latest_checkpoint,
                               load_global, quarantine_checkpoint,
                               save_legacy_npz)

__all__ = ["save", "load", "latest", "fit_to", "CheckpointError",
           "CheckpointCorruptError", "quarantine_checkpoint"]


def save(path: str, step: int, state: Dict[str, Any],
         meta: Optional[Dict[str, Any]] = None) -> str:
    """Atomic single-file save.  ``state`` is a pytree-of-dicts of
    (global) arrays.  Legacy format — see module docstring."""
    return save_legacy_npz(path, step, state, meta)


def load(path: str) -> Tuple[int, Dict[str, Any], Dict[str, Any]]:
    """Load either format (per-shard dir or legacy npz) into GLOBAL
    buffers; returns (step, state_tree, meta)."""
    return load_global(path)


def latest(directory: str, prefix: str = "ckpt_") -> Optional[str]:
    return latest_checkpoint(directory, prefix)
