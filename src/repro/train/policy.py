"""Per-(architecture × mesh) ZeRO++ policy: how the paper's knobs are set.

The paper exposes qwZ / hpZ / qgZ plus the secondary group size as
configuration; this module is the production decision table mapping an
architecture and mesh onto those knobs under a v5e 16 GB HBM budget:

  * small/medium models (< LARGE_PARAMS): full ZeRO++ with the secondary
    partition on the fast ``model`` axis (the paper's per-node group) and
    fp32 Adam moments.
  * large models (>= LARGE_PARAMS): the paper's node-sized secondary copy
    (2·M/16) does not fit 16 GB HBM — same memory wall the paper's Table 4
    shows for MiCS at 18B on 32 GB V100s.  On the multi-pod mesh we use the
    paper's "multiple compute nodes" extension: secondary group = one whole
    pod (('data','model')), which still eliminates ALL cross-pod (DCI)
    weight traffic in the backward pass at 2·M/256 per-device cost.  On the
    single-pod mesh hpZ is off (there is no slower tier to save).  Adam
    moments are stored bf16 (update math stays fp32).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.zeropp import ZeroConfig

LARGE_PARAMS = 32e9


def count_params(arch: ArchConfig) -> int:
    """Analytic parameter count (no Model construction needed)."""
    from repro.models.model import Model
    m = Model(arch, ZeroConfig.local(), world=1)
    return m.n_params()


@dataclasses.dataclass(frozen=True)
class Policy:
    zcfg: ZeroConfig
    moments_dtype: jnp.dtype
    n_params: int
    note: str
    train_accum: int = 1   # gradient-accumulation microbatches (memory knob)


def make_policy(
    arch: ArchConfig,
    mesh_axes: Tuple[str, ...],
    variant: str = "zeropp",     # zeropp | baseline | qwz | hpz | qgz
    **overrides,
) -> Policy:
    """Resolve the ZeRO++ configuration for an (arch, mesh) cell.

    ``variant`` selects the paper's ablations: "baseline" is plain ZeRO-3;
    "qwz"/"hpz"/"qgz" enable exactly one technique (Fig. 13).
    """
    n = count_params(arch)
    large = n >= LARGE_PARAMS
    multi_pod = "pod" in mesh_axes

    on = dict(qwz=variant in ("zeropp", "qwz"),
              hpz=variant in ("zeropp", "hpz"),
              qgz=variant in ("zeropp", "qgz"))

    hpz_axes: Optional[Tuple[str, ...]] = None
    note = ""
    if on["hpz"] and large:
        if multi_pod:
            hpz_axes = ("data", "model")   # secondary group = one pod
            note = (f"{n/1e9:.0f}B params: node-sized secondary copy "
                    f"(2M/16) exceeds 16 GB HBM; secondary group widened to "
                    f"one pod (2M/256) — kills cross-pod weight traffic")
        else:
            on["hpz"] = False
            note = (f"{n/1e9:.0f}B params on single-pod mesh: hpZ off "
                    f"(no slower tier to trade memory against; paper's "
                    f"Table 4 shows the same memory wall for MiCS)")

    kw = dict(
        qwz=on["qwz"], hpz=on["hpz"], qgz=on["qgz"],
        hpz_axes=hpz_axes,
        dp_axes=tuple(mesh_axes),
        intra_axis="model",
    )
    kw.update(overrides)   # explicit overrides win (ablations, tests)
    zcfg = ZeroConfig(**kw)
    moments = jnp.bfloat16 if large else jnp.float32
    # microbatching keeps the >=70B-ACTIVE train cells inside v5e's 16 GB
    # (activation residuals scale with tokens/device x d_model).  Keyed on
    # ACTIVE params: a 235B MoE with 22B active has dense-4B-scale
    # activations and fits at accum=1 — and accum multiplies weight-gather
    # volume, so never use more than memory requires (§Perf cell C:
    # accum=4 cost 4.1x collective time for the same math).
    from repro.models.model import Model as _M
    n_active = _M(arch, zcfg, world=1).n_active_params()
    accum = 2 if n_active >= 70e9 else 1
    return Policy(zcfg=zcfg, moments_dtype=moments, n_params=n, note=note,
                  train_accum=accum)
