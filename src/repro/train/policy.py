"""Per-(architecture × mesh) ZeRO++ policy — thin preset over repro.tune.

The decision logic lives in ``repro.tune.resolve`` (the single owner of
ZeRO++ configuration resolution, DESIGN.md §9); :func:`make_policy` is the
static-preset entry point every existing caller keeps: it runs the
resolver in ``mode="off"`` — the deterministic preset table, no mesh
probe, no ledger feedback — and wraps the result in the legacy
:class:`Policy` record.  The preset rules themselves are unchanged:

  * small/medium models (< LARGE_PARAMS): full ZeRO++ with the secondary
    partition on the fast ``model`` axis (the paper's per-node group) and
    fp32 Adam moments.
  * large models (>= LARGE_PARAMS): the paper's node-sized secondary copy
    (2·M/16) does not fit 16 GB HBM — same memory wall the paper's Table 4
    shows for MiCS at 18B on 32 GB V100s.  On the multi-pod mesh we use the
    paper's "multiple compute nodes" extension: secondary group = one whole
    pod (('data','model')), which still eliminates ALL cross-pod (DCI)
    weight traffic in the backward pass at 2·M/256 per-device cost.  On the
    single-pod mesh hpZ is off (there is no slower tier to save).  Adam
    moments are stored bf16 (update math stays fp32).

For measurement-driven resolution (``--tune=static|probe``) call
``repro.tune.resolve`` directly — it returns a :class:`ResolvedPolicy`
with the same fields plus the probe profile, HBM ledger and a
human-readable ``explain()``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.tune.resolve import LARGE_PARAMS, count_params, resolve

__all__ = ["LARGE_PARAMS", "Policy", "count_params", "make_policy"]


@dataclasses.dataclass(frozen=True)
class Policy:
    zcfg: "ZeroConfig"  # noqa: F821 — repro.core.zeropp.ZeroConfig
    moments_dtype: jnp.dtype
    n_params: int
    note: str
    train_accum: int = 1   # gradient-accumulation microbatches (memory knob)


def make_policy(
    arch: ArchConfig,
    mesh_axes: Tuple[str, ...],
    variant: str = "zeropp",     # zeropp | baseline | qwz | hpz | qgz
    **overrides,
) -> Policy:
    """Resolve the ZeRO++ configuration for an (arch, mesh) cell.

    ``variant`` selects the paper's ablations: "baseline" is plain ZeRO-3;
    "qwz"/"hpz"/"qgz" enable exactly one technique (Fig. 13).  Explicit
    keyword overrides win (ablations, tests).
    """
    rp = resolve(arch, tuple(mesh_axes), variant, mode="off",
                 overrides=overrides)
    return Policy(zcfg=rp.zcfg, moments_dtype=rp.moments_dtype,
                  n_params=rp.n_params, note=rp.note,
                  train_accum=rp.train_accum)
