"""Serving-path builders: prefill and decode steps under shard_map.

The serving layout keeps parameters ZeRO-sharded (flat buffers over every
mesh axis) and gathers per layer group exactly like training's forward —
with qwZ the gather moves INT8.  KV caches shard their batch dim over the
slow axes and their sequence dim over ``kv_axes``; decode uses the exact
2-pass split-KV softmax so any kv sharding works.

Shape policy (see configs.base.SHAPES):
  * prefill_32k  — batch over ('pod','data'), prompt sequence over 'model'
                   (kv cache inherits the same layout).
  * decode_32k   — batch over ('pod','data'), cache sequence over 'model'.
  * long_500k    — global_batch=1: batch unsharded, cache sequence over
                   EVERY mesh axis (the only way 0.5M tokens of KV fit).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map
from repro.models.model import Model
from repro.models.transformer import RunSpec
# specs come from the state subsystem, not the trainer: serving must not
# depend on the training stack (see DESIGN.md §4)
from repro.train.state import load_serving_params, param_specs  # noqa: F401

Array = jax.Array


def _opt(axes) -> Optional[Tuple[str, ...]]:
    t = tuple(axes)
    return t or None


def cache_specs(model: Model, batch_axes, kv_axes) -> Dict[str, Any]:
    """PartitionSpec tree matching ``model.cache_shapes`` exactly."""
    b = _opt(batch_axes)
    kv = _opt(kv_axes)

    def for_kind(kind: str, stacked: bool):
        L = (None,) if stacked else ()
        if kind in ("attn", "local", "moe"):
            s = P(*L, b, kv, None, None)
            return {"k": s, "v": s}
        if kind == "ssd":
            return {"h": P(*L, b, None, None, None),
                    "conv": P(*L, b, None, None)}
        if kind == "rec":
            return {"h": P(*L, b, None), "conv": P(*L, b, None, None)}
        raise ValueError(kind)

    blocks = tuple(for_kind(k, True) for k in model.period)
    rem = tuple(for_kind(k, False) for k in model.period[: model.rem]) \
        if model.rem_spec else None
    return {"blocks": blocks, "rem": rem}


def serve_batch_specs(model: Model, batch_axes, seq_axes) -> Dict[str, P]:
    b = _opt(batch_axes)
    s = _opt(seq_axes)
    cfg = model.cfg
    out = {}
    if cfg.embed_inputs:
        out["embeds"] = P(b, s, None)
    else:
        out["tokens"] = P(b, s)
    if cfg.mrope:
        out["positions"] = P(None, b, s)
    return out


@dataclasses.dataclass(frozen=True)
class ServeStep:
    fn: Callable
    mesh: Any
    in_specs: Tuple[Any, ...]
    out_specs: Tuple[Any, ...]
    run_spec: RunSpec


def build_prefill_step(model: Model, mesh,
                       batch_axes: Tuple[str, ...],
                       seq_axes: Tuple[str, ...],
                       with_last_pos: bool = False,
                       prefetch: Optional[int] = None) -> ServeStep:
    """Prompt ingestion: (params, batch) -> (last-token logits, caches).

    The prefill KV cache inherits the activation layout, so kv_axes ==
    seq_axes by construction.  With ``with_last_pos`` the step takes an
    extra (B,) int32 argument selecting each sequence's logits position —
    the last REAL token of a right-padded prompt (continuous-batching
    engine, prompt-length buckets).  ``prefetch`` overrides the model's
    ring depth for this step (see build_decode_step).
    """
    if prefetch is not None:
        model = model.with_prefetch(prefetch)
    rs = RunSpec(mode="prefill", seq_axes=tuple(seq_axes),
                 kv_axes=tuple(seq_axes))
    p_specs = param_specs(model, tuple(mesh.axis_names))
    b_specs = serve_batch_specs(model, batch_axes, seq_axes)
    c_specs = cache_specs(model, batch_axes, seq_axes)
    logit_spec = P(_opt(batch_axes), None, None)

    if with_last_pos:
        def stepf(params, batch, last_pos):
            return model.prefill_fn(params, batch, rs, last_pos=last_pos)
        in_specs = (p_specs, b_specs, P(_opt(batch_axes)))
    else:
        def stepf(params, batch):
            return model.prefill_fn(params, batch, rs)
        in_specs = (p_specs, b_specs)

    sm = shard_map(stepf, mesh=mesh,
                   in_specs=in_specs,
                   out_specs=(logit_spec, c_specs),
                   check_vma=False)
    return ServeStep(fn=jax.jit(sm), mesh=mesh,
                     in_specs=in_specs,
                     out_specs=(logit_spec, c_specs), run_spec=rs)


def build_decode_step(model: Model, mesh,
                      batch_axes: Tuple[str, ...],
                      kv_axes: Tuple[str, ...],
                      donate: bool = True,
                      prefetch: Optional[int] = None) -> ServeStep:
    """One-token decode: (params, caches, batch, cache_pos) ->
    (logits, new caches).

    ``cache_pos`` is a PER-SEQUENCE (B,) int32 vector, batch-sharded like
    the activations: each row of the batch decodes at its own position, so
    one compiled step serves any mix of in-flight requests (the
    continuous-batching contract, DESIGN.md §5).

    ``prefetch`` overrides the model's ring depth for THIS step: decode
    batches are small enough that one layer's compute rarely covers a
    weight gather on a slow interconnect, so gathering k>1 layers ahead
    pays exactly here (core/schedule.py; depth still clamps to
    n_layers-1).
    """
    if prefetch is not None:
        model = model.with_prefetch(prefetch)
    rs = RunSpec(mode="decode", kv_axes=tuple(kv_axes))
    p_specs = param_specs(model, tuple(mesh.axis_names))
    b_specs = serve_batch_specs(model, batch_axes, ())
    c_specs = cache_specs(model, batch_axes, kv_axes)
    logit_spec = P(_opt(batch_axes), None, None)
    pos_spec = P(_opt(batch_axes))

    def stepf(params, caches, batch, cache_pos):
        return model.decode_fn(params, caches, batch, cache_pos, rs)

    sm = shard_map(stepf, mesh=mesh,
                   in_specs=(p_specs, c_specs, b_specs, pos_spec),
                   out_specs=(logit_spec, c_specs),
                   check_vma=False)
    fn = jax.jit(sm, donate_argnums=(1,) if donate else ())
    return ServeStep(fn=fn, mesh=mesh,
                     in_specs=(p_specs, c_specs, b_specs, pos_spec),
                     out_specs=(logit_spec, c_specs), run_spec=rs)


def paged_cache_specs(model: Model, kv_axes) -> Dict[str, Any]:
    """PartitionSpec tree matching ``model.paged_cache_shapes``.

    The arena's page dim is UNSHARDED (any slot's table may point at any
    physical page); the within-page token dim shards over ``kv_axes`` —
    the same split-KV ownership decode_attend uses, at page granularity.
    With extra mesh axes (e.g. 'data') the arena is replicated across
    them: every shard runs the identical paged step on identical inputs,
    so the replicas stay bit-equal without any cross-axis traffic.
    """
    kv = _opt(kv_axes)

    def for_kind(kind: str, stacked: bool):
        if kind != "attn":
            raise ValueError(f"paged caches are attn-only, got {kind!r}")
        L = (None,) if stacked else ()
        s = P(*L, None, kv, None, None)
        return {"k": s, "v": s}

    blocks = tuple(for_kind(k, True) for k in model.period)
    rem = tuple(for_kind(k, False) for k in model.period[: model.rem]) \
        if model.rem_spec else None
    return {"blocks": blocks, "rem": rem}


def build_paged_step(model: Model, mesh,
                     kv_axes: Tuple[str, ...],
                     donate: bool = True,
                     prefetch: Optional[int] = None) -> ServeStep:
    """Paged multi-token step: (params, arena, batch, page_table,
    start_pos) -> ((B, T, V) logits, new arena).

    ONE builder covers every paged workload — the engine calls it with
    T=1 (batched decode), T=gamma+1 (speculative verify) and B=1/T=chunk
    (chunked prefill); each (B, T) shape compiles once.  The slot->page
    indirection is resolved INSIDE the jitted step (gather + scatter by
    physical page id, models/attention.py paged_*), so the host only
    uploads the small int32 table.  Batch stays unsharded: the arena is
    one global pool whose pages any row may reference, which is
    incompatible with slicing pages per batch shard.
    """
    if prefetch is not None:
        model = model.with_prefetch(prefetch)
    rs = RunSpec(mode="paged", kv_axes=tuple(kv_axes))
    p_specs = param_specs(model, tuple(mesh.axis_names))
    b_specs = serve_batch_specs(model, (), ())
    c_specs = paged_cache_specs(model, kv_axes)
    logit_spec = P(None, None, None)
    table_spec = P(None, None)
    pos_spec = P(None)

    def stepf(params, caches, batch, table, start_pos):
        return model.paged_fn(params, caches, batch, table, start_pos, rs)

    in_specs = (p_specs, c_specs, b_specs, table_spec, pos_spec)
    sm = shard_map(stepf, mesh=mesh,
                   in_specs=in_specs,
                   out_specs=(logit_spec, c_specs),
                   check_vma=False)
    fn = jax.jit(sm, donate_argnums=(1,) if donate else ())
    return ServeStep(fn=fn, mesh=mesh, in_specs=in_specs,
                     out_specs=(logit_spec, c_specs), run_spec=rs)


def pad_prefill_caches(model: Model, caches, kv_len: int):
    """Grow prefill KV caches (length = prompt) to decode capacity.

    Full-attention caches use slot == position, so zero-padding the
    sequence dim to ``kv_len`` is exact (padded slots are masked out by the
    position-validity test in decode_attend).  Ring buffers (local window)
    and recurrent states are already capacity-sized.
    """
    import jax.numpy as jnp

    def grow(kind, cache, stacked):
        if kind not in ("attn", "moe") or cache is None:
            return cache
        axis = 2 if stacked else 1
        out = {}
        for key in ("k", "v"):
            arr = cache[key]
            pad = kv_len - arr.shape[axis]
            if pad > 0:
                widths = [(0, 0)] * arr.ndim
                widths[axis] = (0, pad)
                arr = jnp.pad(arr, widths)
            out[key] = arr
        return out

    blocks = tuple(grow(k, c, True)
                   for k, c in zip(model.period, caches["blocks"]))
    rem = caches.get("rem")
    if rem is not None:
        rem = tuple(grow(k, c, False)
                    for k, c in zip(model.period[: model.rem], rem))
    return {"blocks": blocks, "rem": rem}


def serve_shape_policy(shape_name: str, mesh_axes: Tuple[str, ...]
                       ) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    """(batch_axes, kv_axes) for a named inference shape.

    Validates both inputs instead of silently falling through to the
    default layout: the shape must be a known *serving* shape from
    ``configs.base.SHAPES`` and the mesh must carry the fast ``model``
    axis the KV layout is keyed on.
    """
    from repro.configs.base import SHAPES

    serving = {n for n, s in SHAPES.items() if s.kind in ("prefill",
                                                          "decode")}
    if shape_name not in SHAPES:
        raise ValueError(
            f"unknown inference shape {shape_name!r}; known serving shapes: "
            f"{sorted(serving)}")
    if shape_name not in serving:
        raise ValueError(
            f"shape {shape_name!r} is a {SHAPES[shape_name].kind} shape, "
            f"not a serving one; expected one of {sorted(serving)}")
    axes = tuple(mesh_axes)
    if len(set(axes)) != len(axes):
        raise ValueError(f"duplicate mesh axis names: {axes}")
    if "model" not in axes:
        raise ValueError(
            f"serving layouts shard the KV cache over the fast 'model' "
            f"axis (DESIGN.md §2), absent from mesh axes {axes}")
    fast = ("model",)
    slow = tuple(a for a in axes if a != "model")
    if shape_name == "long_500k":
        return (), axes                  # B=1: shard the cache everywhere
    return slow, fast
