"""Unified sharded-state subsystem: one ``ZeroState`` owns the model state.

This module is the single source of truth for everything about the flat
ZeRO-partitioned model state (see DESIGN.md §4):

  * **Specs** — ``PartitionSpec``/``NamedSharding`` construction for the
    flat parameter and optimizer buffers on any mesh.  The trainer, the
    server, the dry-run, and the examples all get their specs from here
    (previously each kept its own copy).
  * **Init** — sharded fp32 init of (params, opt) straight into the mesh
    layout, and abstract ``ShapeDtypeStruct`` trees for allocation-free
    lowering.
  * **Per-shard checkpoint I/O** — each process writes ONLY its own shards
    of every buffer (tmp dir + atomic rename; ``manifest.json`` carries the
    ``ParamSpec`` layout, world size, quantization block, step and data
    cursor).  Host RAM per process stays O(model/world), not O(model).
  * **Quantized format** — an optional qwZ-style block-quantized payload
    (INT8 values + fp16 per-block scales, ~4x smaller on disk).  fp32
    remains the exact default.
  * **Elastic restore** — a manifest written at world W loads onto world
    W': shards are reassembled, re-padded to the new world's alignment
    (truncating or zero-extending padding only — the logical prefix of each
    flat buffer is invariant) and re-split onto the new mesh.  A params-only
    bf16 path serves the inference stack.

The legacy single-file GLOBAL-npz format of ``train/checkpoint.py`` is kept
readable (restore transparently falls back to it) and that module is now a
thin compat shim over the helpers here.

Multi-process note: this repo simulates pods with host devices inside one
process, so "per process" collapses to process 0 writing every shard, one
file.  The format is already multi-process shaped — N processes write N
shard files into the staging dir and process 0 writes the manifest last,
then renames; ``manifest.json`` presence marks a complete checkpoint.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import time
import zipfile
import zlib
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.optim.adamw import AdamWConfig, init_opt_state

Array = jax.Array
PyTree = Any

_SEP = "::"          # nesting separator in flattened state keys
_RANK = "@"          # key@rank marks one world-shard of a buffer
_SCALES = "#scales"  # key@rank#scales carries the fp16 quant scales

MANIFEST = "manifest.json"
FORMAT_FP32 = "fp32"
FORMAT_INT8 = "int8_blockwise"
_QMAX8 = 127.0


class CheckpointError(RuntimeError):
    """A checkpoint could not be written (after exhausting retries)."""


class CheckpointCorruptError(CheckpointError):
    """A checkpoint on disk failed validation: truncated or bit-flipped
    shard (checksum mismatch / unreadable npz), missing shard, or an
    unparseable manifest.  The file exists but must not be trusted."""


class IOHooks:
    """Injection seam for checkpoint I/O (see testing/faults.py).

    ``ZeroState.save`` calls these at fixed points of the commit protocol;
    any hook object only needs the methods it cares about.  Raising from a
    hook aborts the staged write exactly as a real I/O failure at that
    point would (OSError is retried, anything else propagates).
    """

    def post_shard(self, path: str) -> None:
        """After a shard file is written + fsynced, before its checksum."""

    def pre_manifest(self, staging: str) -> None:
        """After every shard, before the manifest is written."""

    def pre_publish(self, staging: str, final: str) -> None:
        """After the manifest fsync, before the atomic rename."""


def _call_hook(hooks: Any, name: str, *args) -> None:
    if hooks is None:
        return
    fn = getattr(hooks, name, None)
    if fn is not None:
        fn(*args)


def _crc32_file(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            crc = zlib.crc32(chunk, crc)
    return crc & 0xFFFFFFFF


def _fsync_dir(path: str) -> None:
    """fsync a directory entry so renames/creates inside it are durable."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return               # platform without directory fds
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


# np.load failure modes for a truncated / bit-flipped npz: bad zip magic,
# bad zlib stream, short read, or numpy's "Failed to interpret" ValueError.
_SHARD_READ_ERRORS = (OSError, ValueError, EOFError,
                      zipfile.BadZipFile, zlib.error)


# ---------------------------------------------------------------------------
# partition specs (the one copy — trainer/serve/dryrun import from here)
# ---------------------------------------------------------------------------

def param_specs(model, axes: Tuple[str, ...]) -> Dict[str, P]:
    """PartitionSpecs for the global flat parameter buffers: every buffer
    shards its trailing (flat) dim over ALL mesh axes (the ZeRO world)."""
    out = {}
    for name, shape in model.param_shapes().items():
        lead = (None,) * (len(shape) - 1)
        out[name] = P(*lead, tuple(axes))
    return out


def opt_specs(model, axes: Tuple[str, ...]) -> Dict[str, Any]:
    """Optimizer-state specs: moments mirror the parameter layout."""
    ps = param_specs(model, axes)
    return {"m": ps, "v": ps, "count": P()}


def abstract_params(model, dtype=jnp.float32) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the flat parameter buffers (no allocation)."""
    return {k: jax.ShapeDtypeStruct(s, dtype)
            for k, s in model.param_shapes().items()}


def state_shapes(model, opt_cfg: AdamWConfig) -> Tuple[PyTree, PyTree]:
    """ShapeDtypeStructs for (params, opt) — used by the dry-run."""
    pshapes = abstract_params(model, jnp.float32)
    mo = {k: jax.ShapeDtypeStruct(s.shape, opt_cfg.moments_dtype)
          for k, s in pshapes.items()}
    opt = {"m": mo, "v": dict(mo),
           "count": jax.ShapeDtypeStruct((), jnp.int32)}
    return pshapes, opt


def model_param_layout(model) -> Dict[str, Any]:
    """JSON-able ``ParamSpec`` layout of every buffer group (manifest)."""
    out: Dict[str, Any] = {}
    for group, spec in (("embed", model.embed_spec),
                        ("blocks", model.period_spec),
                        ("experts", model.expert_spec),
                        ("rem", model.rem_spec),
                        ("head", model.head_spec),
                        ("unemb", model.unemb_spec)):
        if spec is not None:
            out[group] = {"entries": [[n, list(s)] for n, s in spec.entries],
                          "align": spec.align}
    return out


# ---------------------------------------------------------------------------
# tree flattening / dtype encoding
# ---------------------------------------------------------------------------

def flatten_state(tree: Any, prefix: str = "") -> Dict[str, Any]:
    """Flatten a pytree-of-dicts into {"a::b::c": leaf}."""
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            key = f"{prefix}{_SEP}{k}" if prefix else str(k)
            out.update(flatten_state(v, key))
    else:
        out[prefix] = tree
    return out


def unflatten_state(flat: Mapping[str, Any]) -> Dict[str, Any]:
    tree: Dict[str, Any] = {}
    for key, v in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


_BF16 = np.dtype(jnp.bfloat16)


def _dtype_str(dt) -> str:
    return "bfloat16" if np.dtype(dt) == _BF16 else np.dtype(dt).name


def _np_dtype(name: str):
    return _BF16 if name == "bfloat16" else np.dtype(name)


def _encode(arr: np.ndarray) -> np.ndarray:
    """npz cannot hold bfloat16; store its bits as uint16 (dtype is in the
    manifest layout, so decode is unambiguous)."""
    if arr.dtype == _BF16:
        return arr.view(np.uint16)
    return arr


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name == "bfloat16" and arr.dtype != _BF16:
        return arr.view(_BF16)
    return arr


# ---------------------------------------------------------------------------
# blockwise INT8 payload (numpy mirror of core.quant's symmetric scheme)
# ---------------------------------------------------------------------------

def _fp16_scale(scale: np.ndarray, round_up: bool = False) -> np.ndarray:
    """Cast per-block scales to fp16 without breaking the quantizers'
    invariants: a positive scale must never flush to zero (dequantizing a
    whole block to exact 0), never become inf (dequantizing to nan), and —
    for the ceil-rounding sqrt encoder — never round DOWN (which would let
    ``v_hat < v`` through the clip at qmax)."""
    s16 = scale.astype(np.float16)
    tiny = np.float16(6e-08)          # smallest positive fp16 subnormal
    s16 = np.where((scale > 0) & (s16 == 0), tiny, s16)
    if round_up:
        lt = s16.astype(np.float32) < scale
        s16 = np.where(lt, np.nextafter(s16, np.float16(np.inf)), s16)
    # inf clamp LAST: round_up can nextafter max-finite into inf
    s16 = np.where(np.isinf(s16), np.float16(65504), s16)
    return s16.astype(np.float16)


def quantize_shard(x: np.ndarray, block: int) -> Tuple[np.ndarray, np.ndarray]:
    """Blockwise symmetric INT8 over the trailing dim; fp16 scales.

    Same math as :func:`repro.core.quant.quantize_blockwise` (bits=8):
    per-block scale = absmax/127, round-half-even — except the stored
    scale is fp16 (clamped away from 0/inf, see :func:`_fp16_scale`) and
    the payload is computed AGAINST that stored scale, so the roundtrip
    error per element stays <= stored_scale/2 (+ the qmax clip slack of
    ~2^-11 · absmax when fp16 rounded the scale down).
    """
    lead, n = x.shape[:-1], x.shape[-1]
    nb = n // block
    xb = np.asarray(x, np.float32).reshape(*lead, nb, block)
    absmax = np.abs(xb).max(axis=-1, keepdims=True)
    scale = _fp16_scale(absmax / _QMAX8)
    s32 = scale.astype(np.float32)
    inv = np.where(s32 > 0, 1.0 / np.where(s32 > 0, s32, 1.0), 0.0)
    q = np.clip(np.round(xb * inv), -_QMAX8, _QMAX8).astype(np.int8)
    return q.reshape(*lead, n), scale.squeeze(-1)


def dequantize_shard(q: np.ndarray, scales: np.ndarray, block: int,
                     dtype=np.float32) -> np.ndarray:
    lead, n = q.shape[:-1], q.shape[-1]
    nb = n // block
    x = q.reshape(*lead, nb, block).astype(np.float32) \
        * scales[..., None].astype(np.float32)
    return x.reshape(*lead, n).astype(dtype)


_QMAXU8 = 255.0


def quantize_shard_sqrt(x: np.ndarray, block: int
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Unsigned sqrt-domain blockwise quantization for NONNEGATIVE buffers
    (the Adam second moment): store ``ceil(sqrt(v)/scale)`` in uint8.

    Two deliberate asymmetries vs :func:`quantize_shard`:
      * sqrt domain — v spans ~(max/block ratio)^2, sqrt halves the log
        range so small entries survive 8 bits;
      * ceil rounding — guarantees ``v_hat >= v``.  Adam divides by
        ``sqrt(v_hat)+eps``: an UNDERestimated second moment multiplies the
        step by up to 1/eps and detonates the restored run (observed: loss
        5.2 -> 246 in two steps with symmetric rounding); overestimation
        merely damps the step by <= scale/sqrt(v).
    """
    lead, n = x.shape[:-1], x.shape[-1]
    nb = n // block
    u = np.sqrt(np.maximum(np.asarray(x, np.float32), 0.0)
                ).reshape(*lead, nb, block)
    # scales round UP into fp16: a scale that flushed to 0 or rounded
    # down would re-admit the v_hat < v underestimate this encoder bans
    scale = _fp16_scale(u.max(axis=-1, keepdims=True) / _QMAXU8,
                        round_up=True)
    s32 = scale.astype(np.float32)
    inv = np.where(s32 > 0, 1.0 / np.where(s32 > 0, s32, 1.0), 0.0)
    q = np.clip(np.ceil(u * inv), 0, _QMAXU8).astype(np.uint8)
    return q.reshape(*lead, n), scale.squeeze(-1)


def dequantize_shard_sqrt(q: np.ndarray, scales: np.ndarray, block: int,
                          dtype=np.float32) -> np.ndarray:
    lead, n = q.shape[:-1], q.shape[-1]
    nb = n // block
    u = q.reshape(*lead, nb, block).astype(np.float32) \
        * scales[..., None].astype(np.float32)
    return (u * u).reshape(*lead, n).astype(dtype)


# ---------------------------------------------------------------------------
# elastic re-fit
# ---------------------------------------------------------------------------

def fit_to(arr: np.ndarray, target_shape) -> np.ndarray:
    """Re-fit a flat (…, padded) buffer onto a different padding length.

    Elastic restart: world sizes (and hence alignments) differ between save
    and restore, so the trailing padded dim differs.  Real parameters occupy
    the leading ``spec.size`` elements and padding is zeros, so truncating
    or zero-extending the trailing dim is exact as long as the new padding
    is not smaller than the logical size (guaranteed: padding >= size for
    any world).
    """
    tgt = tuple(target_shape)
    assert arr.shape[:-1] == tgt[:-1], (arr.shape, tgt)
    cur, new = arr.shape[-1], tgt[-1]
    if cur == new:
        return arr
    if cur > new:
        return np.ascontiguousarray(arr[..., :new])
    pad = [(0, 0)] * (arr.ndim - 1) + [(0, new - cur)]
    return np.pad(arr, pad)


# ---------------------------------------------------------------------------
# checkpoint discovery
# ---------------------------------------------------------------------------

def _ckpt_step(name: str, prefix: str) -> Optional[int]:
    """Step number of a checkpoint entry name, or None for foreign files
    (non-integer suffixes must be skipped, not crash the sort)."""
    if not name.startswith(prefix):
        return None
    stem = name[len(prefix):]
    if stem.endswith(".npz"):
        stem = stem[:-4]
    try:
        return int(stem)
    except ValueError:
        return None


def latest_checkpoint(directory: str, prefix: str = "ckpt_") -> Optional[str]:
    """Newest complete checkpoint under ``directory``: either a per-shard
    manifest dir (``ckpt_<step>/manifest.json``) or a legacy ``.npz``.
    Foreign / partially-written entries are ignored."""
    if not directory or not os.path.isdir(directory):
        return None
    best: Tuple[int, str] = (-1, "")
    for name in os.listdir(directory):
        step = _ckpt_step(name, prefix)
        if step is None:
            continue
        full = os.path.join(directory, name)
        if os.path.isdir(full):
            if not os.path.exists(os.path.join(full, MANIFEST)):
                continue  # incomplete (crashed before the manifest rename)
        elif not name.endswith(".npz"):
            continue
        if step > best[0]:
            best = (step, full)
    return best[1] or None


def quarantine_checkpoint(path: str) -> str:
    """Move a corrupt checkpoint (dir or npz) aside as ``<path>.corrupt``.

    The suffix fails :func:`_ckpt_step`'s int() parse, so a quarantined
    checkpoint is never selected by :func:`latest_checkpoint` again, and
    the evidence stays on disk for a post-mortem instead of being deleted.
    """
    dst = path + ".corrupt"
    n = 0
    while os.path.exists(dst):
        n += 1
        dst = f"{path}.corrupt{n}"
    os.rename(path, dst)
    return dst


# ---------------------------------------------------------------------------
# legacy single-file GLOBAL npz (train/checkpoint.py's original format)
# ---------------------------------------------------------------------------

def save_legacy_npz(path: str, step: int, state: Dict[str, Any],
                    meta: Optional[Dict[str, Any]] = None) -> str:
    """Atomic single-file save of GLOBAL buffers (compat path — O(model)
    host RAM; prefer :meth:`ZeroState.save`)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = {k: np.asarray(jax.device_get(v))
            for k, v in flatten_state(state).items()}
    flat["__step__"] = np.asarray(step, np.int64)
    if meta:
        flat["__meta__"] = np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **{k: _encode(v) for k, v in flat.items()})
        os.replace(tmp, path)   # atomic on POSIX
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_legacy_npz(path: str, prefix: Optional[str] = None
                    ) -> Tuple[int, Dict[str, Any], Dict[str, Any]]:
    want = _key_filter(prefix)
    try:
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files
                    if k in ("__step__", "__meta__") or want(k)}
    except FileNotFoundError:
        raise
    except _SHARD_READ_ERRORS as e:
        raise CheckpointCorruptError(
            f"legacy checkpoint {path} is unreadable "
            f"(truncated or corrupted npz): {e}") from e
    step = int(flat.pop("__step__"))
    meta = {}
    if "__meta__" in flat:
        meta = json.loads(flat.pop("__meta__").tobytes().decode())
    return step, unflatten_state(flat), meta


# ---------------------------------------------------------------------------
# per-shard manifest format: load
# ---------------------------------------------------------------------------

def _key_filter(prefix: Optional[str]):
    if prefix is None:
        return lambda key: True
    return lambda key: key == prefix or key.startswith(prefix + _SEP)


def load_global(path: str, prefix: Optional[str] = None
                ) -> Tuple[int, Dict[str, Any], Dict[str, Any]]:
    """Load a checkpoint (per-shard dir or legacy npz) into GLOBAL numpy
    buffers.  Quantized payloads are dequantized to their logical dtype.
    ``prefix`` restricts loading to one state subtree (e.g. ``"params"``
    for serving — the optimizer payload is then never read or dequantized).

    Returns (step, state_tree, meta).
    """
    if not os.path.isdir(path):
        return load_legacy_npz(path, prefix)
    try:
        with open(os.path.join(path, MANIFEST)) as f:
            man = json.load(f)
    except json.JSONDecodeError as e:
        raise CheckpointCorruptError(
            f"checkpoint {path}: manifest is not valid JSON "
            f"(crashed mid-write?): {e}") from e
    world = int(man["world"])
    block = man.get("quant_block")
    sums = man.get("checksums") or {}
    want = _key_filter(prefix)
    raw: Dict[str, np.ndarray] = {}
    for fname in man["shard_files"]:
        full = os.path.join(path, fname)
        if not os.path.exists(full):
            raise CheckpointCorruptError(
                f"checkpoint {path} is missing shard file {fname}")
        want_crc = sums.get(fname)
        if want_crc is not None:
            got = _crc32_file(full)
            if got != int(want_crc):
                raise CheckpointCorruptError(
                    f"checkpoint {path}: shard {fname} checksum mismatch "
                    f"(manifest {int(want_crc):#010x}, file {got:#010x}) — "
                    f"truncated or corrupted on disk")
        try:
            with np.load(full) as z:
                for k in z.files:   # npz members load lazily — only wanted
                    if want(k.split(_RANK, 1)[0]):
                        raw[k] = z[k]
        except _SHARD_READ_ERRORS as e:
            raise CheckpointCorruptError(
                f"checkpoint {path}: shard {fname} is unreadable: {e}"
            ) from e
    flat: Dict[str, np.ndarray] = {}
    for key, info in man["layout"].items():
        if not want(key):
            continue
        dt = info["dtype"]
        if info["replicated"]:
            flat[key] = _decode(raw[key], dt)
            continue
        ranks = []
        for r in range(world):
            pk = f"{key}{_RANK}{r}"
            if pk not in raw:
                raise CheckpointCorruptError(
                    f"checkpoint {path} is missing shard {pk} "
                    f"(world={world}, files={man['shard_files']})")
            sk = pk + _SCALES
            if sk in raw:
                dq = dequantize_shard_sqrt \
                    if info.get("encoding") == "uint8_sqrt_blockwise" \
                    else dequantize_shard
                ranks.append(dq(raw[pk], raw[sk], block, _np_dtype(dt)))
            else:
                ranks.append(_decode(raw[pk], dt))
        flat[key] = np.concatenate(ranks, axis=-1)
    return int(man["step"]), unflatten_state(flat), man.get("meta", {})


def read_manifest(path: str) -> Dict[str, Any]:
    with open(os.path.join(path, MANIFEST)) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# ZeroState
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ZeroState:
    """The sharded ZeRO model state and everything needed to move it.

    Owns (model, mesh, opt_cfg) plus the live (params, opt) pytrees, and
    provides specs, sharded init, per-shard checkpointing and elastic
    restore.  ``params``/``opt`` may be None for an abstract (spec-only)
    state, e.g. in the dry-run.
    """

    model: Any
    mesh: Any
    opt_cfg: AdamWConfig
    params: Optional[PyTree] = None
    opt: Optional[PyTree] = None
    step: int = 0
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------- specs

    @property
    def axes(self) -> Tuple[str, ...]:
        return tuple(self.mesh.axis_names)

    @property
    def world(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.axes]))

    def param_specs(self) -> Dict[str, P]:
        return param_specs(self.model, self.axes)

    def opt_specs(self) -> Dict[str, Any]:
        return opt_specs(self.model, self.axes)

    def param_shardings(self) -> Dict[str, NamedSharding]:
        return {k: NamedSharding(self.mesh, s)
                for k, s in self.param_specs().items()}

    def opt_shardings(self) -> Dict[str, Any]:
        ps = self.param_shardings()
        return {"m": ps, "v": dict(ps),
                "count": NamedSharding(self.mesh, P())}

    def shapes(self) -> Tuple[PyTree, PyTree]:
        return state_shapes(self.model, self.opt_cfg)

    # -------------------------------------------------------------- init

    def init(self, key: Array) -> "ZeroState":
        """Sharded fp32 init of (params, opt) directly into the mesh
        layout (no host-global materialization)."""
        model, opt_cfg = self.model, self.opt_cfg

        def mk():
            params = model.init_params(key, dtype=jnp.float32)
            return params, init_opt_state(params, opt_cfg)

        out_sh = (self.param_shardings(), self.opt_shardings())
        self.params, self.opt = jax.jit(mk, out_shardings=out_sh)()
        return self

    def place_global(self, params: Dict[str, np.ndarray],
                     opt: Optional[Dict[str, Any]] = None) -> "ZeroState":
        """Adopt host-GLOBAL buffers: elastic re-fit each flat buffer onto
        this model's padding (see :func:`fit_to`) and shard onto the mesh.
        This is the restore path minus the file I/O, shared with tests so
        checkpoint roundtrips can be proven bit-exact against it."""
        want = self.model.param_shapes()

        def refit(tree):
            return {k: fit_to(np.asarray(arr), want[k])
                    for k, arr in tree.items()}

        p_sh = self.param_shardings()

        def put(tree, shardings):
            return {k: jax.device_put(v, shardings[k])
                    for k, v in tree.items()}

        self.params = put(refit(params), p_sh)
        if opt is not None:
            self.opt = {
                "m": put(refit(opt["m"]), p_sh),
                "v": put(refit(opt["v"]), p_sh),
                "count": jax.device_put(np.asarray(opt["count"]),
                                        NamedSharding(self.mesh, P())),
            }
        return self

    # -------------------------------------------------------------- save

    def _owned_shards(self, arr, sharded: bool
                      ) -> Dict[int, np.ndarray]:
        """{rank: shard} for the trailing-dim world-shards of ``arr`` that
        live on THIS process's devices (numpy inputs: all of them)."""
        world = self.world
        if not sharded:
            return {-1: np.asarray(jax.device_get(arr))}
        per = arr.shape[-1] // world
        out: Dict[int, np.ndarray] = {}
        if isinstance(arr, jax.Array):
            for s in arr.addressable_shards:
                start = s.index[-1].start or 0
                out[start // per] = np.asarray(s.data)
        else:
            a = np.asarray(arr)
            for r in range(world):
                out[r] = a[..., r * per:(r + 1) * per]
        return out

    def save(self, ckpt_dir: str, step: Optional[int] = None,
             meta: Optional[Dict[str, Any]] = None,
             fmt: str = FORMAT_FP32,
             quant_block: Optional[int] = None,
             io_hooks: Optional[Any] = None,
             retries: int = 0,
             backoff: float = 0.05) -> str:
        """Per-shard atomic save to ``ckpt_dir/ckpt_<step>/``.

        Commit protocol (what a crash at any point leaves behind):
          1. shard files into a ``.tmp`` staging dir, fsynced — crash here
             leaves only ``.tmp`` debris that :func:`latest_checkpoint`
             never selects and the next save sweeps away;
          2. per-shard crc32 checksums collected into the manifest;
          3. ``manifest.json`` written + fsynced LAST (process 0) — its
             presence is the commit record;
          4. atomic ``os.replace`` of staging onto the final name, then a
             directory fsync.  A previous checkpoint for the same step is
             moved aside first so there is never a window with neither.

        Each process writes a single ``shard_<proc>.npz`` holding only the
        world-shards its devices own.  ``retries`` re-runs the staged write
        on OSError with exponential ``backoff`` (the host payload is built
        once; only file I/O is retried); exhaustion raises
        :class:`CheckpointError`.  ``io_hooks`` is the fault-injection seam
        (see :class:`IOHooks`).

        ``fmt="int8_blockwise"`` (alias ``"int8"``) stores every sharded
        float buffer as an 8-bit payload + fp16 per-block scales — the qwZ
        wire format applied to disk, ~4x smaller.  Params and first moments
        use symmetric INT8; the second moment uses the sqrt-domain uint8
        encoder (``v_hat >= v``, see :func:`quantize_shard_sqrt`).  fp32
        stays the exact default.
        """
        if fmt == "int8":
            fmt = FORMAT_INT8
        if fmt not in (FORMAT_FP32, FORMAT_INT8):
            raise ValueError(f"unknown checkpoint format {fmt!r}")
        if quant_block is None:
            quant_block = getattr(self.model.zcfg, "qwz_block", 256)
        step = self.step if step is None else step
        meta = dict(self.meta, **(meta or {}))
        world = self.world

        state: Dict[str, Any] = {"params": self.params}
        spec_tree: Dict[str, Any] = {"params": self.param_specs()}
        if self.opt is not None:
            state["opt"] = self.opt
            spec_tree["opt"] = self.opt_specs()
        flat = flatten_state(state)
        specs = flatten_state(spec_tree)

        # host payload first (one device_get) — retries redo file I/O only
        payload: Dict[str, np.ndarray] = {}
        layout: Dict[str, Any] = {}
        v_prefix = f"opt{_SEP}v"
        for key, arr in flat.items():
            sharded = tuple(specs[key]) != ()
            shards = self._owned_shards(arr, sharded)
            dt = _dtype_str(arr.dtype)
            # the nonnegative second moment takes the sqrt-domain
            # encoder (see quantize_shard_sqrt for why)
            sqrt_domain = key == v_prefix \
                or key.startswith(v_prefix + _SEP)
            encoding = "raw"
            for rank, a in sorted(shards.items()):
                if rank < 0:  # replicated: stored once, by process 0
                    if jax.process_index() == 0:
                        payload[key] = _encode(a)
                    continue
                pk = f"{key}{_RANK}{rank}"
                if (fmt == FORMAT_INT8 and a.dtype.kind == "f"
                        and a.shape[-1] % quant_block == 0):
                    if sqrt_domain:
                        q, sc = quantize_shard_sqrt(a, quant_block)
                        encoding = "uint8_sqrt_blockwise"
                    else:
                        q, sc = quantize_shard(a, quant_block)
                        encoding = "int8_blockwise"
                    payload[pk] = q
                    payload[pk + _SCALES] = sc
                else:
                    payload[pk] = _encode(a)
            layout[key] = {
                "shape": [int(d) for d in np.shape(arr)],
                "dtype": dt,
                "replicated": not sharded,
                "quantized": encoding != "raw",
                "encoding": encoding,
            }
        manifest = {
            "version": 1,
            "step": int(step),
            "world": world,
            "mesh": {a: int(self.mesh.shape[a]) for a in self.axes},
            "format": fmt,
            "quant_block": quant_block if fmt == FORMAT_INT8 else None,
            "scale_dtype": "float16",
            "num_processes": jax.process_count(),
            "shard_files": [f"shard_{p:05d}.npz"
                            for p in range(jax.process_count())],
            "checksums": {},
            "layout": layout,
            "param_layout": model_param_layout(self.model),
            "meta": meta,
        }

        final = os.path.join(ckpt_dir, f"ckpt_{step}")
        os.makedirs(ckpt_dir, exist_ok=True)
        # deterministic SHARED staging dir: every process writes its shard
        # file into the same place (checkpoint dirs live on a shared
        # filesystem), process 0 publishes.  The .tmp/.old suffixed names
        # fail latest_checkpoint's int() parse, so they are never restored.
        staging = final + ".tmp"
        last_err: Optional[BaseException] = None
        for attempt in range(max(0, int(retries)) + 1):
            if attempt:
                time.sleep(backoff * (2 ** (attempt - 1)))
            try:
                return self._write_staged(ckpt_dir, final, staging,
                                          payload, manifest, io_hooks)
            except OSError as e:       # transient I/O — retry from scratch
                last_err = e
                shutil.rmtree(staging, ignore_errors=True)
        raise CheckpointError(
            f"checkpoint write to {final} failed after "
            f"{max(0, int(retries)) + 1} attempt(s): {last_err}"
        ) from last_err

    def _write_staged(self, ckpt_dir: str, final: str, staging: str,
                      payload: Dict[str, np.ndarray],
                      manifest: Dict[str, Any],
                      io_hooks: Optional[Any]) -> str:
        """One attempt at the staged write + publish (see :meth:`save`)."""
        proc = jax.process_index()
        if proc == 0 and os.path.isdir(staging):
            shutil.rmtree(staging)     # stale leftover from a crashed save
        os.makedirs(staging, exist_ok=True)
        try:
            shard_name = f"shard_{proc:05d}.npz"
            spath = os.path.join(staging, shard_name)
            with open(spath, "wb") as f:
                np.savez(f, **payload)
                f.flush()
                os.fsync(f.fileno())   # durable BEFORE the manifest commit
            _call_hook(io_hooks, "post_shard", spath)
            manifest = dict(manifest)
            manifest["checksums"] = {shard_name: _crc32_file(spath)}
            # (multi-process: a barrier would sit here, and process 0
            # would collect every shard's checksum; manifest is last)
            _call_hook(io_hooks, "pre_manifest", staging)
            if proc == 0:   # manifest is process 0's, written last
                mpath = os.path.join(staging, MANIFEST)
                with open(mpath, "w") as f:
                    json.dump(manifest, f, indent=1)
                    f.flush()
                    os.fsync(f.fileno())
                _fsync_dir(staging)
            _call_hook(io_hooks, "pre_publish", staging, final)
            # publish (process 0): move any previous ckpt for this step
            # ASIDE before the rename — never a window with neither the
            # old nor the new checkpoint on disk
            if proc == 0:
                old = final + ".old"
                if os.path.isdir(old):
                    shutil.rmtree(old)
                if os.path.isdir(final):
                    os.rename(final, old)
                os.replace(staging, final)   # atomic publish
                shutil.rmtree(old, ignore_errors=True)
                _fsync_dir(ckpt_dir)
        finally:
            if os.path.isdir(staging):
                shutil.rmtree(staging, ignore_errors=True)
        return final

    # ----------------------------------------------------------- restore

    @classmethod
    def restore(cls, model, mesh, opt_cfg: AdamWConfig,
                ckpt: str) -> Optional["ZeroState"]:
        """Elastic restore: load the latest checkpoint under ``ckpt`` (or
        ``ckpt`` itself if it is a checkpoint path) onto (model, mesh) —
        the saved world size/alignment may differ from the current one."""
        path = cls._resolve(ckpt)
        if path is None:
            return None
        step, tree, meta = load_global(path)
        st = cls(model, mesh, opt_cfg, step=step, meta=meta)
        return st.place_global(tree["params"], tree.get("opt"))

    @classmethod
    def restore_resilient(cls, model, mesh, opt_cfg: AdamWConfig,
                          ckpt: str, quarantine: bool = True,
                          max_fallbacks: int = 8) -> Optional["ZeroState"]:
        """:meth:`restore` with quarantine-and-fall-back: a checkpoint that
        fails validation (:class:`CheckpointCorruptError`) is moved aside
        as ``.corrupt`` (see :func:`quarantine_checkpoint`) and the next
        older checkpoint is tried, until one loads or none remain (then
        returns None — the caller starts from scratch)."""
        tried = 0
        while True:
            path = cls._resolve(ckpt)
            if path is None:
                return None
            try:
                step, tree, meta = load_global(path)
            except CheckpointCorruptError as e:
                if not quarantine or tried >= max_fallbacks:
                    raise
                tried += 1
                q = quarantine_checkpoint(path)
                print(f"[state] corrupt checkpoint quarantined "
                      f"{path} -> {q}: {e}", flush=True)
                continue
            st = cls(model, mesh, opt_cfg, step=step, meta=meta)
            return st.place_global(tree["params"], tree.get("opt"))

    @staticmethod
    def _resolve(ckpt: str) -> Optional[str]:
        if ckpt and os.path.isdir(ckpt) \
                and os.path.exists(os.path.join(ckpt, MANIFEST)):
            return ckpt          # a checkpoint dir itself
        if ckpt and os.path.isfile(ckpt):
            return ckpt          # a legacy npz
        return latest_checkpoint(ckpt)


# ---------------------------------------------------------------------------
# serving load path (params only, bf16)
# ---------------------------------------------------------------------------

def load_serving_params(model, mesh, ckpt: str,
                        dtype=jnp.bfloat16,
                        expect_arch: Optional[str] = None
                        ) -> Dict[str, Array]:
    """Params-only load for the serving stack: elastic re-fit onto
    (model, mesh), cast to ``dtype`` (bf16 default — serving never needs
    the fp32 master or the optimizer moments), sharded placement.

    ``expect_arch`` guards engine boots: if the checkpoint's meta records
    an architecture name and it differs, fail loudly instead of fitting a
    foreign model's buffers into this one's layout (``fit_to`` would
    silently truncate/zero-extend them)."""
    path = ZeroState._resolve(ckpt)
    if path is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt!r}")
    _, tree, meta = load_global(path, prefix="params")
    ck_arch = (meta or {}).get("arch")
    if expect_arch is not None and ck_arch is not None \
            and ck_arch != expect_arch:
        raise ValueError(
            f"checkpoint {path!r} was written for arch {ck_arch!r}, "
            f"engine expects {expect_arch!r}")
    want = model.param_shapes()
    shardings = {k: NamedSharding(mesh, s)
                 for k, s in param_specs(model, tuple(mesh.axis_names)).items()}
    out = {}
    for k, arr in tree["params"].items():
        a = fit_to(np.asarray(arr), want[k]).astype(np.dtype(dtype))
        out[k] = jax.device_put(a, shardings[k])
    return out
