"""The model stack: pattern-scan decoder supporting all assigned families.

A model is a sequence of blocks tiled from ``cfg.pattern`` (e.g. gemma-3 is
5 local + 1 global per period; recurrentgemma is rec/rec/local).  Parameters
live in flat ZeRO buffers:

  embed   : (E_pad,)            token embedding (absent for stub-fed archs)
  blocks  : (n_periods, P_pad)  scanned, one period of the pattern per step
  rem     : (R_pad,)            the L % period leftover layers (if any)
  head    : (H_pad,)            final norm + unembed

Every group is applied through the ZeRO++ engine (``zero_apply``), so each
scan step performs: qwZ-gather(period params) → compute → [bwd: hpZ gather +
qgZ reduce-scatter].  Activations shard batch over ``batch_axes`` and
sequence over ``seq_axes``; decode KV caches shard their sequence dim over
``kv_axes``.  Modality frontends (audio EnCodec frames, VLM patches) are
STUBS: the input pipeline provides precomputed embeddings (and M-RoPE
position streams) directly, per the assignment.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core.partition import ParamSpec
from repro.core.zeropp import ZeroConfig, zero_apply, zero_apply_inference
from repro.models import attention as attn
from repro.models import layers as nn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.core.compat import axis_size as _axis_size

Array = jax.Array


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _attn_entries(cfg: ArchConfig, pre: str) -> List[Tuple[str, Tuple[int, ...]]]:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    e = [(pre + "ln1", (d,)),
         (pre + "wq", (d, H * hd)), (pre + "wk", (d, K * hd)),
         (pre + "wv", (d, K * hd)), (pre + "wo", (H * hd, d))]
    if cfg.qkv_bias:
        e += [(pre + "bq", (H * hd,)), (pre + "bk", (K * hd,)),
              (pre + "bv", (K * hd,))]
    if cfg.qk_norm:
        e += [(pre + "qn", (hd,)), (pre + "kn", (hd,))]
    return e


def _mlp_entries(cfg: ArchConfig, pre: str) -> List[Tuple[str, Tuple[int, ...]]]:
    d = cfg.d_model
    return [(pre + "ln2", (d,)), (pre + "wgu", (d, 2 * cfg.d_ff)),
            (pre + "wdn", (cfg.d_ff, d))]


def _moe_entries(cfg: ArchConfig, pre: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """Router + shared experts only: the routed expert weights live in their
    own chunked parameter groups (see :func:`expert_entries`) so the engine
    gathers them a chunk at a time instead of all E experts at once."""
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_ff
    e = [(pre + "ln2", (d,)), (pre + "router", (d, E))]
    if cfg.n_shared:
        e += [(pre + "sgu", (d, 2 * f * cfg.n_shared)),
              (pre + "sdn", (f * cfg.n_shared, d))]
    return e


def expert_entries(cfg: ArchConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """One expert CHUNK's parameters (E/expert_chunks experts)."""
    d, f = cfg.d_model, cfg.moe_ff
    ec = cfg.n_experts // cfg.expert_chunks
    return [("egu", (ec, d, 2 * f)), ("edn", (ec, f, d))]


def _ssd_entries(cfg: ArchConfig, pre: str) -> List[Tuple[str, Tuple[int, ...]]]:
    d, di, nh = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    gn = cfg.ssm_groups * cfg.ssm_state
    return [(pre + "ln", (d,)),
            (pre + "inp", (d, 2 * di + 2 * gn + nh)),
            (pre + "cw", (cfg.conv_width, cfg.conv_dim)),
            (pre + "alog", (nh,)), (pre + "dskip", (nh,)),
            (pre + "dtb", (nh,)),
            (pre + "onrm", (di,)), (pre + "outp", (di, d))]


def _rec_entries(cfg: ArchConfig, pre: str) -> List[Tuple[str, Tuple[int, ...]]]:
    d, dr = cfg.d_model, cfg.d_rnn
    return [(pre + "ln1", (d,)),
            (pre + "px", (d, dr)), (pre + "pg", (d, dr)),
            (pre + "cw", (cfg.conv_width, dr)),
            (pre + "wa", (dr, dr)), (pre + "ba", (dr,)),
            (pre + "wx", (dr, dr)), (pre + "bx", (dr,)),
            (pre + "loga", (dr,)),
            (pre + "po", (dr, d))] + _mlp_entries(cfg, pre)


def block_entries(cfg: ArchConfig, kind: str, pre: str):
    if kind in ("attn", "local"):
        return _attn_entries(cfg, pre) + _mlp_entries(cfg, pre)
    if kind == "moe":
        return _attn_entries(cfg, pre) + _moe_entries(cfg, pre)
    if kind == "ssd":
        return _ssd_entries(cfg, pre)
    if kind == "rec":
        return _rec_entries(cfg, pre)
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Static run-mode description (shardings + mode)."""
    mode: str = "train"                # train | prefill | decode | paged
    seq_axes: Tuple[str, ...] = ()     # activation sequence sharding
    kv_axes: Tuple[str, ...] = ()      # cache sequence sharding
    kv_len: int = 0                    # decode: global cache capacity
    attn_impl: str = "xla"             # xla | pallas (flash kernel)


def _sub(p: Dict[str, Array], pre: str) -> Dict[str, Array]:
    n = len(pre)
    return {k[n:]: v for k, v in p.items() if k.startswith(pre)}


def _attn_block(cfg, kind, p, h, rs: RunSpec, pos, cache):
    """Attention mixer (+ cache handling); returns (mix_out, new_cache)."""
    B, S, d = h.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    hn = nn.rms_norm(h, p["ln1"])
    q = hn @ p["wq"]
    k = hn @ p["wk"]
    v = hn @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = nn.rms_norm(q, p["qn"])
        k = nn.rms_norm(k, p["kn"])
    cos, sin = pos["rope"]
    q = nn.apply_rope(q, cos, sin)
    k = nn.apply_rope(k, cos, sin)

    window = cfg.window if kind == "local" else 0
    if rs.mode == "paged":
        # Paged serving: the cache is a page arena shared by every slot,
        # addressed through pos["page_table"].  One step shape covers
        # decode (T=1), speculative verify (T=gamma+1) and chunked prefill
        # (B=1, T=chunk): insert the chunk's keys, then attend causally at
        # pos["positions"].  Sliding-window layers keep the slab ring
        # buffer path — the engine gates paged mode to attn-only stacks.
        assert kind != "local", "paged serving does not support window layers"
        kc, vc = attn.paged_insert(cache["k"], cache["v"], k, v,
                                   pos["positions"], pos["page_table"],
                                   rs.kv_axes)
        o = attn.paged_attend(q, kc, vc, pos["positions"],
                              pos["page_table"], kv_seq_axes=rs.kv_axes,
                              logit_softcap=cfg.logit_softcap)
        new_cache = {"k": kc, "v": vc}
    elif rs.mode == "decode":
        cap_g = cache["k"].shape[1] * _axes_prod(rs.kv_axes)  # global capacity
        t = attn.per_seq_pos(pos["cache_pos"], B)        # (B,)
        slot = jnp.mod(t, cap_g)                         # (B,)
        kc, vc = attn.cache_insert(cache["k"], cache["v"], k, v, slot,
                                   rs.kv_axes)
        off = attn.seq_shard_offset(kc.shape[1], rs.kv_axes)
        gslot = off + jnp.arange(kc.shape[1])             # (S_loc,)
        # ring slot -> global position, per sequence: (B, S_loc)
        spos = t[:, None] - jnp.mod(t[:, None] - gslot[None, :], cap_g)
        o = attn.decode_attend(q, kc, vc, t, kv_seq_axes=rs.kv_axes,
                               window=window,
                               logit_softcap=cfg.logit_softcap,
                               slot_positions=spos)
        new_cache = {"k": kc, "v": vc}
    else:
        o = attn.mha(q, k, v, seq_axes=rs.seq_axes, causal=True,
                     window=window, logit_softcap=cfg.logit_softcap,
                     impl=rs.attn_impl)
        new_cache = _build_prefill_cache(cfg, kind, k, v, rs) \
            if rs.mode == "prefill" else cache
    o = o.reshape(B, S, H * hd) @ p["wo"]
    return o, new_cache


def _chunk_for(S: int, chunk: int) -> int:
    """Largest divisor of S that is <= chunk (SSD chunk must tile S)."""
    c = min(chunk, S)
    while S % c:
        c -= 1
    return c


def _axes_prod(axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= _axis_size(a)
    return n


def _build_prefill_cache(cfg, kind, k, v, rs: RunSpec):
    """Convert prefill K/V shards into the decode cache layout."""
    B, S_loc, K, hd = k.shape
    if kind in ("attn", "moe"):
        # full cache, same sharding as prefill activations (kv_axes==seq_axes)
        return {"k": k, "v": v}
    # local layer: ring buffer of the last `window` positions
    W = cfg.window
    kg = k
    vg = v
    for ax in rs.seq_axes:
        kg = lax.all_gather(kg, ax, axis=1, tiled=True)
        vg = lax.all_gather(vg, ax, axis=1, tiled=True)
    S = kg.shape[1]
    slots = jnp.arange(W)
    src = (S - 1) - jnp.mod((S - 1) - slots, W)   # position held by each slot
    kr = jnp.take(kg, src, axis=1)
    vr = jnp.take(vg, src, axis=1)
    # keep only this device's slot shard
    n = _axes_prod(rs.kv_axes)
    loc = W // n
    off = attn.seq_shard_offset(loc, rs.kv_axes)
    return {"k": lax.dynamic_slice_in_dim(kr, off, loc, axis=1),
            "v": lax.dynamic_slice_in_dim(vr, off, loc, axis=1)}


def _mlp_block(cfg, kind, p, h, rs: RunSpec):
    """Feed-forward half (dense); returns (out, aux)."""
    hn = nn.rms_norm(h, p["ln2"])
    return nn.swiglu(hn, p["wgu"], p["wdn"], act=cfg.act), jnp.float32(0)


def moe_pre_block(cfg, p, h, rs: RunSpec, pos, cache):
    """MoE layer up to (and excluding) the routed experts.

    Runs under ONE zero_apply gather: attention + post-attn norm + router
    logits + shared experts.  Returns everything the (separately gathered)
    expert chunks need: (h_after_attn, hn2d, router_logits, shared_y,
    new_cache).
    """
    B, S, d = h.shape
    mix, new_cache = _attn_block(cfg, "moe", p, h, rs, pos, cache)
    h = h + mix
    hn = nn.rms_norm(h, p["ln2"])
    hn2 = hn.reshape(B * S, d)
    logits = hn2 @ p["router"]
    if cfg.n_shared:
        shared_y = moe_lib.shared_ffn(hn2, p["sgu"], p["sdn"]).reshape(B, S, d)
    else:
        shared_y = jnp.zeros_like(h)
    return h, hn2, logits, shared_y, new_cache


def _ssd_block(cfg, p, h, rs: RunSpec, cache):
    B, S, d = h.shape
    di, nh, hp = cfg.d_inner, cfg.ssm_heads, cfg.ssm_headdim
    G, N, Wc = cfg.ssm_groups, cfg.ssm_state, cfg.conv_width
    gn = G * N
    hn = nn.rms_norm(h, p["ln"])
    zxbcdt = hn @ p["inp"]
    z, xBC, dt_raw = jnp.split(zxbcdt, [di, di + cfg.conv_dim], axis=-1)
    # xBC = [x (di), B (gn), C (gn)] passed through the causal conv together
    if rs.mode == "decode":
        carry = cache["conv"]
        y_c, new_conv = nn.causal_conv1d(xBC, p["cw"], carry)
    else:
        halo = ssm_lib.gather_conv_halo(xBC, Wc - 1, rs.seq_axes)
        y_c, tail = nn.causal_conv1d(xBC, p["cw"], halo)
        new_conv = tail
    xBC = jax.nn.silu(y_c)
    x, Bm, Cm = jnp.split(xBC, [di, di + gn], axis=-1)
    x = x.reshape(B, S, nh, hp)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dtb"].astype(jnp.float32))

    if rs.mode == "decode":
        y, h_new = ssm_lib.ssd_step(x[:, 0], dt[:, 0], -jnp.exp(p["alog"]),
                                    Bm[:, 0], Cm[:, 0], cache["h"])
        y = y[:, None]
        new_cache = {"h": h_new, "conv": new_conv}
    else:
        h0 = cache["h"] if (cache and "h" in cache) else None
        y, h_fin = ssm_lib.ssd_scan(x, dt, -jnp.exp(p["alog"]), Bm, Cm,
                                    chunk=_chunk_for(S, cfg.ssm_chunk), h0=h0,
                                    seq_axes=rs.seq_axes)
        new_cache = None
        if rs.mode == "prefill":
            new_cache = {"h": _last_shard_value(h_fin, rs.seq_axes),
                         "conv": _last_shard_value(new_conv, rs.seq_axes)}
    y = y + p["dskip"].astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(h.dtype)
    y = nn.rms_norm(y * jax.nn.silu(z), p["onrm"])
    return y @ p["outp"], new_cache


def select_positions(h: Array, pos: Array, seq_axes: Sequence[str]) -> Array:
    """Per-sequence select: h[b, pos[b], :] under sequence sharding.

    h: (B, S_loc, d), pos: (B,) GLOBAL positions.  Each device one-hot
    reduces its local shard (exact: the sum touches one 1.0 and zeros) and
    the owner's value is psum-combined.  Returns (B, 1, d).  Prefill uses
    this to read last-REAL-token logits from right-padded prompts
    (serve/engine.py buckets prompt lengths, so S may exceed the prompt).
    """
    B, S_loc, _ = h.shape
    off = attn.seq_shard_offset(S_loc, seq_axes)
    idx = pos - off                                   # (B,) local index
    oh = (jnp.arange(S_loc)[None, :] == idx[:, None]).astype(h.dtype)
    v = jnp.einsum("bs,bsd->bd", oh, h)[:, None, :]
    if seq_axes:
        v = lax.psum(v, tuple(seq_axes))
    return v


def _last_shard_value(x: Array, seq_axes: Sequence[str]) -> Array:
    """Replicate the LAST sequence shard's value to all shards (state handoff)."""
    if not seq_axes:
        return x
    n = _axes_prod(seq_axes)
    rank = jnp.int32(0)
    for ax in seq_axes:
        rank = rank * _axis_size(ax) + lax.axis_index(ax)
    sel = (rank == n - 1).astype(x.dtype)
    return lax.psum(x * sel, tuple(seq_axes))


def _rec_block(cfg, p, h, rs: RunSpec, cache):
    B, S, d = h.shape
    dr, Wc = cfg.d_rnn, cfg.conv_width
    hn = nn.rms_norm(h, p["ln1"])
    xb = hn @ p["px"]
    gate = hn @ p["pg"]
    if rs.mode == "decode":
        xc, new_conv = nn.causal_conv1d(xb, p["cw"], cache["conv"])
    else:
        halo = ssm_lib.gather_conv_halo(xb, Wc - 1, rs.seq_axes)
        xc, tail = nn.causal_conv1d(xb, p["cw"], halo)
        new_conv = tail
    r = jax.nn.sigmoid(xc @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(xc @ p["wx"] + p["bx"])
    if rs.mode == "decode":
        y, h_new = ssm_lib.rglru_step(xc[:, 0], r[:, 0], i[:, 0], p["loga"],
                                      cache["h"])
        y = y[:, None]
        new_cache = {"h": h_new, "conv": new_conv}
    else:
        h0 = cache["h"] if (cache and "h" in cache) else None
        y, h_fin = ssm_lib.rglru_scan(xc, r, i, p["loga"], h0=h0,
                                      seq_axes=rs.seq_axes)
        new_cache = None
        if rs.mode == "prefill":
            new_cache = {"h": _last_shard_value(h_fin, rs.seq_axes),
                         "conv": _last_shard_value(new_conv, rs.seq_axes)}
    mix = (y * jax.nn.gelu(gate, approximate=True)) @ p["po"]
    return mix, new_cache


def apply_block(cfg: ArchConfig, kind: str, p: Dict[str, Array], h: Array,
                rs: RunSpec, pos, cache):
    """One block with residuals; returns (h, new_cache, aux).

    ``moe`` blocks are driven by the Model directly (moe_pre_block +
    chunked expert gathers + moe_combine), not through this helper.
    """
    aux = jnp.float32(0)
    if kind in ("attn", "local"):
        mix, new_cache = _attn_block(cfg, kind, p, h, rs, pos, cache)
        h = h + mix
        y, aux = _mlp_block(cfg, kind, p, h, rs)
        h = h + y
    elif kind == "ssd":
        mix, new_cache = _ssd_block(cfg, p, h, rs, cache)
        h = h + mix
    elif kind == "rec":
        mix, new_cache = _rec_block(cfg, p, h, rs, cache)
        h = h + mix
        y, _ = _mlp_block(cfg, "dense", p, h, rs)
        h = h + y
    else:
        raise ValueError(kind)
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# cache construction (global shapes, outside shard_map)
# ---------------------------------------------------------------------------

def init_cache_shapes(cfg: ArchConfig, kind: str, batch: int, kv_len: int,
                      dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    K, hd = cfg.n_kv_heads, cfg.d_head
    if kind == "attn":
        s = (batch, kv_len, K, hd)
        return {"k": jax.ShapeDtypeStruct(s, dtype),
                "v": jax.ShapeDtypeStruct(s, dtype)}
    if kind == "local":
        s = (batch, min(cfg.window, kv_len), K, hd)
        return {"k": jax.ShapeDtypeStruct(s, dtype),
                "v": jax.ShapeDtypeStruct(s, dtype)}
    if kind == "ssd":
        return {"h": jax.ShapeDtypeStruct(
                    (batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim),
                    jnp.float32),
                "conv": jax.ShapeDtypeStruct(
                    (batch, cfg.conv_width - 1, cfg.conv_dim), dtype)}
    if kind == "rec":
        return {"h": jax.ShapeDtypeStruct((batch, cfg.d_rnn), jnp.float32),
                "conv": jax.ShapeDtypeStruct(
                    (batch, cfg.conv_width - 1, cfg.d_rnn), dtype)}
    if kind == "moe":
        return init_cache_shapes(cfg, "attn", batch, kv_len, dtype)
    raise ValueError(kind)
