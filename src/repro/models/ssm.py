"""State-space sequence mixers: Mamba-2 (SSD) and RG-LRU (Griffin).

Both are diagonal linear recurrences ``h_t = a_t ⊙ h_{t-1} + b_t``; under
sequence parallelism each device scans its local shard and the boundary
states are combined with an exchanged prefix (states are tiny compared to
activations, so a gather of per-shard (decay, state) pairs is ~free).

Mamba-2 uses the SSD chunked formulation (arXiv:2405.21060 §6): intra-chunk
attention-like matmuls (MXU-friendly) plus an inter-chunk state recurrence
via associative scan.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from repro.core.compat import axis_size as _axis_size

Array = jax.Array


# ---------------------------------------------------------------------------
# cross-shard prefix for diagonal linear recurrences
# ---------------------------------------------------------------------------

def shard_prefix_state(decay_total: Array, state_final: Array,
                       seq_axes: Sequence[str]) -> Array:
    """Incoming state for this device's shard.

    decay_total: (...,) product of decays over the local shard.
    state_final: (...,) local final state assuming zero incoming state.
    Returns h_in = sum_{r<me} (prod_{r<t<me} decay_t) state_r.
    """
    if not seq_axes:
        return jnp.zeros_like(state_final)
    axes = tuple(seq_axes)
    # stack both tensors along a leading shard dim, ordered by flattened rank
    d = decay_total[None]
    s = state_final[None]
    for ax in reversed(axes):
        d = lax.all_gather(d, ax, axis=0, tiled=True)
        s = lax.all_gather(s, ax, axis=0, tiled=True)
    n = d.shape[0]
    rank = jnp.int32(0)
    for ax in axes:
        rank = rank * _axis_size(ax) + lax.axis_index(ax)
    # sequential prefix over the (static, small) shard count:
    # h_in(0)=0; h_in(k) = d_{k-1}·h_in(k-1) + s_{k-1}
    h_all = [jnp.zeros_like(state_final)]
    for k in range(1, n):
        h_all.append(d[k - 1] * h_all[k - 1] + s[k - 1])
    h_stack = jnp.stack(h_all)  # (n, ...)
    return h_stack[rank]


def gather_conv_halo(x: Array, taps: int, seq_axes: Sequence[str]) -> Array:
    """History (B, taps, C) for a causal conv: previous shard's tail."""
    B, S, C = x.shape
    tail = x[:, S - taps:, :][None]  # (1, B, taps, C)
    if not seq_axes:
        return jnp.zeros((B, taps, C), x.dtype)
    t = tail
    for ax in reversed(tuple(seq_axes)):
        t = lax.all_gather(t, ax, axis=0, tiled=True)
    n = t.shape[0]
    rank = jnp.int32(0)
    for ax in seq_axes:
        rank = rank * _axis_size(ax) + lax.axis_index(ax)
    prev = jnp.where(rank > 0, jnp.clip(rank - 1, 0, n - 1), 0)
    halo = t[prev]  # (B, taps, C)
    return jnp.where(rank > 0, halo, jnp.zeros_like(halo))


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

class SSDState(NamedTuple):
    h: Array          # (B, nh, N, hp) recurrent state
    conv: Array       # (B, W-1, conv_dim) conv history


def ssd_scan(
    x: Array,        # (B, S, nh, hp)
    dt: Array,       # (B, S, nh)  (already softplus'd, >0)
    A: Array,        # (nh,)       (negative)
    Bm: Array,       # (B, S, G, N)
    Cm: Array,       # (B, S, G, N)
    *,
    chunk: int,
    h0: Optional[Array] = None,        # (B, nh, N, hp)
    seq_axes: Sequence[str] = (),
) -> Tuple[Array, Array]:
    """Chunked SSD: returns (y, final_state).

    y[t] = C_t · h_t,   h_t = exp(dt_t A) h_{t-1} + dt_t B_t ⊗ x_t
    """
    Bsz, S, nh, hp = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    hg = nh // G  # heads per B/C group

    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, chunk, nh, hp)
    dtc = dt.reshape(Bsz, nc, chunk, nh).astype(f32)
    Bc = Bm.reshape(Bsz, nc, chunk, G, N)
    Cc = Cm.reshape(Bsz, nc, chunk, G, N)

    a = dtc * A.astype(f32)[None, None, None]         # (B,nc,Q,nh), <0
    cum = jnp.cumsum(a, axis=2)                        # inclusive
    decay_last = jnp.exp(cum[:, :, -1])                # (B,nc,nh)

    # ---- intra-chunk (quadratic in chunk -> MXU-friendly) -----------------
    # CB[b,c,i,j,g] = C_i · B_j
    CB = jnp.einsum("bcign,bcjgn->bcijg", Cc.astype(f32), Bc.astype(f32))
    CBh = jnp.repeat(CB, hg, axis=-1)                  # (B,nc,Q,Q,nh)
    seg = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,i,j,nh)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = jnp.where(tri[None, None, :, :, None], CBh * seg, 0.0)
    M = M * dtc[:, :, None, :, :]                      # j-weighted by dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xc.astype(f32))

    # ---- chunk boundary states -------------------------------------------
    # S_c[h,n,p] = sum_j exp(cum_last - cum_j) dt_j B_j[n] x_j[p]
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dtc         # (B,nc,Q,nh)
    Bh = jnp.repeat(Bc, hg, axis=3)                    # (B,nc,Q,nh,N)
    S_state = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp",
                         w, Bh.astype(f32), xc.astype(f32))

    # ---- inter-chunk recurrence (associative scan over chunks) ------------
    dl = decay_last[:, :, :, None, None]               # (B,nc,nh,1,1)

    def comb(c1, c2):
        d1, s1 = c1
        d2, s2 = c2
        return d1 * d2, d2 * s1 + s2

    d_acc, s_acc = lax.associative_scan(comb, (dl, S_state), axis=1)
    # exclusive prefix: state entering chunk c (from local chunks only)
    h_in_local = jnp.concatenate(
        [jnp.zeros_like(s_acc[:, :1]), s_acc[:, :-1]], axis=1)
    d_prefix = jnp.concatenate(
        [jnp.ones_like(d_acc[:, :1]), d_acc[:, :-1]], axis=1)  # (B,nc,nh,1,1)

    # ---- cross-shard / carried-in state -----------------------------------
    decay_dev = d_acc[:, -1, :, 0, 0]                  # (B,nh) total local decay
    state_dev = s_acc[:, -1]                           # (B,nh,N,hp)
    if seq_axes:
        h0_in = shard_prefix_state(decay_dev[..., None, None], state_dev,
                                   seq_axes)
    else:
        h0_in = jnp.zeros_like(state_dev)
    if h0 is not None:
        # carried-in state decays through all shards preceding this one
        h0_in = h0_in + (_total_prefix_decay(decay_dev, seq_axes)[..., None, None]
                         * h0.astype(f32))

    h_in = h_in_local + d_prefix * h0_in[:, None]      # (B,nc,nh,N,hp)

    # ---- inter-chunk output contribution ----------------------------------
    Ch = jnp.repeat(Cc, hg, axis=3)                    # (B,nc,Q,nh,N)
    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         Ch.astype(f32) * jnp.exp(cum)[..., None], h_in)

    y = (y_intra + y_inter).reshape(Bsz, S, nh, hp)
    h_final = decay_dev[..., None, None] * h0_in + state_dev
    return y.astype(x.dtype), h_final


def _total_prefix_decay(decay_dev: Array, seq_axes: Sequence[str]) -> Array:
    """Product of decays over all shards strictly before this one."""
    if not seq_axes:
        return jnp.ones_like(decay_dev)
    d = decay_dev[None]
    for ax in reversed(tuple(seq_axes)):
        d = lax.all_gather(d, ax, axis=0, tiled=True)
    n = d.shape[0]
    rank = jnp.int32(0)
    for ax in seq_axes:
        rank = rank * _axis_size(ax) + lax.axis_index(ax)
    cum = jnp.cumprod(d, axis=0)
    prefix = jnp.concatenate([jnp.ones_like(cum[:1]), cum[:-1]], axis=0)
    return prefix[rank]


def ssd_step(
    x: Array,        # (B, nh, hp) single token
    dt: Array,       # (B, nh)
    A: Array,        # (nh,)
    Bm: Array,       # (B, G, N)
    Cm: Array,       # (B, G, N)
    h: Array,        # (B, nh, N, hp)
) -> Tuple[Array, Array]:
    """Single decode step of the SSD recurrence."""
    f32 = jnp.float32
    G = Bm.shape[1]
    hg = x.shape[1] // G
    decay = jnp.exp(dt.astype(f32) * A.astype(f32)[None])          # (B,nh)
    Bh = jnp.repeat(Bm, hg, axis=1).astype(f32)                     # (B,nh,N)
    Ch = jnp.repeat(Cm, hg, axis=1).astype(f32)
    upd = (dt.astype(f32)[..., None, None] * Bh[..., None]
           * x.astype(f32)[:, :, None, :])                          # (B,nh,N,hp)
    h_new = decay[..., None, None] * h + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h_new)
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------

def rglru_scan(
    x: Array,          # (B, S, D) post-conv activations
    r: Array,          # (B, S, D) recurrence gate in (0,1)
    i: Array,          # (B, S, D) input gate in (0,1)
    log_a: Array,      # (D,) negative log-decay parameter (=-c*softplus(Λ))
    *,
    h0: Optional[Array] = None,
    seq_axes: Sequence[str] = (),
) -> Tuple[Array, Array]:
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t ⊙ x_t),  a_t = exp(log_a · r_t)."""
    f32 = jnp.float32
    log_at = log_a.astype(f32)[None, None] * r.astype(f32)  # (B,S,D) <= 0
    a = jnp.exp(log_at)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_at), 0.0, 1.0)) \
        * (i.astype(f32) * x.astype(f32))

    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_acc, h_local = lax.associative_scan(comb, (a, b), axis=1)

    decay_dev = a_acc[:, -1]         # (B,D)
    state_dev = h_local[:, -1]       # (B,D)
    h_in = shard_prefix_state(decay_dev, state_dev, seq_axes) \
        if seq_axes else jnp.zeros_like(state_dev)
    if h0 is not None:
        h_in = h_in + _total_prefix_decay(decay_dev, seq_axes) * h0.astype(f32)
    h = h_local + a_acc * h_in[:, None]
    h_final = decay_dev * h_in + state_dev
    return h.astype(x.dtype), h_final


def rglru_step(x, r, i, log_a, h):
    """Single decode step.  x/r/i: (B, D); h: (B, D)."""
    f32 = jnp.float32
    log_at = log_a.astype(f32)[None] * r.astype(f32)
    a = jnp.exp(log_at)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_at), 0.0, 1.0)) \
        * (i.astype(f32) * x.astype(f32))
    h_new = a * h + b
    return h_new.astype(x.dtype), h_new
