"""Model driver: parameter groups, init, loss / prefill / decode.

``Model`` owns the flat ZeRO parameter groups and drives the pattern scan
over blocks through the ZeRO++ engine.  It is mode- and mesh-agnostic:
the trainer/server wraps its methods in shard_map; smoke tests call them
directly with ``ZeroConfig.local()``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig
from repro.core.partition import ParamSpec
from repro.core.schedule import (zero_apply_scan, zero_chunk_scan,
                                 zero_chunk_scan_hpz,
                                 zero_chunk_scan_inference,
                                 zero_scan_inference)
from repro.core.zeropp import (
    ZeroConfig,
    fwd_gather_quant,
    qwz_gemm_eligible,
    zero_apply,
    zero_apply_inference,
)
from repro.kernels import ops as kops
from repro.models import attention as attn_lib
from repro.models import layers as nn
from repro.models import moe as moe_lib
from repro.models.transformer import (RunSpec, apply_block, block_entries,
                                      expert_entries, init_cache_shapes,
                                      moe_pre_block, _sub)

Array = jax.Array


def _inv_softplus(y):
    return float(np.log(np.expm1(y)))


def _spec_chunk0(xs, i):
    """Speculative-gather source for the MoE layer ring: layer ``i``'s
    FIRST expert-chunk primary shard (routing-ahead dispatch — experts
    are gathered in full regardless of routing, so the gather can issue
    under earlier layers' compute).  ``xs`` is the layer scan's stacked
    inputs: the expert stack itself (train/prefill) or (experts, caches)
    (decode)."""
    eflat = xs[0] if isinstance(xs, tuple) else xs
    return lax.dynamic_index_in_dim(eflat, i, axis=0, keepdims=False)[0]


def _bwd_spec_chunk0(auxs, i):
    """Backward mirror of :func:`_spec_chunk0` for the reverse ring:
    layer ``i``'s first expert-chunk SECONDARY shard, drawn from the
    stacked per-layer residuals (the sec stacks saved by the forward).
    The outer backward scan hpZ-gathers it one iteration early so the
    nested recompute's chunk ring seeds from a ring slot instead of
    issuing its own synchronous fast-tier gather."""
    return lax.dynamic_index_in_dim(auxs, i, axis=0, keepdims=False)[0]


class Model:
    def __init__(self, cfg: ArchConfig, zcfg: ZeroConfig, world: int = 1):
        self.cfg = cfg
        self.zcfg = zcfg
        self.world = world
        period = cfg.pattern
        self.period = period
        self.n_periods = cfg.n_layers // len(period)
        self.rem = cfg.n_layers % len(period)
        align = zcfg.align(world) if zcfg.distributed else zcfg.align(1)

        self.is_moe = "moe" in period
        if self.is_moe:
            # assigned MoE archs are pure-MoE stacks; the chunked expert
            # path assumes one MoE layer per scan step
            assert period == ("moe",), "moe must be the whole pattern"
            self.expert_spec = ParamSpec(tuple(expert_entries(cfg)),
                                         align=align)
        else:
            self.expert_spec = None

        pe: List = []
        for i, kind in enumerate(period):
            pe += block_entries(cfg, kind, f"{i}.")
        self.period_spec = ParamSpec(tuple(pe), align=align)
        if self.rem:
            re_ = []
            for i, kind in enumerate(period[: self.rem]):
                re_ += block_entries(cfg, kind, f"{i}.")
            self.rem_spec = ParamSpec(tuple(re_), align=align)
        else:
            self.rem_spec = None
        if not cfg.embed_inputs:
            self.embed_spec = ParamSpec((("emb", (cfg.vocab, cfg.d_model)),),
                                        align=align)
        else:
            self.embed_spec = None
        self.head_spec = ParamSpec((("fnorm", (cfg.d_model,)),), align=align)
        # unembedding: TRANSPOSED (V, d), split into vocab-row chunks that
        # are gathered one at a time (streaming log-sum-exp across chunks)
        nv = cfg.unemb_chunks or self._auto_unemb_chunks()
        assert cfg.vocab % nv == 0, (cfg.vocab, nv)
        self.unemb_chunks = nv
        self.vchunk = cfg.vocab // nv
        self.unemb_spec = ParamSpec(
            (("unemb", (self.vchunk, cfg.d_model)),), align=align)

        self.n_moe_layers = sum(1 for k in period for _ in [0] if k == "moe") \
            * self.n_periods + sum(1 for k in period[: self.rem] if k == "moe")

    def with_prefetch(self, k: int) -> "Model":
        """A shallow copy of this model with ring depth ``k`` (layer AND
        chunk scans).  Specs are shared (immutable); only the schedule
        changes — serving uses this to deepen the decode-path ring on
        slow interconnects without rebuilding the model."""
        import copy
        m = copy.copy(self)
        m.zcfg = dataclasses.replace(self.zcfg, prefetch=k)
        return m

    def _auto_unemb_chunks(self, target_bytes: int = 512 * 2 ** 20) -> int:
        cfg = self.cfg
        total = cfg.vocab * cfg.d_model * 2  # bf16 gathered
        want = max(1, -(-total // target_bytes))
        # floor of 4 for big vocabularies: the streaming-LSE logits tile is
        # (T, V/nv) fp32, so nv also bounds the logits working set
        if cfg.vocab >= 32768:
            want = max(want, 4)
        nv = want
        while cfg.vocab % nv:
            nv += 1
        return nv

    # ------------------------------------------------------------------ init

    def param_shapes(self) -> Dict[str, Tuple[int, ...]]:
        """GLOBAL flat buffer shapes (dry-run uses these directly)."""
        out: Dict[str, Tuple[int, ...]] = {}
        if self.embed_spec:
            out["embed"] = (self.embed_spec.padded_size,)
        out["blocks"] = (self.n_periods, self.period_spec.padded_size)
        if self.is_moe:
            out["experts"] = (self.n_periods, self.cfg.expert_chunks,
                              self.expert_spec.padded_size)
        if self.rem_spec:
            out["rem"] = (self.rem_spec.padded_size,)
        out["head"] = (self.head_spec.padded_size,)
        out["unemb"] = (self.unemb_chunks, self.unemb_spec.padded_size)
        return out

    def n_params(self) -> int:
        n = self.period_spec.size * self.n_periods + self.head_spec.size
        n += self.unemb_spec.size * self.unemb_chunks
        if self.is_moe:
            n += self.expert_spec.size * self.cfg.expert_chunks \
                * self.n_periods
        if self.rem_spec:
            n += self.rem_spec.size
        if self.embed_spec:
            n += self.embed_spec.size
        return n

    def comm_events(self, accum: int = 1) -> list:
        """Enumerate every ZeRO engine collective one training step issues.

        Returns ``[{"kind", "elems", "count", "site"}, ...]`` where kind is
        fwd_gather / bwd_gather / grad_reduce, elems the GLOBAL flat buffer
        length, and count how many times that collective runs per step.
        ``zeropp.step_wire_by_label`` folds this into per-label wire bytes —
        the analytic projection that the runtime jaxpr-measured counters
        are gated against (obs/report.py), so the counting here mirrors
        core/schedule.py exactly:

          * a depth-k layer/chunk ring issues n + k gathers per phase
            (k ring-seed + n body prefetches) and n + k reduces (the first
            k are the ring's dummy zero-reduces — still real wire);
          * a W0-seeded chunk ring (speculative chunk-0 buffer) skips one
            seed gather;
          * the synchronous path (effective prefetch 0) issues exactly n;
          * with hpZ, backward re-gathers ride the fast tier, EXCEPT the
            MoE prefetch-0 nested recompute, whose per-chunk zero_apply
            re-runs the qwZ forward gather before its hpZ backward one.
        """
        z = self.zcfg
        ev: list = []
        if not z.distributed:
            return ev

        def add(kind, elems, count, site):
            if count > 0:
                ev.append({"kind": kind, "elems": int(elems),
                           "count": float(count) * accum, "site": site})

        # single zero_apply sites: 1 gather / 1 bwd gather / 1 reduce each
        sites = []
        if self.embed_spec:
            sites.append(("embed", self.embed_spec.padded_size, 1))
        if self.rem_spec:
            sites.append(("rem", self.rem_spec.padded_size, 1))
        sites.append(("head", self.head_spec.padded_size, 1))
        sites.append(("unemb", self.unemb_spec.padded_size,
                      self.unemb_chunks))
        for site, e, c in sites:
            add("fwd_gather", e, c, site)
            add("bwd_gather", e, c, site)
            add("grad_reduce", e, c, site)

        n = self.n_periods
        k = z.effective_prefetch(n)
        P = self.period_spec.padded_size
        add("fwd_gather", P, n + k, "blocks.fwd")
        add("bwd_gather", P, n + k, "blocks.bwd")
        add("grad_reduce", P, n + k, "blocks.reduce")

        if not self.is_moe:
            return ev

        nc = self.cfg.expert_chunks
        kc = z.effective_prefetch(nc)
        E = self.expert_spec.padded_size
        hpz_remat = z.hpz and z.distributed
        spec_on = k >= 1 and kc >= 1  # routing-ahead chunk-0 ring active

        if spec_on:
            add("fwd_gather", E, n + k, "blocks.spec")
        # chunk pipeline, forward: W0 seed skip when the spec ring feeds it
        add("fwd_gather", E, n * (nc + kc - (1 if spec_on else 0)),
            "experts.fwd")

        if k >= 1:
            if hpz_remat:
                # nested hpZ recompute (zero_chunk_scan_hpz): its own fwd
                # replay + its bwd ring, all on the fast tier
                if spec_on:
                    add("bwd_gather", E, n + k, "blocks.bwd_spec")
                add("bwd_gather", E,
                    n * (nc + kc - (1 if spec_on else 0)),
                    "experts.bwd_recompute")
                add("bwd_gather", E, n * (nc + kc), "experts.bwd")
            else:
                # recompute differentiates plain zero_chunk_scan: a fresh
                # forward pass (qwZ tier) plus its backward ring
                add("fwd_gather", E, n * (nc + kc), "experts.bwd_recompute")
                add("bwd_gather", E, n * (nc + kc), "experts.bwd")
            add("grad_reduce", E, n * (nc + kc), "experts.reduce")
        else:
            # prefetch-0: per-layer zero_apply recompute runs each chunk's
            # own zero_apply — qwZ fwd re-gather THEN hpZ/bwd gather
            add("fwd_gather", E, n * nc, "experts.bwd_recompute")
            add("bwd_gather", E, n * nc, "experts.bwd")
            add("grad_reduce", E, n * nc, "experts.reduce")
        return ev

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: shared + top_k experts)."""
        cfg = self.cfg
        if not cfg.n_experts:
            return self.n_params()
        per_expert = 3 * cfg.d_model * cfg.moe_ff
        inactive = (cfg.n_experts - cfg.top_k) * per_expert
        return self.n_params() - inactive * cfg.n_layers

    def _init_fn(self, name: str):
        cfg = self.cfg
        base = name.split(".")[-1]
        rng_scaled = lambda std: (lambda k, s: jax.random.normal(k, s) * std)
        if base == "emb":
            return rng_scaled(0.02)
        if base == "unemb":  # stored (V_chunk, d): scale by 1/sqrt(d)
            return lambda k, s: jax.random.normal(k, s) / np.sqrt(s[-1])
        if base in ("wq", "wk", "wv", "wgu", "router", "px", "pg", "wa",
                    "wx", "inp"):
            return lambda k, s: jax.random.normal(k, s) / np.sqrt(s[0])
        if base in ("wo", "wdn", "po", "outp", "sdn"):
            return lambda k, s: jax.random.normal(k, s) / np.sqrt(s[0])
        if base in ("egu", "sgu"):
            return lambda k, s: jax.random.normal(k, s) / np.sqrt(s[-2])
        if base == "edn":
            return lambda k, s: jax.random.normal(k, s) / np.sqrt(s[-2])
        if base == "cw":
            return lambda k, s: jax.random.normal(k, s) / np.sqrt(s[0])
        if base == "alog":
            return lambda k, s: jnp.log(jax.random.uniform(k, s, minval=1.0,
                                                           maxval=16.0))
        if base == "dskip":
            return lambda k, s: jnp.ones(s)
        if base == "dtb":
            lo, hi = _inv_softplus(1e-3), _inv_softplus(0.1)
            return lambda k, s: jax.random.uniform(k, s, minval=lo, maxval=hi)
        if base == "loga":
            return lambda k, s: jax.random.uniform(k, s, minval=-0.8,
                                                   maxval=-0.01)
        return None  # zeros: norms, biases

    def init_params(self, key: Array, dtype=None) -> Dict[str, Array]:
        """GLOBAL flat buffers (small models / examples; dry-run never calls)."""
        dtype = dtype or self.zcfg.param_dtype
        out: Dict[str, Array] = {}
        ks = jax.random.split(key, 4 + 2 * self.n_periods)
        if self.embed_spec:
            fns = {n: self._init_fn(n) for n, _ in self.embed_spec.entries}
            out["embed"] = self.embed_spec.init(ks[0], fns, jnp.float32).astype(dtype)
        bufs = []
        fns = {n: self._init_fn(n) for n, _ in self.period_spec.entries}
        for g in range(self.n_periods):
            bufs.append(self.period_spec.init(ks[2 + g], fns, jnp.float32))
        out["blocks"] = jnp.stack(bufs).astype(dtype)
        if self.is_moe:
            efns = {n: self._init_fn(n) for n, _ in self.expert_spec.entries}
            ebufs = []
            for g in range(self.n_periods):
                kc = jax.random.split(ks[2 + self.n_periods + g],
                                      self.cfg.expert_chunks)
                ebufs.append(jnp.stack([
                    self.expert_spec.init(kc[c], efns, jnp.float32)
                    for c in range(self.cfg.expert_chunks)]))
            out["experts"] = jnp.stack(ebufs).astype(dtype)
        if self.rem_spec:
            fns = {n: self._init_fn(n) for n, _ in self.rem_spec.entries}
            out["rem"] = self.rem_spec.init(ks[1], fns, jnp.float32).astype(dtype)
        fns = {n: self._init_fn(n) for n, _ in self.head_spec.entries}
        out["head"] = self.head_spec.init(ks[-1], fns, jnp.float32).astype(dtype)
        ufns = {n: self._init_fn(n) for n, _ in self.unemb_spec.entries}
        kv = jax.random.split(ks[-2], self.unemb_chunks)
        out["unemb"] = jnp.stack([
            self.unemb_spec.init(kv[c], ufns, jnp.float32)
            for c in range(self.unemb_chunks)]).astype(dtype)
        return out

    # ------------------------------------------------------------- positions

    def _rope_tables(self, batch: Dict[str, Array], rs: RunSpec,
                     s_local: int, cache_pos: Optional[Array] = None):
        cfg = self.cfg
        if cfg.mrope:
            pos = batch["positions"]  # (3, B, S_loc) from the frontend stub
            cos, sin = nn.mrope_tables(pos, cfg.d_head, cfg.rope_theta)
        else:
            if rs.mode == "decode":
                # per-sequence positions: (B,) -> (B, 1) rope tables
                p = cache_pos[:, None]
            else:
                p = attn_lib.seq_shard_offset(s_local, rs.seq_axes) \
                    + jnp.arange(s_local)
            cos, sin = nn.rope_table(p, cfg.d_head, cfg.rope_theta)
        return lax.stop_gradient(cos), lax.stop_gradient(sin)

    # ----------------------------------------------------------- moe layer

    def _moe_layer(self, rs: RunSpec, train: bool, W, eflat, h, cos, sin,
                   cache_pos, cache, W_spec=None, sec=None,
                   collect_sec: bool = False):
        """One MoE layer given the layer's already-gathered shared weights.

        The LAYER-level engine (zero_apply_scan for training,
        zero_scan_inference for serving) owns the shared-param gather: with
        ``prefetch=k>=1`` layer i+k's qwZ gather is in flight under this
        layer's routing/expert compute, and in backward the hpZ gathers /
        qgZ reduces of the shared params ride the mirrored reverse ring
        exactly like a dense block.  Inside the layer:

          pre     (gathered): attn + ln2 + router logits + shared experts
          dispatch (pure):    sort-based token->slot routing, indices only
          chunks  (nc-deep zero_chunk_scan): each chunk rebuilds its slot
                              buffer from the token activations and runs
                              the grouped GEMMs; chunk c+k's expert-weight
                              gather is issued under chunk c's expert_ffn
                              (prefetch=0: synchronous per-chunk gathers)
          combine (pure):     gated scatter back to tokens

        Three engine-owned hooks (see core/schedule.py, DESIGN.md §3):

          * ``W_spec`` — layer chunk 0's expert weights, pre-gathered by
            the outer ring under the PREVIOUS layers' compute (routing-
            ahead dispatch: experts are gathered in full regardless of
            routing, so the gather need not wait for the router).  Chunk 0
            seeds the chunk ring from it; without it, dispatch gates the
            first gather.  Every expert-weight byte after chunk 0 is
            ring-buffered either way.
          * ``collect_sec`` — also return the stack of per-chunk secondary
            (hpZ) shards, to be threaded through the outer scan's
            residuals.
          * ``sec`` — a saved secondary stack: the chunk pipeline replays
            from it on the hpZ fast tier (zero_chunk_scan_hpz) instead of
            re-gathering on qwZ — the nested-recompute path.

        Keeping only (h, hn2, indices) as inter-gather values bounds the
        per-layer activation residual to O(T·d), not O(T·k·capacity·d).
        Returns (h_out, new_cache, aux_loss, sec_stack-or-None).
        """
        cfg, z = self.cfg, self.zcfg
        B, S = h.shape[0], h.shape[1]
        d = cfg.d_model
        nc = cfg.expert_chunks
        Ec = cfg.n_experts // nc

        p = _sub(self.period_spec.unpack(W.astype(z.compute_dtype)), "0.")
        posd = {"rope": (cos, sin), "cache_pos": cache_pos}
        h2, hn2, logits, shared_y, new_cache = moe_pre_block(
            cfg, p, h, rs, posd, cache)

        capacity = None
        if rs.mode != "train":  # serving must be drop-free (decode==prefill)
            capacity = moe_lib.serve_capacity(
                hn2.shape[0], cfg.top_k, cfg.n_experts)
        disp = moe_lib.moe_dispatch(
            hn2, logits, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, capacity=capacity)
        chunk_slots = Ec * disp.cap

        def chunk_f(Wc, c, hn2, dest, src_tok, g_sorted):
            pc = self.expert_spec.unpack(Wc.astype(z.compute_dtype))
            buf = moe_lib.build_chunk_buf(hn2, dest, src_tok,
                                          c * chunk_slots, chunk_slots)
            out = moe_lib.expert_ffn(buf.reshape(Ec, disp.cap, d),
                                     pc["egu"], pc["edn"])
            # gate multiply INSIDE the chunk: router grads come from the
            # chunk's own recompute, and the outer combine stays index-only
            g = moe_lib.build_chunk_gates(g_sorted, dest, c * chunk_slots,
                                          chunk_slots)
            return out * g.reshape(Ec, disp.cap, 1).astype(out.dtype)

        cidx = jnp.arange(nc, dtype=jnp.int32)
        sec_out = None
        if not train:
            outs = zero_chunk_scan_inference(chunk_f, z)(
                eflat, cidx, hn2, disp.dest, disp.src_tok, disp.g_sorted,
                W0=W_spec)
        elif sec is not None:
            # nested recompute: replay the chunk pipeline from the saved
            # secondary shards — every gather on the hpZ fast tier.
            # W_spec here is the outer bwd_spec ring's pre-gathered
            # chunk-0 buffer (None on the unprefetched path).
            outs = zero_chunk_scan_hpz(chunk_f, z)(
                eflat, sec, cidx, hn2, disp.dest, disp.src_tok,
                disp.g_sorted, W0=W_spec)
        elif collect_sec:
            outs, sec_out = zero_chunk_scan(chunk_f, z,
                                            collect_secondary=True)(
                eflat, cidx, hn2, disp.dest, disp.src_tok, disp.g_sorted,
                W0=W_spec)
        else:
            outs = zero_chunk_scan(chunk_f, z)(
                eflat, cidx, hn2, disp.dest, disp.src_tok, disp.g_sorted,
                W0=W_spec)
        y = moe_lib.moe_combine(outs.reshape(cfg.n_experts, disp.cap, d),
                                disp)
        h3 = h2 + shared_y + y.reshape(B, S, d).astype(h2.dtype)
        return h3, new_cache, disp.aux_loss, sec_out

    def _moe_inference_scan(self, moe_f):
        """Layer scan for the serving MoE stack: routing-ahead speculative
        chunk-0 gather when the chunk ring can be seeded from it (nc >= 2,
        prefetched), plain scan otherwise.  ``moe_f(W, W_spec, h, x,
        *bargs)`` always takes the speculative buffer (None when off)."""
        z = self.zcfg
        if z.effective_prefetch(self.cfg.expert_chunks) >= 1:
            return zero_scan_inference(moe_f, z, spec=_spec_chunk0)
        return zero_scan_inference(
            lambda W, h, x, *b: moe_f(W, None, h, x, *b), z)

    # ------------------------------------------------------------------ train

    def loss_fn(self, params: Dict[str, Array], batch: Dict[str, Array],
                rs: RunSpec, dp_world: int) -> Tuple[Array, Dict[str, Array]]:
        """Local loss (sum-NLL / global token count).  psum-able."""
        cfg, z = self.cfg, self.zcfg
        if cfg.embed_inputs:
            h = batch["embeds"].astype(z.compute_dtype)
        else:
            toks = batch["tokens"]
            emb_f = lambda W, t: self.embed_spec.unpack(W)["emb"][t] \
                .astype(z.compute_dtype)
            h = zero_apply(emb_f, z)(params["embed"], toks)
        B, S_loc = h.shape[0], h.shape[1]
        cos, sin = self._rope_tables(batch, rs, S_loc)
        global_tokens = float(B * S_loc * dp_world)

        def period_fn(W, h, cos, sin, spec=self.period_spec, kinds=self.period):
            p = spec.unpack(W.astype(z.compute_dtype))
            aux = jnp.float32(0)
            for i, kind in enumerate(kinds):
                h, _, a = apply_block(cfg, kind, _sub(p, f"{i}."), h, rs,
                                      {"rope": (cos, sin)}, None)
                aux = aux + a
            return h, aux

        if self.is_moe:
            # the same ring-prefetched layer scan as the dense stack:
            # layer i+k's SHARED-param gather rides under layer i's
            # routing + expert compute, and the expert-chunk stack flows
            # through xs into each layer's own zero_chunk_scan pipeline.
            # Two ring-only knobs (core/schedule.py): spec pre-gathers
            # layer i+k's chunk-0 expert weights (routing-ahead
            # dispatch), and with hpZ the chunk secondary shards thread
            # through the outer residuals so the nested remat replays
            # chunk gathers on the fast tier (f_fwd/f_bwd).
            hpz_remat = z.hpz and z.distributed
            # the speculative gather only pays when the chunk ring can be
            # seeded from it (nc >= 2, prefetched); with a single chunk
            # the sync chunk path would re-gather and the speculation
            # would be pure wasted wire bytes
            spec = _spec_chunk0 \
                if z.effective_prefetch(cfg.expert_chunks) >= 1 else None

            def moe_f(W, h, eflat, cos, sin):
                h2, _, aux, _ = self._moe_layer(rs, True, W, eflat, h,
                                                cos, sin, None, None)
                return h2, aux

            def moe_f_fwd(W, W_spec, h, eflat, cos, sin):
                h2, _, aux, sec = self._moe_layer(
                    rs, True, W, eflat, h, cos, sin, None, None,
                    W_spec=W_spec, collect_sec=hpz_remat)
                return h2, aux, sec

            def moe_f_bwd(W, h, eflat, sec, cos, sin, W0=None):
                h2, _, aux, _ = self._moe_layer(
                    rs, True, W, eflat, h, cos, sin, None, None, sec=sec,
                    W_spec=W0)
                return h2, aux

            ap = zero_apply_scan(
                moe_f, z, f_fwd=moe_f_fwd,
                f_bwd=moe_f_bwd if hpz_remat else None,
                spec=spec,
                bwd_spec=_bwd_spec_chunk0
                if (hpz_remat and spec is not None) else None)
            h, auxs = ap(params["blocks"], h, params["experts"], cos, sin)
        else:
            # prefetched (z.prefetch>=1) or synchronous (0) block scan —
            # see core/schedule.py
            ap = zero_apply_scan(
                lambda W, h, x, cos, sin: period_fn(W, h, cos, sin), z)
            h, auxs = ap(params["blocks"], h, None, cos, sin)
        aux = jnp.sum(auxs)
        if self.rem_spec:
            ap_rem = zero_apply(
                partial(period_fn, spec=self.rem_spec,
                        kinds=self.period[: self.rem]), z)
            h, aux_r = ap_rem(params["rem"], h, cos, sin)
            aux = aux + aux_r

        def norm_fn(W, h):
            p = self.head_spec.unpack(W.astype(z.compute_dtype))
            return nn.rms_norm(h, p["fnorm"])

        hn = zero_apply(norm_fn, z)(params["head"], h)
        nll_sum = self._streaming_xent(
            lambda f: zero_apply(f, z), params["unemb"],
            hn.reshape(-1, cfg.d_model), batch["targets"].reshape(-1))
        loss = nll_sum / global_tokens
        metrics = {"nll_sum": nll_sum, "tokens": jnp.float32(B * S_loc)}
        if self.n_moe_layers:
            aux_mean = aux / (self.n_moe_layers * dp_world)
            loss = loss + cfg.aux_loss_weight * aux_mean
            metrics["moe_aux"] = aux
        return loss, metrics

    # -------------------------------------------------------------- head

    def _streaming_xent(self, zw, unemb, hn2, targets) -> Array:
        """Sum-NLL with the (V, d) unembedding gathered one vocab chunk at
        a time; log-sum-exp streams across chunks (flash-style, exact).

        Full (T, V) logits never exist: each chunk's zero_apply computes
        (per-token max, rel-sum-exp, gold-logit contribution) — (T,)-sized
        outputs — and the scan combines them with the running-max rule.
        """
        z = self.zcfg
        Vc = self.vchunk
        T = hn2.shape[0]

        def chunk_f(Wc, hn2, targets, c):
            p = self.unemb_spec.unpack(Wc.astype(z.compute_dtype))
            logits = jnp.einsum("td,vd->tv", hn2, p["unemb"],
                                preferred_element_type=jnp.float32)
            m_c = jnp.max(logits, axis=1)
            s_c = jnp.sum(jnp.exp(logits - m_c[:, None]), axis=1)
            idx = targets - c * Vc
            in_r = (idx >= 0) & (idx < Vc)
            g = jnp.take_along_axis(
                logits, jnp.clip(idx, 0, Vc - 1)[:, None], axis=1)[:, 0]
            return m_c, s_c, jnp.where(in_r, g, 0.0)

        ap = zw(chunk_f)

        def body(carry, xs):
            m, l, gold = carry
            Wc, c = xs
            m_c, s_c, g_c = ap(Wc, hn2, targets, c)
            m_new = jnp.maximum(m, m_c)
            l = l * jnp.exp(m - m_new) + s_c * jnp.exp(m_c - m_new)
            return (m_new, l, gold + g_c), ()

        init = (jnp.full((T,), -1e30, jnp.float32),
                jnp.zeros((T,), jnp.float32), jnp.zeros((T,), jnp.float32))
        (m, l, gold), _ = lax.scan(
            body, init, (unemb, jnp.arange(self.unemb_chunks,
                                           dtype=jnp.int32)))
        return jnp.sum(m + jnp.log(l) - gold)

    def _head_logits(self, zi, params, h_last) -> Array:
        """Serving head: (B, S, V) logits assembled from vocab chunks."""
        z = self.zcfg
        cfg = self.cfg

        def norm_fn(W, hl):
            p = self.head_spec.unpack(W.astype(z.compute_dtype))
            return nn.rms_norm(hl, p["fnorm"])

        hn = zi(norm_fn)(params["head"], h_last)

        Vc, d = self.vchunk, cfg.d_model
        if qwz_gemm_eligible(z, Vc, d):
            # fused head: gather the qwZ payload WITHOUT dequantizing and
            # let the dequant-GEMM kernel apply the scales in its k-tile
            # loop — the bf16 (Vc, d) chunk never materializes.  The unemb
            # entry sits at flat offset 0 of its spec, so payload rows are
            # a plain reshape; the two eligible scale layouts are per-row
            # groups (d % block == 0) or one-block-covers-whole-rows
            # (block % d == 0).
            blk = z.qwz_block

            def ap(Wc, hn):
                pq, sq = fwd_gather_quant(Wc, z)
                pr = pq[: Vc * d].reshape(Vc, d)
                if d % blk == 0:
                    sr = sq[: Vc * (d // blk)].reshape(Vc, d // blk)
                else:
                    sr = jnp.repeat(sq[: Vc // (blk // d)], blk // d)[:, None]
                out2 = kops.dequant_matmul(
                    hn.reshape(-1, d), pr, sr,
                    compute_dtype=z.compute_dtype)
                return out2.reshape(hn.shape[0], hn.shape[1], Vc)
        else:
            def chunk_f(Wc, hn):
                p = self.unemb_spec.unpack(Wc.astype(z.compute_dtype))
                return jnp.einsum("bsd,vd->bsv", hn, p["unemb"],
                                  preferred_element_type=jnp.float32)

            ap = zi(chunk_f)

        def body(carry, Wc):
            return carry, ap(Wc, hn)

        _, chunks = lax.scan(body, (), params["unemb"])  # (nv, B, S, Vc)
        B, S = hn.shape[0], hn.shape[1]
        return jnp.moveaxis(chunks, 0, 2).reshape(B, S, cfg.vocab)

    # ------------------------------------------------------------ prefill

    def prefill_fn(self, params, batch, rs: RunSpec,
                   last_pos: Optional[Array] = None) -> Tuple[Array, Any]:
        """Forward over a prompt; returns (last-token logits, caches).

        ``last_pos`` (B,) selects per-sequence logits positions — the last
        REAL token of each (possibly right-padded) prompt.  Default: the
        final sequence position, the unpadded behaviour.
        """
        cfg, z = self.cfg, self.zcfg
        zi = lambda f: zero_apply_inference(f, z)
        if cfg.embed_inputs:
            h = batch["embeds"].astype(z.compute_dtype)
        else:
            h = zi(lambda W, t: self.embed_spec.unpack(W)["emb"][t]
                   .astype(z.compute_dtype))(params["embed"], batch["tokens"])
        B, S_loc = h.shape[0], h.shape[1]
        pos = {"rope": self._rope_tables(batch, rs, S_loc)}

        def period_fn(W, h, kinds=self.period, spec=self.period_spec):
            p = spec.unpack(W.astype(z.compute_dtype))
            caches = []
            for i, kind in enumerate(kinds):
                h, c, _ = apply_block(cfg, kind, _sub(p, f"{i}."), h, rs,
                                      pos, None)
                caches.append(c)
            return h, tuple(caches)

        if self.is_moe:
            cos, sin = pos["rope"]

            def moe_f(W, W_spec, h, eflat, cos, sin):
                h2, c, _, _ = self._moe_layer(rs, False, W, eflat, h,
                                              cos, sin, None, None,
                                              W_spec=W_spec)
                return h2, (c,)

            ap = self._moe_inference_scan(moe_f)
            h, caches = ap(params["blocks"], h, params["experts"], cos, sin)
        else:
            ap = zero_scan_inference(
                lambda W, h, x: period_fn(W, h), z)
            h, caches = ap(params["blocks"], h, None)
        rem_caches = None
        if self.rem_spec:
            h, rem_caches = zi(partial(period_fn, kinds=self.period[:self.rem],
                                       spec=self.rem_spec))(params["rem"], h)

        from repro.models.transformer import _last_shard_value, \
            select_positions
        if last_pos is None:
            h_last = _last_shard_value(h[:, -1:, :], rs.seq_axes)
        else:
            h_last = select_positions(h, last_pos, rs.seq_axes)

        logits = self._head_logits(zi, params, h_last)
        return logits, {"blocks": caches, "rem": rem_caches}

    # ------------------------------------------------------------- decode

    def decode_fn(self, params, caches, batch, cache_pos: Array,
                  rs: RunSpec) -> Tuple[Array, Any]:
        """One decode step.  batch: tokens (B,1) or embeds (B,1,d).

        ``cache_pos`` is PER-SEQUENCE — a (B,) int32 vector (a scalar is
        broadcast): every batch row may sit at a different position, which
        is what lets the continuous-batching engine decode requests
        admitted at different steps in one batch.
        """
        cfg, z = self.cfg, self.zcfg
        zi = lambda f: zero_apply_inference(f, z)
        if cfg.embed_inputs:
            h = batch["embeds"].astype(z.compute_dtype)
        else:
            h = zi(lambda W, t: self.embed_spec.unpack(W)["emb"][t]
                   .astype(z.compute_dtype))(params["embed"], batch["tokens"])
        cache_pos = attn_lib.per_seq_pos(cache_pos, h.shape[0])
        pos = {"rope": self._rope_tables(batch, rs, 1, cache_pos=cache_pos),
               "cache_pos": cache_pos}

        def period_fn(W, h, cache, kinds=self.period, spec=self.period_spec):
            p = spec.unpack(W.astype(z.compute_dtype))
            new = []
            for i, kind in enumerate(kinds):
                h, c, _ = apply_block(cfg, kind, _sub(p, f"{i}."), h, rs,
                                      pos, cache[i])
                new.append(c)
            return h, tuple(new)

        if self.is_moe:
            cos, sin = pos["rope"]

            def moe_f(W, W_spec, h, x, cos, sin, cache_pos):
                eflat, cache = x
                h2, c, _, _ = self._moe_layer(rs, False, W, eflat, h,
                                              cos, sin, cache_pos,
                                              cache[0], W_spec=W_spec)
                return h2, (c,)

            ap = self._moe_inference_scan(moe_f)
            h, new_caches = ap(
                params["blocks"], h,
                (params["experts"], caches["blocks"]), cos, sin,
                pos["cache_pos"])
        else:
            ap = zero_scan_inference(
                lambda W, h, cache: period_fn(W, h, cache), z)
            h, new_caches = ap(params["blocks"], h, caches["blocks"])
        new_rem = None
        if self.rem_spec:
            h, new_rem = zi(partial(period_fn, kinds=self.period[:self.rem],
                                    spec=self.rem_spec))(
                params["rem"], h, caches["rem"])

        logits = self._head_logits(zi, params, h)
        return logits, {"blocks": new_caches, "rem": new_rem}

    # -------------------------------------------------------------- paged

    def paged_fn(self, params, caches, batch, page_table: Array,
                 start_pos: Array, rs: RunSpec) -> Tuple[Array, Any]:
        """One paged-serving step: (B, T) tokens against a page arena.

        ``caches`` hold a PAGE ARENA — (n_pages, page_size, K, hd) per
        layer, shared by every slot — instead of per-slot slabs;
        ``page_table`` (B, Pm) maps each row's logical pages to physical
        ones (-1 = unmapped: the row writes nothing and attends to
        nothing).  One step shape serves all three paged workloads:
        T=1 batched decode, T=gamma+1 speculative verify, and B=1
        T=chunk chunked prefill.  Row r's token j sits at position
        ``start_pos[r] + j``; logits come back for every position,
        (B, T, V).  Paged mode is attn-only (no window/ssd/rec/moe).
        """
        cfg, z = self.cfg, self.zcfg
        assert not self.is_moe and set(self.period) == {"attn"}, \
            "paged serving supports dense attn-only stacks"
        assert not cfg.mrope, "paged serving does not support mrope"
        zi = lambda f: zero_apply_inference(f, z)
        h = zi(lambda W, t: self.embed_spec.unpack(W)["emb"][t]
               .astype(z.compute_dtype))(params["embed"], batch["tokens"])
        B, T = h.shape[0], h.shape[1]
        start_pos = attn_lib.per_seq_pos(start_pos, B)
        tpos = start_pos[:, None] + jnp.arange(T, dtype=jnp.int32)  # (B, T)
        cos, sin = nn.rope_table(tpos, cfg.d_head, cfg.rope_theta)
        pos = {"rope": (lax.stop_gradient(cos), lax.stop_gradient(sin)),
               "cache_pos": start_pos, "positions": tpos,
               "page_table": page_table}

        def period_fn(W, h, cache, kinds=self.period, spec=self.period_spec):
            p = spec.unpack(W.astype(z.compute_dtype))
            new = []
            for i, kind in enumerate(kinds):
                h, c, _ = apply_block(cfg, kind, _sub(p, f"{i}."), h, rs,
                                      pos, cache[i])
                new.append(c)
            return h, tuple(new)

        ap = zero_scan_inference(
            lambda W, h, cache: period_fn(W, h, cache), z)
        h, new_caches = ap(params["blocks"], h, caches["blocks"])
        new_rem = None
        if self.rem_spec:
            h, new_rem = zi(partial(period_fn, kinds=self.period[:self.rem],
                                    spec=self.rem_spec))(
                params["rem"], h, caches["rem"])

        logits = self._head_logits(zi, params, h)
        return logits, {"blocks": new_caches, "rem": new_rem}

    def paged_cache_shapes(self, n_pages: int, page_size: int,
                           dtype=jnp.bfloat16):
        """GLOBAL page-arena shapes matching paged_fn's cache layout.

        Same per-layer layout as :meth:`cache_shapes` with (batch, kv_len)
        reinterpreted as (n_pages, page_size): the arena's page dim is
        unsharded, the within-page token dim shards over kv_axes.
        """
        assert set(self.period) == {"attn"}, "paged caches are attn-only"
        return self.cache_shapes(n_pages, page_size, dtype)

    def init_paged_caches(self, n_pages: int, page_size: int,
                          dtype=jnp.bfloat16):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.paged_cache_shapes(n_pages, page_size, dtype))

    # ------------------------------------------------------------- caches

    def cache_shapes(self, batch: int, kv_len: int, dtype=jnp.bfloat16):
        """GLOBAL cache shapes pytree matching decode_fn's layout."""
        per = [init_cache_shapes(self.cfg, k, batch, kv_len, dtype)
               for k in self.period]
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((self.n_periods,) + s.shape,
                                           s.dtype), tuple(per))
        rem = None
        if self.rem_spec:
            rem = tuple(init_cache_shapes(self.cfg, k, batch, kv_len, dtype)
                        for k in self.period[: self.rem])
        return {"blocks": stacked, "rem": rem}

    def init_caches(self, batch: int, kv_len: int, dtype=jnp.bfloat16):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_shapes(batch, kv_len, dtype))


def _xent_chunked(h2d: Array, unemb: Array, targets: Array,
                  chunk: int = 1024) -> Array:
    """Sum-NLL with logits materialized one token-chunk at a time (fp32 LSE),
    rematerialized in backward — keeps the (T, V) logits out of memory."""
    T, d = h2d.shape
    if T <= chunk:
        nll, _ = nn.softmax_xent((h2d @ unemb)[None], targets[None])
        return nll
    n = T // chunk
    rem = T - n * chunk

    @jax.checkpoint
    def chunk_nll(hc, tc):
        nll, _ = nn.softmax_xent((hc @ unemb)[None], tc[None])
        return nll

    def body(acc, xs):
        hc, tc = xs
        return acc + chunk_nll(hc, tc), ()

    acc, _ = lax.scan(body, jnp.float32(0),
                      (h2d[: n * chunk].reshape(n, chunk, d),
                       targets[: n * chunk].reshape(n, chunk)))
    if rem:
        acc = acc + chunk_nll(h2d[n * chunk:], targets[n * chunk:])
    return acc
