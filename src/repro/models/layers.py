"""Shared neural building blocks (pure functions on explicit weights)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def swiglu(x: Array, w_gate_up: Array, w_down: Array,
           act: str = "silu") -> Array:
    """Gated MLP.  ``w_gate_up``: (d, 2*ff) fused gate|up; ``w_down``: (ff, d)."""
    gu = x @ w_gate_up
    g, u = jnp.split(gu, 2, axis=-1)
    if act == "silu":
        a = jax.nn.silu(g)
    elif act == "gelu":
        a = jax.nn.gelu(g, approximate=True)
    else:
        raise ValueError(act)
    return (a * u) @ w_down


def rope_table(positions: Array, head_dim: int, theta: float) -> Tuple[Array, Array]:
    """(cos, sin) tables for rotary embedding.  positions: (..., S) int32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """Rotate pairs (x1, x2) -> (x1 cos - x2 sin, x1 sin + x2 cos).

    x: (B, S, H, hd); cos/sin: (B, S, half) or (S, half).
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mrope_tables(positions_thw: Array, head_dim: int, theta: float,
                 sections: Tuple[float, float, float] = (0.25, 0.375, 0.375)
                 ) -> Tuple[Array, Array]:
    """M-RoPE (Qwen2-VL §3.1): the rotary half-dim is split into three
    sections driven by temporal / height / width position streams.

    positions_thw: (3, B, S) int32.  Returns (cos, sin): (B, S, half).
    """
    half = head_dim // 2
    s_t = int(half * sections[0])
    s_h = int(half * sections[1])
    s_w = half - s_t - s_h
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    pos = positions_thw.astype(jnp.float32)  # (3, B, S)
    sec_of = jnp.concatenate([
        jnp.zeros((s_t,), jnp.int32),
        jnp.ones((s_h,), jnp.int32),
        jnp.full((s_w,), 2, jnp.int32),
    ])
    # pick the position stream per frequency index
    p = jnp.moveaxis(pos, 0, -1)[:, :, sec_of]        # (B, S, half)
    ang = p * freqs[None, None, :]
    return jnp.cos(ang), jnp.sin(ang)


def causal_conv1d(x: Array, w: Array, carry: Optional[Array] = None
                  ) -> Tuple[Array, Array]:
    """Depthwise causal temporal convolution (Mamba / Griffin stem).

    x: (B, S, C); w: (W, C) depthwise taps.  ``carry``: (B, W-1, C) history
    from the previous sequence shard / decode step (zeros if None).
    Returns (y, new_carry) where new_carry is the last W-1 inputs.
    """
    W = w.shape[0]
    B, S, C = x.shape
    if carry is None:
        carry = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)  # (B, S+W-1, C)
    y = jnp.zeros_like(x)
    for i in range(W):  # W is tiny (4); unrolled taps fuse well
        y = y + xp[:, i:i + S, :] * w[i][None, None, :]
    new_carry = xp[:, S:, :] if W > 1 else jnp.zeros((B, 0, C), x.dtype)
    return y, new_carry


def softmax_xent(logits: Array, targets: Array, mask: Optional[Array] = None
                 ) -> Tuple[Array, Array]:
    """Token NLL sum (fp32) and count.  logits (..., V); targets (...)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        cnt = jnp.sum(mask)
    else:
        cnt = jnp.asarray(nll.size, jnp.float32)
    return jnp.sum(nll), cnt
