"""Fine-grained Mixture-of-Experts (DeepSeekMoE / Qwen3-MoE style).

shared experts (always on) + routed experts with top-k gating.  Dispatch is
sort-based (argsort tokens by expert, capacity-bounded scatter/gather) —
O(T·k log) index work instead of a dense (T, E, C) one-hot tensor, which
matters at 128 experts.  Under ZeRO++ the expert weights are ordinary flat
parameters (gathered per layer by the engine); no expert-parallel all-to-all
is required, which is exactly the paper's "no model code refactoring" point.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class MoEOut(NamedTuple):
    y: Array          # (T, d)
    aux_loss: Array   # () switch-style load-balance loss
    dropped_frac: Array  # () fraction of (token, expert) slots dropped


class Dispatch(NamedTuple):
    """Routing result: token->expert-slot assignment (pure index work).

    Splitting dispatch from expert compute lets the ZeRO++ engine gather
    expert weights in CHUNKS (a zero_chunk_scan over the stacked chunk
    shards: chunk c+k's gather in flight under chunk c's grouped GEMMs
    for ring depth k = ZeroConfig.prefetch, prefetch=0 falling back to
    one synchronous zero_apply per chunk) — the analogue of DeepSpeed's
    per-module gather granularity, without which a 128-expert layer would
    materialize multi-GB gathered weight buffers.  Chunk 0 itself can be
    seeded from the layer ring's speculative gather (routing-ahead
    dispatch, core/schedule.py `spec`): experts are gathered in full
    regardless of routing, so only the indices — not the first gather —
    wait on the router.

    Only INDICES are stored (not the (E, cap, d) slot buffer): each chunk
    rebuilds its slice of the buffer from the token activations inside its
    own gather scope, so the activation residual per MoE layer is the
    (T, d) token tensor, not the ~top_k×capacity_factor× larger slot buffer.
    """
    cap: int          # static slots per expert
    gates: Array      # (T, k) fp32 combine weights
    keep: Array       # (T*k,) bool  slot-capacity survivors (sorted order)
    dest: Array       # (T*k,) int32 slot index (E*cap = dropped)
    src_tok: Array    # (T*k,) int32 source token row for each sorted pair
    g_sorted: Array   # (T*k,) fp32 gate value per sorted pair.  The gate
                      # multiply happens INSIDE each expert chunk (so the
                      # router gradient is produced by the chunk's own
                      # recompute); the final combine is a pure gather-sum
                      # whose VJP needs only indices — otherwise autodiff
                      # saves a (T, k, d) expert-output residual PER LAYER.
    inv: Array        # (T*k,) int32 inverse sort permutation
    aux_loss: Array   # ()
    dropped_frac: Array  # ()


def route_topk(logits: Array, top_k: int,
               norm_topk: bool = True) -> Tuple[Array, Array]:
    """Softmax-then-top-k routing (DeepSeek / Qwen convention).

    Returns (gates (T, k) fp32, expert_idx (T, k) int32).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    if norm_topk:
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
    return gates, idx


def serve_capacity(T: int, top_k: int, E: int, cf: float = 2.0) -> int:
    """Inference capacity: exact (drop-free) for small token counts
    (decode), generously padded for prefill.  Training keeps the paper-
    style statistical capacity; serving must not drop tokens or decode
    would diverge from prefill."""
    stat = -(-int(T * top_k * cf) // E)
    return int(min(T * top_k, max(stat, 8 * top_k)))


def moe_dispatch(
    x: Array,                 # (T, d) tokens
    logits: Array,            # (T, E) router logits
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    norm_topk: bool = True,
    capacity: Optional[int] = None,
) -> Dispatch:
    """Route tokens into capacity-bounded per-expert slot buffers."""
    T, d = x.shape
    E = logits.shape[-1]
    gates, eidx = route_topk(logits, top_k, norm_topk)

    # ---- load-balance aux loss (Switch eq. 4) -----------------------------
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs, axis=0)                      # mean router prob / expert
    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.float32)  # (T, k, E)
    ce = jnp.mean(jnp.sum(onehot, axis=1), axis=0) / top_k  # token frac / expert
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch ----------------------------------------------
    cap = capacity if capacity is not None \
        else int(max(1, (T * top_k * capacity_factor) // E))
    e_flat = eidx.reshape(-1)                         # (T*k,)
    tok_of = jnp.repeat(jnp.arange(T), top_k)

    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    # slot of each routed pair within its expert
    group_start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    slot = jnp.arange(T * top_k) - group_start[e_sorted]
    keep = slot < cap
    dest = jnp.where(keep, e_sorted * cap + slot, E * cap)  # overflow bin

    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    inv = jnp.argsort(order)
    return Dispatch(cap, gates, keep, dest, tok_of[order],
                    gates.reshape(-1)[order], inv, aux, dropped)


def build_chunk_buf(x: Array, dest: Array, src_tok: Array,
                    chunk_start_slot: Array, chunk_slots: int) -> Array:
    """Materialize one expert chunk's slot buffer from token activations.

    x: (T, d); dest/src_tok from Dispatch; chunk_start_slot: () int32
    (= chunk_index * Ec * cap, may be traced); chunk_slots: Ec * cap.
    Returns (chunk_slots, d) with an implicit overflow row dropped.
    """
    local = dest - chunk_start_slot
    in_chunk = (local >= 0) & (local < chunk_slots)
    idx = jnp.where(in_chunk, local, chunk_slots)     # out-of-chunk -> dropped
    buf = jnp.zeros((chunk_slots + 1, x.shape[-1]), x.dtype)
    buf = buf.at[idx].set(x[src_tok], mode="drop")
    return buf[:chunk_slots]


def expert_ffn(buf: Array, w_gate_up: Array, w_down: Array) -> Array:
    """Grouped expert GEMMs on a (chunk of) slot buffer.

    buf: (Ec, cap, d); w_gate_up: (Ec, d, 2*ff); w_down: (Ec, ff, d).
    Called once per expert chunk under its own zero_apply gather.
    """
    gu = jnp.einsum("ecd,edf->ecf", buf, w_gate_up)
    g, u = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def build_chunk_gates(g_sorted: Array, dest: Array, chunk_start_slot,
                      chunk_slots: int) -> Array:
    """(chunk_slots,) gate value per slot of one expert chunk."""
    local = dest - chunk_start_slot
    in_chunk = (local >= 0) & (local < chunk_slots)
    idx = jnp.where(in_chunk, local, chunk_slots)
    g = jnp.zeros((chunk_slots + 1,), g_sorted.dtype)
    return g.at[idx].set(g_sorted, mode="drop")[:chunk_slots]


def moe_combine(out: Array, disp: Dispatch, out_dtype=None) -> Array:
    """Scatter (already gate-weighted) expert outputs back to tokens.

    out: (E, cap, d) slot outputs, gates already applied in-chunk — this is
    a pure gather-sum, so its VJP saves indices only.
    """
    E, cap, d = out.shape
    T = disp.gates.shape[0]
    top_k = disp.gates.shape[1]
    out_flat = jnp.concatenate(
        [out.reshape(E * cap, d), jnp.zeros((1, d), out.dtype)], axis=0)
    y_sorted = out_flat[jnp.where(disp.keep, disp.dest, E * cap)]
    y_pairs = y_sorted[disp.inv].reshape(T, top_k, d)
    return jnp.sum(y_pairs, axis=1) if out_dtype is None \
        else jnp.sum(y_pairs, axis=1).astype(out_dtype)


def moe_ffn_chunked(x, disp: Dispatch, w_gate_up, w_down) -> Array:
    """Reference single-shot expert pass via the chunk primitives."""
    E = w_gate_up.shape[0]
    buf = build_chunk_buf(x, disp.dest, disp.src_tok, jnp.int32(0),
                          E * disp.cap).reshape(E, disp.cap, -1)
    out = expert_ffn(buf, w_gate_up, w_down)
    g = build_chunk_gates(disp.g_sorted, disp.dest, jnp.int32(0),
                          E * disp.cap).reshape(E, disp.cap, 1)
    return moe_combine(out * g.astype(out.dtype), disp)


def shared_ffn(x: Array, shared_gate_up: Array, shared_down: Array) -> Array:
    """Always-on shared experts (DeepSeekMoE)."""
    gu_s = x @ shared_gate_up
    gs, us = jnp.split(gu_s, 2, axis=-1)
    return (jax.nn.silu(gs) * us) @ shared_down


def moe_mlp(
    x: Array,                 # (T, d) tokens
    router_w: Array,          # (d, E)
    w_gate_up: Array,         # (E, d, 2*ff) routed experts, fused gate|up
    w_down: Array,            # (E, ff, d)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    norm_topk: bool = True,
    shared_gate_up: Optional[Array] = None,  # (d, 2*ff_shared)
    shared_down: Optional[Array] = None,     # (ff_shared, d)
) -> MoEOut:
    """Single-shot token-choice top-k MoE (dispatch + all experts + combine).

    Reference composition of the pieces above; the Model uses the chunked
    path so expert gathers stay bounded.
    """
    logits = x @ router_w                             # (T, E)
    disp = moe_dispatch(x, logits, top_k=top_k,
                        capacity_factor=capacity_factor, norm_topk=norm_topk)
    y = moe_ffn_chunked(x, disp, w_gate_up, w_down)
    if shared_gate_up is not None:
        y = y + shared_ffn(x, shared_gate_up, shared_down)
    return MoEOut(y.astype(x.dtype), disp.aux_loss, disp.dropped_frac)
