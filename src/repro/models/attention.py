"""GQA attention: training (optionally sequence-parallel), prefill, decode.

Sequence parallelism (activations sharded on the sequence dim over the
``seq_axes`` mesh axes) follows the all-gather-KV scheme: queries stay
local, K/V are gathered across the sequence shards — cheap under GQA where
the KV heads are a small fraction of Q heads.  Decode uses an exact 2-pass
split-KV softmax (pmax/psum), the TPU analogue of flash-decoding, so the
KV cache can shard its *sequence* dimension over any set of mesh axes
regardless of head counts.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from repro.core.compat import axis_size as _axis_size

Array = jax.Array
NEG_INF = -1e30


def _gather_seq(x: Array, seq_axes: Sequence[str]) -> Array:
    """All-gather a (B, S_loc, ...) tensor along dim 1 over seq_axes.

    bf16 moves as u16 bits so no backend/optimizer can upcast the wire
    dtype (see collectives.gather_bf16); the bitcast is not differentiable,
    so the VJP (reduce-scatter of the cotangent) is supplied explicitly.
    """
    if not seq_axes:
        return x
    return _gather_seq_vjp(x, tuple(seq_axes))


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _gather_seq_vjp(x, seq_axes):
    from repro.core.collectives import gather_bf16
    for ax in seq_axes:
        x = gather_bf16(x, ax, axis=1)
    return x


def _gather_seq_fwd(x, seq_axes):
    return _gather_seq_vjp(x, seq_axes), None


def _gather_seq_bwd(seq_axes, _, g):
    for ax in reversed(seq_axes):
        g = lax.psum_scatter(g, ax, scatter_dimension=1, tiled=True)
    return (g,)


_gather_seq_vjp.defvjp(_gather_seq_fwd, _gather_seq_bwd)


def seq_shard_offset(s_local: int, seq_axes: Sequence[str]) -> Array:
    """Global position of this device's first sequence element."""
    off = jnp.int32(0)
    for ax in seq_axes:
        off = off * _axis_size(ax) + lax.axis_index(ax)
    return off * s_local


def mha(
    q: Array,                     # (B, Sq, H, hd) local query shard
    k: Array,                     # (B, Sq, K, hd) local key shard
    v: Array,                     # (B, Sq, K, hd)
    *,
    seq_axes: Sequence[str] = (),
    causal: bool = True,
    window: int = 0,              # >0: sliding-window (local) attention
    softmax_scale: Optional[float] = None,
    logit_softcap: float = 0.0,
    kv_chunk: int = 1024,         # flash path kicks in above this length
    impl: str = "xla",            # xla | pallas (flash kernel, §Perf)
) -> Array:
    """Training/prefill attention with optional sequence parallelism.

    Short sequences use the dense path; long sequences use the chunked
    online-softmax (flash) path with a hand-written VJP, keeping the
    working set at O(Sq·kv_chunk) instead of O(Sq·S) — mandatory for the
    32k/500k shapes where the dense logits would be tens of GB.

    ``impl="pallas"`` routes to the Pallas flash kernel (logit tiles stay
    in VMEM; HBM sees Q/K/V/O only).  The kernel computes its own absolute
    positions, so it requires unsharded sequence (batch-first layout);
    sequence-parallel cells fall back to the jnp flash path.
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    scale = softmax_scale or hd ** -0.5

    kg = _gather_seq(k, seq_axes)   # (B, S, K, hd)
    vg = _gather_seq(v, seq_axes)
    S = kg.shape[1]

    q_pos = seq_shard_offset(Sq, seq_axes) + jnp.arange(Sq)

    if impl == "pallas" and not seq_axes and S >= 512 and S % 512 == 0 \
            and Sq % 512 == 0:
        from repro.kernels.flash_ops import flash_attention_kernel
        return flash_attention_kernel(q, kg, vg, scale, causal, window,
                                      logit_softcap)

    if S > kv_chunk and S % kv_chunk == 0:
        return flash_attention(q, kg, vg, q_pos, scale=scale, causal=causal,
                               window=window, logit_softcap=logit_softcap,
                               kv_chunk=kv_chunk)

    k_pos = jnp.arange(S)
    # GQA: repeat KV heads up to H
    rep = H // K
    kgr = jnp.repeat(kg, rep, axis=2)
    vgr = jnp.repeat(vg, rep, axis=2)

    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kgr,
                        preferred_element_type=jnp.float32) * scale
    if logit_softcap:
        logits = jnp.tanh(logits / logit_softcap) * logit_softcap
    mask = jnp.ones((Sq, S), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vgr)


# ---------------------------------------------------------------------------
# chunked online-softmax attention (flash) with hand-written VJP
# ---------------------------------------------------------------------------

def _chunk_logits(q, kc, k0, q_pos, scale, causal, window, softcap):
    """(B,H,Sq,kc) masked fp32 logits for one KV chunk starting at k0."""
    B, Sq, H, hd = q.shape
    K = kc.shape[2]
    rep = H // K
    kcr = jnp.repeat(kc, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kcr,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    k_pos = k0 + jnp.arange(kc.shape[1])
    mask = jnp.ones((Sq, kc.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    return jnp.where(mask[None, None], logits, NEG_INF)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def flash_attention(q, kg, vg, q_pos, scale, causal, window, logit_softcap,
                    kv_chunk):
    out, _, _ = _flash_fwd_impl(q, kg, vg, q_pos, scale, causal, window,
                                logit_softcap, kv_chunk)
    return out


def _flash_fwd_impl(q, kg, vg, q_pos, scale, causal, window, softcap,
                    kv_chunk):
    B, Sq, H, hd = q.shape
    S, K = kg.shape[1], kg.shape[2]
    nk = S // kv_chunk
    ks = jnp.moveaxis(kg.reshape(B, nk, kv_chunk, K, hd), 1, 0)
    vs = jnp.moveaxis(vg.reshape(B, nk, kv_chunk, K, hd), 1, 0)
    k0s = jnp.arange(nk) * kv_chunk

    def step(carry, xs):
        m, l, acc = carry
        kc, vc, k0 = xs
        logits = _chunk_logits(q, kc, k0, q_pos, scale, causal, window,
                               softcap)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        rep = H // K
        vcr = jnp.repeat(vc, rep, axis=2)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(q.dtype), vcr)
        acc = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l, acc), ()

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (ks, vs, k0s))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).astype(q.dtype)
    out = jnp.moveaxis(out, 1, 2)  # (B, Sq, H, hd)
    return out, m, l_safe


def _flash_fwd(q, kg, vg, q_pos, scale, causal, window, softcap, kv_chunk):
    out, m, l = _flash_fwd_impl(q, kg, vg, q_pos, scale, causal, window,
                                softcap, kv_chunk)
    return out, (q, kg, vg, q_pos, out, m, l)


def _flash_bwd(scale, causal, window, softcap, kv_chunk, res, dout):
    q, kg, vg, q_pos, out, m, l = res
    B, Sq, H, hd = q.shape
    S, K = kg.shape[1], kg.shape[2]
    nk = S // kv_chunk
    rep = H // K

    do = jnp.moveaxis(dout, 1, 2).astype(jnp.float32)    # (B,H,Sq,hd)
    o = jnp.moveaxis(out, 1, 2).astype(jnp.float32)
    D = jnp.sum(do * o, axis=-1)                          # (B,H,Sq)

    ks = jnp.moveaxis(kg.reshape(B, nk, kv_chunk, K, hd), 1, 0)
    vs = jnp.moveaxis(vg.reshape(B, nk, kv_chunk, K, hd), 1, 0)
    k0s = jnp.arange(nk) * kv_chunk

    def step(dq, xs):
        kc, vc, k0 = xs
        logits = _chunk_logits(q, kc, k0, q_pos, scale, causal, window,
                               softcap)
        p = jnp.exp(logits - m[..., None]) / l[..., None]  # (B,H,Sq,kc)
        vcr = jnp.repeat(vc, rep, axis=2)
        dp = jnp.einsum("bhqd,bkhd->bhqk", do, vcr.astype(jnp.float32))
        dl = p * (dp - D[..., None])                       # d logits (capped)
        if softcap:
            # logits = softcap * tanh(raw / softcap); recompute tanh term.
            # Masked positions hold NEG_INF (dl is already 0 there) — zero
            # the chain factor explicitly so 0 * inf doesn't produce NaN.
            t = logits / softcap
            chain = jnp.where(logits <= NEG_INF / 2, 0.0, 1.0 - t * t)
            dl = dl * chain
        kcr = jnp.repeat(kc, rep, axis=2)
        dq_c = jnp.einsum("bhqk,bkhd->bhqd", dl,
                          kcr.astype(jnp.float32)) * scale
        dk_h = jnp.einsum("bhqk,bhqd->bkhd", dl,
                          jnp.moveaxis(q, 1, 2).astype(jnp.float32)) * scale
        p32 = p
        dv_h = jnp.einsum("bhqk,bhqd->bkhd", p32, do)
        # GQA: fold the repeated head dim back onto the K kv-heads
        dk_c = dk_h.reshape(B, kv_chunk, K, rep, hd).sum(axis=3)
        dv_c = dv_h.reshape(B, kv_chunk, K, rep, hd).sum(axis=3)
        return dq + dq_c, (dk_c, dv_c)

    dq0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    dq, (dks, dvs) = lax.scan(step, dq0, (ks, vs, k0s))
    dq = jnp.moveaxis(dq, 1, 2).astype(q.dtype)           # (B,Sq,H,hd)
    dkg = jnp.moveaxis(dks, 0, 1).reshape(B, S, K, hd).astype(kg.dtype)
    dvg = jnp.moveaxis(dvs, 0, 1).reshape(B, S, K, hd).astype(vg.dtype)
    return dq, dkg, dvg, None


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def per_seq_pos(cache_pos: Array, batch: int) -> Array:
    """Normalize ``cache_pos`` to a per-sequence (B,) int32 vector.

    The decode path is continuously batched: every sequence in the batch
    may sit at a different position (see serve/engine.py).  A scalar is
    accepted for the uniform-position case and broadcast.
    """
    p = jnp.asarray(cache_pos, jnp.int32)
    if p.ndim == 0:
        return jnp.broadcast_to(p, (batch,))
    assert p.shape == (batch,), (p.shape, batch)
    return p


def decode_attend(
    q: Array,                     # (B, 1, H, hd) new-token queries
    k_cache: Array,               # (B, S_loc, K, hd) local KV-seq shard
    v_cache: Array,
    cache_pos: Array,             # (B,) or () int32: pos of the newest token
    *,
    kv_seq_axes: Sequence[str] = (),
    softmax_scale: Optional[float] = None,
    window: int = 0,
    logit_softcap: float = 0.0,
    slot_positions: Optional[Array] = None,  # (B,S_loc) or (S_loc,) slot pos
) -> Array:
    """Exact split-KV decode attention (2-pass max/sum-exp combine).

    Each device scores its local KV shard, then the global max, normalizer
    and weighted values are combined with pmax/psum over ``kv_seq_axes``.
    ``cache_pos`` is per-sequence: sequences at different positions (the
    continuous-batching workload) share one decode step, each row masking
    its own valid prefix.  ``slot_positions`` supports ring-buffer caches
    (sliding-window layers): slot s holds the token at that global position
    (may be negative = empty).
    """
    B, _, H, hd = q.shape
    S_loc, K = k_cache.shape[1], k_cache.shape[2]
    scale = softmax_scale or hd ** -0.5
    cache_pos = per_seq_pos(cache_pos, B)

    rep = H // K
    kk = jnp.repeat(k_cache, rep, axis=2)  # (B, S_loc, H, hd)
    vv = jnp.repeat(v_cache, rep, axis=2)

    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                        preferred_element_type=jnp.float32) * scale
    if logit_softcap:
        logits = jnp.tanh(logits / logit_softcap) * logit_softcap
    if slot_positions is None:
        pos = seq_shard_offset(S_loc, kv_seq_axes) + jnp.arange(S_loc)
    else:
        pos = slot_positions
    if pos.ndim == 1:
        pos = pos[None, :]                             # -> (1|B, S_loc)
    valid = (pos >= 0) & (pos <= cache_pos[:, None])   # (B, S_loc)
    if window:
        valid &= pos > cache_pos[:, None] - window
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)

    m = jnp.max(logits, axis=-1)                       # (B,H,1)
    if kv_seq_axes:
        m = lax.pmax(m, tuple(kv_seq_axes))
    e = jnp.exp(logits - m[..., None])
    e = jnp.where(valid[:, None, None, :], e, 0.0)
    denom = jnp.sum(e, axis=-1)                        # (B,H,1)
    num = jnp.einsum("bhqk,bkhd->bqhd", e.astype(q.dtype), vv)
    if kv_seq_axes:
        denom = lax.psum(denom, tuple(kv_seq_axes))
        num = lax.psum(num, tuple(kv_seq_axes))
    out = num / jnp.moveaxis(denom, 1, 2)[..., None].astype(num.dtype)
    return out.astype(q.dtype)


def _kv_axes_world(kv_seq_axes: Sequence[str]) -> int:
    w = 1
    for ax in kv_seq_axes:
        w *= _axis_size(ax)
    return w


def paged_insert(
    k_cache: Array,               # (N_pages, page_loc, K, hd) local shard
    v_cache: Array,
    k_new: Array,                 # (B, T, K, hd)
    v_new: Array,
    positions: Array,             # (B, T) int32 global write positions
    table: Array,                 # (B, Pm) int32 physical page ids, -1 empty
    kv_seq_axes: Sequence[str] = (),
) -> Tuple[Array, Array]:
    """Scatter new K/V into a paged arena through per-slot page tables.

    The arena's page dim is unsharded; the *within-page* token dim is
    sharded over ``kv_seq_axes`` (global page size = page_loc x world, this
    device owning within-page offsets [d_off, d_off + page_loc)).  Writes
    whose logical page maps to -1 (inactive row / past the reservation) or
    whose within-page offset belongs to another shard are dropped — this
    is what lets one batched step carry prefilling/idle rows without ever
    touching pages they don't own.
    """
    N, page_loc = k_cache.shape[0], k_cache.shape[1]
    B, T = positions.shape
    Pm = table.shape[1]
    page = page_loc * _kv_axes_world(kv_seq_axes)
    d_off = seq_shard_offset(page_loc, kv_seq_axes)

    lp = positions // page                                     # (B, T)
    phys = jnp.take_along_axis(table, jnp.clip(lp, 0, Pm - 1), axis=1)
    loc = positions % page - d_off
    ok = (phys >= 0) & (lp >= 0) & (lp < Pm) & (loc >= 0) & (loc < page_loc)
    rows = jnp.where(ok, phys, N).reshape(-1)                  # N = out of range
    cols = jnp.clip(loc, 0, page_loc - 1).reshape(-1)

    def upd(cache, new):
        flat = new.reshape(B * T, new.shape[2], new.shape[3])
        return cache.at[rows, cols].set(flat.astype(cache.dtype),
                                        mode="drop")

    return upd(k_cache, k_new), upd(v_cache, v_new)


def paged_attend(
    q: Array,                     # (B, T, H, hd) chunk queries
    k_cache: Array,               # (N_pages, page_loc, K, hd) local shard
    v_cache: Array,
    positions: Array,             # (B, T) int32 query positions
    table: Array,                 # (B, Pm) int32 physical page ids, -1 empty
    *,
    kv_seq_axes: Sequence[str] = (),
    softmax_scale: Optional[float] = None,
    logit_softcap: float = 0.0,
) -> Array:
    """Exact split-KV attention over a paged arena (2-pass pmax/psum).

    Gathers each row's pages into a (B, Pm*page_loc) causal view; key
    positions are reconstructed from logical page index x page size +
    within-page offset, with -1 marking unmapped pages.  Multi-token rows
    (T > 1: chunked prefill, speculative verify) get per-query causal
    masks against their own just-inserted keys.  Unlike decode_attend the
    normalizer is clamped: an all-(-1) table row (idle slot riding the
    batched step) attends to nothing and yields zeros, not NaN.
    """
    B, T, H, hd = q.shape
    N, page_loc, K = k_cache.shape[0], k_cache.shape[1], k_cache.shape[2]
    Pm = table.shape[1]
    page = page_loc * _kv_axes_world(kv_seq_axes)
    d_off = seq_shard_offset(page_loc, kv_seq_axes)
    scale = softmax_scale or hd ** -0.5
    S = Pm * page_loc

    safe = jnp.maximum(table, 0)
    kk = k_cache[safe].reshape(B, S, K, hd)
    vv = v_cache[safe].reshape(B, S, K, hd)
    rep = H // K
    kk = jnp.repeat(kk, rep, axis=2)                           # (B, S, H, hd)
    vv = jnp.repeat(vv, rep, axis=2)

    kpos = jnp.where(
        (table >= 0)[:, :, None],
        jnp.arange(Pm, dtype=jnp.int32)[None, :, None] * page + d_off
        + jnp.arange(page_loc, dtype=jnp.int32)[None, None, :],
        -1,
    ).reshape(B, S)

    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                        preferred_element_type=jnp.float32) * scale
    if logit_softcap:
        logits = jnp.tanh(logits / logit_softcap) * logit_softcap
    valid = (kpos >= 0)[:, None, :] & \
        (kpos[:, None, :] <= positions[:, :, None])            # (B, T, S)
    logits = jnp.where(valid[:, None, :, :], logits, NEG_INF)

    m = jnp.max(logits, axis=-1)                               # (B, H, T)
    if kv_seq_axes:
        m = lax.pmax(m, tuple(kv_seq_axes))
    e = jnp.exp(logits - m[..., None])
    e = jnp.where(valid[:, None, :, :], e, 0.0)
    denom = jnp.sum(e, axis=-1)                                # (B, H, T)
    num = jnp.einsum("bhqk,bkhd->bqhd", e.astype(q.dtype), vv)
    if kv_seq_axes:
        denom = lax.psum(denom, tuple(kv_seq_axes))
        num = lax.psum(num, tuple(kv_seq_axes))
    denom = jnp.maximum(denom, 1e-30)
    out = num / jnp.moveaxis(denom, 1, 2)[..., None].astype(num.dtype)
    return out.astype(q.dtype)


def cache_insert(
    k_cache: Array,               # (B, S_loc, K, hd)
    v_cache: Array,
    k_new: Array,                 # (B, 1, K, hd)
    v_new: Array,
    cache_pos: Array,             # (B,) or () int32 global write position
    kv_seq_axes: Sequence[str] = (),
) -> Tuple[Array, Array]:
    """Write each sequence's new K/V into whichever device owns its slot.

    ``cache_pos`` is per-sequence, so every batch row writes at its own
    slot (rows whose slot lives on another KV shard are left untouched
    there and written by the owner).
    """
    B, S_loc = k_cache.shape[0], k_cache.shape[1]
    off = seq_shard_offset(S_loc, kv_seq_axes)
    pos = per_seq_pos(cache_pos, B)
    local_idx = jnp.clip(pos - off, 0, S_loc - 1)
    mine = (pos >= off) & (pos < off + S_loc)

    def upd(cache, new):
        def one(c, n, i, m):   # c: (S_loc, K, hd), n: (1, K, hd)
            u = lax.dynamic_update_slice_in_dim(c, n.astype(c.dtype), i,
                                                axis=0)
            return jnp.where(m, u, c)

        return jax.vmap(one)(cache, new, local_idx, mine)

    return upd(k_cache, k_new), upd(v_cache, v_new)
