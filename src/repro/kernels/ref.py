"""Pure-jnp oracles for every Pallas kernel in this package.

The numerical definitions live in :mod:`repro.core.quant`; this module
re-exports them under kernel-shaped signatures so each kernel's test sweeps
``assert_allclose(kernel(interpret=True), ref)`` against one source of truth.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import (
    QuantConfig,
    dequantize_blockwise,
    quantize_blockwise,
)

Array = jax.Array


def quantize_ref(x: Array, cfg: QuantConfig) -> Tuple[Array, Array]:
    """Blockwise symmetric quantization of the trailing dim."""
    return quantize_blockwise(x, cfg)


def dequantize_ref(payload: Array, scales: Array, cfg: QuantConfig,
                   out_dtype=jnp.float32) -> Array:
    return dequantize_blockwise(payload, scales, cfg, out_dtype)


def quantize_reordered_ref(x: Array, cfg: QuantConfig) -> Tuple[Array, Array]:
    """qgZ fused reorder+quant oracle: transpose (Y, X, L) -> (X, Y, L) then
    quantize the trailing dim (paper §4.2 "tensor-reorder and quantization
    fusion"; the transpose is Eq. (1)->(2) slice reordering)."""
    xt = jnp.swapaxes(x, 0, 1)
    return quantize_blockwise(xt, cfg)


def dequant_reduce_ref(payload: Array, scales: Array, cfg: QuantConfig,
                       out_dtype=jnp.float32) -> Array:
    """Dequantize N contributions (leading dim) and sum in fp32.

    Large inputs are processed in column segments (lax.map) so the fp32
    dequantized intermediate never materializes whole — same tiling the
    fused Pallas kernel uses.
    """
    N = payload.shape[0]
    if payload.size > (1 << 23):
        nb = scales.shape[-1]
        npb = payload.shape[-1] // nb          # payload bytes per block
        nseg = 1
        for cand in range(2, nb + 1):
            if nb % cand == 0 and payload.size // cand <= (1 << 23):
                nseg = cand
                break
        if nseg > 1:
            ps = payload.reshape(N, nseg, -1).swapaxes(0, 1)
            ss = scales.reshape(N, nseg, -1).swapaxes(0, 1)
            out = jax.lax.map(
                lambda t: jnp.sum(dequantize_blockwise(t[0], t[1], cfg,
                                                       jnp.float32), axis=0),
                (ps, ss))
            return out.reshape(-1).astype(out_dtype)
    deq = dequantize_blockwise(payload, scales, cfg, jnp.float32)
    return jnp.sum(deq, axis=0).astype(out_dtype)


def dequant_matmul_ref(x: Array, payload: Array, scales: Array,
                       compute_dtype=jnp.bfloat16,
                       out_dtype=jnp.float32) -> Array:
    """Staged oracle for the fused INT8 dequant-GEMM: dequantize the whole
    weight matrix through ``compute_dtype`` rounding, then one einsum with
    fp32 accumulation.  Elementwise identical to ``dequantize_blockwise``
    (fp32 scale multiply, then .astype) + the serving head einsum — the
    ``xla`` kernel backend dispatches here, so it is bit-identical to the
    pre-fusion staged hot path.

    x: (T, K); payload: (N, K) int8; scales: (N, NB) with K % NB == 0.
    """
    N, K = payload.shape
    nb = scales.shape[-1]
    assert K % nb == 0, (K, nb)
    kb = K // nb
    w = (payload.reshape(N, nb, kb).astype(jnp.float32)
         * scales[..., None]).reshape(N, K).astype(compute_dtype)
    out = jnp.einsum("tk,nk->tn", x, w,
                     preferred_element_type=jnp.float32)
    return out.astype(out_dtype)


def dequant_reduce_quant_ref(
    payload: Array, scales: Array, cfg_in: QuantConfig, cfg_out: QuantConfig,
) -> Tuple[Array, Array]:
    """qgZ inner fusion oracle (paper §4.2 "sequential dequantization,
    reduction, and quantization ... single kernel"): dequant N contributions,
    fp32 reduce, requantize the partial sums."""
    acc = dequant_reduce_ref(payload, scales, cfg_in, jnp.float32)
    return quantize_blockwise(acc, cfg_out)
