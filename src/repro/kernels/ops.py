"""Backend-dispatching wrappers over the quantization kernels.

Hot-path quantization call sites (core/collectives.py) go through this
module: on TPU they hit the Pallas kernels; on CPU (tests, dry-run,
benchmarks) they hit the pure-jnp reference, which is numerically identical
(the kernel tests prove it bit-exactly for round-to-nearest-even inputs).

``FORCE`` pins the implementation for tests/benchmarks:
  None       -> by backend (tpu: pallas, else ref)
  "ref"      -> pure jnp always
  "pallas"   -> compiled pallas (TPU only)
  "interpret"-> pallas interpret mode (runs the kernel body on CPU; used by
                the kernel-vs-ref test sweeps)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig
from repro.kernels import ref as _ref
from repro.kernels import quant_block as _qb
from repro.kernels import fused_dequant_reduce_quant as _fq

Array = jax.Array

FORCE: Optional[str] = None


def _mode() -> str:
    if FORCE is not None:
        return FORCE
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _as2d(x: Array) -> Tuple[Array, Tuple[int, ...]]:
    lead = x.shape[:-1]
    n = 1
    for s in lead:
        n *= s
    return x.reshape(n, x.shape[-1]), lead


def quantize_blockwise(x: Array, cfg: QuantConfig,
                       key: Optional[Array] = None) -> Tuple[Array, Array]:
    mode = _mode()
    if mode == "ref" or cfg.stochastic or key is not None:
        from repro.core.quant import quantize_blockwise as q
        return q(x, cfg, key)
    x2, lead = _as2d(x)
    p, s = _qb.quantize_pallas(x2, cfg, interpret=(mode == "interpret"))
    return p.reshape(*lead, p.shape[-1]), s.reshape(*lead, s.shape[-1])


def dequantize_blockwise(payload: Array, scales: Array, cfg: QuantConfig,
                         out_dtype=jnp.float32) -> Array:
    mode = _mode()
    if mode == "ref":
        from repro.core.quant import dequantize_blockwise as d
        return d(payload, scales, cfg, out_dtype)
    p2, lead = _as2d(payload)
    s2, _ = _as2d(scales)
    x = _qb.dequantize_pallas(p2, s2, cfg, out_dtype,
                              interpret=(mode == "interpret"))
    return x.reshape(*lead, x.shape[-1])


def quantize_reordered(x: Array, cfg: QuantConfig,
                       key: Optional[Array] = None) -> Tuple[Array, Array]:
    """(Y, X, L) -> transpose to (X, Y, L), quantize trailing dim (fused)."""
    mode = _mode()
    if mode == "ref" or cfg.stochastic or key is not None:
        xt = jnp.swapaxes(x, 0, 1)
        from repro.core.quant import quantize_blockwise as q
        return q(xt, cfg, key)
    return _qb.quantize_reordered_pallas(x, cfg,
                                         interpret=(mode == "interpret"))


def dequant_reduce(payload: Array, scales: Array, cfg: QuantConfig,
                   out_dtype=jnp.float32) -> Array:
    """Sum N quantized contributions in fp32: (N, P), (N, NB) -> (C,)."""
    mode = _mode()
    if mode == "ref":
        return _ref.dequant_reduce_ref(payload, scales, cfg, out_dtype)
    return _fq.dequant_reduce_pallas(payload, scales, cfg, out_dtype,
                                     interpret=(mode == "interpret"))


def dequant_reduce_quant(payload: Array, scales: Array, cfg_in: QuantConfig,
                         cfg_out: QuantConfig,
                         key: Optional[Array] = None) -> Tuple[Array, Array]:
    """Fused dequant -> fp32 reduce -> requant (qgZ intra-hop, §4.2)."""
    mode = _mode()
    if mode == "ref" or cfg_out.stochastic or key is not None:
        acc = _ref.dequant_reduce_ref(payload, scales, cfg_in, jnp.float32)
        from repro.core.quant import quantize_blockwise as q
        return q(acc, cfg_out, key)
    return _fq.dequant_reduce_quant_pallas(payload, scales, cfg_in, cfg_out,
                                           interpret=(mode == "interpret"))
