"""Backend-dispatching wrappers over the quantization kernels.

This module is the SEAM between the numerical hot path and its
implementations: every quantized byte on the training and serving hot path
(core/collectives.py qwZ/qgZ, the serving INT8 head GEMM in
models/model.py) calls through here, and nothing outside ``repro.kernels``
imports a kernel module directly.  Backends (see kernels/platform.py for
resolution and the off-TPU error contract):

  ``pallas``    compiled Pallas TPU kernels (TPU only, hard error elsewhere)
  ``interpret`` the same kernel bodies through the Pallas interpreter —
                CPU CI executes the real kernel code, bit-for-bit
  ``xla``       pure-jnp references (core.quant / kernels.ref); alias "ref"

Selection: ``set_backend()`` / ``use_backend()`` here, the
``REPRO_KERNEL_BACKEND`` environment variable, or the platform default
(tpu: pallas, else xla).  The legacy ``FORCE`` module global is still
honoured (oldest precedence name for ``set_backend``).

Stochastic rounding threads PRNG keys into the kernels by drawing the
uniform field OUTSIDE the pallas_call (``core.quant.stochastic_uniform``
reproduces the reference's segmentation and key-split structure exactly)
and passing it as an extra tiled input: the in-kernel comparison
``u < s - floor(s)`` is then bit-identical to the jnp reference, so the
determinism-through-dispatch contract (fixed key -> identical payloads on
every backend) holds with the kernels actually running.  This covers the
fused ``dequant_reduce_quant`` too: its (C,) accumulator is requantized
with a uniform field drawn on the reference's 1-D segmentation, so the
intra-hop stochastic requant runs in-kernel on every backend.  The one
deliberate xla route left is ``cfg.stochastic`` with ``key=None``, which
goes to the reference to hit its loud "needs a PRNG key" assert.
"""
from __future__ import annotations

import contextlib
from typing import TYPE_CHECKING, Optional, Tuple

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # runtime import would be circular: core.collectives imports us
    from repro.core.quant import QuantConfig

from repro.kernels import platform
from repro.kernels import ref as _ref
from repro.kernels import quant_block as _qb
from repro.kernels import fused_dequant_reduce_quant as _fq
# stdlib-only metrics (obs.metrics imports neither jax nor repro): safe at
# the bottom of the import graph.  Counts routing DECISIONS — inside jit
# the wrapper body runs once per trace, so these are dispatch counts, not
# per-step execution counts (exactly what backend-selection debugging needs).
from repro.obs.metrics import count_dispatch as _count_dispatch

Array = jax.Array

# Programmatic override; None defers to $REPRO_KERNEL_BACKEND, then the
# platform default.  Prefer set_backend()/use_backend() over writing this.
FORCE: Optional[str] = None


def set_backend(name: Optional[str]) -> None:
    """Pin the kernel backend process-wide (None restores resolution via
    env/platform).  Validates eagerly: 'pallas' off-TPU raises here, at
    configuration time, not at the first hot-path call."""
    global FORCE
    if name is not None:
        platform.resolve(name)  # validate, incl. the off-TPU error
    FORCE = name


@contextlib.contextmanager
def use_backend(name: Optional[str]):
    """Scoped :func:`set_backend` (tests, benchmarks)."""
    global FORCE
    old = FORCE
    set_backend(name)
    try:
        yield
    finally:
        FORCE = old


def backend() -> str:
    """The backend the next kernel call will dispatch to."""
    return platform.resolve(FORCE)


def _as2d(x: Array) -> Tuple[Array, Tuple[int, ...]]:
    lead = x.shape[:-1]
    n = 1
    for s in lead:
        n *= s
    return x.reshape(n, x.shape[-1]), lead


def quantize_blockwise(x: Array, cfg: QuantConfig,
                       key: Optional[Array] = None) -> Tuple[Array, Array]:
    """Blockwise quantize the trailing dim (qwZ shard quantize; qgZ hop 1)."""
    mode = backend()
    if mode == "xla" or (cfg.stochastic and key is None):
        # second arm: reference raises the loud "needs a PRNG key" assert
        from repro.core.quant import quantize_blockwise as q
        _count_dispatch("quantize_blockwise", "xla")
        return q(x, cfg, key)
    _count_dispatch("quantize_blockwise", mode)
    u = None
    if cfg.stochastic:
        from repro.core.quant import stochastic_uniform
        u = stochastic_uniform(x.shape, cfg, key)
    x2, lead = _as2d(x)
    u2 = None if u is None else u.reshape(x2.shape)
    p, s = _qb.quantize_pallas(x2, cfg, u=u2,
                               interpret=(mode == "interpret"))
    return p.reshape(*lead, p.shape[-1]), s.reshape(*lead, s.shape[-1])


def dequantize_blockwise(payload: Array, scales: Array, cfg: QuantConfig,
                         out_dtype=jnp.float32) -> Array:
    """Inverse of :func:`quantize_blockwise`; writes ``out_dtype`` (the qwZ
    gather passes bf16) directly — no fp32 materialization of the output."""
    mode = backend()
    _count_dispatch("dequantize_blockwise", mode)
    if mode == "xla":
        from repro.core.quant import dequantize_blockwise as d
        return d(payload, scales, cfg, out_dtype)
    p2, lead = _as2d(payload)
    s2, _ = _as2d(scales)
    x = _qb.dequantize_pallas(p2, s2, cfg, out_dtype,
                              interpret=(mode == "interpret"))
    return x.reshape(*lead, x.shape[-1])


def quantize_reordered(x: Array, cfg: QuantConfig,
                       key: Optional[Array] = None) -> Tuple[Array, Array]:
    """(Y, X, L) -> transpose to (X, Y, L), quantize trailing dim — qgZ
    step 1 with the remap folded into the kernel's BlockSpec index_map."""
    mode = backend()
    if mode == "xla" or (cfg.stochastic and key is None):
        xt = jnp.swapaxes(x, 0, 1)
        from repro.core.quant import quantize_blockwise as q
        _count_dispatch("quantize_reordered", "xla")
        return q(xt, cfg, key)
    _count_dispatch("quantize_reordered", mode)
    u = None
    if cfg.stochastic:
        # the reference draws on the transposed (X, Y, L) layout
        from repro.core.quant import stochastic_uniform
        Y, X, L = x.shape
        u = stochastic_uniform((X, Y, L), cfg, key)
    return _qb.quantize_reordered_pallas(x, cfg, u=u,
                                         interpret=(mode == "interpret"))


def dequant_reduce(payload: Array, scales: Array, cfg: QuantConfig,
                   out_dtype=jnp.float32) -> Array:
    """Sum N quantized contributions in fp32: (N, P), (N, NB) -> (C,)."""
    mode = backend()
    _count_dispatch("dequant_reduce", mode)
    if mode == "xla":
        return _ref.dequant_reduce_ref(payload, scales, cfg, out_dtype)
    return _fq.dequant_reduce_pallas(payload, scales, cfg, out_dtype,
                                     interpret=(mode == "interpret"))


def dequant_reduce_quant(payload: Array, scales: Array, cfg_in: QuantConfig,
                         cfg_out: QuantConfig,
                         key: Optional[Array] = None) -> Tuple[Array, Array]:
    """Fused dequant -> fp32 reduce -> requant (qgZ intra-hop, §4.2)."""
    mode = backend()
    if mode == "xla" or (cfg_out.stochastic and key is None):
        # second arm: reference raises the loud "needs a PRNG key" assert
        acc = _ref.dequant_reduce_ref(payload, scales, cfg_in, jnp.float32)
        from repro.core.quant import quantize_blockwise as q
        _count_dispatch("dequant_reduce_quant", "xla")
        return q(acc, cfg_out, key)
    _count_dispatch("dequant_reduce_quant", mode)
    u = None
    if cfg_out.stochastic:
        # the reference requantizes the flat (C,) accumulator, so the
        # uniform field uses its 1-D segmentation — this closed the last
        # stochastic xla fallback (DESIGN.md §7)
        from repro.core.quant import stochastic_uniform
        C = payload.shape[1] * 2 if cfg_in.bits == 4 else payload.shape[1]
        u = stochastic_uniform((C,), cfg_out, key)
    return _fq.dequant_reduce_quant_pallas(payload, scales, cfg_in, cfg_out,
                                           u=u,
                                           interpret=(mode == "interpret"))


def dequant_matmul(x: Array, payload: Array, scales: Array,
                   compute_dtype=jnp.bfloat16,
                   out_dtype=jnp.float32) -> Array:
    """Fused INT8-weight x activation GEMM: ``x @ dequant(payload).T``.

    x: (T, K) activations; payload: (N, K) int8 rows; scales: (N, NB)
    fp32 with K % NB == 0 (each row's K splits into NB scale groups).
    Dequantized weights round through ``compute_dtype`` (bf16) before the
    MXU — exactly the staged gather-dequant-einsum math — so the ``xla``
    backend is bit-identical to the staged path; the kernel applies the
    scales inside its k-tile loop (INT8 rows stream from HBM at 1 B/elem,
    never materializing the bf16 weight matrix).
    """
    mode = backend()
    _count_dispatch("dequant_matmul", mode)
    if mode == "xla":
        return _ref.dequant_matmul_ref(x, payload, scales,
                                       compute_dtype=compute_dtype,
                                       out_dtype=out_dtype)
    from repro.kernels import dequant_matmul as _dm
    return _dm.dequant_matmul_pallas(x, payload, scales,
                                     compute_dtype=compute_dtype,
                                     out_dtype=out_dtype,
                                     interpret=(mode == "interpret"))
