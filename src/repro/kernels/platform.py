"""The ONE platform probe + backend resolver for the kernel layer.

Every kernel entry point (quant ops in :mod:`repro.kernels.ops`, flash
attention in :mod:`repro.kernels.flash_ops`) resolves its implementation
through this module, so "which backend am I on?" is answered exactly once
and cannot disagree between call sites (the old ``ops._mode`` /
``flash_ops._interpret`` pair could).

Backends:

  ``pallas``    compiled Pallas TPU kernels.  Requesting it off-TPU is a
                hard error — Pallas TPU kernels either miscompile or fall
                over on other platforms, and a silent fallback would make
                every benchmark number a lie.
  ``interpret`` the same kernel bodies run through the Pallas interpreter
                (pure XLA ops, any platform).  Slow; exists so CPU CI can
                execute the real kernel code paths bit-for-bit.
  ``xla``       the pure-jnp reference implementations (core.quant /
                kernels.ref).  The numerical source of truth and the
                fallback for features the kernels do not cover
                (stochastic rounding).  ``ref`` is accepted as an alias.

Resolution order (first hit wins):

  1. an explicit force (``ops.set_backend`` / ``ops.use_backend`` /
     the legacy ``ops.FORCE`` module global),
  2. the ``REPRO_KERNEL_BACKEND`` environment variable,
  3. platform default: ``pallas`` on TPU, ``xla`` elsewhere.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

ENV_VAR = "REPRO_KERNEL_BACKEND"

BACKENDS = ("pallas", "interpret", "xla")
_ALIASES = {"ref": "xla"}


def is_tpu() -> bool:
    """True iff jax's default backend is a TPU (the only platform the
    compiled Pallas kernels in this package target)."""
    return jax.default_backend() == "tpu"


def canonical(name: str) -> str:
    """Normalize a backend name; raise on anything unknown."""
    name = _ALIASES.get(name, name)
    if name not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {name!r}; expected one of "
            f"{BACKENDS} (or alias 'ref' for 'xla')")
    return name


def resolve(force: Optional[str] = None) -> str:
    """Resolve the active kernel backend (see module docstring for the
    precedence).  Raises RuntimeError if ``pallas`` is selected on a
    non-TPU platform — never a silent fallback."""
    if force is not None:
        mode = canonical(force)
    else:
        env = os.environ.get(ENV_VAR)
        if env:
            mode = canonical(env)
        else:
            mode = "pallas" if is_tpu() else "xla"
    if mode == "pallas" and not is_tpu():
        src = "forced" if force is not None else (
            f"${ENV_VAR}" if os.environ.get(ENV_VAR) else "default")
        raise RuntimeError(
            f"kernel backend 'pallas' ({src}) requires a TPU, but jax's "
            f"default backend is {jax.default_backend()!r}.  Use "
            f"'interpret' to run the kernel bodies on this platform, or "
            f"'xla' for the pure-jnp reference.")
    return mode


def interpret_flag(force: Optional[str] = None) -> bool:
    """The ``interpret=`` argument a ``pallas_call`` site should pass for
    the resolved backend.  Only meaningful for kernels without an ``xla``
    reference split at the dispatch layer (flash attention): ``pallas``
    compiles, anything else interprets."""
    return resolve(force) != "pallas"
