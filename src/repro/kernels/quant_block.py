"""Pallas TPU kernels: blockwise quantize / dequantize (+ fused reorder).

These are the TPU adaptation of the paper's custom CUDA quantization library
(§4.2): the CUDA version chases vectorized 16B global-memory transactions
and register-file blocking; the TPU version expresses the same intent as
VMEM tiles shaped for the VPU — trailing (lane) dimension a multiple of 128,
sublane tiles of 8 — so each ``pallas_call`` instance streams one HBM tile
through VMEM exactly once.

Layout contract (shared with core.quant): the quantization block is a run of
``block_size`` *contiguous trailing* elements, and every tile holds an
integer number of blocks, so scales never cross tile boundaries.

The fused reorder+quant kernel implements the paper's "tensor slice
reordering ... realized within a fused quantization and remapping kernel":
the (Y, X, L) -> (X, Y, L) transpose of qgZ is folded into the input
``BlockSpec.index_map``, so reordering costs zero extra memory traffic —
the Pallas analogue of fusing the remap into the quant kernel's loads.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import QuantConfig

Array = jax.Array

# TPU tiling constants: lane width 128, sublane 8 (fp32) — tiles are chosen
# as multiples of these so the MXU/VPU see hardware-aligned shapes.
_LANE = 128
_SUBLANE = 8
_MAX_TILE_COLS = 4096  # cap the per-instance VMEM working set


def _divisor_at_most(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap."""
    best = 1
    for d in range(1, int(n ** 0.5) + 1):
        if n % d == 0:
            for c in (d, n // d):
                if c <= cap and c > best:
                    best = c
    return best


def pick_tiles(rows: int, cols: int, block: int) -> Tuple[int, int]:
    """(row_tile, col_tile): col_tile holds whole quant blocks, lane-friendly."""
    nb = cols // block
    max_blocks = max(1, _MAX_TILE_COLS // block)
    cb = _divisor_at_most(nb, max_blocks)
    rt = _divisor_at_most(rows, _SUBLANE)
    return rt, cb * block


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------

def _quant_body(x, block: int, qmax: float, pack: bool, u=None):
    """Shared math: (rt, ct) float tile -> (payload, scales).

    ``u`` (optional, same tile shape as ``x``) is a pre-drawn uniform field
    for stochastic rounding: ``q = floor(s) + (u < s - floor(s))`` — the
    exact comparison core.quant._round performs, so a field produced by
    core.quant.stochastic_uniform rounds bit-identically to the reference.
    """
    rt, ct = x.shape
    nb = ct // block
    xb = x.astype(jnp.float32).reshape(rt, nb, block)
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = absmax / qmax
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    scaled = xb * inv
    if u is None:
        q = jnp.round(scaled)
    else:
        ub = u.astype(jnp.float32).reshape(rt, nb, block)
        lo = jnp.floor(scaled)
        q = lo + (ub < scaled - lo).astype(jnp.float32)
    q = jnp.clip(q, -qmax, qmax).astype(jnp.int8)
    q = q.reshape(rt, ct)
    if pack:  # int4: two nibbles per byte along the trailing dim
        q2 = q.reshape(rt, ct // 2, 2)
        q = ((q2[..., 0] & 0xF) | ((q2[..., 1] & 0xF) << 4)).astype(jnp.int8)
    return q, scale.reshape(rt, nb)


def _quant_kernel(x_ref, payload_ref, scale_ref, *, block, qmax, pack):
    q, s = _quant_body(x_ref[...], block, qmax, pack)
    payload_ref[...] = q
    scale_ref[...] = s


def _quant_kernel_sr(x_ref, u_ref, payload_ref, scale_ref, *, block, qmax,
                     pack):
    q, s = _quant_body(x_ref[...], block, qmax, pack, u=u_ref[...])
    payload_ref[...] = q
    scale_ref[...] = s


def quantize_pallas(x: Array, cfg: QuantConfig,
                    u: Array = None,
                    interpret: bool = False) -> Tuple[Array, Array]:
    """Blockwise quantize the trailing dim of a 2-D array.

    x: (R, C) float, C % cfg.block_size == 0.
    u: optional (R, C) float32 uniform field -> stochastic rounding (same
       tiling as x; see core.quant.stochastic_uniform).
    Returns (payload int8 (R, C or C//2), scales f32 (R, C//block)).
    """
    R, C = x.shape
    block = cfg.block_size
    assert C % block == 0, (C, block)
    pack = cfg.bits == 4
    rt, ct = pick_tiles(R, C, block)
    nbt = ct // block
    pt = ct // 2 if pack else ct
    grid = (R // rt, C // ct)
    x_spec = pl.BlockSpec((rt, ct), lambda i, j: (i, j))
    if u is None:
        kernel = functools.partial(_quant_kernel, block=block, qmax=cfg.qmax,
                                   pack=pack)
        in_specs, operands = [x_spec], (x,)
    else:
        assert u.shape == x.shape, (u.shape, x.shape)
        kernel = functools.partial(_quant_kernel_sr, block=block,
                                   qmax=cfg.qmax, pack=pack)
        in_specs, operands = [x_spec, x_spec], (x, u)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((rt, pt), lambda i, j: (i, j)),
            pl.BlockSpec((rt, nbt), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C // 2 if pack else C), jnp.int8),
            jax.ShapeDtypeStruct((R, C // block), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# dequantize
# ---------------------------------------------------------------------------

def _dequant_body(p, s, block: int, pack: bool, out_dtype):
    rt = p.shape[0]
    if pack:
        lo = (p << 4) >> 4   # arithmetic shift on int8 sign-extends
        hi = p >> 4
        p = jnp.stack([lo, hi], axis=-1).reshape(rt, p.shape[1] * 2)
    ct = p.shape[1]
    nb = ct // block
    x = p.reshape(rt, nb, block).astype(jnp.float32) * s[..., None]
    return x.reshape(rt, ct).astype(out_dtype)


def _dequant_kernel(p_ref, s_ref, out_ref, *, block, pack, out_dtype):
    out_ref[...] = _dequant_body(p_ref[...], s_ref[...], block, pack, out_dtype)


def dequantize_pallas(payload: Array, scales: Array, cfg: QuantConfig,
                      out_dtype=jnp.float32,
                      interpret: bool = False) -> Array:
    """Inverse of :func:`quantize_pallas`.  payload: (R, P); scales (R, NB)."""
    R, P = payload.shape
    pack = cfg.bits == 4
    C = P * 2 if pack else P
    block = cfg.block_size
    rt, ct = pick_tiles(R, C, block)
    nbt = ct // block
    pt = ct // 2 if pack else ct
    grid = (R // rt, C // ct)
    kernel = functools.partial(_dequant_kernel, block=block, pack=pack,
                               out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, pt), lambda i, j: (i, j)),
            pl.BlockSpec((rt, nbt), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), out_dtype),
        interpret=interpret,
    )(payload, scales)


# ---------------------------------------------------------------------------
# fused reorder (transpose) + quantize — qgZ step 1 (§3.3.3 + §4.2)
# ---------------------------------------------------------------------------

def _quant3_kernel(x_ref, payload_ref, scale_ref, *, block, qmax, pack):
    x = x_ref[...]                     # (1, 1, ct) — one (x, y) slice tile
    q, s = _quant_body(x.reshape(1, -1), block, qmax, pack)
    payload_ref[...] = q.reshape(x_ref.shape[0], x_ref.shape[1], -1)
    scale_ref[...] = s.reshape(x_ref.shape[0], x_ref.shape[1], -1)


def _quant3_kernel_sr(x_ref, u_ref, payload_ref, scale_ref, *, block, qmax,
                      pack):
    x = x_ref[...]
    q, s = _quant_body(x.reshape(1, -1), block, qmax, pack,
                       u=u_ref[...].reshape(1, -1))
    payload_ref[...] = q.reshape(x_ref.shape[0], x_ref.shape[1], -1)
    scale_ref[...] = s.reshape(x_ref.shape[0], x_ref.shape[1], -1)


def quantize_reordered_pallas(x: Array, cfg: QuantConfig,
                              u: Array = None,
                              interpret: bool = False) -> Tuple[Array, Array]:
    """Transpose (Y, X, L) -> (X, Y, L) and quantize trailing dim, fused.

    The transpose is expressed purely in the input ``index_map`` — the
    kernel reads tile (y=j, x=i) while writing tile (i, j), so the reorder
    rides along with the quantization loads (no separate transpose pass).

    ``u`` (optional, stochastic rounding) is the uniform field drawn on the
    already-transposed shape (X, Y, L) — the layout the reference draws on
    after its ``swapaxes`` — so its BlockSpec is the identity (output-side)
    index_map, not the transposing one.
    """
    Y, X, L = x.shape
    block = cfg.block_size
    assert L % block == 0
    pack = cfg.bits == 4
    _, lt = pick_tiles(1, L, block)
    nbt = lt // block
    ptile = lt // 2 if pack else lt
    grid = (X, Y, L // lt)
    if u is None:
        kernel = functools.partial(_quant3_kernel, block=block, qmax=cfg.qmax,
                                   pack=pack)
        in_specs = [pl.BlockSpec((1, 1, lt), lambda i, j, k: (j, i, k))]
        operands = (x,)
    else:
        assert u.shape == (X, Y, L), (u.shape, (X, Y, L))
        kernel = functools.partial(_quant3_kernel_sr, block=block,
                                   qmax=cfg.qmax, pack=pack)
        in_specs = [pl.BlockSpec((1, 1, lt), lambda i, j, k: (j, i, k)),
                    pl.BlockSpec((1, 1, lt), lambda i, j, k: (i, j, k))]
        operands = (x, u)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, ptile), lambda i, j, k: (i, j, k)),
            pl.BlockSpec((1, 1, nbt), lambda i, j, k: (i, j, k)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((X, Y, L // 2 if pack else L), jnp.int8),
            jax.ShapeDtypeStruct((X, Y, L // block), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
