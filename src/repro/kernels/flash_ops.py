"""jit-ready wrapper around the Pallas flash-attention kernels.

``flash_attention_kernel`` is a custom_vjp whose forward/backward run the
Pallas kernels (compiled on TPU; interpret mode elsewhere).  Restriction:
queries must start at position 0 (no sequence-parallel offset) — the
dry-run's batch-first layout satisfies this; models/attention.py falls back
to the jnp flash path when a sequence offset exists.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import platform
from repro.kernels.flash_attention import flash_bwd_pallas, flash_fwd_pallas


def _interpret() -> bool:
    # Shared platform probe (kernels/platform.py) — honours
    # $REPRO_KERNEL_BACKEND and raises if 'pallas' is forced off-TPU,
    # instead of this module and kernels/ops.py probing independently.
    return platform.interpret_flag()


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention_kernel(q, kg, vg, scale, causal, window, softcap,
                           bq=512, bk=512):
    out, _, _ = flash_fwd_pallas(q, kg, vg, scale=scale, causal=causal,
                                 window=window, softcap=softcap, bq=bq,
                                 bk=bk, interpret=_interpret())
    return out


def _fwd(q, kg, vg, scale, causal, window, softcap, bq, bk):
    out, m, l = flash_fwd_pallas(q, kg, vg, scale=scale, causal=causal,
                                 window=window, softcap=softcap, bq=bq,
                                 bk=bk, interpret=_interpret())
    return out, (q, kg, vg, out, m, l)


def _bwd(scale, causal, window, softcap, bq, bk, res, dout):
    q, kg, vg, out, m, l = res
    dq, dkg, dvg = flash_bwd_pallas(q, kg, vg, out, m, l, dout, scale=scale,
                                    causal=causal, window=window,
                                    softcap=softcap, bq=bq, bk=bk,
                                    interpret=_interpret())
    return dq, dkg, dvg


flash_attention_kernel.defvjp(_fwd, _bwd)
