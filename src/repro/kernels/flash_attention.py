"""Pallas TPU flash attention (forward + backward).

Beyond-paper §Perf optimization: the XLA-level chunked-softmax attention in
models/attention.py materializes the (B,H,Sq,kc) logit tiles in HBM at
every dot boundary — for the train/prefill shapes that traffic DOMINATES
the roofline memory term.  This kernel keeps each (q-tile × kv-tile) logit
block in VMEM; HBM sees only Q/K/V/O (+ the m/l softmax stats).

Tiling: grid (B, H, Sq/bq, S/bk) with the kv axis innermost; the output
blocks for a q-tile map to the same slot for every kv step, so Pallas keeps
them VMEM-resident as running (acc, m, l) state — no scratch needed.  GQA
folds the head-group mapping into the K/V index_map (no materialized
repeat).  Masking (causal / sliding window / softcap) matches
models/attention.py, and the backward recomputes logits per tile (standard
flash backward: dq on a q-major grid, dk/dv on a kv-major grid).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array
NEG_INF = -1e30


def _masked_logits(q, k, q0, k0, bq, bk, scale, causal, window, softcap):
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    q_pos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        m &= q_pos >= k_pos
    if window:
        m &= q_pos - k_pos < window
    return jnp.where(m, logits, NEG_INF)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                *, scale, causal, window, softcap, bq, bk, nk):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    logits = _masked_logits(q, k, pl.program_id(2) * bq, j * bk, bq, bk,
                            scale, causal, window, softcap)
    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p, axis=-1)
    m_ref[0, 0] = m_new
    acc_ref[0, 0] = acc_ref[0, 0] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def flash_fwd_pallas(q: Array, kg: Array, vg: Array, *, scale: float,
                     causal: bool = True, window: int = 0,
                     softcap: float = 0.0, bq: int = 512, bk: int = 512,
                     interpret: bool = False
                     ) -> Tuple[Array, Array, Array]:
    """q: (B,Sq,H,hd); kg/vg: (B,S,K,hd).  Returns (out (B,Sq,H,hd), m, l).

    out = acc/l is finished outside the kernel (acc accumulates fp32 in the
    output block, which stays VMEM-resident across the inner kv steps).
    """
    B, Sq, H, hd = q.shape
    S, K = kg.shape[1], kg.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, S)
    assert Sq % bq == 0 and S % bk == 0, (Sq, S, bq, bk)
    rep = H // K
    grid = (B, H, Sq // bq, S // bk)

    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                               window=window, softcap=softcap, bq=bq, bk=bk,
                               nk=S // bk)
    qs = jnp.swapaxes(q, 1, 2)          # (B,H,Sq,hd)
    ks = jnp.swapaxes(kg, 1, 2)         # (B,K,S,hd)
    vs = jnp.swapaxes(vg, 1, 2)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        interpret=interpret,
    )(qs, ks, vs)
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).astype(q.dtype)
    return jnp.swapaxes(out, 1, 2), m, l


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, dsum_ref,
                   dq_ref, *, scale, causal, window, softcap, bq, bk):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    logits = _masked_logits(q, k, pl.program_id(2) * bq, j * bk, bq, bk,
                            scale, causal, window, softcap)
    p = jnp.exp(logits - m_ref[0, 0][:, None]) / l_ref[0, 0][:, None]
    do = do_ref[0, 0].astype(jnp.float32)
    dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dl = p * (dp - dsum_ref[0, 0][:, None])
    if softcap:
        t = logits / softcap
        dl = dl * jnp.where(logits <= NEG_INF / 2, 0.0, 1.0 - t * t)
    dq_ref[0, 0] += jax.lax.dot_general(
        dl, k.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, dsum_ref,
                    dk_ref, dv_ref, *, scale, causal, window, softcap,
                    bq, bk, rep):
    # grid (B, K, nkv, rep, nq): the dk/dv block index (b, g, j) is constant
    # across the two innermost dims, so the accumulator block stays
    # VMEM-resident for its whole reduction (consecutive revisits only).
    r = pl.program_id(3)   # head within the GQA group
    i = pl.program_id(4)   # q tile (innermost)

    @pl.when((i == 0) & (r == 0))
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    logits = _masked_logits(q, k, i * bq, pl.program_id(2) * bk, bq, bk,
                            scale, causal, window, softcap)
    p = jnp.exp(logits - m_ref[0, 0][:, None]) / l_ref[0, 0][:, None]
    do = do_ref[0, 0].astype(jnp.float32)
    dv_ref[0, 0] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dl = p * (dp - dsum_ref[0, 0][:, None])
    if softcap:
        t = logits / softcap
        dl = dl * jnp.where(logits <= NEG_INF / 2, 0.0, 1.0 - t * t)
    dk_ref[0, 0] += jax.lax.dot_general(
        dl, q.astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale


def flash_bwd_pallas(q, kg, vg, out, m, l, dout, *, scale, causal=True,
                     window=0, softcap=0.0, bq=512, bk=512,
                     interpret=False):
    """Returns (dq, dkg, dvg) matching flash_fwd_pallas inputs."""
    B, Sq, H, hd = q.shape
    S, K = kg.shape[1], kg.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, S)
    rep = H // K
    qs = jnp.swapaxes(q, 1, 2)
    ks = jnp.swapaxes(kg, 1, 2)
    vs = jnp.swapaxes(vg, 1, 2)
    dos = jnp.swapaxes(dout, 1, 2)
    os_ = jnp.swapaxes(out, 1, 2)
    dsum = jnp.sum(dos.astype(jnp.float32) * os_.astype(jnp.float32),
                   axis=-1)                        # (B,H,Sq)

    # ---- dq: grid (B, H, nq, nk), kv innermost --------------------------
    kdq = functools.partial(_bwd_dq_kernel, scale=scale, causal=causal,
                            window=window, softcap=softcap, bq=bq, bk=bk)
    dq = pl.pallas_call(
        kdq,
        grid=(B, H, Sq // bq, S // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), jnp.float32),
        interpret=interpret,
    )(qs, ks, vs, dos, m, l, dsum)

    # ---- dk/dv: grid (B, K, nkv, rep, nq); heads fold onto K groups ----
    kdkv = functools.partial(_bwd_dkv_kernel, scale=scale, causal=causal,
                             window=window, softcap=softcap, bq=bq, bk=bk,
                             rep=rep)
    dk, dv = pl.pallas_call(
        kdkv,
        grid=(B, K, S // bk, rep, Sq // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda b, g, j, r, i: (b, g * rep + r, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, g, j, r, i: (b, g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, g, j, r, i: (b, g, j, 0)),
            pl.BlockSpec((1, 1, bq, hd),
                         lambda b, g, j, r, i: (b, g * rep + r, i, 0)),
            pl.BlockSpec((1, 1, bq),
                         lambda b, g, j, r, i: (b, g * rep + r, i)),
            pl.BlockSpec((1, 1, bq),
                         lambda b, g, j, r, i: (b, g * rep + r, i)),
            pl.BlockSpec((1, 1, bq),
                         lambda b, g, j, r, i: (b, g * rep + r, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, hd), lambda b, g, j, r, i: (b, g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, g, j, r, i: (b, g, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, K, S, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qs, ks, vs, dos, m, l, dsum)

    dq = jnp.swapaxes(dq, 1, 2).astype(q.dtype)
    dkg = jnp.swapaxes(dk, 1, 2).astype(kg.dtype)
    dvg = jnp.swapaxes(dv, 1, 2).astype(vg.dtype)
    return dq, dkg, dvg
