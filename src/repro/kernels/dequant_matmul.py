"""Pallas TPU kernel: fused INT8-weight x activation GEMM.

The serving decode head consumes qwZ-gathered INT8 weights.  The staged
path dequantizes the whole (N, K) weight matrix to bf16 in HBM and then
runs the GEMM — 2 B/elem written + 2 B/elem re-read that exist only to
feed the MXU.  This kernel applies the blockwise scales inside the k-tile
loop instead: INT8 rows stream from HBM once at 1 B/elem, are dequantized
in VMEM per (bn, bk) tile, and hit the MXU directly.  HBM weight traffic
drops 4x -> 1x bytes (see benchmarks/kernel_bench.py for the analytic
ratio); the bf16 weight matrix never exists.

Numerics: dequantized tiles round through ``compute_dtype`` (bf16) before
the dot — the exact elementwise math of the staged
``dequantize_blockwise(..., bf16)`` + einsum — and partial products
accumulate in an fp32 output block (``preferred_element_type``).  The only
divergence from the staged einsum is fp32 summation ORDER (k-tiled
accumulation), so parity tests against :func:`repro.kernels.ref.
dequant_matmul_ref` use a tight allclose (~1 ulp of the fp32 partial
sums), not bit-equality; the ``xla`` backend in kernels/ops.py IS the
staged math and stays bit-identical.

Layout contract (shared with core.quant / quant_block.py): scales cover
``kb = K // NB`` contiguous trailing elements per row; the k tile is a
multiple of ``kb`` so scale groups never straddle tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quant_block import _divisor_at_most

Array = jax.Array

_MAX_TILE = 512  # cap per-instance k/n tile extent (VMEM working set)


def _gemm_kernel(x_ref, w_ref, s_ref, out_ref, *, kb, nk, compute_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[...]                                   # (bn, bk) int8
    s = s_ref[...]                                   # (bn, bk // kb) f32
    bn, bk = w.shape
    wf = (w.reshape(bn, bk // kb, kb).astype(jnp.float32)
          * s[..., None]).reshape(bn, bk).astype(compute_dtype)
    out_ref[...] += jax.lax.dot_general(
        x_ref[...], wf, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def dequant_matmul_pallas(x: Array, payload: Array, scales: Array,
                          compute_dtype=jnp.bfloat16,
                          out_dtype=jnp.float32,
                          interpret: bool = False) -> Array:
    """``x @ dequant(payload).T`` with scales applied in the k-tile loop.

    x: (T, K) activations; payload: (N, K) int8; scales: (N, NB) f32 with
    K % NB == 0.  Returns (T, N) ``out_dtype``.
    """
    T, K = x.shape
    N, Kw = payload.shape
    assert Kw == K, (Kw, K)
    nb = scales.shape[-1]
    assert scales.shape == (N, nb) and K % nb == 0, (scales.shape, K)
    kb = K // nb

    cb = _divisor_at_most(nb, max(1, _MAX_TILE // kb))
    bk = cb * kb
    bt = _divisor_at_most(T, 128)
    bn = _divisor_at_most(N, _MAX_TILE)
    nk = K // bk
    grid = (T // bt, N // bn, K // bk)  # k innermost: out block (i, j) stays
    #                                     VMEM-resident across the k loop
    kernel = functools.partial(_gemm_kernel, kb=kb, nk=nk,
                               compute_dtype=compute_dtype)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, bk // kb), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((T, N), jnp.float32),
        interpret=interpret,
    )(x, payload, scales)
    return out if out_dtype == jnp.float32 else out.astype(out_dtype)
