"""Pallas TPU kernel: fused dequantize -> fp32 reduce -> (re)quantize.

This is the qgZ inner operator (paper §4.2): after each all-to-all hop,
every device holds N quantized contributions to its gradient slice; they
must be dequantized, summed in full precision, and (for the intra-node hop)
re-quantized for the next hop.  Running those as three separate ops costs
3x reads + 2x writes of the fp32 intermediate; fusing them into one kernel
touches HBM once per input byte and once per output byte — the fusion the
paper credits with "reduc[ing] total memory traffic by 9x".

Tiling: grid over the slice length only; the contribution dim N (= GPUs per
node in the paper, mesh axis size here, <= 32) lives entirely inside the
tile, so the reduction is a single VMEM-resident ``sum`` over the sublane
dimension.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import QuantConfig
from repro.kernels.quant_block import pick_tiles, _quant_body

Array = jax.Array


def _unpack4(p: Array) -> Array:
    lo = (p << 4) >> 4
    hi = p >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1],
                                                p.shape[-1] * 2)


def _dequant_sum(p, s, block: int, pack: bool):
    """(N, pt) payload + (N, nbt) scales -> (ct,) fp32 sum."""
    if pack:
        p = _unpack4(p)
    N, ct = p.shape
    nb = ct // block
    deq = p.reshape(N, nb, block).astype(jnp.float32) * s[..., None]
    return jnp.sum(deq, axis=0).reshape(ct)  # fp32 reduce (accuracy: §3.3)


def _reduce_kernel(p_ref, s_ref, out_ref, *, block, pack, out_dtype):
    acc = _dequant_sum(p_ref[...], s_ref[...], block, pack)
    out_ref[...] = acc.astype(out_dtype)[None]


def _reduce_requant_kernel(p_ref, s_ref, out_p_ref, out_s_ref, *,
                           block, pack_in, qmax_out, pack_out):
    acc = _dequant_sum(p_ref[...], s_ref[...], block, pack_in)
    q, s = _quant_body(acc[None], block, qmax_out, pack_out)
    out_p_ref[...] = q
    out_s_ref[...] = s


def _reduce_requant_kernel_sr(p_ref, s_ref, u_ref, out_p_ref, out_s_ref, *,
                              block, pack_in, qmax_out, pack_out):
    """Stochastic-rounding variant: the requantization consumes a (1, ct)
    tile of pre-drawn uniforms (core.quant.stochastic_uniform), exactly
    like the standalone SR quant kernel — the PRNG stays outside the
    kernel so pallas/interpret/xla round identically per element."""
    acc = _dequant_sum(p_ref[...], s_ref[...], block, pack_in)
    q, s = _quant_body(acc[None], block, qmax_out, pack_out, u=u_ref[...])
    out_p_ref[...] = q
    out_s_ref[...] = s


def dequant_reduce_pallas(payload: Array, scales: Array, cfg: QuantConfig,
                          out_dtype=jnp.float32,
                          interpret: bool = False) -> Array:
    """Dequantize N contributions and sum: (N, P), (N, NB) -> (C,) fp32.

    Used for the final hop of qgZ (no requantization afterwards).
    """
    N, P = payload.shape
    pack = cfg.bits == 4
    C = P * 2 if pack else P
    block = cfg.block_size
    _, ct = pick_tiles(1, C, block)
    nbt = ct // block
    pt = ct // 2 if pack else ct
    grid = (C // ct,)
    kernel = functools.partial(_reduce_kernel, block=block, pack=pack,
                               out_dtype=out_dtype)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((N, pt), lambda j: (0, j)),
            pl.BlockSpec((N, nbt), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, ct), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, C), out_dtype),
        interpret=interpret,
    )(payload, scales)
    return out[0]


def dequant_reduce_quant_pallas(
    payload: Array, scales: Array,
    cfg_in: QuantConfig, cfg_out: QuantConfig,
    u: Optional[Array] = None,
    interpret: bool = False,
) -> Tuple[Array, Array]:
    """qgZ intra-hop fusion: (N, P), (N, NB) -> requantized (P'), (NB).

    ``cfg_in`` describes the incoming payload, ``cfg_out`` the outgoing
    (they share block_size; bits may differ, e.g. INT4 -> INT4).  ``u``
    is an optional (C,) uniform field for stochastic requantization,
    drawn OUTSIDE the kernel with the reference's segmentation
    (core.quant.stochastic_uniform) so every backend rounds bit-
    identically.
    """
    assert cfg_in.block_size == cfg_out.block_size
    N, P = payload.shape
    pack_in = cfg_in.bits == 4
    pack_out = cfg_out.bits == 4
    C = P * 2 if pack_in else P
    block = cfg_in.block_size
    _, ct = pick_tiles(1, C, block)
    nbt = ct // block
    pt_in = ct // 2 if pack_in else ct
    pt_out = ct // 2 if pack_out else ct
    grid = (C // ct,)
    in_specs = [
        pl.BlockSpec((N, pt_in), lambda j: (0, j)),
        pl.BlockSpec((N, nbt), lambda j: (0, j)),
    ]
    operands = [payload, scales]
    if u is None:
        kernel = functools.partial(_reduce_requant_kernel, block=block,
                                   pack_in=pack_in, qmax_out=cfg_out.qmax,
                                   pack_out=pack_out)
    else:
        assert u.shape == (C,), (u.shape, C)
        kernel = functools.partial(_reduce_requant_kernel_sr, block=block,
                                   pack_in=pack_in, qmax_out=cfg_out.qmax,
                                   pack_out=pack_out)
        in_specs.append(pl.BlockSpec((1, ct), lambda j: (0, j)))
        operands.append(u.reshape(1, C))
    out_p, out_s = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, pt_out), lambda j: (0, j)),
            pl.BlockSpec((1, nbt), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, C // 2 if pack_out else C), jnp.int8),
            jax.ShapeDtypeStruct((1, C // block), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    return out_p[0], out_s[0]
