"""XLA flags that let the compiler *execute* the prefetched schedule.

core/schedule.py arranges the program so that each scan iteration's
collectives are data-independent of its matmuls (verified structurally by
hlo_analysis.analyze_overlap).  Turning that freedom into wall-clock
overlap is the latency-hiding scheduler's job, and it is backend-specific:

  * TPU/GPU — the LHS pass rewrites collectives into async start/done
    pairs and hoists the starts above independent compute.  These are the
    flags the paper's DeepSpeed runs effectively rely on (NCCL streams).
  * CPU — no LHS pass exists; the thunk runtime's concurrency-optimized
    scheduler is the closest analogue.  The schedule is still *verified*
    on CPU via the dependence analysis; it just is not timed there.

``enable_overlap_flags()`` must run before the first jax import in the
process (XLA reads the env once at backend init) — launch/train.py calls
it at the top of ``main()``.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

# NOTE: XLA aborts the process on unknown/malformed flags, so each list
# holds only flags valid for that platform's jaxlib: the gpu/cpu lists are
# verified to parse against this repo's pinned jaxlib; --xla_tpu_* flags
# exist only in libtpu builds (passing platform="tpu" on a CPU/GPU jaxlib
# WILL abort at backend init — that is XLA's behaviour, not a typo here).
OVERLAP_FLAGS = {
    "tpu": (
        "--xla_tpu_enable_latency_hiding_scheduler=true",
    ),
    "gpu": (
        "--xla_gpu_enable_latency_hiding_scheduler=true",
        "--xla_gpu_enable_highest_priority_async_stream=true",
    ),
    "cpu": (
        "--xla_cpu_enable_concurrency_optimized_scheduler=true",
    ),
}


def overlap_xla_flags(platform: str = "cpu") -> Sequence[str]:
    """The latency-hiding flags for ``platform`` (tpu | gpu | cpu)."""
    return OVERLAP_FLAGS.get(platform, ())


def enable_overlap_flags(platform: str = "cpu",
                         env: Optional[dict] = None) -> str:
    """Append the platform's overlap flags to XLA_FLAGS (idempotent).

    Returns the resulting XLA_FLAGS value.  ``env`` defaults to
    ``os.environ``; pass a dict to build a subprocess environment instead.
    """
    env = os.environ if env is None else env
    parts = env.get("XLA_FLAGS", "").split()
    present = {p.split("=", 1)[0] for p in parts}
    for flag in overlap_xla_flags(platform):
        # match on the flag NAME: a user-set opposite value wins, we never
        # append a duplicate that would silently override it
        if flag.split("=", 1)[0] not in present:
            parts.append(flag)
    env["XLA_FLAGS"] = " ".join(parts)
    return env["XLA_FLAGS"]
