"""Loop-aware analysis of compiled (post-optimization) HLO text.

``compiled.cost_analysis()`` visits every instruction ONCE — it does not
multiply while-loop bodies by their trip counts, which makes it useless for
layer-scanned models (the entire per-layer compute/communication lives in a
while body).  This module re-derives the three roofline inputs by walking
the HLO computation graph with trip-count multiplication:

  * flops       — 2·|out|·K for every dot, recursing through while/call/
                  fusion/conditional, × trip count inside loops
  * hbm_bytes   — Σ (operand + output bytes) per *materialized* instruction
                  (fusion = one kernel: its operands/outputs are the HBM
                  traffic; internals are free), × trip count
  * collectives — per-op operand/wire bytes with ring-algorithm volume
                  formulas, split by interconnect tier (model / data / pod),
                  × trip count

Trip counts are parsed from each loop condition's integer constants — our
loops all come from lax.scan, whose conditions compare the induction
variable against a literal.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*)?\{\s*$")


def _split_instr(line: str):
    """(name, type_str, opcode) or None.  Handles tuple types that contain
    parens and /*index=N*/ comments (while/conditional results)."""
    m = _ASSIGN_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):          # tuple type: consume balanced parens
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str = rest[: i + 1]
        tail = rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        tail = rest[sp + 1:].lstrip()
    p = tail.find("(")
    if p <= 0:
        return None
    opcode = tail[:p].strip()
    if not re.fullmatch(r"[\w\-]+", opcode):
        return None
    return name, type_str, opcode
_GROUPS_V1_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
             "after-all", "iota", "partition-id", "replica-id"}


def _first_type_dims(tstr: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(tstr):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _type_bytes(tstr: str) -> int:
    total = 0
    for dt, dims in _first_type_dims(tstr):
        if dt in _DTYPE_BYTES:
            total += int(np.prod(dims)) * _DTYPE_BYTES[dt] if dims \
                else _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    line: str


def _parse_operands(line: str, opcode: str) -> List[str]:
    i = line.find(opcode + "(")
    if i < 0:
        return []
    call = line[i + len(opcode) + 1:]
    depth, args = 1, []
    buf = ""
    for ch in call:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args.append(buf)
                break
        if depth >= 1:
            buf += ch
    return re.findall(r"%([\w.\-]+)", "".join(args))


def parse_module(text: str) -> Dict[str, List[Instr]]:
    """Split HLO text into computations (name -> instruction list)."""
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m and ("(" in line or line.startswith("ENTRY")):
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        got = _split_instr(line)
        if got:
            name, tstr, opcode = got
            comps[cur].append(Instr(name, tstr, opcode,
                                    _parse_operands(line, opcode), line))
    return comps


def _attr_comp(line: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _trip_count(comps, cond_name: str) -> int:
    """Max integer constant in the loop condition = scan trip count."""
    best = 1
    for ins in comps.get(cond_name, []):
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _parse_groups(line: str) -> Optional[np.ndarray]:
    m = _GROUPS_V1_RE.search(line)
    if m:
        groups = [[int(x) for x in g.split(",") if x]
                  for g in re.findall(r"\{([^}]*)\}", m.group(1))]
        width = max(len(g) for g in groups)
        return np.array([g + [g[-1]] * (width - len(g)) for g in groups])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        return ids.reshape(ng, gs)
    return None


def _group_tier(groups: Optional[np.ndarray], multi_pod: bool) -> str:
    if groups is None:
        return "data"
    g = groups
    if multi_pod and np.ptp(g // 256, axis=1).max() > 0:
        return "pod"
    if np.ptp((g % 256) // 16, axis=1).max() > 0:
        return "data"
    return "model"


def _wire_bytes(op: str, in_bytes: int, out_bytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-gather":
        return max(out_bytes - in_bytes, 0)
    if op == "reduce-scatter":
        return max(in_bytes - out_bytes, 0)
    if op == "all-reduce":
        return 2.0 * in_bytes * (n - 1) / n
    if op == "all-to-all":
        return in_bytes * (n - 1) / n
    if op == "collective-permute":
        return float(in_bytes)
    return float(in_bytes)


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    out_elems = 1
    for dt, dims in _first_type_dims(ins.type_str):
        out_elems = int(np.prod(dims)) if dims else 1
        break
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if not m or not ins.operands:
        return 2.0 * out_elems  # dot with no contraction info
    cdims = [int(x) for x in m.group(1).split(",") if x]
    lhs_t = shapes.get(ins.operands[0])
    if lhs_t is None:
        return 2.0 * out_elems
    for dt, dims in _first_type_dims(lhs_t):
        k = 1
        for c in cdims:
            if c < len(dims):
                k *= dims[c]
        return 2.0 * out_elems * k
    return 2.0 * out_elems


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    transcendentals: float = 0.0
    coll_per_op: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    coll_per_tier: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {"model": 0.0, "data": 0.0, "pod": 0.0})
    coll_count: int = 0

    def add_collective(self, base: str, in_b: float, wire: float, tier: str,
                       mult: float):
        d = self.coll_per_op.setdefault(
            base, {"count": 0, "operand_bytes": 0.0, "wire_bytes": 0.0})
        d["count"] += mult
        d["operand_bytes"] += in_b * mult
        d["wire_bytes"] += wire * mult
        self.coll_per_tier[tier] += wire * mult
        self.coll_count += int(mult)


def _walk(comps, name: str, mult: float, t: Totals, multi_pod: bool,
          world: int, memo: Dict[str, "Totals"], depth: int = 0):
    """Accumulate totals for one computation, scaled by ``mult``."""
    if depth > 50:
        return
    shapes = {i.name: i.type_str for i in comps.get(name, [])}
    for ins in comps.get(name, []):
        op = ins.opcode
        if op == "while":
            body = _attr_comp(ins.line, "body")
            cond = _attr_comp(ins.line, "condition")
            trips = _trip_count(comps, cond) if cond else 1
            if body:
                _walk(comps, body, mult * trips, t, multi_pod, world, memo,
                      depth + 1)
            continue
        if op in ("call", "async-start"):
            tgt = _attr_comp(ins.line, "to_apply") \
                or _attr_comp(ins.line, "calls")
            if tgt:
                _walk(comps, tgt, mult, t, multi_pod, world, memo, depth + 1)
            continue
        if op == "conditional":
            for tgt in re.findall(r"%([\w.\-]+)",
                                  ins.line.split("branch_computations")[-1]
                                  if "branch_computations" in ins.line
                                  else ""):
                _walk(comps, tgt, mult, t, multi_pod, world, memo, depth + 1)
            continue

        base = op.replace("-start", "")
        if base in _COLL_OPS and not op.endswith("-done"):
            in_b = sum(_type_bytes(shapes.get(o, "")) for o in ins.operands)
            out_b = _type_bytes(ins.type_str)
            groups = _parse_groups(ins.line)
            n = groups.shape[1] if groups is not None else world
            tier = _group_tier(groups, multi_pod)
            wire = _wire_bytes(base, in_b, out_b, n)
            t.add_collective(base, in_b, wire, tier, mult)
            t.hbm_bytes += (in_b + out_b) * mult
            continue

        if op == "fusion":
            tgt = _attr_comp(ins.line, "calls")
            if tgt:
                # dots may hide inside fusions: count their flops, but the
                # fusion's HBM traffic is its own output (operands were
                # counted when produced)
                sub = memo.get(tgt)
                if sub is None:
                    sub = Totals()
                    _walk(comps, tgt, 1.0, sub, multi_pod, world, {},
                          depth + 1)
                    sub.hbm_bytes = 0.0
                    memo[tgt] = sub
                t.flops += sub.flops * mult
                t.transcendentals += sub.transcendentals * mult
            t.hbm_bytes += _type_bytes(ins.type_str) * mult
            continue

        if op in ("dot", "convolution"):
            t.flops += _dot_flops(ins, shapes) * mult
            # dots re-read both operands from HBM (weights/activations) and
            # write the product: count operands + output
            t.hbm_bytes += (sum(_type_bytes(shapes.get(o, ""))
                                for o in ins.operands)
                            + _type_bytes(ins.type_str)) * mult
            continue
        if op in ("exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                  "sine", "cosine", "logistic"):
            t.transcendentals += _type_bytes(ins.type_str) * mult

        if op not in _FREE_OPS:
            # non-dot materializations: count the write once; reads were
            # someone else's write (fusion-blind traffic lower bound — see
            # DESIGN.md §Roofline caveats)
            t.hbm_bytes += _type_bytes(ins.type_str) * mult


def analyze_hlo(text: str, world: int, multi_pod: bool) -> Dict:
    """Loop-aware flops / bytes / collective totals for the entry module."""
    comps = parse_module(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
                break
    if entry is None:  # fall back: biggest computation
        entry = max(comps, key=lambda k: len(comps[k]))
    t = Totals()
    _walk(comps, entry, 1.0, t, multi_pod, world, {})
    return {
        "flops": t.flops,
        "hbm_bytes": t.hbm_bytes,
        "transcendental_bytes": t.transcendentals,
        "collectives": {
            "per_op": t.coll_per_op,
            "per_tier_wire": t.coll_per_tier,
            "count": t.coll_count,
            "operand_bytes": sum(d["operand_bytes"]
                                 for d in t.coll_per_op.values()),
            "wire_bytes": sum(d["wire_bytes"]
                              for d in t.coll_per_op.values()),
        },
    }


# ---------------------------------------------------------------------------
# liveness-aware peak memory estimate
# ---------------------------------------------------------------------------

_ALIAS_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
              "after-all", "constant", "iota", "partition-id", "replica-id"}


def _comp_peak(comps, name: str, memo: Dict[str, float]) -> float:
    """Peak live bytes of one computation under its textual (program-order)
    schedule — a valid sequential schedule, hence an ACHIEVABLE peak.

    The CPU backend's actual buffer assignment schedules for thread
    concurrency and can hold many more buffers live simultaneously; a TPU
    compiler schedules much closer to program order.  Aliasing ops are free;
    while loops contribute state + max(body, cond) peak; fusions contribute
    their output only (internals live in registers/VMEM).
    """
    if name in memo:
        return memo[name]
    memo[name] = 0.0  # cycle guard
    instrs = comps.get(name, [])
    sizes: Dict[str, float] = {}
    alias_of: Dict[str, str] = {}

    def root(n):  # follow alias chains to the owning buffer
        seen = set()
        while n in alias_of and n not in seen:
            seen.add(n)
            n = alias_of[n]
        return n

    # last textual use index per buffer root
    last_use: Dict[str, int] = {}
    for i, ins in enumerate(instrs):
        for o in ins.operands:
            last_use[o] = i
    live: Dict[str, float] = {}
    # parameters live from entry
    for ins in instrs:
        if ins.opcode == "parameter":
            sizes[ins.name] = _type_bytes(ins.type_str)
            live[ins.name] = sizes[ins.name]
    peak = sum(live.values())

    for i, ins in enumerate(instrs):
        extra = 0.0
        if ins.opcode in _ALIAS_OPS:
            if ins.opcode in ("get-tuple-element", "bitcast") and ins.operands:
                alias_of[ins.name] = ins.operands[0]
            sizes.setdefault(ins.name, 0.0)
        else:
            out_b = float(_type_bytes(ins.type_str))
            sizes[ins.name] = out_b
            live[ins.name] = out_b
            if ins.opcode == "while":
                body = _attr_comp(ins.line, "body")
                cond = _attr_comp(ins.line, "condition")
                extra = max(_comp_peak(comps, body, memo) if body else 0.0,
                            _comp_peak(comps, cond, memo) if cond else 0.0)
            elif ins.opcode in ("call", "conditional"):
                tgt = _attr_comp(ins.line, "to_apply")
                if tgt:
                    extra = _comp_peak(comps, tgt, memo)
        peak = max(peak, sum(live.values()) + extra)
        # free buffers whose last use has passed
        for o in list(live):
            if last_use.get(o, -1) <= i and o != ins.name:
                # keep if some alias of it is used later
                still = any(last_use.get(a, -1) > i
                            for a, r in alias_of.items() if root(r) == o)
                if not still:
                    del live[o]
    memo[name] = peak
    return peak


def estimate_peak_bytes(text: str) -> float:
    """Liveness-based peak for the entry computation (program order)."""
    comps = parse_module(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
                break
    if entry is None:
        entry = max(comps, key=lambda k: len(comps[k]))
    return _comp_peak(comps, entry, {})


# ---------------------------------------------------------------------------
# communication/compute overlap detection (prefetched schedule verification)
# ---------------------------------------------------------------------------
#
# Two complementary detections, because backends differ in what the compiled
# HLO shows:
#
#  * async pairs — GPU/TPU latency-hiding schedulers rewrite collectives
#    into ``all-gather-start``/``all-gather-done`` (or ``async-start`` /
#    ``async-done``) pairs and hoist the start above independent compute.
#    A pair with a dot scheduled between start and done IS overlap,
#    directly observable.
#  * dependence analysis — the CPU backend (and any backend before the LHS
#    pass) keeps collectives synchronous in the HLO text, so overlap has to
#    be read off the *structure*: inside a while (scan) body, a collective
#    that neither consumes this iteration's matmul results nor feeds them
#    is schedulable concurrently with the body's compute.  That is exactly
#    what the prefetched schedule produces (gathers feed only the loop
#    carry; the pipelined reduce-scatter consumes only the carry), and what
#    the synchronous schedule cannot (its gathers feed the dots directly).
#
# ``overlap_fraction`` is the wire-byte-weighted share of in-loop
# collectives that are overlappable; async pairs, when present, are
# reported alongside.  Nested loops (the MoE expert-chunk scan inside the
# layer scan) are weighted by their enclosing trip-count product, and a
# loop body with no compute at all (gather-only remat loops) exposes its
# collectives — see _body_overlap / _loop_multipliers.
#
# Ring-depth accounting: the depth-k prefetch ring (core/schedule.py)
# inserts each gather's result into a (k, ...) carried ring buffer, so the
# value is not consumed for k iterations — the gather is credited against
# k iterations of compute, not one.  ``slack_iters`` is read off the HLO
# structurally (the leading dim of the ring buffer the collective's result
# is dynamic-update-sliced into; 1 when no ring is found), and
# :func:`effective_overlap` turns (slack, per-iteration flops, wire bytes)
# into a wall-clock-model overlap fraction at an explicit operating point
# (peak flops, per-tier bandwidth, per-collective latency): structure says
# which bytes CAN move under compute, the operating point says which bytes
# FIT.  Depth k>1 strictly increases the fit when one iteration's compute
# cannot cover a gather — the low-bandwidth regime the ring exists for.


def _fusion_has_dot(comps, name: str, memo: Dict[str, bool],
                    visiting: Optional[set] = None) -> bool:
    if name in memo:
        return memo[name]
    visiting = set() if visiting is None else visiting
    if name in visiting:   # cycle (malformed HLO): unresolved, don't cache
        return False
    visiting.add(name)
    res = False
    for ins in comps.get(name, []):
        if ins.opcode in ("dot", "convolution"):
            res = True
            break
        if ins.opcode == "fusion":
            tgt = _attr_comp(ins.line, "calls")
            if tgt and _fusion_has_dot(comps, tgt, memo, visiting):
                res = True
                break
    visiting.discard(name)
    memo[name] = res
    return res


def _is_compute(comps, ins: Instr, memo: Dict[str, bool]) -> bool:
    """Does this instruction perform matmul work (directly or via fusion)?"""
    if ins.opcode in ("dot", "convolution"):
        return True
    if ins.opcode == "fusion":
        tgt = _attr_comp(ins.line, "calls")
        return bool(tgt) and _fusion_has_dot(comps, tgt, memo)
    return False


def _ring_slack(by_name: Dict[str, "Instr"], users: Dict[str, List[str]],
                ins: "Instr") -> int:
    """Iterations of compute a collective's result can hide under.

    The depth-k ring schedule dynamic-update-slices each prefetched buffer
    into a (k, ...) ring carried by the loop, so the value is first READ k
    iterations after the gather was issued.  Walk the collective's user
    chain (through dequantize fusions / converts / reshapes, which keep
    the leading shape) until an op inserts it into a buffer with one extra
    leading dim — that dim is the ring depth.  No ring found (the value is
    consumed directly, e.g. the classic double buffer's bare carry or a
    synchronous gather) = 1.
    """
    shapes = _first_type_dims(ins.type_str)
    if not shapes:
        return 1
    base = shapes[0][1]
    seen, stack = set(), [ins.name]
    while stack:
        cur = stack.pop()
        for u in users.get(cur, []):
            if u in seen:
                continue
            seen.add(u)
            ui = by_name.get(u)
            if ui is None:
                continue
            udims_list = _first_type_dims(ui.type_str)
            if not udims_list:
                continue
            udims = udims_list[0][1]
            if (ui.opcode in ("dynamic-update-slice", "fusion")
                    and len(udims) == len(base) + 1 and udims[1:] == base
                    and udims[0] >= 1):
                return udims[0]
            stack.append(u)
    return 1


def _body_flops(comps, body: str, memo: Dict[str, float]) -> float:
    """Per-iteration matmul flops of one while body (nested loops counted
    at their trip counts — one outer iteration runs them in full)."""
    if body not in memo:
        t = Totals()
        _walk(comps, body, 1.0, t, False, 1, {})
        memo[body] = t.flops
    return memo[body]


def _body_overlap(comps, body: str, fus_memo: Dict[str, bool],
                  multi_pod: bool = False) -> List[Dict]:
    """Classify each collective in one while body as overlappable or
    exposed, by within-iteration dependence on matmul compute.

    A body with NO matmul compute at all (e.g. the gather-only loop XLA
    leaves behind when a nested remat's recomputed GEMMs are dead-code
    eliminated — the MoE expert-chunk re-gather) exposes every collective:
    independence means nothing when the iteration has nothing to hide
    behind."""
    instrs = comps.get(body, [])
    by_name = {i.name: i for i in instrs}
    users: Dict[str, List[str]] = {}
    for ins in instrs:
        for o in ins.operands:
            users.setdefault(o, []).append(ins.name)

    def reaches_compute_down(name: str) -> bool:
        seen, stack = set(), [name]
        while stack:
            cur = stack.pop()
            for u in users.get(cur, []):
                if u in seen:
                    continue
                seen.add(u)
                ins = by_name.get(u)
                if ins is None:
                    continue
                if _is_compute(comps, ins, fus_memo):
                    return True
                stack.append(u)
        return False

    def derives_from_compute_up(name: str) -> bool:
        seen, stack = set(), [name]
        while stack:
            cur = stack.pop()
            ins = by_name.get(cur)
            if ins is None:
                continue
            for o in ins.operands:
                if o in seen:
                    continue
                seen.add(o)
                oi = by_name.get(o)
                if oi is None:
                    continue
                if _is_compute(comps, oi, fus_memo):
                    return True
                stack.append(o)
        return False

    has_compute = any(_is_compute(comps, i, fus_memo) for i in instrs)
    out = []
    shapes = {i.name: i.type_str for i in instrs}
    for ins in instrs:
        base = ins.opcode.replace("-start", "")
        if base not in _COLL_OPS or ins.opcode.endswith("-done"):
            continue
        in_b = sum(_type_bytes(shapes.get(o, "")) for o in ins.operands)
        out_b = _type_bytes(ins.type_str)
        groups = _parse_groups(ins.line)
        n = groups.shape[1] if groups is not None else 0
        wire = _wire_bytes(base, in_b, out_b, n) if n else float(in_b)
        overlappable = (has_compute
                        and not reaches_compute_down(ins.name)
                        and not derives_from_compute_up(ins.name))
        out.append({"op": base, "name": ins.name, "wire_bytes": wire,
                    "overlappable": overlappable,
                    "tier": _group_tier(groups, multi_pod),
                    "slack_iters": _ring_slack(by_name, users, ins)
                    if overlappable else 1})
    return out


def _async_pairs(comps, fus_memo: Dict[str, bool]) -> Tuple[int, int]:
    """(n_async_collective_pairs, n_pairs_enclosing_compute) across all
    computations — textual program order between start and done."""
    pairs = enclosing = 0
    for name, instrs in comps.items():
        pos = {i.name: k for k, i in enumerate(instrs)}
        for ins in instrs:
            op = ins.opcode
            base = op.replace("-start", "")
            is_coll_start = op.endswith("-start") and base in _COLL_OPS
            if not (is_coll_start or op == "async-start"):
                continue
            # find the matching done: the (unique) *-done/async-done user
            done_idx = None
            for other in instrs:
                if ins.name in other.operands and (
                        other.opcode.endswith("-done")):
                    done_idx = pos[other.name]
                    break
            if done_idx is None:
                continue
            pairs += 1
            lo = pos[ins.name]
            if any(_is_compute(comps, instrs[k], fus_memo)
                   for k in range(lo + 1, done_idx)):
                enclosing += 1
    return pairs, enclosing


def _loop_multipliers(comps, entry: str) -> Dict[str, float]:
    """body name -> product of ENCLOSING loops' trip counts, walking from
    ``entry`` through while/call/conditional edges.

    A while nested inside another while's body (the MoE expert-chunk scan
    inside the layer scan) runs its trips once per outer iteration; its
    wire bytes must be weighted by the outer trip product or the nested
    (overlappable) chunk gathers are undercounted relative to the
    top-level loops.  Fusions are not traversed (XLA fusions cannot
    contain loops)."""
    mults: Dict[str, float] = {}
    seen = set()

    def walk(name: str, mult: float, depth: int = 0):
        if depth > 50 or (name, mult) in seen:
            return
        seen.add((name, mult))
        for ins in comps.get(name, []):
            if ins.opcode == "while":
                body = _attr_comp(ins.line, "body")
                cond = _attr_comp(ins.line, "condition")
                trips = _trip_count(comps, cond) if cond else 1
                if body:
                    mults[body] = max(mults.get(body, 0.0), mult)
                    walk(body, mult * trips, depth + 1)
            elif ins.opcode in ("call", "async-start", "conditional"):
                for key in ("to_apply", "calls"):
                    tgt = _attr_comp(ins.line, key)
                    if tgt and tgt in comps:
                        walk(tgt, mult, depth + 1)
                if ins.opcode == "conditional" and \
                        "branch_computations" in ins.line:
                    for tgt in re.findall(
                            r"%([\w.\-]+)",
                            ins.line.split("branch_computations")[-1]):
                        if tgt in comps:
                            walk(tgt, mult, depth + 1)

    walk(entry, 1.0)
    return mults


def _entry_name(text: str, comps) -> Optional[str]:
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                return m.group(1)
    return max(comps, key=lambda k: len(comps[k])) if comps else None


def analyze_overlap(text: str, multi_pod: bool = False) -> Dict:
    """Overlap metrics for a compiled HLO module (see block comment above).
    ``multi_pod`` feeds the tier classifier so cross-pod collectives are
    priced at the pod tier by :func:`effective_overlap`.

    Returns:
      in_loop_wire_bytes      — Σ wire bytes of collectives in while bodies
                                (× trip count × enclosing-loop trips)
      overlapped_wire_bytes   — the overlappable subset
      overlap_fraction        — overlapped / in_loop (0.0 when no in-loop
                                collectives)
      per_loop                — per while-body breakdown (``outer_mult`` is
                                the enclosing-loop trip product — nested
                                MoE chunk scans run once per outer layer)
      async_pairs / async_pairs_enclosing_compute — LHS-scheduler evidence,
                                when the backend emits async collectives
    """
    comps = parse_module(text)
    fus_memo: Dict[str, bool] = {}
    flop_memo: Dict[str, float] = {}
    entry = _entry_name(text, comps)
    mults = _loop_multipliers(comps, entry) if entry else {}
    per_loop = {}
    total = overlapped = 0.0
    n_coll = n_over = 0
    for name, instrs in comps.items():
        for ins in instrs:
            if ins.opcode != "while":
                continue
            body = _attr_comp(ins.line, "body")
            cond = _attr_comp(ins.line, "condition")
            if not body or body in per_loop:
                continue
            trips = _trip_count(comps, cond) if cond else 1
            colls = _body_overlap(comps, body, fus_memo, multi_pod)
            if not colls:
                continue
            mult = mults.get(body, 1.0)
            wire = sum(c["wire_bytes"] for c in colls) * trips * mult
            over = sum(c["wire_bytes"] for c in colls
                       if c["overlappable"]) * trips * mult
            per_loop[body] = {
                "trip_count": trips,
                "outer_mult": mult,
                "collectives": len(colls),
                "overlappable": sum(c["overlappable"] for c in colls),
                "wire_bytes": wire,
                "overlapped_wire_bytes": over,
                "has_compute": bool(_body_flops(comps, body, flop_memo)),
                "flops_per_iter": _body_flops(comps, body, flop_memo),
                "max_slack_iters": max(c["slack_iters"] for c in colls),
                "colls": [{k: c[k] for k in ("op", "wire_bytes",
                                             "overlappable", "tier",
                                             "slack_iters")}
                          for c in colls],
            }
            total += wire
            overlapped += over
            n_coll += len(colls)
            n_over += sum(c["overlappable"] for c in colls)
    pairs, enclosing = _async_pairs(comps, fus_memo)
    return {
        "in_loop_wire_bytes": total,
        "overlapped_wire_bytes": overlapped,
        "overlap_fraction": (overlapped / total) if total else 0.0,
        "in_loop_collectives": n_coll,
        "overlappable_collectives": n_over,
        "per_loop": per_loop,
        "async_pairs": pairs,
        "async_pairs_enclosing_compute": enclosing,
    }


# ---------------------------------------------------------------------------
# depth-credited (wall-clock-model) overlap at an operating point
# ---------------------------------------------------------------------------

# the canonical low-bandwidth operating point for ring measurements
# (checks.check_ring_overlap_depth, benchmarks/overlap_bench.py): ALL
# tiers priced at the slow interconnect.  On the <=16-device smoke meshes
# _group_tier's replica-group classification is degenerate (everything
# reads as the fast tier), so uniform pricing is the only honest way to
# measure the slow-interconnect regime there; per-tier bandwidths belong
# to real multi-node meshes.
RING_OPERATING_POINT = {
    "peak_flops": 197e12,                       # bf16 flop/s per chip
    "tier_bw": {"model": 12.5e9, "data": 12.5e9, "pod": 12.5e9},  # 1 IB
    "coll_latency_s": 20e-6,
}


def effective_overlap(ov: Dict, *, peak_flops: float,
                      tier_bw: Dict[str, float],
                      coll_latency_s: float = 0.0) -> Dict:
    """Ring-depth-credited overlap fraction at an explicit operating point.

    ``overlap_fraction`` (structural) says which in-loop wire bytes CAN be
    scheduled under compute; this model says which bytes FIT: a collective
    issued d iterations early (``slack_iters`` — the ring depth read off
    the HLO) has a window of d iterations of body compute to complete in,

        t_window = min(d, trips) · flops_per_iter / peak_flops
        t_comm   = coll_latency_s + wire / tier_bw[tier]
        hidden   = wire · min(1, t_window / t_comm)

    Exposed (structurally dependent) collectives hide nothing.  The
    fraction is monotone in ring depth and coincides with the structural
    fraction when every overlappable collective fits its window —
    ``prefetch=2`` beats ``prefetch=1`` exactly in the regime where one
    iteration's compute cannot cover a gather (slow interconnects, small
    decode batches).  ``ov`` is an :func:`analyze_overlap` result.
    """
    total = hidden = 0.0
    for loop in ov["per_loop"].values():
        weight = loop["trip_count"] * loop["outer_mult"]
        t_iter = loop["flops_per_iter"] / peak_flops
        for c in loop["colls"]:
            wire = c["wire_bytes"] * weight
            total += wire
            if not c["overlappable"] or c["wire_bytes"] <= 0:
                continue
            bw = tier_bw.get(c["tier"], min(tier_bw.values()))
            t_comm = coll_latency_s + c["wire_bytes"] / bw
            window = min(c["slack_iters"], loop["trip_count"]) * t_iter
            hidden += wire * (1.0 if t_comm <= 0.0
                              else min(1.0, window / t_comm))
    return {
        "effective_overlap_fraction": (hidden / total) if total else 0.0,
        "hidden_wire_bytes": hidden,
        "in_loop_wire_bytes": total,
        "operating_point": {"peak_flops": peak_flops, "tier_bw": tier_bw,
                            "coll_latency_s": coll_latency_s},
    }
