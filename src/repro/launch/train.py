"""End-to-end training driver: data -> train_step -> checkpoint -> restart.

Production behaviours exercised here (and by tests/examples):
  * deterministic synthetic data pipeline (cursor == step counter)
  * periodic atomic PER-SHARD checkpoints via the ZeroState subsystem
    (train/state.py), optionally INT8 block-quantized (--ckpt-format int8)
  * restart-from-latest on failure (``--simulate-failure-at`` raises mid-run
    to prove it), including ELASTIC restart onto a different device count —
    flat buffers re-fit onto the new world's padding (see state.fit_to)
  * per-step metrics (loss / grad-norm / tokens/s)
  * ``--elastic``: the fault-tolerant supervisor (train/elastic.py) with
    async background checkpoints, SIGTERM grace drain, restart on worker
    death and live ``--reshard`` mid-run; ``--fault-*`` flags inject the
    failure menu from testing/faults.py for the smoke suite

Run on CPU with simulated devices, e.g.:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch gpt-350m --reduced \
      --mesh 4x2 --steps 20 --batch 16 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Built:
    """build_everything's result: unpacks like the legacy 6-tuple
    (``mesh, arch, model, opt_cfg, step, lm = build_everything(...)``)
    while also carrying the resolved policy for --tune consumers."""
    mesh: Any
    arch: Any
    model: Any
    opt_cfg: Any
    step: Any
    lm: Any
    policy: Any = None      # Policy (tune=off) or tune.ResolvedPolicy

    def __iter__(self):
        return iter((self.mesh, self.arch, self.model, self.opt_cfg,
                     self.step, self.lm))


def build_everything(arch_name: str, mesh_shape: Tuple[int, ...],
                     variant: str, reduced: bool, batch: int, seq: int,
                     lr: float, accum: int = 1, moe_chunks: int = 0,
                     tune: str = "off", hbm_gb: float = 16.0) -> "Built":
    """Construct (mesh, model, train_step, data, specs) for a run.

    ``tune``: "off" keeps the static preset table (train/policy.py);
    "static"/"probe" route through ``repro.tune.resolve`` — the committed
    profile or a live mesh probe feeding the prefetch/block/hpZ knobs,
    with the (k+1)-ring HBM ledger charged against ``hbm_gb``.
    """
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.data.synthetic import SyntheticLM
    from repro.models.model import Model
    from repro.optim.adamw import AdamWConfig
    from repro.optim.schedule import warmup_cosine
    from repro.train import trainer as trainer_lib
    from repro.train.policy import make_policy

    from repro.core.compat import auto_axis_types, make_mesh
    axes = ("data", "model") if len(mesh_shape) == 2 \
        else ("pod", "data", "model")
    mesh = make_mesh(mesh_shape, axes, axis_types=auto_axis_types(len(axes)))
    arch = get_config(arch_name)
    if reduced:
        arch = arch.reduced()
    if moe_chunks:
        arch = dataclasses.replace(arch, expert_chunks=moe_chunks)
    world = int(np.prod(mesh_shape))
    if tune and tune != "off":
        from repro.tune import GB, resolve
        pol = resolve(
            arch, axes, variant, mode=tune,
            mesh=mesh if tune == "probe" else None,
            mesh_sizes=dict(zip(axes, (int(s) for s in mesh_shape))),
            hbm_budget_bytes=int(hbm_gb * GB),
            tokens_per_device=max((batch * seq) // world, 1))
    else:
        pol = make_policy(arch, axes, variant)
    model = Model(arch, pol.zcfg, world=world)
    opt_cfg = AdamWConfig(lr=warmup_cosine(lr, 10, 10_000),
                          moments_dtype=pol.moments_dtype)
    step = trainer_lib.build_train_step(model, mesh, opt_cfg, accum=accum,
                                        global_batch=batch)
    lm = SyntheticLM(vocab=arch.vocab, seq_len=seq, seed=7)
    return Built(mesh, arch, model, opt_cfg, step, lm, policy=pol)


def save_ckpt(ckpt_dir: str, step_i: int, state, meta: Dict,
              fmt: str = "fp32"):
    """Per-shard atomic save of a :class:`repro.train.state.ZeroState`."""
    return state.save(ckpt_dir, step_i, meta=meta, fmt=fmt)


def restore_ckpt(ckpt_dir: str, model, mesh, opt_cfg):
    """Load latest checkpoint and re-shard onto the CURRENT mesh/model
    (elastic: the saved world size/alignment may differ)."""
    from repro.train.state import ZeroState

    st = ZeroState.restore(model, mesh, opt_cfg, ckpt_dir)
    if st is None:
        return None
    return st.step, st.params, st.opt, st.meta


def _setup_telemetry(args):
    """Install the jsonl tracer when ``--metrics-dir`` is given; otherwise
    leave the disabled singleton in place (no-op spans, zero overhead)."""
    from repro.obs.trace import Tracer, get_tracer, set_tracer
    if getattr(args, "metrics_dir", None):
        tracer = Tracer(os.path.join(args.metrics_dir, "events.jsonl"))
        set_tracer(tracer)
        return tracer
    return get_tracer()


def _comm_per_step(ts, mesh, params, opt, batch) -> Dict[str, float]:
    """One-time jaxpr walk of the train step: per-device wire bytes by
    collective label.  Traced once (abstract eval — no execution), then
    folded into host counters every step."""
    import jax
    from repro.launch.jaxpr_analysis import analyze_jaxpr
    cj = jax.make_jaxpr(ts.fn)(params, opt, batch)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return analyze_jaxpr(cj, sizes)["collectives"]["wire_by_label"]


def train_loop(args) -> Dict[str, Any]:
    import jax
    from repro.data.synthetic import make_batch
    from repro.obs.metrics import get_registry
    from repro.train.state import ZeroState
    from repro.train.trainer import place_batch

    mesh_shape = tuple(int(x) for x in args.mesh.split("x"))
    tune = getattr(args, "tune", "off") or "off"
    built = build_everything(
        args.arch, mesh_shape, args.variant, args.reduced, args.batch,
        args.seq, args.lr, args.accum, tune=tune,
        hbm_gb=getattr(args, "hbm_gb", 16.0))
    mesh, arch, model, opt_cfg, ts, lm = built
    pol = built.policy
    if tune != "off":
        print(f"[tune] {pol.explain()}")

    start = 0
    st = None
    if args.ckpt_dir:
        st = ZeroState.restore(model, mesh, opt_cfg, args.ckpt_dir)
    if st is not None:
        start = st.step
        print(f"[train] restored step {start} from {args.ckpt_dir} "
              f"(saved world={st.meta.get('world')}, now={ts.world})")
    else:
        st = ZeroState(model, mesh, opt_cfg).init(
            jax.random.PRNGKey(args.seed))
    params, opt = st.params, st.opt

    b_specs = ts.in_specs[2]
    losses = []
    t_start = time.time()
    telemetry = bool(getattr(args, "metrics_dir", None))
    tracer = _setup_telemetry(args)
    trace_steps = int(getattr(args, "trace_steps", 0) or 0)
    reg = get_registry()
    if telemetry:
        # record the chosen policy so dashboards can segment runs by knob
        z = pol.zcfg
        reg.gauge("tune.prefetch").set(z.prefetch)
        reg.gauge("tune.qwz").set(int(z.qwz))
        reg.gauge("tune.hpz").set(int(z.hpz))
        reg.gauge("tune.qgz").set(int(z.qgz))
        reg.gauge("tune.qwz_block").set(z.qwz_block)
        reg.gauge("tune.qgz_block").set(z.qgz_block)
        reg.gauge("tune.mode").set(
            {"off": 0, "static": 1, "probe": 2}.get(tune, 0))
    comm = None   # {label: per-device bytes/step}, filled on first step
    for i in range(start, args.steps):
        if args.simulate_failure_at is not None \
                and i == args.simulate_failure_at:
            raise RuntimeError(f"simulated node failure at step {i}")
        host = make_batch(arch, lm, i, args.batch)
        if args.accum > 1:
            host = {k: v.reshape((args.accum, -1) + v.shape[1:])
                    for k, v in host.items()}
        batch = place_batch(host, mesh, b_specs)
        if telemetry and comm is None:
            comm = _comm_per_step(ts, mesh, params, opt, batch)
            for lbl, b in comm.items():
                reg.gauge(f"comm.{lbl}.bytes_per_step").set(b)
        # profiler annotations only for the first --trace-steps steps (the
        # TraceAnnotation enter/exit is the one per-step cost worth gating)
        tracer.profiler_annotations = (i - start) < trace_steps
        t_step = time.monotonic_ns()
        with tracer.span("train.step", step=i):
            params, opt, metrics = ts.fn(params, opt, batch)
            loss = float(metrics["loss"])
        losses.append(loss)
        if telemetry:
            wall_ms = (time.monotonic_ns() - t_step) / 1e6
            reg.histogram("train.step.wall_ms").observe(wall_ms)
            reg.counter("train.steps").inc()
            reg.counter("train.tokens").inc(float(metrics["tokens"]))
            for lbl, b in comm.items():
                reg.counter(f"comm.{lbl}.bytes").inc(b)
            tracer.counter("train.steps", 1, step=i)
            tracer.counter("train.tokens", float(metrics["tokens"]), step=i)
            tracer.flush()
        if args.log_every and (i % args.log_every == 0 or i == args.steps - 1):
            dt = time.time() - t_start
            toks = float(metrics["tokens"]) * (i - start + 1)
            print(f"[train] step {i} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"tok/s {toks / max(dt, 1e-9):,.0f}")
        if args.ckpt_dir and args.ckpt_every \
                and (i + 1) % args.ckpt_every == 0:
            st.params, st.opt, st.step = params, opt, i + 1
            save_ckpt(args.ckpt_dir, i + 1, st,
                      {"world": ts.world, "arch": arch.name,
                       "data_cursor": i + 1},
                      fmt=args.ckpt_format)
    gate_report = None
    if telemetry:
        from repro.obs.report import (export_snapshot,
                                      projected_wire_by_label, runtime_gate)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        projected = projected_wire_by_label(model, sizes, accum=args.accum)
        gate_report = runtime_gate(
            measured=comm or {}, projected=projected,
            strict=bool(getattr(args, "obs_gate", False)))
        policy_dict = (pol.as_dict() if hasattr(pol, "as_dict")
                       else {"mode": "off", "prefetch": pol.zcfg.prefetch,
                             "note": pol.note})
        export_snapshot(
            os.path.join(args.metrics_dir, "BENCH_runtime.json"),
            extra={"gate": gate_report,
                   "policy": policy_dict,
                   "config": {"arch": arch.name, "variant": args.variant,
                              "mesh": list(mesh_shape), "tune": tune,
                              "steps": args.steps, "batch": args.batch,
                              "seq": args.seq, "accum": args.accum}})
        tracer.close()
        ok = "PASS" if gate_report["ok"] else "FAIL"
        print(f"[train] obs gate {ok}: comm labels "
              f"{sorted((comm or {}))} vs analytic projection "
              f"(BENCH -> {args.metrics_dir}/BENCH_runtime.json)")
    return {"losses": losses, "entropy_bound": lm.entropy_bound,
            "final_loss": losses[-1] if losses else None,
            "gate": gate_report}


def run_elastic(args) -> None:
    """Drive one run under the elastic supervisor (train/elastic.py),
    translating CLI fault/reshard knobs into the injection harness."""
    from repro.train.elastic import ElasticConfig, Supervisor

    faults = None
    plan = {}
    if args.fault_die_at is not None:
        plan[args.fault_die_at] = "die"
    if args.fault_preempt_at is not None:
        plan[args.fault_preempt_at] = "preempt"
    if plan:
        from repro.testing.faults import StepFaults
        faults = StepFaults(plan)
    hooks = []
    if args.fault_slow_write:
        from repro.testing.faults import SlowIO
        hooks.append(SlowIO(args.fault_slow_write))
    if args.fault_flaky_writes:
        from repro.testing.faults import FlakyIO
        hooks.append(FlakyIO(args.fault_flaky_writes))
    io_hooks = None
    if hooks:
        from repro.testing.faults import ChainedHooks
        io_hooks = hooks[0] if len(hooks) == 1 else ChainedHooks(hooks)
    reshard_plan = None
    if args.reshard:
        reshard_plan = {}
        for part in args.reshard.split(","):
            step_s, shape_s = part.split(":")
            reshard_plan[int(step_s)] = tuple(
                int(x) for x in shape_s.split("x"))

    cfg = ElasticConfig(
        arch=args.arch, reduced=args.reduced,
        mesh=tuple(int(x) for x in args.mesh.split("x")),
        variant=args.variant, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=args.lr, accum=args.accum, seed=args.seed,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        ckpt_format=args.ckpt_format, async_ckpt=not args.sync_ckpt,
        retries=args.ckpt_retries, backoff=args.ckpt_backoff,
        grace=args.grace, max_restarts=args.max_restarts,
        metrics_dir=args.metrics_dir)
    sup = Supervisor(cfg, faults=faults, reshard_plan=reshard_plan,
                     io_hooks=io_hooks)
    sup.install_signal_handlers()
    out = sup.run_supervised()
    last = out["losses"].get(out["final_step"] - 1)
    print(f"[elastic] done: status={out['status']} "
          f"final_step={out['final_step']} restarts={out['restarts']} "
          f"resharded={out['resharded']} "
          f"last_loss={last if last is None else f'{last:.4f}'}")


def main():
    # before any jax import: let the backend's latency-hiding scheduler
    # exploit the prefetched schedule (core/schedule.py, launch/xla_flags.py)
    from repro.launch.xla_flags import enable_overlap_flags
    enable_overlap_flags(os.environ.get("REPRO_PLATFORM", "cpu"))

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-350m")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-friendly)")
    ap.add_argument("--mesh", default="2x2", help="e.g. 4x2 or 2x2x2")
    ap.add_argument("--variant", default="zeropp")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-format", default="fp32",
                    choices=["fp32", "int8"],
                    help="per-shard payload: exact fp32 (default) or "
                         "qwZ-style block-quantized INT8 + fp16 scales "
                         "(~4x smaller)")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--simulate-failure-at", type=int, default=None)
    ap.add_argument("--max-restarts", type=int, default=2)
    # elastic supervisor mode (train/elastic.py) + its fault-injection knobs
    ap.add_argument("--elastic", action="store_true",
                    help="run under the elastic supervisor: async "
                         "checkpoints, SIGTERM grace drain, restart on "
                         "worker death, live resharding")
    ap.add_argument("--sync-ckpt", action="store_true",
                    help="elastic mode: blocking in-loop saves instead of "
                         "the async background writer")
    ap.add_argument("--grace", type=float, default=30.0,
                    help="seconds between preemption signal and exit")
    ap.add_argument("--ckpt-retries", type=int, default=0)
    ap.add_argument("--ckpt-backoff", type=float, default=0.05)
    ap.add_argument("--reshard", default=None,
                    help="live reshard plan, e.g. '3:2x2,6:4x2'")
    ap.add_argument("--fault-die-at", type=int, default=None,
                    help="inject a worker death at this step")
    ap.add_argument("--fault-preempt-at", type=int, default=None,
                    help="inject a graceful preemption at this step")
    ap.add_argument("--fault-slow-write", type=float, default=None,
                    help="sleep this long inside every shard write")
    ap.add_argument("--fault-flaky-writes", type=int, default=None,
                    help="fail the first N shard writes with OSError")
    # telemetry (obs/): jsonl event log, metrics registry, BENCH export
    ap.add_argument("--metrics-dir", default=None,
                    help="enable telemetry: write events.jsonl + "
                         "BENCH_runtime.json here (default: disabled, "
                         "zero-overhead no-op tracer)")
    ap.add_argument("--trace-steps", type=int, default=0,
                    help="wrap the first N steps in jax.profiler "
                         "TraceAnnotations (requires --metrics-dir)")
    ap.add_argument("--obs-gate", action="store_true",
                    help="assert the measured-vs-projected comm gate "
                         "(1%% per collective label) instead of only "
                         "reporting it")
    ap.add_argument("--tune", default="off",
                    choices=["off", "static", "probe"],
                    help="policy resolution (repro.tune): off = static "
                         "preset table; static = committed probe profile "
                         "(deterministic, CI); probe = time real "
                         "collectives on the live mesh at boot")
    ap.add_argument("--hbm-gb", type=float, default=16.0,
                    help="per-device HBM budget the tune ledger charges "
                         "the (k+1) ring buffers against")
    ap.add_argument("--kernel-backend", default=None,
                    choices=["pallas", "interpret", "xla", "ref"],
                    help="quant-kernel backend (kernels/ops.py); default "
                         "resolves $REPRO_KERNEL_BACKEND then platform "
                         "(pallas on TPU, xla elsewhere)")
    args = ap.parse_args()

    if args.kernel_backend is not None:
        from repro.kernels import ops as kops
        kops.set_backend(args.kernel_backend)

    if args.elastic:
        return run_elastic(args)

    # launcher-level fault tolerance: restart from latest checkpoint
    restarts = 0
    while True:
        try:
            out = train_loop(args)
            break
        except RuntimeError as e:
            if "simulated node failure" not in str(e) \
                    or restarts >= args.max_restarts:
                raise
            restarts += 1
            args.simulate_failure_at = None
            print(f"[train] {e} -> restarting from checkpoint "
                  f"({restarts}/{args.max_restarts})")
    print(f"[train] done: final loss {out['final_loss']:.4f} "
          f"(entropy bound {out['entropy_bound']:.4f}, "
          f"restarts={restarts})")


if __name__ == "__main__":
    main()
