"""Roofline inputs derived from the JAXPR (not the compiled HLO).

The CPU backend legalizes bf16 arithmetic AND collectives to f32, so the
compiled HLO systematically doubles every bf16 byte count (wire and HBM) —
useless for a TPU roofline.  The jaxpr has the TRUE dtypes, the REAL mesh
axis names on every collective, and explicit scan trip counts, so the
traversal here is exact where the HLO parse was heuristic:

  * flops        — dot_general from dimension_numbers × scan lengths
  * hbm_bytes    — eqn outputs (+ dot/collective operands) × scan lengths:
                   a fusion-blind traffic model (upper-bound-ish; see
                   DESIGN.md §Roofline caveats)
  * collectives  — wire bytes per op with ring formulas, per mesh-axis tier
  * peak_bytes   — program-order liveness over the jaxpr with true dtypes
                   (the TPU memory proxy; the CPU XLA temp number is kept
                   alongside as a scheduler-inflated upper bound)

All sub-jaxprs (pjit, scan, custom_vjp, remat, shard_map, cond) are walked
recursively.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

_COLL = {"all_gather", "psum", "reduce_scatter", "psum_scatter",
         "all_to_all", "ppermute"}
_TIER_RANK = {"model": 0, "data": 1, "pod": 2}
# ops that necessarily materialize their result on TPU (everything
# elementwise/layout is fusable and counted as free)
_MATERIALIZING = {"gather", "scatter", "scatter-add", "scatter_add",
                  "dynamic_update_slice", "dynamic_slice", "sort", "argsort",
                  "top_k", "cumsum", "cumlogsumexp", "concatenate", "pad"}


def _aval_bytes(aval) -> float:
    try:
        return float(np.prod(aval.shape) * aval.dtype.itemsize) \
            if aval.shape else float(aval.dtype.itemsize)
    except Exception:
        return 0.0


def _axes_of(params) -> Tuple[str, ...]:
    ax = params.get("axis_name", params.get("axis_index_groups_axis", ()))
    if ax is None:
        return ()
    if isinstance(ax, (tuple, list)):
        return tuple(str(a) for a in ax)
    return (str(ax),)


def _tier(axes: Tuple[str, ...]) -> str:
    best = "model"
    for a in axes:
        if _TIER_RANK.get(a, 0) > _TIER_RANK[best]:
            best = a if a in _TIER_RANK else best
    return best


# Telemetry attribution: core/collectives.py wraps each ZeRO collective in
# a ``zero.<op>`` jax.named_scope; the label survives into the eqn's
# name_stack (through scan bodies, and through custom_vjp transposition
# where it appears wrapped, e.g. "transpose(jvp(zero.hpz_gather))").  Any
# collective outside such a scope (loss psums, metric reductions) buckets
# to "other".  The innermost (last) label wins if scopes ever nest.
_LABEL_RE = re.compile(r"zero\.\w+")


def _coll_label(eqn) -> str:
    try:
        stack = str(eqn.source_info.name_stack)
    except Exception:
        return "other"
    hits = _LABEL_RE.findall(stack)
    return hits[-1] if hits else "other"


def _wire(prim: str, in_b: float, out_b: float, n: int) -> float:
    if n <= 1:
        return 0.0
    if prim == "all_gather":
        return max(out_b - in_b, 0.0)
    if prim in ("reduce_scatter", "psum_scatter"):
        return max(in_b - out_b, 0.0)
    if prim == "psum":
        return 2.0 * in_b * (n - 1) / n
    if prim == "all_to_all":
        return in_b * (n - 1) / n
    if prim == "ppermute":
        return in_b
    return in_b


def _sub_jaxprs(eqn) -> List[Tuple[Any, float]]:
    """(sub_jaxpr, trip_multiplier) pairs reachable from an eqn."""
    p = eqn.params
    prim = eqn.primitive.name
    out = []
    if prim == "scan":
        out.append((p["jaxpr"].jaxpr, float(p["length"])))
    elif prim == "while":
        # our loops are scans; a raw while gets trip=1 (documented)
        out.append((p["body_jaxpr"].jaxpr, 1.0))
    elif prim == "cond":
        for br in p["branches"]:
            out.append((br.jaxpr, 1.0))
    else:
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if key in p:
                j = p[key]
                out.append((getattr(j, "jaxpr", j), 1.0))
    return out


@dataclasses.dataclass
class JTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_per_op: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    coll_per_tier: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {"model": 0.0, "data": 0.0, "pod": 0.0})
    coll_count: float = 0.0
    wire_by_label: Dict[str, float] = dataclasses.field(default_factory=dict)


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    k = 1.0
    for d in lc:
        k *= lhs.shape[d]
    out_elems = float(np.prod(eqn.outvars[0].aval.shape)) \
        if eqn.outvars[0].aval.shape else 1.0
    return 2.0 * out_elems * k


def _walk(jaxpr, mult: float, t: JTotals, mesh_shape: Dict[str, int],
          depth: int = 0):
    if depth > 64:
        return
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "pallas_call":
            # one kernel: HBM traffic is its operands + results; flops come
            # from the kernel body x grid size
            out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars)
            in_b = sum(_aval_bytes(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))
            t.hbm_bytes += (in_b + out_b) * mult
            body = eqn.params.get("jaxpr")
            grid = 1.0
            gm = eqn.params.get("grid_mapping")
            if gm is not None:
                for g in getattr(gm, "grid", ()) or ():
                    if isinstance(g, int):
                        grid *= g
            if body is not None:
                tt = JTotals()
                _walk(getattr(body, "jaxpr", body), mult * grid, tt,
                      mesh_shape, depth + 1)
                t.flops += tt.flops
            continue
        subs = _sub_jaxprs(eqn)
        if prim == "cond" and subs:
            # count the most expensive branch
            best = None
            for sub, m in subs:
                tt = JTotals()
                _walk(sub, mult * m, tt, mesh_shape, depth + 1)
                if best is None or tt.flops > best.flops:
                    best = tt
            t.flops += best.flops
            t.hbm_bytes += best.hbm_bytes
            for k, v in best.coll_per_tier.items():
                t.coll_per_tier[k] += v
            for k, d in best.coll_per_op.items():
                dd = t.coll_per_op.setdefault(
                    k, {"count": 0.0, "operand_bytes": 0.0, "wire_bytes": 0.0})
                for f in dd:
                    dd[f] += d[f]
            for k, v in best.wire_by_label.items():
                t.wire_by_label[k] = t.wire_by_label.get(k, 0.0) + v
            t.coll_count += best.coll_count
            continue
        if subs:
            for sub, m in subs:
                _walk(sub, mult * m, t, mesh_shape, depth + 1)
            # scan boundary traffic: stacked xs read once, stacked
            # ys/carry written once (per outer execution)
            if prim == "scan":
                state = sum(_aval_bytes(v.aval) for v in eqn.outvars)
                state += sum(_aval_bytes(v.aval) for v in eqn.invars
                             if hasattr(v, "aval"))
                t.hbm_bytes += state * mult
            continue

        out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars)
        in_b = sum(_aval_bytes(v.aval) for v in eqn.invars
                   if hasattr(v, "aval"))

        if prim in _COLL:
            axes = _axes_of(eqn.params)
            n = 1
            for a in axes:
                n *= mesh_shape.get(a, 1)
            wire = _wire(prim, in_b, out_b, n)
            tier = _tier(axes)
            d = t.coll_per_op.setdefault(
                prim, {"count": 0.0, "operand_bytes": 0.0, "wire_bytes": 0.0})
            d["count"] += mult
            d["operand_bytes"] += in_b * mult
            d["wire_bytes"] += wire * mult
            t.coll_per_tier[tier] += wire * mult
            lbl = _coll_label(eqn)
            t.wire_by_label[lbl] = t.wire_by_label.get(lbl, 0.0) + wire * mult
            t.coll_count += mult
            t.hbm_bytes += (in_b + out_b) * mult
            continue

        if prim == "dot_general":
            t.flops += _dot_flops(eqn) * mult
            t.hbm_bytes += (in_b + out_b) * mult
            continue

        if prim in _MATERIALIZING:
            t.hbm_bytes += (out_b * 2 + (in_b if prim.startswith("scatter")
                                         or prim == "dynamic_update_slice"
                                         else 0)) * mult
        # everything else: elementwise/layout ops are assumed fused into
        # their producing/consuming kernels (TPU-optimistic floor; the CPU
        # XLA number in memory.xla_cpu_* is the unfused upper bound)


# layout ops whose outputs alias their input buffer (no new allocation on
# TPU: reshapes are bitcasts; transposes/converts fold into consuming dots)
_ALIAS_PRIMS = {"reshape", "squeeze", "expand_dims", "broadcast_in_dim",
                "transpose", "bitcast_convert_type", "stop_gradient",
                "optimization_barrier"}


def _peak(jaxpr, depth: int = 0) -> float:
    """Program-order liveness peak (true dtypes).

    Alias-aware: layout ops keep their INPUT alive instead of allocating;
    sub-jaxpr peaks exclude their parameters (already live at the caller).
    """
    if depth > 64:
        return 0.0

    def is_var(v):
        return type(v).__name__ != "Literal"

    alias_of: Dict[Any, Any] = {}

    def root(v):
        seen = set()
        while v in alias_of and id(v) not in seen:
            seen.add(id(v))
            v = alias_of[v]
        return v

    for eqn in jaxpr.eqns:
        if eqn.primitive.name in _ALIAS_PRIMS and len(eqn.invars) == 1 \
                and is_var(eqn.invars[0]) \
                and _aval_bytes(eqn.outvars[0].aval) \
                <= _aval_bytes(eqn.invars[0].aval):
            alias_of[eqn.outvars[0]] = eqn.invars[0]

    last_use: Dict[Any, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if is_var(v):
                last_use[root(v)] = i
    for v in jaxpr.outvars:
        if is_var(v):
            last_use[root(v)] = len(jaxpr.eqns) + 1

    live: Dict[Any, float] = {}
    for v in jaxpr.invars + jaxpr.constvars:
        live[v] = _aval_bytes(v.aval)
    peak = sum(live.values())
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.outvars:
            if v not in alias_of:
                live[v] = _aval_bytes(v.aval)
        inner = 0.0
        for sub, m in _sub_jaxprs(eqn):
            # exclude sub params: those buffers are the eqn operands,
            # already counted in the caller's live set
            param_b = sum(_aval_bytes(v.aval)
                          for v in sub.invars + sub.constvars)
            inner = max(inner, _peak(sub, depth + 1) - param_b)
        peak = max(peak, sum(live.values()) + inner)
        for v in list(live):
            if last_use.get(v, -1) <= i:
                ok = any(root(ov) is v for ov in eqn.outvars)
                if not ok:
                    del live[v]
    return peak


def analyze_jaxpr(closed_jaxpr, mesh_shape: Dict[str, int]) -> Dict[str, Any]:
    jx = closed_jaxpr.jaxpr
    t = JTotals()
    _walk(jx, 1.0, t, mesh_shape)
    world = 1
    for n in mesh_shape.values():
        world *= n
    # per-device: the jaxpr is the shard_map body-level program after jit;
    # avals inside shard_map are per-device.  dot/bytes sums above already
    # reflect per-device work.
    return {
        "flops": t.flops,
        "hbm_bytes": t.hbm_bytes,
        "collectives": {
            "per_op": t.coll_per_op,
            "per_tier_wire": t.coll_per_tier,
            "wire_by_label": t.wire_by_label,
            "count": t.coll_count,
            "operand_bytes": sum(d["operand_bytes"]
                                 for d in t.coll_per_op.values()),
            "wire_bytes": sum(d["wire_bytes"]
                              for d in t.coll_per_op.values()),
        },
        "peak_bytes": _peak(jx),
    }


def shard_map_body(closed_jaxpr):
    """Find the (first) shard_map body jaxpr — per-device avals.

    The peak-liveness walk must run on per-device shapes; the jit wrapper
    levels above carry GLOBAL arrays.
    """
    jx = closed_jaxpr.jaxpr if hasattr(closed_jaxpr, "jaxpr") else closed_jaxpr
    stack = [jx]
    seen = 0
    while stack and seen < 10000:
        cur = stack.pop(0)
        for eqn in cur.eqns:
            seen += 1
            if eqn.primitive.name in ("shard_map", "smap"):
                sub = eqn.params.get("jaxpr")
                return getattr(sub, "jaxpr", sub)
            for sub, _ in _sub_jaxprs(eqn):
                stack.append(sub)
    return jx
