import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax pins the device
# count at first backend init, and the production meshes need 512 host
# placeholder devices (multi-pod 2x16x16; the single-pod 16x16 mesh uses the
# first 256 of them).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the real distributed step (train_step for
``train_*`` shapes, serve prefill/decode for the inference shapes) against
ShapeDtypeStruct inputs (no allocation), compiles it, and extracts:

  * ``compiled.memory_analysis()``  — proves the cell fits per-device HBM
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline
  * collective bytes parsed from the compiled HLO, split by interconnect
    tier (model-ring / cross-data / cross-pod) — cost_analysis does not
    report collectives, so we sum operand sizes per op ourselves and apply
    ring-algorithm wire-volume formulas.

Results are dumped as one JSON per cell; benchmarks/roofline.py renders the
EXPERIMENTS.md tables from them.
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# hardware model (TPU v5e-like, per assignment)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (model/data tiers)
DCI_BW = 6.25e9            # bytes/s per chip across pods (assumed, DESIGN.md)
HBM_BYTES = 16 * 2 ** 30   # v5e HBM capacity


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+(\S+?)\(")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _type_bytes(tstr: str) -> int:
    """Bytes of an HLO type string (possibly a tuple)."""
    total = 0
    for m in _SHAPE_RE.finditer(tstr):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_groups(line: str) -> Optional[np.ndarray]:
    """Replica groups as an (n_groups, group_size) id array, if present."""
    m = _GROUPS_V1_RE.search(line)
    if m:
        groups = [[int(x) for x in g.split(",") if x]
                  for g in re.findall(r"\{([^}]*)\}", m.group(1))]
        width = max(len(g) for g in groups)
        return np.array([g + [g[-1]] * (width - len(g)) for g in groups])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        return ids.reshape(ng, gs)
    return None


def _group_tier(groups: Optional[np.ndarray], world: int,
                multi_pod: bool) -> str:
    """Which interconnect tier a collective's groups span.

    Device layout is row-major over the mesh: id = ((pod·16)+data)·16+model.
    """
    if groups is None:
        return "model"
    g = groups
    if multi_pod and np.ptp(g // 256, axis=1).max() > 0:
        return "pod"
    if np.ptp((g % 256) // 16, axis=1).max() > 0:
        return "data"
    return "model"


def _wire_bytes(op: str, in_bytes: int, out_bytes: int, n: int) -> float:
    """Ring-algorithm wire volume per device for one collective."""
    if n <= 1:
        return 0.0
    if op == "all-gather":
        return max(out_bytes - in_bytes, 0)
    if op == "reduce-scatter":
        return max(in_bytes - out_bytes, 0)
    if op == "all-reduce":
        return 2.0 * in_bytes * (n - 1) / n
    if op == "all-to-all":
        return in_bytes * (n - 1) / n
    if op == "collective-permute":
        return float(in_bytes)
    return float(in_bytes)


def parse_collectives(hlo_text: str, world: int, multi_pod: bool
                      ) -> Dict[str, Any]:
    """Sum collective operand/wire bytes per op type and per tier."""
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            sizes[m.group(1)] = _type_bytes(m.group(2))

    per_op: Dict[str, Dict[str, float]] = {}
    per_tier = {"model": 0.0, "data": 0.0, "pod": 0.0}
    count = 0
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        opcode = m.group(3)
        base = opcode.replace("-start", "")
        if base not in _COLL_OPS:
            continue
        if opcode.endswith("-done"):
            continue
        count += 1
        # operand list: %names inside the call parens
        call = line[line.index(opcode + "(") + len(opcode) + 1:]
        depth = 1
        args = ""
        for ch in call:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args += ch
        ops = re.findall(r"%([\w.\-]+)", args)
        in_b = sum(sizes.get(o, 0) for o in ops)
        out_b = _type_bytes(m.group(2))
        groups = _parse_groups(line)
        n = groups.shape[1] if groups is not None else world
        tier = _group_tier(groups, world, multi_pod)
        wire = _wire_bytes(base, in_b, out_b, n)
        d = per_op.setdefault(base, {"count": 0, "operand_bytes": 0.0,
                                     "wire_bytes": 0.0})
        d["count"] += 1
        d["operand_bytes"] += in_b
        d["wire_bytes"] += wire
        per_tier[tier] += wire
    total_operand = sum(d["operand_bytes"] for d in per_op.values())
    total_wire = sum(d["wire_bytes"] for d in per_op.values())
    return {"per_op": per_op, "per_tier_wire": per_tier, "count": count,
            "operand_bytes": total_operand, "wire_bytes": total_wire}


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def _abstract(tree, mesh, specs):
    """ShapeDtypeStructs with NamedShardings attached (zero allocation)."""
    import jax
    from jax.sharding import NamedSharding

    def mk(leaf, spec):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(mk, tree, specs)


def train_batch_shapes(model, shape_cfg):
    """GLOBAL abstract batch for a train step."""
    import jax
    import jax.numpy as jnp
    cfg = model.cfg
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    out = {"targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.embed_inputs:
        out["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                             jnp.bfloat16)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.mrope:
        out["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    return out


def serve_batch_shapes(model, B, S):
    import jax
    import jax.numpy as jnp
    cfg = model.cfg
    out = {}
    if cfg.embed_inputs:
        out["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                             jnp.bfloat16)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.mrope:
        out["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    return out


def _jaxpr_info(fn, args, mesh):
    import jax
    from repro.launch.jaxpr_analysis import (analyze_jaxpr, shard_map_body,
                                             _peak)
    cj = jax.make_jaxpr(fn)(*args)
    mesh_shape = dict(mesh.shape)
    res = analyze_jaxpr(cj, mesh_shape)
    res["peak_bytes"] = _peak(shard_map_body(cj))
    return res


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               variant: str = "zeropp", serve_params_dtype=None,
               want_jaxpr: bool = True, attn_impl: str = "xla",
               accum: int = 0, serve_bits: int = 8,
               ) -> Tuple[Any, Dict[str, Any]]:
    """Build and lower one cell; returns (lowered, info).

    info['jaxpr_analysis'] carries the true-dtype roofline inputs (see
    jaxpr_analysis.py — the CPU backend's HLO upcasts bf16 to f32 and would
    double every byte count)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import SHAPES, get_config, shape_supported
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import Model
    from repro.optim.adamw import AdamWConfig
    from repro.train import serve as serve_lib
    from repro.train import state as state_lib
    from repro.train import trainer as trainer_lib
    from repro.tune import resolve, serve_ledger, train_ledger

    arch = get_config(arch_name)
    shape = SHAPES[shape_name]
    ok, why = shape_supported(arch, shape_name)
    if not ok:
        return None, {"skipped": True, "why": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = tuple(mesh.axis_names)
    world = int(np.prod(list(mesh.shape.values())))
    overrides = {}
    if shape.kind != "train" and serve_bits == 4:
        # weight-only INT4 serving (qwZ with 4-bit payload, finer blocks)
        overrides = dict(qwz_bits=4, qwz_block=128)
    # the same single owner as train/serve boot (repro.tune.resolve);
    # mode="off" keeps the preset table so cell configs stay bit-stable
    pol = resolve(arch, axes, variant, mode="off", overrides=overrides)
    model = Model(arch, pol.zcfg, world=world)
    info: Dict[str, Any] = {
        "skipped": False, "world": world, "axes": axes,
        "n_params": model.n_params(), "n_active": model.n_active_params(),
        "policy_note": pol.note, "variant": variant,
        "hpz_axes": pol.zcfg.secondary_axes if pol.zcfg.hpz else None,
    }
    # ring depth actually in effect per scan (clamped to n-1; anything
    # beyond it would silently lap the ring — see ZeroConfig.prefetch)
    eff = {"layers": pol.zcfg.effective_prefetch(model.n_periods)}
    if model.is_moe:
        eff["expert_chunks"] = pol.zcfg.effective_prefetch(
            arch.expert_chunks)
    info["prefetch"] = pol.zcfg.prefetch
    info["prefetch_effective"] = eff

    info["kind"] = shape.kind
    if accum == 0 and shape.kind == "train":
        accum = pol.train_accum          # policy default (memory fit)
    accum = max(accum, 1)
    info["accum_used"] = accum
    # analytic HBM ledger (repro.tune.memory) — charges the (k+1)
    # prefetch-ring live buffers the old memory model missed; reported
    # alongside the measured jaxpr peak in analyze()
    sizes = {a: int(s) for a, s in mesh.shape.items()}
    if shape.kind == "train":
        micro_tok = max(
            shape.global_batch * shape.seq_len // world // accum, 1)
        led = train_ledger(
            model, sizes,
            moments_itemsize=jnp.dtype(pol.moments_dtype).itemsize,
            tokens_per_device=micro_tok, accum=accum,
            budget_bytes=HBM_BYTES)
    else:
        led = serve_ledger(model, sizes, n_slots=shape.global_batch,
                           kv_len=shape.seq_len, budget_bytes=HBM_BYTES)
    info["ledger"] = led.as_dict()
    if shape.kind == "train":
        opt_cfg = AdamWConfig(moments_dtype=pol.moments_dtype)
        ts = trainer_lib.build_train_step(model, mesh, opt_cfg, donate=True,
                                          global_batch=shape.global_batch
                                          // max(accum, 1),
                                          accum=accum, attn_impl=attn_impl)
        p_sh, o_sh = state_lib.state_shapes(model, opt_cfg)
        params = _abstract(p_sh, mesh, ts.in_specs[0])
        opt = _abstract(o_sh, mesh, ts.in_specs[1])
        bsh = train_batch_shapes(model, shape)
        if accum > 1:
            import jax as _jax
            bsh = {k: _jax.ShapeDtypeStruct(
                (accum, v.shape[0] // accum) + v.shape[1:]
                if k != "positions" else
                (accum, 3, v.shape[1] // accum) + v.shape[2:], v.dtype)
                for k, v in bsh.items()}
        batch = _abstract(bsh, mesh, ts.in_specs[2])
        lowered = ts.fn.lower(params, opt, batch)
        info["tokens_per_step"] = shape.global_batch * shape.seq_len
        import jax as _j
        info["donated_bytes"] = sum(
            int(np.prod(x.shape)) * x.dtype.itemsize
            for x in _j.tree.leaves((params, opt))) // world
        if want_jaxpr:
            info["jaxpr_analysis"] = _jaxpr_info(
                ts.fn, (params, opt, batch), mesh)
    elif shape.kind == "prefill":
        batch_axes = tuple(a for a in axes if a != "model")
        ps = serve_lib.build_prefill_step(model, mesh, batch_axes, ("model",))
        pdt = serve_params_dtype or jnp.bfloat16
        p_sh = state_lib.abstract_params(model, pdt)
        params = _abstract(p_sh, mesh, ps.in_specs[0])
        batch = _abstract(
            serve_batch_shapes(model, shape.global_batch, shape.seq_len),
            mesh, ps.in_specs[1])
        lowered = ps.fn.lower(params, batch)
        info["tokens_per_step"] = shape.global_batch * shape.seq_len
        if want_jaxpr:
            info["jaxpr_analysis"] = _jaxpr_info(ps.fn, (params, batch), mesh)
    else:  # decode
        batch_axes, kv_axes = serve_lib.serve_shape_policy(shape_name, axes)
        # page-granularity alternative to the slab kv_pool line: what the
        # paged engine (serve.PagedKVPool) would charge at full capacity.
        # The paged arena shards only within-page tokens over kv_axes and
        # replicates across the rest, so the honest per-device bill can be
        # LARGER than the fully-sharded slab — the win is admission
        # granularity (pages, not whole slots), not raw bytes.
        kv_world = int(np.prod([sizes.get(a, 1) for a in kv_axes]))
        page = kv_world * max(1, 16 // kv_world)
        if shape.seq_len % page == 0 and not model.is_moe \
                and set(model.period) == {"attn"}:
            pled = serve_ledger(model, sizes, n_slots=shape.global_batch,
                                kv_len=shape.seq_len, page_size=page,
                                kv_axes=kv_axes, budget_bytes=HBM_BYTES)
            pps = shape.seq_len // page
            info["paged_pool"] = {
                "page_size": page,
                "pages_per_slot": pps,
                "n_pages": shape.global_batch * pps,
                "kv_pool_bytes": pled.line("kv_pool"),
                "slab_kv_pool_bytes": led.line("kv_pool"),
                "ledger_fits": pled.fits,
            }
        ds = serve_lib.build_decode_step(model, mesh, batch_axes, kv_axes,
                                         donate=True)
        pdt = serve_params_dtype or jnp.bfloat16
        p_sh = state_lib.abstract_params(model, pdt)
        params = _abstract(p_sh, mesh, ds.in_specs[0])
        caches = _abstract(
            model.cache_shapes(shape.global_batch, shape.seq_len),
            mesh, ds.in_specs[1])
        batch = _abstract(serve_batch_shapes(model, shape.global_batch, 1),
                          mesh, ds.in_specs[2])
        # per-sequence cache_pos vector (see train/serve.py)
        pos = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        lowered = ds.fn.lower(params, caches, batch, pos)
        info["tokens_per_step"] = shape.global_batch
        import jax as _j
        info["donated_bytes"] = sum(
            int(np.prod(x.shape)) * x.dtype.itemsize
            for x in _j.tree.leaves(caches)) // world
        if want_jaxpr:
            info["jaxpr_analysis"] = _jaxpr_info(
                ds.fn, (params, caches, batch, pos), mesh)
    return lowered, info


def analyze(lowered, info: Dict[str, Any], multi_pod: bool) -> Dict[str, Any]:
    """Compile and extract memory / cost / collective / roofline terms."""
    world = info["world"]
    t0 = time.time()
    compiled = lowered.compile()
    info["compile_s"] = round(time.time() - t0, 1)

    # ---- memory -----------------------------------------------------------
    # two views: (a) XLA CPU buffer assignment — an upper bound inflated by
    # the CPU backend's bf16->f32 legalization and concurrency-first
    # scheduling; (b) jaxpr program-order liveness with TRUE dtypes — the
    # TPU proxy that gates fits_16gb (see jaxpr_analysis.py).
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem["xla_cpu_" + k] = int(v)
        args = mem.get("xla_cpu_argument_size_in_bytes", 0)
        alias = mem.get("xla_cpu_alias_size_in_bytes", 0)
        mem["xla_cpu_peak_upper_bound"] = int(
            args + mem.get("xla_cpu_output_size_in_bytes", 0)
            + mem.get("xla_cpu_temp_size_in_bytes", 0) - alias)
    except Exception as e:  # pragma: no cover
        mem["error"] = repr(e)
    ja = info.get("jaxpr_analysis")
    if ja:
        peak = int(ja["peak_bytes"])
        # donation: in-place updated state (params+opt for train, KV caches
        # for decode) is double-counted by the liveness walk (it cannot see
        # input-output aliasing); subtract the donated bytes once
        don = int(info.get("donated_bytes", 0))
        mem["peak_bytes_undonated"] = peak
        mem["donated_bytes"] = don
        mem["peak_bytes_per_device"] = max(peak - don, 0)
    else:
        mem["peak_bytes_per_device"] = mem.get("xla_cpu_peak_upper_bound", 0)
    mem["fits_16gb"] = bool(mem["peak_bytes_per_device"] <= HBM_BYTES)
    led = info.get("ledger")
    if led:
        # the analytic (k+1)-ring-aware bill next to the measured peak
        mem["ledger_total_bytes"] = int(led["total_bytes"])
        mem["ledger_fits"] = bool(led["fits"])
        mem["ledger_ring_bytes"] = int(sum(
            b for name, b in led["lines"].items() if name.startswith("ring_")))
    info["memory"] = mem

    # ---- cost ----------------------------------------------------------
    # xla's cost_analysis visits each instruction once (while bodies are NOT
    # multiplied by trip count), so we re-derive flops/bytes/collectives
    # with the loop-aware walker; the raw xla numbers are kept for reference
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost["xla_flops_unrolled_once"] = float(ca.get("flops", 0.0))
    except Exception as e:  # pragma: no cover
        cost["error"] = repr(e)

    hlo_text = compiled.as_text()   # serialize once: reused below
    ja = info.get("jaxpr_analysis")
    if ja:
        cost["flops"] = ja["flops"]               # per-device, true dtypes
        cost["bytes_accessed"] = ja["hbm_bytes"]
        coll = ja["collectives"]
    else:  # fallback: loop-aware HLO parse (bf16 counted as f32 on CPU)
        from repro.launch.hlo_analysis import analyze_hlo
        hlo = analyze_hlo(hlo_text, world, multi_pod)
        cost["flops"] = hlo["flops"]
        cost["bytes_accessed"] = hlo["hbm_bytes"]
        coll = hlo["collectives"]
    info["cost"] = cost
    info["collectives"] = coll

    # ---- schedule overlap (prefetch verification, see hlo_analysis) -------
    from repro.launch.hlo_analysis import analyze_overlap
    try:
        info["overlap"] = analyze_overlap(hlo_text, multi_pod)
    except Exception as e:  # pragma: no cover
        info["overlap"] = {"error": repr(e)}
    info.pop("jaxpr_analysis", None)  # folded into cost/collectives/memory

    # ---- roofline --------------------------------------------------------
    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes_accessed", 0.0)
    tier = coll["per_tier_wire"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_ici = (tier["model"] + tier["data"]) / ICI_BW
    coll_dci = tier["pod"] / DCI_BW
    collective_s = coll_ici + coll_dci
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s, "collective_ici_s": coll_ici,
             "collective_dci_s": coll_dci}
    dominant = max(terms, key=lambda k: terms[k]
                   if k in ("compute_s", "memory_s", "collective_s") else -1)
    n_active = info["n_active"]
    # train: fwd 2ND + bwd 4ND; prefill/decode: fwd only (2ND)
    flops_per_tok = 6.0 if info.get("kind") == "train" else 2.0
    model_flops = flops_per_tok * n_active * info["tokens_per_step"]
    hlo_flops_global = flops_dev * world
    info["roofline"] = {
        **{k: float(v) for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio":
            model_flops / hlo_flops_global if hlo_flops_global else 0.0,
        "step_time_s": max(compute_s, memory_s, collective_s),
        "mfu_bound": (model_flops / world / PEAK_FLOPS) /
            max(compute_s, memory_s, collective_s, 1e-30),
    }
    return info


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def run_one(arch: str, shape: str, multi_pod: bool, variant: str,
            out_dir: Optional[str], attn_impl: str = "xla",
            accum: int = 0, tag: str = "",
            serve_bits: int = 8) -> Dict[str, Any]:
    t0 = time.time()
    lowered, info = lower_cell(arch, shape, multi_pod, variant,
                               attn_impl=attn_impl, accum=accum,
                               serve_bits=serve_bits)
    info.update({"arch": arch, "shape": shape, "attn_impl": attn_impl,
                 "accum": accum, "tag": tag,
                 "mesh": "2x16x16" if multi_pod else "16x16"})
    if not info.get("skipped"):
        info["lower_s"] = round(time.time() - t0, 1)
        info = analyze(lowered, info, multi_pod)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}__{shape}__{info['mesh']}__{variant}"
        if tag:
            name += "__" + tag
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(info, f, indent=1, default=str)
    return info


def run_matrix(archs, shapes, meshes, variant, out_dir, timeout=3600):
    """Spawn one subprocess per cell (isolates compile memory; resumable —
    cells with an existing JSON are skipped)."""
    import subprocess
    todo = []
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                tag = f"{arch}__{shape}__{mesh}__{variant}"
                path = os.path.join(out_dir, tag + ".json")
                if os.path.exists(path):
                    print(f"SKIP (cached) {tag}")
                    continue
                todo.append((arch, shape, mesh, tag))
    print(f"{len(todo)} cells to run")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    for i, (arch, shape, mesh, tag) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--variant", variant,
               "--out", out_dir]
        if mesh == "2x16x16":
            cmd.append("--multi-pod")
        t0 = time.time()
        try:
            r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                               timeout=timeout)
            status = "ok" if r.returncode == 0 else f"rc={r.returncode}"
            if r.returncode != 0:
                err = (r.stdout + r.stderr).strip().splitlines()
                with open(os.path.join(out_dir, tag + ".FAILED"), "w") as f:
                    f.write(r.stdout + r.stderr)
                status += " :: " + (err[-1][:200] if err else "?")
        except subprocess.TimeoutExpired:
            status = "TIMEOUT"
        print(f"[{i+1}/{len(todo)}] {tag}: {status} "
              f"({time.time()-t0:.0f}s)", flush=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="zeropp",
                    choices=["zeropp", "baseline", "qwz", "hpz", "qgz"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true",
                    help="run the full (arch x shape x mesh) matrix in "
                         "per-cell subprocesses")
    ap.add_argument("--meshes", default="16x16,2x16x16")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--attn", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--accum", type=int, default=0)  # 0 = policy default
    ap.add_argument("--serve-bits", type=int, default=8, choices=[4, 8])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.all:
        from repro.configs import ASSIGNED, SHAPES
        archs = [args.arch] if args.arch else ASSIGNED
        shapes = [args.shape] if args.shape else list(SHAPES)
        run_matrix(archs, shapes, args.meshes.split(","), args.variant,
                   args.out, args.timeout)
        return
    assert args.arch and args.shape

    info = run_one(args.arch, args.shape, args.multi_pod, args.variant,
                   args.out, attn_impl=args.attn, accum=args.accum,
                   tag=args.tag, serve_bits=args.serve_bits)
    if info.get("skipped"):
        print(f"SKIP {args.arch} {args.shape}: {info['why']}")
        return
    r = info["roofline"]
    m = info["memory"]
    print(f"CELL {args.arch} {args.shape} mesh={info['mesh']} "
          f"variant={args.variant}")
    print(f"  params={info['n_params']/1e9:.2f}B "
          f"active={info['n_active']/1e9:.2f}B world={info['world']}")
    print(f"  memory: peak/dev={m.get('peak_bytes_per_device', 0)/2**30:.2f}"
          f" GiB fits16GB={m.get('fits_16gb')}")
    if "ledger_total_bytes" in m:
        print(f"  ledger: total={m['ledger_total_bytes']/2**30:.2f} GiB "
              f"(ring={m['ledger_ring_bytes']/2**30:.2f} GiB) "
              f"fits={m['ledger_fits']}")
    print(f"  roofline: compute={r['compute_s']*1e3:.2f}ms "
          f"memory={r['memory_s']*1e3:.2f}ms "
          f"collective={r['collective_s']*1e3:.2f}ms "
          f"(ici={r['collective_ici_s']*1e3:.2f} "
          f"dci={r['collective_dci_s']*1e3:.2f}) -> {r['dominant']}")
    print(f"  useful_flops_ratio={r['useful_flops_ratio']:.3f} "
          f"mfu_bound={r['mfu_bound']:.3f} "
          f"compile={info.get('compile_s')}s")
    if "prefetch" in info:
        print(f"  schedule: prefetch={info['prefetch']} "
              f"effective={info['prefetch_effective']}")
    pp = info.get("paged_pool")
    if pp:
        print(f"  paged pool: page_size={pp['page_size']} "
              f"n_pages={pp['n_pages']} "
              f"({pp['pages_per_slot']} pages/slot) "
              f"kv/dev={pp['kv_pool_bytes']/2**30:.2f} GiB "
              f"vs slab {pp['slab_kv_pool_bytes']/2**30:.2f} GiB "
              f"fits={pp['ledger_fits']}")
    ov = info.get("overlap", {})
    if "overlap_fraction" in ov:
        loops = ov.get("per_loop", {})
        nested = sum(1 for d in loops.values()
                     if d.get("outer_mult", 1.0) > 1.0)
        slack = max((d.get("max_slack_iters", 1) for d in loops.values()),
                    default=1)
        print(f"  overlap: fraction={ov['overlap_fraction']:.3f} "
              f"({ov['overlappable_collectives']}/{ov['in_loop_collectives']}"
              f" in-loop collectives over {len(loops)} loops, {nested} "
              f"nested; max ring slack={slack} iters; "
              f"async pairs={ov['async_pairs']})")


if __name__ == "__main__":
    main()
