"""Production mesh construction.

Axis semantics (DESIGN.md §2): ``model`` is the fast interconnect tier (the
paper's intra-node NVLink analogue — hpZ secondary groups and the qgZ intra
hop live here), ``data`` the slower tier, and ``pod`` the slowest (inter-pod
DCI).  The ZeRO world is ALL axes flattened; "model" does not mean tensor
parallelism — it carries sequence-parallel activations and the fast-tier
collectives.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run pins the device count before first use).
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.core.compat import auto_axis_types, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    The dry-run environment exposes 512 placeholder devices; the single-pod
    mesh takes the first 256 so both meshes build in one process.  Device
    ids are row-major over the mesh (host platform preserves order), which
    the dry-run's collective-tier classifier relies on.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) > n:
        devs = devs[:n]
    return make_mesh(shape, axes, devices=devs,
                     axis_types=auto_axis_types(len(axes)))


def make_test_mesh(shape: Tuple[int, ...] = None, axes: Tuple[str, ...] = None):
    """Small mesh over however many (simulated) devices exist."""
    n = jax.device_count()
    if shape is None:
        if n >= 8:
            shape, axes = (2, n // 4, 2), ("pod", "data", "model")
        elif n >= 4:
            shape, axes = (n // 2, 2), ("data", "model")
        else:
            shape, axes = (1, n), ("data", "model")
    return make_mesh(shape, axes, axis_types=auto_axis_types(len(axes)))


def mesh_axis_names(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)
