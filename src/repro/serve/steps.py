"""Step-builder layer of the serving stack (re-export of train/serve).

The engine consumes prefill/decode steps and cache specs from here;
``repro.train.serve`` remains the implementation (shard_map step builders
over the ZeRO-sharded parameter layout — with qwZ the per-layer weight
gathers move INT8; both builders take ``prefetch=k`` to deepen the
weight-gather ring for slow interconnects, see core/schedule.py).  See
DESIGN.md §5 for the ownership split: the engine owns slots and
scheduling, this layer owns step/sharding specs, ZeroState owns
parameters.
"""
from repro.train.serve import (  # noqa: F401
    ServeStep,
    build_decode_step,
    build_paged_step,
    build_prefill_step,
    cache_specs,
    pad_prefill_caches,
    paged_cache_specs,
    serve_batch_specs,
    serve_shape_policy,
)
