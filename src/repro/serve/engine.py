"""Continuous-batching inference engine.

The drive loop turns the shard_map step builders (``repro.serve.steps``)
into a serving engine: requests are admitted whenever the KV pool has a
free slot, prefilled into that slot, then decoded TOGETHER with every
other in-flight request by ONE jitted decode step — the per-sequence
``cache_pos`` contract (DESIGN.md §5) lets rows sit at different
positions.  Retired slots recycle to queued requests, so the decode batch
stays occupied under sustained traffic.

Step anatomy (``ServeEngine.step``):

  0. expire   — requests past their per-request ``deadline`` (absolute
                ``clock()`` time) are dropped with status ``"timeout"``:
                active ones release their KV slot back to the pool, queued
                ones leave the queue without ever taking a slot.
  1. admit    — FIFO scheduler pops requests while slots are free; each
                prompt is padded to its length bucket (pure-attention
                models; others prefill at exact length), prefilled with
                batch=1, and its caches inserted into the pool slot.  The
                prefill's per-sequence ``last_pos`` logits give the first
                generated token (streamed immediately: time-to-first-token
                is one prefill, never a decode-batch wait).
  2. decode   — one batched step over ALL slots: tokens (n_slots, 1),
                cache_pos (n_slots,).  Inactive slots decode a dummy token
                at position 0 of their own slot; admission overwrites the
                whole slot, so garbage never leaks across requests.
  3. retire   — EOS / max-new-tokens / KV-capacity exhaustion free the
                slot for the next admission.

Weights stay ZeRO-sharded across the whole mesh and move through the same
qwZ INT8 block-quantized all-gather as training's forward (paper §
quantized weight communication) — ``from_checkpoint`` boots from the
per-shard INT8 checkpoint format (ZeroState) via the bf16 serving path.
``prefetch=k`` deepens the per-layer weight-gather ring of both steps
(core/schedule.py): on slow interconnects, where a decode step's compute
cannot cover one layer's gather, k>1 layers of lookahead keeps the
pipeline fed (benchmarks/throughput_model.py models the break-even k).

Greedy decoding through the engine is bit-identical to running each
request alone through the raw prefill+decode steps: per-row ops (matmuls,
norms, attention with per-row masks) do not mix batch rows, and the qwZ
weight gathers are batch-independent (tests/test_serve_engine.py).

Paged mode (``pool="paged"``, DESIGN.md §10) swaps the whole-slot pool
for a ``PagedKVPool`` page arena + per-slot page tables, resolved inside
ONE jitted paged step (``steps.build_paged_step``) that covers batched
decode (T=1), chunked prefill (B=1, T=chunk) and speculative verify
(T=spec_tokens+1).  On top of the table ride the prefix cache (chain-
hashed full prompt pages, refcounted, LRU-retained), chunked prefill
(every prompt ingests in fixed-size chunks interleaved with decode
ticks) and speculative decoding (``draft=(model, params)``: a small
drafter proposes spec_tokens greedily, the target verifies them in one
multi-token step — greedy output is token-identical to target-only
decode by construction, the drafter only changes how many positions each
target step advances).  Paged mode requires dense attn-only stacks and
unsharded batch (the arena is one global pool any row may reference).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.obs.metrics import Histogram, get_registry
from repro.obs.trace import get_tracer
from repro.serve import steps
from repro.serve.kv_pool import KVPool, PagedKVPool
from repro.serve.sampling import SamplerCache, request_key, token_key
from repro.serve.scheduler import FIFOScheduler, Request

Array = jax.Array


@dataclasses.dataclass
class _Active:
    """One in-flight request: ``pos`` is the cache position of the last
    sampled (not yet cache-written) token — the next decode's cache_pos."""
    req: Request
    slot: int
    pos: int
    n_gen: int
    last_token: int
    key: Array


@dataclasses.dataclass(eq=False)        # identity equality: ndarray fields
class _Prefill:
    """A paged request mid-prefill: ``done``/``d_done`` are the next chunk
    start for the target / drafter (seeded past a prefix-cache hit), and
    ``logits_row`` holds the target's final-chunk logits row once its last
    chunk ran (the first token samples from it when BOTH models finish)."""
    req: Request
    slot: int
    done: int
    d_done: int
    logits_row: Optional[Array] = None


class ServeEngine:
    def __init__(self, model, mesh, params: Dict[str, Array], *,
                 n_slots: int, kv_len: int,
                 batch_axes: Tuple[str, ...] = (),
                 kv_axes: Tuple[str, ...] = ("model",),
                 scheduler: Optional[FIFOScheduler] = None,
                 cache_dtype=None, donate: bool = True,
                 prefetch: Optional[int] = None,
                 kernel_backend: Optional[str] = None,
                 tune: str = "off", hbm_gb: float = 16.0,
                 pool: str = "slab", page_size: int = 16,
                 n_pages: Optional[int] = None,
                 chunk_size: Optional[int] = None,
                 prefix_cache: bool = True,
                 draft: Optional[Tuple[Any, Dict[str, Array]]] = None,
                 spec_tokens: int = 4,
                 clock: Callable[[], float] = time.monotonic):
        cfg = model.cfg
        self.policy = None
        if tune and tune != "off":
            # boot through the same resolver as training (repro.tune):
            # serve workload — the ledger charges the KV pool and the
            # forward-only ring; explicit prefetch/kernel_backend args
            # still win over the resolved defaults
            from repro.tune import GB, resolve
            mesh_axes = tuple(mesh.axis_names)
            rp = resolve(
                cfg, mesh_axes, "zeropp", mode=tune,
                mesh=mesh if tune == "probe" else None,
                mesh_sizes=dict(zip(mesh_axes,
                                    (int(s) for s in mesh.devices.shape))),
                hbm_budget_bytes=int(hbm_gb * GB),
                workload="serve", n_slots=n_slots, kv_len=kv_len)
            self.policy = rp
            if prefetch is None:
                prefetch = rp.zcfg.prefetch
            if kernel_backend is None:
                kernel_backend = rp.kernel_backend
        if kernel_backend is not None:
            # pin the quant-kernel backend (pallas/interpret/xla) for every
            # step this engine compiles — validated eagerly, so a 'pallas'
            # request off-TPU fails here instead of mid-serve
            from repro.kernels import ops as kops
            kops.set_backend(kernel_backend)
        if prefetch is not None:
            # deepen the weight-gather ring for the whole serving path:
            # decode batches are small, so on slow interconnects one
            # layer's compute cannot cover a gather — k>1 layers of
            # lookahead keeps the pipeline fed (core/schedule.py)
            model = model.with_prefetch(prefetch)
        if cfg.embed_inputs or cfg.mrope:
            raise ValueError(
                "ServeEngine drives token-in models; embed/M-RoPE frontends "
                "need their own input pipeline")
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        b_world = 1
        for a in batch_axes:
            b_world *= sizes[a]
        if n_slots % max(b_world, 1):
            raise ValueError(f"n_slots={n_slots} must divide over batch "
                             f"axes {batch_axes} (world {b_world})")
        if "local" in model.period and kv_len < cfg.window:
            raise ValueError(
                f"kv_len={kv_len} below the sliding window {cfg.window}: "
                f"ring caches from prefill would not fit the pool")
        if pool not in ("slab", "paged"):
            raise ValueError(f"pool must be 'slab' or 'paged', got {pool!r}")
        if draft is not None and pool != "paged":
            raise ValueError("speculative decoding rides the paged step; "
                             "pass pool='paged'")
        self.model = model
        self.mesh = mesh
        self.params = params
        self.n_slots = n_slots
        self.kv_len = kv_len
        self.pool_kind = pool
        cdtype = cache_dtype or model.zcfg.compute_dtype
        self.scheduler = scheduler if scheduler is not None \
            else FIFOScheduler(kv_len=kv_len)
        # prompts right-padded to buckets are exact only when every layer
        # masks by position (full attention): recurrent/ring/MoE states
        # would absorb the pad tokens, so those prefill at exact length
        self._pad_ok = set(model.period) == {"attn"}
        self.draft_pool = None
        self._prefilling: List[_Prefill] = []
        if pool == "paged":
            if batch_axes:
                raise ValueError(
                    "paged serving keeps the batch unsharded: the page "
                    "arena is one global pool any slot may reference, "
                    f"incompatible with batch_axes={batch_axes}")
            if not self._pad_ok:
                raise ValueError(
                    "paged serving supports dense attn-only stacks; got "
                    f"period {model.period} (use pool='slab')")
            self._chunk = chunk_size if chunk_size is not None \
                else min(kv_len, 2 * page_size)
            if self._chunk % page_size or self._chunk < 1:
                raise ValueError(f"chunk_size {self._chunk} must be a "
                                 f"positive multiple of page_size "
                                 f"{page_size}")
            self.pool = PagedKVPool(model, mesh, n_slots, kv_len,
                                    page_size=page_size, n_pages=n_pages,
                                    kv_axes=kv_axes, dtype=cdtype,
                                    prefix_cache=prefix_cache)
            # ONE builder; each (B, T) workload shape compiles once:
            # (n_slots, 1) decode, (1, chunk) prefill, (n_slots, g+1) verify
            self._paged = steps.build_paged_step(model, mesh, kv_axes,
                                                 donate=donate)
            if draft is not None:
                dmodel, dparams = draft
                if dmodel.cfg.vocab != cfg.vocab:
                    raise ValueError(
                        f"drafter vocab {dmodel.cfg.vocab} != target vocab "
                        f"{cfg.vocab}")
                if spec_tokens < 2:
                    raise ValueError("spec_tokens must be >= 2 (one draft "
                                     "round must beat plain decode)")
                self.spec_tokens = spec_tokens
                self.draft_model = dmodel
                self.draft_params = dparams
                # the drafter arena stays at FULL page capacity (its
                # reservations can then never fail while a slot is free),
                # so drafter slot ids always mirror the target pool's
                self.draft_pool = PagedKVPool(
                    dmodel, mesh, n_slots, kv_len, page_size=page_size,
                    kv_axes=kv_axes, dtype=cdtype,
                    prefix_cache=prefix_cache)
                self._draft_paged = steps.build_paged_step(
                    dmodel, mesh, kv_axes, donate=donate)
                self._spec_hist = Histogram("serve.spec_accepted",
                                            window=512)
        else:
            self.pool = KVPool(model, mesh, n_slots, kv_len,
                               batch_axes=batch_axes, kv_axes=kv_axes,
                               dtype=cdtype)
            # prefill: batch=1 per request (jit recompiles per bucket
            # length); decode: ONE compiled step for the whole pool
            self._prefill = steps.build_prefill_step(model, mesh, (), (),
                                                     with_last_pos=True)
            self._decode = steps.build_decode_step(model, mesh, batch_axes,
                                                   kv_axes, donate=donate)
        self._samplers = SamplerCache()
        self.clock = clock                       # injectable for tests
        self.slots: List[Optional[_Active]] = [None] * n_slots
        self.results: Dict[int, List[int]] = {}
        self.status: Dict[int, str] = {}   # uid -> queued/active/done/timeout
        self.slot_history: Dict[int, int] = {}   # uid -> slot (tests)
        # telemetry (engine-local so concurrent/sequential engines in one
        # process don't bleed into each other's stats(); event counts are
        # mirrored into the process registry for BENCH export).  Lifecycle
        # counts are exactly-once by construction: "expired" increments
        # where the request irrevocably leaves the system — scheduler.expire
        # pops queued requests, _retire clears the slot of active ones.
        self._counts = {"admitted": 0, "completed": 0, "expired": 0,
                        "prefill_chunks": 0}
        self._submit_t: Dict[int, float] = {}     # uid -> clock() at submit
        self._ttft = Histogram("serve.ttft_ms", window=512)
        self._tok_lat = Histogram("serve.tok_latency_ms", window=512)
        self._decode_win: deque = deque(maxlen=256)  # (wall_s, toks) per tick
        self._tick = 0

    # ------------------------------------------------------------- boot

    @classmethod
    def from_checkpoint(cls, model, mesh, ckpt: str, *,
                        dtype=jnp.bfloat16, **kw) -> "ServeEngine":
        """Boot from a ZeroState checkpoint (per-shard fp32 or INT8) via
        the params-only bf16 serving load path."""
        from repro.train.state import load_serving_params
        params = load_serving_params(model, mesh, ckpt, dtype=dtype,
                                     expect_arch=model.cfg.name)
        return cls(model, mesh, params, **kw)

    # ---------------------------------------------------------- requests

    def submit(self, prompt, **kw) -> int:
        """Queue a request; returns its uid.  Keyword args mirror
        ``scheduler.Request`` (max_new_tokens, temperature, top_k, top_p,
        seed, eos_id, on_token, deadline — absolute ``clock()`` time after
        which the request is dropped with status ``"timeout"``)."""
        if self.draft_pool is not None and kw.get("temperature", 0.0) > 0:
            raise ValueError(
                "speculative decoding verifies greedily: temperature>0 "
                "requests are not token-identical under it")
        req = Request(prompt=np.asarray(prompt, np.int32), **kw)
        uid = self.scheduler.submit(req)
        self.results[uid] = []
        self.status[uid] = "queued"
        self._submit_t[uid] = self.clock()
        return uid

    @property
    def n_active(self) -> int:
        return sum(a is not None for a in self.slots)

    @property
    def done(self) -> bool:
        return not self.n_active and not self._prefilling \
            and not len(self.scheduler)

    # ------------------------------------------------------------- steps

    def _put(self, tree, specs):
        return {k: jax.device_put(v, NamedSharding(self.mesh, specs[k]))
                for k, v in tree.items()}

    def _sample(self, req: Request, logits_row, key) -> int:
        fn = self._samplers((req.temperature, req.top_k, req.top_p))
        return int(fn(jnp.asarray(logits_row), key))

    def _emit(self, a: _Active, token: int) -> None:
        self.results[a.req.uid].append(token)
        if a.req.on_token is not None:
            a.req.on_token(a.req.uid, token)

    def _finished(self, a: _Active, token: int) -> bool:
        if a.req.eos_id is not None and token == a.req.eos_id:
            return True
        if a.n_gen >= a.req.max_new_tokens:
            return True
        return a.pos >= self.kv_len              # no slot left to write to

    def _retire(self, a: _Active, status: str = "done") -> None:
        self.slots[a.slot] = None
        self.pool.free(a.slot)
        if self.draft_pool is not None:
            self.draft_pool.free(a.slot)
        self.status[a.req.uid] = status
        key = "completed" if status == "done" else "expired"
        self._counts[key] += 1
        get_registry().counter(f"serve.{key}").inc()
        get_tracer().event("serve.retire", uid=a.req.uid, status=status,
                           n_gen=a.n_gen)

    def _expire(self, now: float) -> None:
        """Time out requests past their deadline: active ones release their
        KV slot back to the pool, queued ones never take one.  Each expiry
        increments the counter exactly once — scheduler.expire removes a
        queued request from the queue, _retire clears an active one's slot,
        and a request is never in both states."""
        for req in self.scheduler.expire(now):
            self.status[req.uid] = "timeout"
            self._counts["expired"] += 1
            get_registry().counter("serve.expired").inc()
            get_tracer().event("serve.expire_queued", uid=req.uid)
        for a in list(self.slots):
            if a is not None and a.req.deadline is not None \
                    and now >= a.req.deadline:
                self._retire(a, status="timeout")
        for pf in list(self._prefilling):
            if pf.req.deadline is not None and now >= pf.req.deadline:
                self._prefilling.remove(pf)
                self.pool.free(pf.slot)
                if self.draft_pool is not None:
                    self.draft_pool.free(pf.slot)
                self.status[pf.req.uid] = "timeout"
                self._counts["expired"] += 1
                get_registry().counter("serve.expired").inc()
                get_tracer().event("serve.expire_prefilling",
                                   uid=pf.req.uid)

    def _admit(self, emitted: List[Tuple[int, int]]) -> None:
        for req, bucket in self.scheduler.admit(self.pool.n_free):
            self.status[req.uid] = "active"
            self._counts["admitted"] += 1
            get_registry().counter("serve.admitted").inc()
            slot = self.pool.alloc()
            assert slot is not None
            P = len(req.prompt)
            Lp = bucket if self._pad_ok else P
            toks = np.zeros((1, Lp), np.int32)
            toks[0, :P] = req.prompt
            batch = self._put({"tokens": toks}, self._prefill.in_specs[1])
            with get_tracer().span("serve.prefill", uid=req.uid, slot=slot,
                                   prompt_len=P, bucket=Lp, step=self._tick):
                logits, caches = self._prefill.fn(
                    self.params, batch, jnp.full((1,), P - 1, jnp.int32))
            self.pool.write_prefill(slot, caches, P)
            self.slot_history[req.uid] = slot
            key = request_key(req.seed)
            tok = self._sample(req, logits[0, 0], token_key(key, 0))
            # TTFT on the engine clock: submit -> first generated token
            # (one prefill; never waits on the decode batch)
            t0 = self._submit_t.get(req.uid)
            if t0 is not None:
                ttft_ms = (self.clock() - t0) * 1e3
                self._ttft.observe(ttft_ms)
                get_registry().histogram("serve.ttft_ms").observe(ttft_ms)
            a = _Active(req=req, slot=slot, pos=P, n_gen=1,
                        last_token=tok, key=key)
            self._emit(a, tok)
            emitted.append((req.uid, tok))
            if self._finished(a, tok):
                self._retire(a)
            else:
                self.slots[slot] = a

    # ------------------------------------------------------- paged engine

    def _run_paged(self, draft: bool, tokens: np.ndarray, table: np.ndarray,
                   start: np.ndarray) -> Array:
        """One jitted paged step (target or drafter): uploads the (B, T)
        tokens, (B, Pm) page table and (B,) start positions, advances the
        pool's arena in place (donated), returns (B, T, V) logits."""
        step = self._draft_paged if draft else self._paged
        pool = self.draft_pool if draft else self.pool
        params = self.draft_params if draft else self.params
        batch = self._put({"tokens": np.asarray(tokens, np.int32)},
                          step.in_specs[2])
        table_dev = jax.device_put(
            np.asarray(table, np.int32),
            NamedSharding(self.mesh, step.in_specs[3]))
        start_dev = jax.device_put(
            np.asarray(start, np.int32),
            NamedSharding(self.mesh, step.in_specs[4]))
        logits, pool.caches = step.fn(params, pool.caches, batch,
                                      table_dev, start_dev)
        return logits

    def _admit_paged(self) -> None:
        """Admit while a slot AND the full page reservation fit.  The head
        of the queue blocks admission when its pages don't fit (strict
        FIFO): reservations are all-or-nothing, so a refused head mutates
        nothing and retries next tick."""
        while self.pool.n_free:
            req = self.scheduler.peek()
            if req is None:
                break
            res = self.pool.alloc(req.prompt, req.max_new_tokens,
                                  align=self._chunk)
            if res is None:
                break
            slot, matched = res
            d_matched = matched
            if self.draft_pool is not None:
                # reserve the drafter's spec_tokens of lookahead too; its
                # full-capacity arena makes this infallible slot-for-slot
                dres = self.draft_pool.alloc(
                    req.prompt, req.max_new_tokens + self.spec_tokens,
                    align=self._chunk)
                assert dres is not None and dres[0] == slot, \
                    "drafter pool must mirror target slots"
                d_matched = dres[1]
            self.scheduler.pop()
            self.status[req.uid] = "active"
            self._counts["admitted"] += 1
            get_registry().counter("serve.admitted").inc()
            self.slot_history[req.uid] = slot
            get_tracer().event("serve.admit_paged", uid=req.uid, slot=slot,
                               matched=matched)
            self._prefilling.append(
                _Prefill(req=req, slot=slot, done=matched,
                         d_done=d_matched))

    def _prefill_chunk(self, draft: bool, pf: _Prefill) -> Array:
        """Run ONE fixed-size prefill chunk for ``pf`` (zero-padded past
        the prompt; the pad's garbage KV is causally masked and later
        overwritten by decode writes at those positions)."""
        start = pf.d_done if draft else pf.done
        prompt = pf.req.prompt
        end = min(start + self._chunk, len(prompt))
        toks = np.zeros((1, self._chunk), np.int32)
        toks[0, : end - start] = prompt[start:end]
        pool = self.draft_pool if draft else self.pool
        logits = self._run_paged(draft, toks,
                                 pool.table[pf.slot: pf.slot + 1],
                                 np.full((1,), start, np.int32))
        if draft:
            pf.d_done = end
        else:
            pf.done = end
        return logits

    def _prefill_tick(self, emitted: List[Tuple[int, int]]) -> None:
        """Advance every mid-prefill request by ONE chunk (target and,
        when drafting, drafter) — the chunk quantum is what lets decode
        ticks interleave with long-prompt ingestion.  A request whose
        models have both finished samples its first token here."""
        tracer = get_tracer()
        for pf in list(self._prefilling):
            P = len(pf.req.prompt)
            if pf.done < P:
                s = pf.done
                with tracer.span("serve.prefill_chunk", uid=pf.req.uid,
                                 slot=pf.slot, start=s, step=self._tick):
                    logits = self._prefill_chunk(False, pf)
                self._counts["prefill_chunks"] += 1
                if pf.done >= P:
                    # final chunk: the row holding the LAST prompt token's
                    # logits seeds the first sampled token
                    pf.logits_row = logits[0, (P - 1) - s]
            if self.draft_pool is not None and pf.d_done < P:
                self._prefill_chunk(True, pf)
            if pf.done >= P and (self.draft_pool is None
                                 or pf.d_done >= P):
                self._finish_prefill(pf, emitted)

    def _finish_prefill(self, pf: _Prefill,
                        emitted: List[Tuple[int, int]]) -> None:
        req, slot = pf.req, pf.slot
        P = len(req.prompt)
        self._prefilling.remove(pf)
        self.pool.lengths[slot] = P
        self.pool.register_prefix(slot, req.prompt)
        if self.draft_pool is not None:
            self.draft_pool.lengths[slot] = P
            self.draft_pool.register_prefix(slot, req.prompt)
        key = request_key(req.seed)
        tok = self._sample(req, pf.logits_row, token_key(key, 0))
        t0 = self._submit_t.get(req.uid)
        if t0 is not None:
            ttft_ms = (self.clock() - t0) * 1e3
            self._ttft.observe(ttft_ms)
            get_registry().histogram("serve.ttft_ms").observe(ttft_ms)
        a = _Active(req=req, slot=slot, pos=P, n_gen=1,
                    last_token=tok, key=key)
        self._emit(a, tok)
        emitted.append((req.uid, tok))
        if self._finished(a, tok):
            self._retire(a)
        else:
            self.slots[slot] = a

    def _active_rows(self, active: List[_Active], width: int
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(tokens, start, table) step inputs with NON-active rows fully
        masked: an all-(-1) table row writes nothing and attends to
        nothing, so idle/prefilling slots riding the batched step can
        never touch pages they don't own (shared prefix pages included)."""
        tokens = np.zeros((self.n_slots, width), np.int32)
        start = np.zeros((self.n_slots,), np.int32)
        table = np.full_like(self.pool.table, -1)
        for a in active:
            tokens[a.slot, 0] = a.last_token
            start[a.slot] = a.pos
            table[a.slot] = self.pool.table[a.slot]
        return tokens, start, table

    def _decode_paged(self, active: List[_Active],
                      emitted: List[Tuple[int, int]], tracer) -> None:
        tokens, start, table = self._active_rows(active, 1)
        t0 = time.perf_counter()
        with tracer.span("serve.decode", step=self._tick,
                         batch=len(active)):
            logits = self._run_paged(False, tokens, table, start)
            n_tok = 0
            for a in active:
                tok = self._sample(a.req, logits[a.slot, 0],
                                   token_key(a.key, a.n_gen))
                a.n_gen += 1
                a.pos += 1
                self.pool.lengths[a.slot] = a.pos
                a.last_token = tok
                self._emit(a, tok)
                emitted.append((a.req.uid, tok))
                n_tok += 1
                if self._finished(a, tok):
                    self._retire(a)
        dur = time.perf_counter() - t0
        self._decode_win.append((dur, n_tok))
        lat_ms = dur * 1e3
        self._tok_lat.observe(lat_ms)
        get_registry().histogram("serve.tok_latency_ms").observe(lat_ms)

    def _spec_tick(self, active: List[_Active],
                   emitted: List[Tuple[int, int]], tracer) -> None:
        """One speculative round: g greedy drafter steps propose tokens
        x_1..x_g, ONE multi-token target step verifies positions p..p+g,
        and each row commits the longest draft prefix the target agrees
        with (+1 bonus token from the target's own logits).

        Acceptance is capped at g-1 drafts (g emitted tokens): accepting
        all g would leave the drafter's cache with a hole at p+g (x_g was
        proposed but never written), breaking the next round.  Rejected
        positions hold garbage KV in both caches; the next round's writes
        cover [p', p'+g] ⊇ that garbage before anything reads it.  Under
        greedy sampling every emitted token is a target argmax given the
        same committed stream, so the output is token-identical to
        target-only decode — the drafter only sets the stride.
        """
        g = self.spec_tokens
        tokens, start, ttable = self._active_rows(active, g + 1)
        dtable = np.full_like(self.draft_pool.table, -1)
        for a in active:
            dtable[a.slot] = self.draft_pool.table[a.slot]
        x = tokens                                    # x[:, 0] = pending
        t0 = time.perf_counter()
        with tracer.span("serve.spec_round", step=self._tick,
                         batch=len(active)):
            for j in range(g):
                dlogits = self._run_paged(True, x[:, j: j + 1], dtable,
                                          start + j)
                x[:, j + 1] = np.asarray(
                    jnp.argmax(dlogits[:, 0, :], axis=-1), np.int32)
            vlogits = self._run_paged(False, x, ttable, start)
            truth = np.asarray(jnp.argmax(vlogits, axis=-1), np.int32)
            n_tok = 0
            for a in active:
                p = a.pos
                m = 0
                while True:
                    tok = int(truth[a.slot, m])
                    a.n_gen += 1
                    a.pos = p + m + 1
                    a.last_token = tok
                    self.pool.lengths[a.slot] = a.pos
                    self.draft_pool.lengths[a.slot] = a.pos
                    self._emit(a, tok)
                    emitted.append((a.req.uid, tok))
                    n_tok += 1
                    if self._finished(a, tok):
                        self._retire(a)
                        break
                    if m >= g - 1 or int(x[a.slot, m + 1]) != tok:
                        break
                    m += 1
                self._spec_hist.observe(m + 1)
                get_registry().histogram("serve.spec_accepted") \
                    .observe(m + 1)
        dur = time.perf_counter() - t0
        self._decode_win.append((dur, n_tok))
        lat_ms = dur * 1e3
        self._tok_lat.observe(lat_ms)
        get_registry().histogram("serve.tok_latency_ms").observe(lat_ms)

    def step(self) -> List[Tuple[int, int]]:
        """One engine iteration: admit waiting requests, then one batched
        decode over every occupied slot.  Returns the (uid, token) pairs
        emitted this step, in slot order.  Paged mode additionally runs
        one prefill chunk per mid-prefill request before the decode (or
        speculative) tick."""
        emitted: List[Tuple[int, int]] = []
        tracer = self._tick_begin()
        self._expire(self.clock())
        if self.pool_kind == "paged":
            self._admit_paged()
            self._prefill_tick(emitted)
            active = [a for a in self.slots if a is not None]
            if active:
                if self.draft_pool is not None:
                    self._spec_tick(active, emitted, tracer)
                else:
                    self._decode_paged(active, emitted, tracer)
            self._tick_end(tracer)
            return emitted
        self._admit(emitted)
        active = [a for a in self.slots if a is not None]
        if not active:
            self._tick_end(tracer)
            return emitted
        tokens = np.zeros((self.n_slots, 1), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        for a in active:
            tokens[a.slot, 0] = a.last_token
            pos[a.slot] = a.pos
        batch = self._put({"tokens": tokens}, self._decode.in_specs[2])
        pos_dev = jax.device_put(
            pos, NamedSharding(self.mesh, self._decode.in_specs[3]))
        t0 = time.perf_counter()
        with tracer.span("serve.decode", step=self._tick,
                         batch=len(active)):
            logits, self.pool.caches = self._decode.fn(
                self.params, self.pool.caches, batch, pos_dev)
            n_tok = 0
            for a in active:
                # device-side row slice: no full-batch host copy + re-upload
                tok = self._sample(a.req, logits[a.slot, 0],
                                   token_key(a.key, a.n_gen))
                a.n_gen += 1
                a.pos += 1
                self.pool.lengths[a.slot] += 1
                a.last_token = tok
                self._emit(a, tok)
                emitted.append((a.req.uid, tok))
                n_tok += 1
                if self._finished(a, tok):
                    self._retire(a)
        # every active sequence gained one token this tick, so the tick's
        # wall time (decode + sampling) IS its per-token latency
        dur = time.perf_counter() - t0
        self._decode_win.append((dur, n_tok))
        lat_ms = dur * 1e3
        self._tok_lat.observe(lat_ms)
        get_registry().histogram("serve.tok_latency_ms").observe(lat_ms)
        self._tick_end(tracer)
        return emitted

    def _tick_begin(self):
        self._tick += 1
        return get_tracer()

    def _tick_end(self, tracer) -> None:
        reg = get_registry()
        reg.gauge("serve.slot_occupancy").set(self.n_active / self.n_slots)
        reg.gauge("serve.queue_depth").set(len(self.scheduler))
        tracer.flush()  # tick boundary: host telemetry only, never in-jit

    def stats(self) -> Dict[str, Any]:
        """Point-in-time snapshot: lifecycle counts, occupancy, and
        sliding-window latency quantiles (p50/p90/p99, exact over the
        Histogram window, which bounds memory).  Paged engines add pool
        utilization + prefix-cache counters, speculative ones the
        accepted-tokens-per-verify distribution."""
        win = list(self._decode_win)
        toks = sum(n for _, n in win)
        secs = sum(d for d, _ in win)
        out = {
            "admitted": self._counts["admitted"],
            "completed": self._counts["completed"],
            "expired": self._counts["expired"],
            "queued": len(self.scheduler),
            "active": self.n_active,
            "occupancy": self.n_active / self.n_slots,
            "steps": self._tick,
            "ttft_ms": self._ttft.quantiles(),
            "tok_latency_ms": self._tok_lat.quantiles(),
            "tok_per_s": (toks / secs) if secs > 0 else None,
            "policy": self.policy.as_dict() if self.policy else None,
        }
        if self.pool_kind == "paged":
            out["prefill_chunks"] = self._counts["prefill_chunks"]
            out["prefilling"] = len(self._prefilling)
            out["pool"] = self.pool.utilization()
            if self.draft_pool is not None:
                q = self._spec_hist.quantiles()
                q["mean"] = self._spec_hist.mean
                out["spec_accepted"] = q
        return out

    def run(self, max_steps: Optional[int] = None) -> Dict[int, List[int]]:
        """Drive until every submitted request retires; returns
        uid -> generated tokens (EOS included when hit)."""
        n = 0
        while not self.done:
            self.step()
            n += 1
            if max_steps is not None and n >= max_steps and not self.done:
                raise RuntimeError(
                    f"engine did not drain in {max_steps} steps "
                    f"({self.n_active} active, {len(self.scheduler)} queued)")
        return self.results
