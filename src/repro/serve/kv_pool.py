"""KV cache pools: whole-slot slabs and the paged block arena.

Two pool disciplines share this module (DESIGN.md §5/§10):

``KVPool`` — fixed-capacity whole slots with FIFO recycling.  The pool
owns ONE cache pytree of batch size ``n_slots`` (the decode batch), laid
out exactly like ``Model.cache_shapes`` and sharded with
``steps.cache_specs``.  A request occupies one slot for its lifetime:

  admit  -> ``alloc()`` hands out the oldest retired slot (FIFO recycling)
  prefill-> ``write_prefill`` inserts the request's padded prefill caches
            at the slot's batch index via ``jax.lax.dynamic_update_slice``
            under ONE jitted writer (the slot index is traced, so one
            compile serves every slot)
  decode -> the engine's jitted decode step updates all slots in place
            (per-sequence cache_pos; inactive slots write their own slot's
            position 0, which the next prefill overwrites)
  retire -> ``free()`` zeroes the slot's length and recycles it

``PagedKVPool`` — a fixed arena of KV *pages* plus a per-slot page
table.  A request pins only the pages its tokens occupy (reserved in
full at admission: ceil(min(prompt+max_new, kv_len)/page_size) pages, so
allocation can never fail mid-generation), and full prompt pages are
shared across requests through a chain-hash prefix cache with refcounts
and LRU retention.  The page table is the ONLY host<->device traffic:
the slot->page indirection itself is resolved inside the jitted paged
step (models/attention.py paged_insert/paged_attend).

Host-side metadata (free lists, tables, refcounts, hashes) never enters
jit.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict, deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding

from repro.serve import steps

Array = jax.Array


class KVPool:
    def __init__(self, model, mesh, n_slots: int, kv_len: int,
                 batch_axes: Tuple[str, ...] = (),
                 kv_axes: Tuple[str, ...] = ("model",),
                 dtype=jnp.bfloat16):
        self.model = model
        self.mesh = mesh
        self.n_slots = n_slots
        self.kv_len = kv_len
        self.specs = steps.cache_specs(model, batch_axes, kv_axes)
        caches = model.init_caches(n_slots, kv_len, dtype)
        self.caches = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            caches, self.specs)
        self.lengths = np.zeros(n_slots, np.int32)   # valid tokens per slot
        self._free: Deque[int] = deque(range(n_slots))
        self._writer = jax.jit(self._write_tree, donate_argnums=(0,))

    # ------------------------------------------------------------ slots

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self) -> Optional[int]:
        """Oldest retired slot first — recycling is FIFO, so freed slots
        are provably reused (tests assert this)."""
        return self._free.popleft() if self._free else None

    def free(self, slot: int) -> None:
        assert 0 <= slot < self.n_slots and slot not in self._free
        self.lengths[slot] = 0
        self._free.append(slot)

    # ------------------------------------------------------------ writes

    def _write_tree(self, pool, new, slot):
        """Insert a batch=1 cache tree at batch index ``slot``.

        Stacked block caches carry (n_periods, B, ...) so the batch axis is
        1; the remainder group is unstacked, batch axis 0.
        """
        def ins(pl, nl, b_ax):
            starts = [jnp.int32(0)] * pl.ndim
            starts[b_ax] = jnp.asarray(slot, jnp.int32)
            return lax.dynamic_update_slice(pl, nl.astype(pl.dtype), starts)

        blocks = tuple(
            jax.tree.map(lambda p, n: ins(p, n, 1), pb, nb)
            for pb, nb in zip(pool["blocks"], new["blocks"]))
        rem = None
        if pool.get("rem") is not None:
            rem = tuple(
                jax.tree.map(lambda p, n: ins(p, n, 0), pr, nr)
                for pr, nr in zip(pool["rem"], new["rem"]))
        return {"blocks": blocks, "rem": rem}

    def write_prefill(self, slot: int, prefill_caches: Any,
                      prompt_len: int) -> None:
        """Grow a request's prefill caches to pool capacity and insert them
        at ``slot``.  The insert covers the FULL slot (zero-padded beyond
        the prefill length), so a recycled slot can never leak its previous
        occupant; the zero region stays masked (decode's validity test is
        pos <= cache_pos) until the decode loop overwrites it."""
        grown = steps.pad_prefill_caches(self.model, prefill_caches,
                                         self.kv_len)
        self.caches = self._writer(self.caches, grown, slot)
        self.lengths[slot] = prompt_len


def _mesh_axes_prod(mesh, axes: Sequence[str]) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    w = 1
    for ax in axes:
        w *= sizes[ax]
    return w


def _page_hash(prev: bytes, tokens: np.ndarray) -> bytes:
    """Chain hash of one full page of prompt tokens.

    Chained (page i's hash covers pages 0..i) so equal page CONTENT at
    different prompt offsets never aliases — a cached page is reusable
    only when the entire prefix leading to it matches.
    """
    return hashlib.blake2b(prev + tokens.astype(np.int64).tobytes(),
                           digest_size=16).digest()


class PagedKVPool:
    """Fixed page arena + per-slot page tables + prefix cache.

    Page lifecycle (every page is in exactly one of these states):

      free      -> on ``_free_pages``; content is garbage
      active    -> refcount >= 1: referenced by that many slot tables
      cached    -> refcount == 0 but REGISTERED in the prefix cache:
                   parked in ``_lru`` with content retained, revivable by
                   a prefix hit, reclaimed oldest-first only under pool
                   pressure (eviction touches refcount-0 pages only, by
                   construction)

    Only FULL prompt pages are ever registered (a page decode will still
    write into is never shared), and a prefix match is capped at
    prompt_len - 1 tokens so at least one suffix token always runs
    through prefill to produce the first-token logits.
    """

    def __init__(self, model, mesh, n_slots: int, kv_len: int,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 kv_axes: Tuple[str, ...] = ("model",),
                 dtype=jnp.bfloat16, prefix_cache: bool = True):
        if set(model.period) != {"attn"}:
            raise ValueError("PagedKVPool supports dense attn-only stacks; "
                             f"got period {model.period}")
        w = _mesh_axes_prod(mesh, kv_axes)
        if page_size % w:
            raise ValueError(f"page_size {page_size} must divide over the "
                             f"{w}-way kv sharding {tuple(kv_axes)}")
        if kv_len % page_size:
            raise ValueError(f"kv_len {kv_len} % page_size {page_size} != 0")
        self.model = model
        self.mesh = mesh
        self.n_slots = n_slots
        self.kv_len = kv_len
        self.page_size = page_size
        self.pages_per_slot = kv_len // page_size
        self.n_pages = n_pages if n_pages is not None \
            else n_slots * self.pages_per_slot
        self.prefix_enabled = prefix_cache

        self.specs = steps.paged_cache_specs(model, kv_axes)
        arena = model.init_paged_caches(self.n_pages, page_size, dtype)
        self.caches = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            arena, self.specs)

        self.table = np.full((n_slots, self.pages_per_slot), -1, np.int32)
        self.lengths = np.zeros(n_slots, np.int32)
        self.refcount = np.zeros(self.n_pages, np.int32)
        self._free_slots: Deque[int] = deque(range(n_slots))
        self._free_pages: Deque[int] = deque(range(self.n_pages))
        self._cache: Dict[bytes, int] = {}      # registered: hash -> page
        self._hash_of: Dict[int, bytes] = {}    # registered: page -> hash
        self._lru: "OrderedDict[bytes, int]" = OrderedDict()
        self.counters = {"prefix_hits": 0, "prefix_tokens_reused": 0,
                         "evicted": 0}

    # ------------------------------------------------------------ queries

    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def free_pages(self) -> int:
        """Pages allocatable right now (free list + evictable LRU)."""
        return len(self._free_pages) + len(self._lru)

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        """Table entries a request pins: every position it may ever write
        (prompt + generated, clipped to capacity), page-rounded."""
        hi = min(prompt_len + max_new, self.kv_len)
        return -(-hi // self.page_size)

    def match_prefix(self, prompt: Sequence[int], align: int = 1
                     ) -> Tuple[int, List[Tuple[bytes, int]]]:
        """Longest reusable prefix of ``prompt`` already resident as
        registered pages.  Returns (matched_tokens, [(hash, page), ...]).

        Full pages only; capped at prompt_len - 1 tokens (at least one
        token must run through prefill for the first-token logits); then
        truncated DOWN to a multiple of ``align`` (the engine passes its
        prefill chunk size, keeping chunk boundaries of a hit prefill
        aligned with the cold one — that alignment is what makes the hit
        path bitwise identical to the cold path).
        """
        if not self.prefix_enabled:
            return 0, []
        toks = np.asarray(prompt, np.int64)
        limit = min((len(toks) - 1) // self.page_size,
                    self.pages_per_slot)
        pairs: List[Tuple[bytes, int]] = []
        h = b""
        for i in range(limit):
            h = _page_hash(h, toks[i * self.page_size:
                                   (i + 1) * self.page_size])
            pg = self._cache.get(h)
            if pg is None:
                break
            pairs.append((h, pg))
        matched = len(pairs) * self.page_size
        if align > 1:
            matched = (matched // align) * align
            pairs = pairs[: matched // self.page_size]
        return matched, pairs

    # ------------------------------------------------------- admit / free

    def _claim_page(self) -> int:
        if self._free_pages:
            return self._free_pages.popleft()
        h, pg = self._lru.popitem(last=False)      # oldest registered page
        del self._cache[h]
        del self._hash_of[pg]
        self.counters["evicted"] += 1
        return pg

    def alloc(self, prompt: Sequence[int], max_new: int, align: int = 1
              ) -> Optional[Tuple[int, int]]:
        """Admit one request: reserve a slot and its FULL page budget.

        Returns (slot, matched_prefix_tokens) or None (no slot, or not
        enough claimable pages — all-or-nothing: a refused admission
        mutates nothing).  Matched prefix pages are revived/refcounted,
        the rest claimed from the free list (evicting LRU pages if
        pressed).  Eager reservation means decode/speculative writes can
        never hit an unmapped in-budget page mid-flight; writes past the
        budget (speculative overshoot) are dropped by paged_insert.
        """
        if not self._free_slots:
            return None
        matched, pairs = self.match_prefix(prompt, align)
        need = self.pages_needed(len(prompt), max_new)
        in_lru = sum(1 for h, _ in pairs if h in self._lru)
        claimable = len(self._free_pages) + len(self._lru) - in_lru
        if need - len(pairs) > claimable:
            return None
        slot = self._free_slots.popleft()
        row = np.full(self.pages_per_slot, -1, np.int32)
        for i, (h, pg) in enumerate(pairs):
            if h in self._lru:
                del self._lru[h]                   # revive a parked page
            self.refcount[pg] += 1
            row[i] = pg
        for i in range(len(pairs), need):
            pg = self._claim_page()
            self.refcount[pg] = 1
            row[i] = pg
        self.table[slot] = row
        self.lengths[slot] = 0
        if matched:
            self.counters["prefix_hits"] += 1
            self.counters["prefix_tokens_reused"] += matched
        return slot, matched

    def register_prefix(self, slot: int, prompt: Sequence[int]) -> None:
        """Publish ``slot``'s full prompt pages into the prefix cache.

        Called once prefill has written them.  Only pages every one of
        whose tokens is a PROMPT token are registered (decode writes start
        at prompt_len, so page prompt_len//page_size onward may mutate);
        pages already registered (a matched prefix) or whose chain hash is
        already published under a different physical page are skipped.
        """
        if not self.prefix_enabled:
            return
        toks = np.asarray(prompt, np.int64)
        h = b""
        for i in range(len(toks) // self.page_size):
            h = _page_hash(h, toks[i * self.page_size:
                                   (i + 1) * self.page_size])
            pg = int(self.table[slot, i])
            assert pg >= 0
            if pg in self._hash_of or h in self._cache:
                continue
            self._cache[h] = pg
            self._hash_of[pg] = h

    def free(self, slot: int) -> None:
        """Release a slot: unreference its pages.  Pages hitting
        refcount 0 go to the LRU (content retained) if registered, else
        straight back to the free list."""
        assert 0 <= slot < self.n_slots and slot not in self._free_slots
        for pg in self.table[slot]:
            pg = int(pg)
            if pg < 0:
                continue
            self.refcount[pg] -= 1
            assert self.refcount[pg] >= 0
            if self.refcount[pg] == 0:
                h = self._hash_of.get(pg)
                if h is not None:
                    self._lru[h] = pg
                else:
                    self._free_pages.append(pg)
        self.table[slot] = -1
        self.lengths[slot] = 0
        self._free_slots.append(slot)

    # ------------------------------------------------------------- stats

    def utilization(self) -> Dict[str, Any]:
        active = int(self.n_pages - len(self._free_pages) - len(self._lru))
        return {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "pages_active": active,
            "pages_cached": len(self._lru),
            "pages_free": len(self._free_pages),
            "utilization": active / max(1, self.n_pages),
            **self.counters,
        }
