"""Slot-based KV cache pool: fixed capacity, per-slot lengths, recycling.

The pool owns ONE cache pytree of batch size ``n_slots`` (the decode
batch), laid out exactly like ``Model.cache_shapes`` and sharded with
``steps.cache_specs``.  A request occupies one slot for its lifetime:

  admit  -> ``alloc()`` hands out the oldest retired slot (FIFO recycling)
  prefill-> ``write_prefill`` inserts the request's padded prefill caches
            at the slot's batch index via ``jax.lax.dynamic_update_slice``
            under ONE jitted writer (the slot index is traced, so one
            compile serves every slot)
  decode -> the engine's jitted decode step updates all slots in place
            (per-sequence cache_pos; inactive slots write their own slot's
            position 0, which the next prefill overwrites)
  retire -> ``free()`` zeroes the slot's length and recycles it

Host-side metadata (free list, per-slot lengths) never enters jit.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Deque, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding

from repro.serve import steps

Array = jax.Array


class KVPool:
    def __init__(self, model, mesh, n_slots: int, kv_len: int,
                 batch_axes: Tuple[str, ...] = (),
                 kv_axes: Tuple[str, ...] = ("model",),
                 dtype=jnp.bfloat16):
        self.model = model
        self.mesh = mesh
        self.n_slots = n_slots
        self.kv_len = kv_len
        self.specs = steps.cache_specs(model, batch_axes, kv_axes)
        caches = model.init_caches(n_slots, kv_len, dtype)
        self.caches = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            caches, self.specs)
        self.lengths = np.zeros(n_slots, np.int32)   # valid tokens per slot
        self._free: Deque[int] = deque(range(n_slots))
        self._writer = jax.jit(self._write_tree, donate_argnums=(0,))

    # ------------------------------------------------------------ slots

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self) -> Optional[int]:
        """Oldest retired slot first — recycling is FIFO, so freed slots
        are provably reused (tests assert this)."""
        return self._free.popleft() if self._free else None

    def free(self, slot: int) -> None:
        assert 0 <= slot < self.n_slots and slot not in self._free
        self.lengths[slot] = 0
        self._free.append(slot)

    # ------------------------------------------------------------ writes

    def _write_tree(self, pool, new, slot):
        """Insert a batch=1 cache tree at batch index ``slot``.

        Stacked block caches carry (n_periods, B, ...) so the batch axis is
        1; the remainder group is unstacked, batch axis 0.
        """
        def ins(pl, nl, b_ax):
            starts = [jnp.int32(0)] * pl.ndim
            starts[b_ax] = jnp.asarray(slot, jnp.int32)
            return lax.dynamic_update_slice(pl, nl.astype(pl.dtype), starts)

        blocks = tuple(
            jax.tree.map(lambda p, n: ins(p, n, 1), pb, nb)
            for pb, nb in zip(pool["blocks"], new["blocks"]))
        rem = None
        if pool.get("rem") is not None:
            rem = tuple(
                jax.tree.map(lambda p, n: ins(p, n, 0), pr, nr)
                for pr, nr in zip(pool["rem"], new["rem"]))
        return {"blocks": blocks, "rem": rem}

    def write_prefill(self, slot: int, prefill_caches: Any,
                      prompt_len: int) -> None:
        """Grow a request's prefill caches to pool capacity and insert them
        at ``slot``.  The insert covers the FULL slot (zero-padded beyond
        the prefill length), so a recycled slot can never leak its previous
        occupant; the zero region stays masked (decode's validity test is
        pos <= cache_pos) until the decode loop overwrites it."""
        grown = steps.pad_prefill_caches(self.model, prefill_caches,
                                         self.kv_len)
        self.caches = self._writer(self.caches, grown, slot)
        self.lengths[slot] = prompt_len
