"""FIFO request queue with admission control.

Admission is keyed on two things only (continuous batching keeps the rest
of the policy in the engine):

  * **free slots** — a request is admitted the moment the KV pool has a
    slot for it; ``admit(n_free)`` never returns more requests than slots.
  * **prompt-length buckets** — prompts are bucketed into a fixed ladder
    of padded lengths, so the number of distinct compiled prefill shapes
    is bounded by ``len(buckets)`` no matter how many distinct prompt
    lengths the traffic carries.

Requests that can never run (prompt + one generated token exceeding the
pool's KV capacity) are rejected at ``submit`` with a clear error instead
of clogging the queue.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Callable, Deque, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request.  ``on_token`` streams (uid, token) as each
    token is sampled — before the request completes."""
    prompt: np.ndarray                      # (P,) int32 token ids
    max_new_tokens: int = 16
    temperature: float = 0.0                # 0 -> greedy
    top_k: int = 0                          # 0 -> disabled
    top_p: float = 1.0                      # 1 -> disabled
    seed: int = 0
    eos_id: Optional[int] = None
    on_token: Optional[Callable[[int, int], None]] = None
    deadline: Optional[float] = None        # absolute engine-clock time
    uid: int = -1                           # assigned at submit


def default_buckets(kv_len: int, start: int = 8) -> Tuple[int, ...]:
    """Power-of-two ladder start, 2*start, ... capped at kv_len.

    ``start`` is clamped to ``kv_len // 2`` so the ladder always holds at
    least one bucket strictly below capacity — with ``start >= kv_len`` it
    used to degenerate to the single bucket ``(kv_len,)``, silently
    padding every short prompt to full KV capacity in prefill.  A ladder
    that cannot have a sub-capacity bucket (``kv_len < 2``) raises.
    """
    if start < 1:
        raise ValueError(f"bucket ladder start must be >= 1, got {start}")
    if kv_len < 2:
        raise ValueError(
            f"kv_len={kv_len} leaves a degenerate one-bucket ladder: every "
            f"prompt would prefill padded to full KV capacity")
    start = min(start, kv_len // 2)
    out = []
    b = start
    while b < kv_len:
        out.append(b)
        b *= 2
    out.append(kv_len)
    return tuple(out)


class FIFOScheduler:
    """First-in-first-out queue; admission keyed on free slots."""

    def __init__(self, kv_len: int,
                 buckets: Optional[Sequence[int]] = None):
        self.kv_len = kv_len
        self.buckets = tuple(sorted(set(buckets or default_buckets(kv_len))))
        if self.buckets[-1] > kv_len:
            raise ValueError(
                f"bucket {self.buckets[-1]} exceeds KV capacity {kv_len}")
        self._queue: Deque[Request] = deque()
        self._uids = itertools.count()

    def __len__(self) -> int:
        return len(self._queue)

    def bucket_for(self, prompt_len: int) -> int:
        """Smallest bucket holding the prompt; raises if none can."""
        for b in self.buckets:
            if b >= prompt_len:
                return b
        raise ValueError(
            f"prompt length {prompt_len} exceeds the largest bucket "
            f"{self.buckets[-1]} (KV capacity {self.kv_len})")

    def submit(self, req: Request) -> int:
        req.prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        plen = len(req.prompt)        # validate the FLAT length that runs
        if plen < 1:
            raise ValueError("empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens={req.max_new_tokens}: the engine always "
                f"emits at least one token (the prefill's first sample)")
        if plen + 1 > self.kv_len:
            raise ValueError(
                f"prompt length {plen} leaves no room to generate within "
                f"KV capacity {self.kv_len}")
        self.bucket_for(plen)                 # validates against the ladder
        req.uid = next(self._uids)
        self._queue.append(req)
        return req.uid

    def admit(self, n_free: int) -> List[Tuple[Request, int]]:
        """Pop up to ``n_free`` requests with their padded prompt lengths."""
        out: List[Tuple[Request, int]] = []
        while self._queue and len(out) < n_free:
            req = self._queue.popleft()
            out.append((req, self.bucket_for(len(req.prompt))))
        return out

    def peek(self) -> Optional[Request]:
        """Head of the queue without popping — the paged engine's
        admission loop must check page availability before committing to
        a pop (FIFO order is preserved under head-of-line blocking)."""
        return self._queue[0] if self._queue else None

    def pop(self) -> Request:
        return self._queue.popleft()

    def expire(self, now: float) -> List[Request]:
        """Drop queued requests whose deadline has passed: a request that
        timed out waiting must never occupy a KV slot."""
        expired = [r for r in self._queue
                   if r.deadline is not None and now >= r.deadline]
        if expired:
            dead = {id(r) for r in expired}   # ndarray fields break ==
            self._queue = deque(r for r in self._queue
                                if id(r) not in dead)
        return expired
