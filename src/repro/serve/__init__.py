"""repro.serve — continuous-batching inference engine (DESIGN.md §5).

Layering: the engine owns slots and scheduling, ``steps`` (over
train/serve.py) owns the shard_map step builders and sharding specs,
ZeroState (train/state.py) owns parameters.
"""
from repro.serve.engine import ServeEngine                      # noqa: F401
from repro.serve.kv_pool import KVPool, PagedKVPool             # noqa: F401
from repro.serve.sampling import (sample_logits, top_k_mask,    # noqa: F401
                                  top_p_mask)
from repro.serve.scheduler import FIFOScheduler, Request        # noqa: F401
from repro.serve import steps                                   # noqa: F401
