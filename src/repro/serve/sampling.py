"""Token sampling: temperature / top-k / top-p with per-request seeds.

Pure functions over a trailing vocab axis, plus a small compiled-sampler
cache keyed on the (temperature, top_k, top_p) triple — requests sharing
sampling parameters share one compiled sampler, and greedy requests
(temperature == 0) compile to a bare argmax.

Seed discipline: every request owns a PRNGKey derived from its integer
seed; the key for the n-th generated token is ``fold_in(key, n)``, so a
request's stream is reproducible regardless of which other requests share
its decode batches.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def top_k_mask(logits: Array, k: int) -> Array:
    """Boolean mask keeping EXACTLY the k largest entries of the last axis
    (ties broken by index order, matching ``lax.top_k``)."""
    V = logits.shape[-1]
    if k <= 0 or k >= V:
        return jnp.ones(logits.shape, bool)
    flat = logits.reshape(-1, V)
    _, idx = jax.lax.top_k(flat, k)                    # (N, k)
    rows = jnp.arange(flat.shape[0])[:, None]
    mask = jnp.zeros(flat.shape, bool).at[rows, idx].set(True)
    return mask.reshape(logits.shape)


def top_p_mask(logits: Array, p: float) -> Array:
    """Nucleus mask: the smallest prefix of probability-sorted tokens whose
    cumulative probability reaches ``p`` (the argmax is always kept)."""
    V = logits.shape[-1]
    if p >= 1.0:
        return jnp.ones(logits.shape, bool)
    flat = logits.reshape(-1, V).astype(jnp.float32)
    order = jnp.argsort(-flat, axis=-1)                # descending
    srt = jnp.take_along_axis(flat, order, axis=-1)
    probs = jax.nn.softmax(srt, axis=-1)
    csum = jnp.cumsum(probs, axis=-1)
    # token i stays while the mass BEFORE it is < p; the first always stays
    keep_sorted = (csum - probs) < p
    rows = jnp.arange(flat.shape[0])[:, None]
    mask = jnp.zeros(flat.shape, bool).at[rows, order].set(keep_sorted)
    return mask.reshape(logits.shape)


def sample_logits(logits: Array, key: Array, temperature: float = 0.0,
                  top_k: int = 0, top_p: float = 1.0) -> Array:
    """Sample token ids from (..., V) logits.  temperature == 0 is greedy
    argmax (the key is unused); otherwise top-k, then top-p, then a
    categorical draw at the given temperature."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits.astype(jnp.float32) / temperature
    if top_k:
        l = jnp.where(top_k_mask(l, top_k), l, NEG_INF)
    if top_p < 1.0:
        l = jnp.where(top_p_mask(l, top_p), l, NEG_INF)
    return jax.random.categorical(key, l, axis=-1).astype(jnp.int32)


class SamplerCache:
    """One jitted sampler per distinct (temperature, top_k, top_p)."""

    def __init__(self):
        self._fns = {}

    def __call__(self, params: Tuple[float, int, float]):
        fn = self._fns.get(params)
        if fn is None:
            t, k, p = params
            fn = jax.jit(partial(sample_logits, temperature=t, top_k=k,
                                 top_p=p))
            self._fns[params] = fn
        return fn


def request_key(seed: int) -> Array:
    return jax.random.PRNGKey(seed)


def token_key(key: Array, n_generated: int) -> Array:
    return jax.random.fold_in(key, n_generated)
