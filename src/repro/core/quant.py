"""Blockwise symmetric quantization — the numerical core of qwZ / qgZ.

The paper (§3.1, Fig. 2) uses block-based symmetric quantization: each
contiguous block of elements gets an independent scale ``max|x| / qmax`` so
that outliers only poison their own block.  INT8 is used for weight
all-gather (qwZ) and INT4 (packed two-per-int8) for gradient all-to-all
(qgZ).

Everything here is pure jnp and shape-polymorphic; the Pallas kernels in
``repro.kernels`` implement the same math for the TPU hot path and are
checked against these functions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

_QMAX = {8: 127.0, 4: 7.0}

# Flat buffers above this many elements are (de)quantized in segments via
# lax.map: numerically identical (elementwise math is unchanged), but the
# fp32 intermediates materialize one segment at a time instead of as a
# full-buffer temporary — multi-GB gathered weight buffers would otherwise
# spike peak memory during the quant pipeline.  Mirrors the Pallas kernels'
# tile streaming.
_SEG_ELEMS = 1 << 23


def _segments(n: int, block: int, target: Optional[int] = None) -> int:
    """Largest segment count such that n/nseg is a multiple of block and
    <= target elements; 1 means no segmentation."""
    target = _SEG_ELEMS if target is None else target
    if n <= target or n % block:
        return 1
    nb = n // block
    best = 1
    for nseg in range(2, nb + 1):
        if nb % nseg == 0 and n // nseg <= target:
            return nseg
        if nb % nseg == 0:
            best = nseg
    return best


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static description of a blockwise quantization scheme."""

    bits: int = 8              # 8 (qwZ default) or 4 (qgZ default)
    block_size: int = 256      # elements per scale block
    stochastic: bool = False   # stochastic rounding (beyond-paper option)

    def __post_init__(self):
        if self.bits not in (4, 8):
            raise ValueError(f"bits must be 4 or 8, got {self.bits}")
        if self.block_size % 2:
            raise ValueError("block_size must be even (int4 packing)")

    @property
    def qmax(self) -> float:
        return _QMAX[self.bits]

    @property
    def packed_block(self) -> int:
        """Bytes of payload per block."""
        return self.block_size if self.bits == 8 else self.block_size // 2

    def payload_bytes(self, n: int) -> int:
        """Communication payload (quantized values only) for n elements.

        int4 packs two values per byte; an odd trailing element still
        occupies a whole byte (ceil), so dryrun/comm-volume accounting
        matches the bytes actually moved."""
        return n if self.bits == 8 else (n + 1) // 2

    def wire_bytes(self, n: int, scale_bytes: int = 4) -> int:
        """Payload + scales actually moved on the wire for n elements.

        Scales are float32 — 4 bytes each — end to end: quantize_blockwise
        emits fp32 scales and the collectives move them losslessly.  qwZ
        gathers them on a second all-gather; the qgZ all-to-alls pack them
        INTO the payload message (bitcast to int8 lanes — see
        collectives._pack_scales), so either way the wire total is
        payload + 4·n_blocks.  (This default was 2 for a long time,
        silently under-counting every analytic comm-volume number by
        2 bytes per block; the runtime jaxpr-measured counters caught
        it.)"""
        nblocks = -(-n // self.block_size)
        return self.payload_bytes(n) + nblocks * scale_bytes


def pad_to_block(x: Array, block_size: int) -> Array:
    """Pad a 1-D array so its length is a multiple of ``block_size``."""
    n = x.shape[-1]
    rem = (-n) % block_size
    if rem:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
        x = jnp.pad(x, pad)
    return x


def _round(x: Array, stochastic: bool, key: Optional[Array]) -> Array:
    if not stochastic:
        return jnp.round(x)
    assert key is not None, "stochastic rounding needs a PRNG key"
    lo = jnp.floor(x)
    p_up = x - lo
    u = jax.random.uniform(key, x.shape, dtype=x.dtype)
    return lo + (u < p_up).astype(x.dtype)


def stochastic_uniform(shape: Tuple[int, ...], cfg: QuantConfig,
                       key: Array) -> Array:
    """The exact uniform field ``quantize_blockwise(x, cfg, key)`` draws.

    Reproduces the reference's segmentation structure — 1-D buffers split
    into ``_segments`` with per-segment keys, large multi-dim arrays mapped
    over rows with per-row keys, everything else a single draw on the
    blocked shape — so the same ``key`` yields bit-identical rounding
    whether the comparison ``u < x·inv − floor(x·inv)`` runs in the jnp
    reference or inside a Pallas kernel fed this field as an extra input
    (kernels/ops.py threads it through).  Returns float32 of ``shape``.
    """
    n = shape[-1]
    if n % cfg.block_size:
        raise ValueError(f"trailing dim {n} not a multiple of block "
                         f"{cfg.block_size}")
    if len(shape) == 1:
        nseg = _segments(n, cfg.block_size)
        if nseg > 1:
            seg = n // nseg
            u = jax.lax.map(lambda k: stochastic_uniform((seg,), cfg, k),
                            jax.random.split(key, nseg))
            return u.reshape(-1)
    else:
        size = 1
        for s in shape:
            size *= s
        if size > _SEG_ELEMS and n <= _SEG_ELEMS:
            nrows = size // n
            u = jax.lax.map(lambda k: stochastic_uniform((n,), cfg, k),
                            jax.random.split(key, nrows))
            return u.reshape(*shape[:-1], n)
    nblocks = n // cfg.block_size
    u = jax.random.uniform(
        key, (*shape[:-1], nblocks, cfg.block_size), dtype=jnp.float32)
    return u.reshape(shape)


def quantize_blockwise(
    x: Array,
    cfg: QuantConfig,
    key: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Quantize the trailing dimension of ``x`` blockwise.

    Args:
      x: float array; trailing dim must be a multiple of ``cfg.block_size``.
      cfg: quantization config.
      key: PRNG key, required iff ``cfg.stochastic``.

    Returns:
      (payload, scales):
        payload: int8 array.  For bits=8 same trailing length as x; for
          bits=4 trailing length halved (two nibbles per byte).
        scales: float32, shape ``x.shape[:-1] + (n_blocks,)``.
    """
    n = x.shape[-1]
    if n % cfg.block_size:
        raise ValueError(f"trailing dim {n} not a multiple of block {cfg.block_size}")

    # Segmentation applies with or without stochastic rounding: the key is
    # split per segment/row so the fp32 intermediates stay segment-sized.
    # (Skipping segmentation when a key was present used to materialize the
    # full-buffer fp32 temporary — the exact peak-memory spike _SEG_ELEMS
    # exists to prevent — on stochastic qgZ of large flat gradients.)
    if x.ndim == 1:
        nseg = _segments(n, cfg.block_size)
        if nseg > 1:
            seg = n // nseg
            if key is None:
                p, s = jax.lax.map(lambda xs: quantize_blockwise(xs, cfg),
                                   x.reshape(nseg, seg))
            else:
                p, s = jax.lax.map(
                    lambda a: quantize_blockwise(a[0], cfg, key=a[1]),
                    (x.reshape(nseg, seg), jax.random.split(key, nseg)))
            return p.reshape(-1), s.reshape(-1)
    elif x.size > _SEG_ELEMS and n <= _SEG_ELEMS:
        # multi-dim (e.g. qgZ's (Y, X, L) slices): map over flattened
        # leading rows so the fp32 intermediate is one row at a time
        lead = x.shape[:-1]
        rows = x.reshape(-1, n)
        if key is None:
            p, s = jax.lax.map(lambda r: quantize_blockwise(r, cfg), rows)
        else:
            p, s = jax.lax.map(
                lambda a: quantize_blockwise(a[0], cfg, key=a[1]),
                (rows, jax.random.split(key, rows.shape[0])))
        return (p.reshape(*lead, -1), s.reshape(*lead, -1))

    nblocks = n // cfg.block_size
    xb = x.reshape(*x.shape[:-1], nblocks, cfg.block_size).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = absmax / cfg.qmax
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    q = _round(xb * inv, cfg.stochastic, key)
    q = jnp.clip(q, -cfg.qmax, cfg.qmax).astype(jnp.int8)
    q = q.reshape(*x.shape[:-1], n)
    if cfg.bits == 4:
        q = pack_int4(q)
    return q, scale.squeeze(-1)


def dequantize_blockwise(
    payload: Array,
    scales: Array,
    cfg: QuantConfig,
    out_dtype: jnp.dtype = jnp.float32,
) -> Array:
    """Inverse of :func:`quantize_blockwise`."""
    if payload.ndim == 1:
        n = payload.shape[-1] * (2 if cfg.bits == 4 else 1)
        nseg = _segments(n, cfg.block_size)
        if nseg > 1:
            pay = payload.reshape(nseg, -1)
            sc = scales.reshape(nseg, -1)
            x = jax.lax.map(
                lambda ps: dequantize_blockwise(ps[0], ps[1], cfg, out_dtype),
                (pay, sc))
            return x.reshape(-1)
    q = unpack_int4(payload) if cfg.bits == 4 else payload
    n = q.shape[-1]
    nblocks = n // cfg.block_size
    qb = q.reshape(*q.shape[:-1], nblocks, cfg.block_size)
    x = qb.astype(jnp.float32) * scales[..., None]
    return x.reshape(*q.shape[:-1], n).astype(out_dtype)


def pack_int4(q: Array) -> Array:
    """Pack int8 values in [-8, 7] two-per-byte along the trailing dim."""
    lo = q[..., 0::2] & 0xF
    hi = (q[..., 1::2] & 0xF) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(p: Array) -> Array:
    """Unpack nibbles packed by :func:`pack_int4` (sign-extending)."""
    lo = (p << 4) >> 4  # arithmetic shifts on int8 sign-extend the low nibble
    hi = p >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*p.shape[:-1], p.shape[-1] * 2)


def quantize_global(x: Array, bits: int = 8) -> Tuple[Array, Array]:
    """Non-blocked (single-scale) quantization — the paper's Fig. 2 baseline.

    Used only for the convergence ablation (Fig. 14: non-blocked diverges).
    """
    qmax = _QMAX[bits]
    absmax = jnp.max(jnp.abs(x))
    scale = (absmax / qmax).astype(jnp.float32)
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) * inv), -qmax, qmax).astype(jnp.int8)
    if bits == 4:
        q = pack_int4(q)
    return q, scale


def dequantize_global(q: Array, scale: Array, bits: int = 8,
                      out_dtype: jnp.dtype = jnp.float32) -> Array:
    if bits == 4:
        q = unpack_int4(q)
    return (q.astype(jnp.float32) * scale).astype(out_dtype)


def quantization_error(x: Array, cfg: QuantConfig) -> Array:
    """Max-abs roundtrip error; used by tests and the Fig. 2 benchmark."""
    q, s = quantize_blockwise(x, cfg)
    return jnp.max(jnp.abs(dequantize_blockwise(q, s, cfg) - x.astype(jnp.float32)))
