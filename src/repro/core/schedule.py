"""Prefetched, double-buffered layer schedule for the ZeRO++ engine.

:func:`repro.core.zeropp.zero_apply` runs every collective synchronously on
the critical path: gather layer *i*, compute layer *i*, gather layer *i+1*,
... — the "no overlap" worst case that ``benchmarks/throughput_model.py``
models.  The paper's throughput numbers assume the DeepSpeed schedule where
the next layer's all-gather is in flight *under* the current layer's
compute.  This module is that schedule, expressed as a double-buffered
``lax.scan`` (see DESIGN.md §3 for the buffer lifetimes):

  forward   carry holds layer *i*'s gathered (qwZ-dequantized) weights; the
            body issues layer *i+1*'s gather BEFORE computing layer *i*, so
            the two are data-independent inside one loop iteration and
            XLA's latency-hiding scheduler can run the gather asynchronously
            under the matmuls.
  backward  the reverse scan prefetches layer *i-1*'s hpZ (fast-tier)
            gather under layer *i*'s recompute+vjp, and carries layer
            *i+1*'s unreduced gradient so its qgZ reduce-scatter also runs
            under layer *i*'s compute (one step behind — the gradient
            "bucket" of the DeepSpeed engine).

``optimization_barrier`` discipline: each iteration ends by pinning the
(compute result, prefetched weights[, pipelined gradient]) tuple TOGETHER.
The joint barrier forces all of them to complete inside the iteration (XLA
cannot sink the collective into the next iteration or resurrect it at its
use site) while leaving them mutually independent — exactly the structure
the latency-hiding scheduler needs to emit async-start early and
async-done late.  Nothing creates a dependency *between* the collective
and the compute; that would serialize them and reproduce the synchronous
schedule with extra steps.

``ZeroConfig.prefetch = 0`` selects the synchronous reference schedule
(a scan over per-layer :func:`zero_apply`), kept as the bit-exact baseline:
both schedules issue identical collectives in identical per-layer order,
so losses match exactly (tests/test_schedule.py proves it).

MoE stacks use the same machinery at TWO granularities (DESIGN.md §3):
the layer scan prefetches the next layer's shared (attn/router/shared-
expert) gather exactly as above, with the routed-expert chunk stack riding
through ``xs`` unpeeked; inside each layer, :func:`zero_chunk_scan` runs
the expert-chunk pipeline — chunk c+1's weight gather issued under chunk
c's grouped GEMMs, chunk gradients' qgZ reduce pipelined one step behind.
One known cost of the nesting: the outer scan's backward remat re-runs
the inner chunk scan, so each expert chunk is re-gathered once on the
forward (qwZ) tier during backward — overlappable, and identical values,
but extra wire bytes (see ROADMAP open items for the hpZ-aware recompute).

Cost of the uniform scan body: the forward issues one wasted gather (the
last iteration prefetches layer 0 again, result discarded) and the
backward one dummy reduce-scatter (of zeros) and one wasted fast-tier
gather — O(1/n_layers) extra wire bytes, all of it off the critical path.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import collectives as cl
from repro.core.zeropp import ZeroConfig, fwd_gather, grad_reduce, zero_apply

Array = jax.Array


# ---------------------------------------------------------------------------
# pytree helpers: cotangents for mixed float/int trees
# ---------------------------------------------------------------------------

def _is_float(x) -> bool:
    return jnp.issubdtype(jnp.result_type(x), jnp.inexact)


def _split_floats(tree):
    """Partition a pytree into (float leaves, int leaves); each side keeps
    the full tree structure with ``None`` in the other side's positions."""
    floats = jax.tree.map(lambda x: x if _is_float(x) else None, tree)
    ints = jax.tree.map(lambda x: None if _is_float(x) else x, tree)
    return floats, ints


def _merge(floats, ints):
    """Inverse of :func:`_split_floats`."""
    f_leaves, treedef = jax.tree.flatten(floats, is_leaf=lambda x: x is None)
    i_leaves, _ = jax.tree.flatten(ints, is_leaf=lambda x: x is None)
    return jax.tree.unflatten(
        treedef, [f if f is not None else i
                  for f, i in zip(f_leaves, i_leaves)])


def _float0_like(x, extra_leading: Tuple[int, ...] = ()):
    """The cotangent jax expects for a non-differentiable (integer) leaf."""
    return np.zeros(extra_leading + tuple(x.shape), jax.dtypes.float0)


def _int_cotangents(tree, extra_leading: Tuple[int, ...] = ()):
    return jax.tree.map(lambda x: _float0_like(x, extra_leading), tree)


# ---------------------------------------------------------------------------
# backward-pass gather (hpZ fast tier, or the paper's second global gather)
# ---------------------------------------------------------------------------

def _bwd_gather(shard: Array, z: ZeroConfig) -> Array:
    if z.hpz:
        return cl.hpz_all_gather(shard, z.secondary_axes)
    return fwd_gather(shard, z)


def _bwd_src(stacked: Array, res_ws, z: ZeroConfig):
    """Per-layer shard stack the backward gathers from: the secondary
    (intra-node) shards saved by the forward when hpZ is on, else the
    primary shards themselves (the paper's second global gather)."""
    return res_ws if z.hpz else stacked


# ---------------------------------------------------------------------------
# the prefetched scan primitive
# ---------------------------------------------------------------------------

def zero_apply_scan(f: Callable, z: ZeroConfig):
    """Scan ``f`` over stacked per-layer primary shards, ZeRO++ style.

    ``f(W_full, h, x, *bargs) -> (h_next, y)`` where

      * ``W_full``  — the layer's gathered full weights (flat),
      * ``h``       — the scan carry (activations),
      * ``x``       — this layer's slice of the per-layer inputs pytree
                      ``xs`` (pass ``xs=None`` when there are none),
      * ``bargs``   — broadcast (layer-invariant) arrays, e.g. rope tables,
      * ``y``       — per-layer output, stacked into ``ys``.

    Returns ``run(stacked, h0, xs, *bargs) -> (h_final, ys)``,
    differentiable w.r.t. ``stacked``, ``h0``, and every float leaf of
    ``xs``/``bargs``.  ``f`` is recomputed in the backward pass (activation
    checkpointing), exactly like :func:`zero_apply`.

    ``z.prefetch >= 1`` uses the double-buffered schedule; ``0`` (or a
    single-layer stack, or local mode) the synchronous reference.  Both
    produce bit-identical outputs.
    """

    def run_sync(stacked, h0, xs, *bargs):
        ap = zero_apply(lambda W, h, x, *b: f(W, h, x, *b), z)

        def body(h, sx):
            p, x = sx
            h2, y = ap(p, h, x, *bargs)
            return h2, y

        return lax.scan(body, h0, (stacked, xs))

    def run_prefetch(stacked, h0, xs, *bargs):
        return _prefetched(f, z)(stacked, h0, xs, tuple(bargs))

    def run(stacked, h0, xs, *bargs):
        n = stacked.shape[0]
        if not z.distributed or z.prefetch < 1 or n < 2:
            return run_sync(stacked, h0, xs, *bargs)
        return run_prefetch(stacked, h0, xs, *bargs)

    return run


def _prefetched(f: Callable, z: ZeroConfig):
    """The double-buffered custom_vjp core (distributed, n >= 2)."""

    @jax.custom_vjp
    def scanned(stacked, h0, xs, bargs):
        out, _ = scanned_fwd(stacked, h0, xs, bargs)
        return out

    def scanned_fwd(stacked, h0, xs, bargs):
        n = stacked.shape[0]
        W0 = fwd_gather(stacked[0], z)

        def body(carry, sx):
            h, W = carry
            i, x = sx
            # prefetch layer i+1's gather FIRST: the jaxpr issues it before
            # this layer's matmuls, and nothing makes the compute depend on
            # it.  The last iteration re-gathers layer 0 (discarded).
            p_next = lax.dynamic_index_in_dim(
                stacked, jnp.remainder(i + 1, n), axis=0, keepdims=False)
            W_next = fwd_gather(p_next, z)
            h2, y = f(W, h, x, *bargs)
            if z.hpz:
                # re-partition the gathered weights into this device's
                # secondary shard: zero extra communication (paper §3.2.1)
                res_w = cl.slice_secondary(W, z.secondary_axes)
            else:
                res_w = jnp.zeros((0,), W.dtype)  # bwd re-gathers primary
            # joint pin: gather and compute both finish inside this
            # iteration but stay mutually independent (overlappable)
            h2, W_next = lax.optimization_barrier((h2, W_next))
            return (h2, W_next), (y, res_w, h)

        (h_final, _), (ys, res_ws, h_ins) = lax.scan(
            body, (h0, W0), (jnp.arange(n, dtype=jnp.int32), xs))
        return (h_final, ys), (stacked, res_ws, h_ins, xs, bargs)

    def scanned_bwd(res, ct):
        stacked, res_ws, h_ins, xs, bargs = res
        ct_h, ct_ys = ct
        n = stacked.shape[0]
        src = _bwd_src(stacked, res_ws, z)

        xs_f, xs_i = _split_floats(xs)
        bargs_f, bargs_i = _split_floats(bargs)

        def f_flt(W, h, x_f, b_f, x_i):
            return f(W, h, _merge(x_f, x_i), *_merge(b_f, bargs_i))

        W_last = _bwd_gather(src[n - 1], z)
        zero_b = jax.tree.map(
            lambda v: jnp.zeros(v.shape, v.dtype), bargs_f)
        # dW of layer i+1 rides the carry: its reduce-scatter runs inside
        # layer i's iteration, overlapped with the recompute+vjp.  The
        # first (i = n-1) iteration reduces zeros (discarded).
        dW0 = jnp.zeros((stacked.shape[1] * cl.axis_size(z.dp_axes),),
                        jnp.float32)

        def body(carry, sx):
            g_h, W, dW_pend, bg = carry
            i, x_f, x_i, h_in, ct_y = sx
            # 1. reduce the PREVIOUS layer's gradient   [no dep on 3.]
            dprev = grad_reduce(dW_pend, z)
            # 2. prefetch layer i-1's backward gather   [no dep on 3.]
            p_prev = jax.tree.map(
                lambda s: lax.dynamic_index_in_dim(
                    s, jnp.remainder(i - 1, n), axis=0, keepdims=False),
                src)
            W_prev = _bwd_gather(p_prev, z)
            # 3. recompute layer i and differentiate (remat)
            _, vjp_fn = jax.vjp(
                lambda w, hh, xf, bf: f_flt(w, hh, xf, bf, x_i),
                W, h_in, x_f, bargs_f)
            dW, dh, dx_f, db_f = vjp_fn((g_h, ct_y))
            bg = jax.tree.map(jnp.add, bg, db_f)
            dWflat = dW.reshape(-1).astype(jnp.float32)
            # joint pin: collectives (1., 2.) and compute (3.) all complete
            # inside this iteration, mutually independent
            dh, W_prev, dWflat, dprev = lax.optimization_barrier(
                (dh, W_prev, dWflat, dprev))
            return (dh, W_prev, dWflat, bg), (dprev, dx_f)

        (dh0, _, dW_first, bg), (dprevs, dxs_f) = lax.scan(
            body,
            (ct_h, W_last, dW0, zero_b),
            (jnp.arange(n, dtype=jnp.int32), xs_f, xs_i, h_ins, ct_ys),
            reverse=True)
        # dprevs[i] is layer i+1's reduced gradient (slot n-1 is the dummy
        # zero-reduce); layer 0's gradient leaves the scan in the carry.
        dprim0 = grad_reduce(dW_first, z)
        dstacked = jnp.concatenate(
            [dprim0[None].astype(dprevs.dtype), dprevs[:-1]], axis=0)
        dxs = _merge(dxs_f, _int_cotangents(xs_i, (n,)))
        dbargs = _merge(bg, _int_cotangents(bargs_i))
        return dstacked, dh0, dxs, dbargs

    def fwd(stacked, h0, xs, bargs):
        return scanned_fwd(stacked, h0, xs, bargs)

    scanned.defvjp(fwd, scanned_bwd)
    return scanned


# ---------------------------------------------------------------------------
# carry-less chunk pipeline (MoE expert chunks)
# ---------------------------------------------------------------------------

def _chunk_runner(engine, f: Callable, z: ZeroConfig):
    """Adapt a carry-less per-chunk ``f(W_full, x, *bargs) -> y`` onto a
    scan engine by threading a dummy scalar carry."""
    run = engine(lambda W, h, x, *b: (h, f(W, x, *b)), z)

    def run_chunks(stacked, xs, *bargs):
        _, ys = run(stacked, jnp.zeros((), jnp.float32), xs, *bargs)
        return ys

    return run_chunks


def zero_chunk_scan(f: Callable, z: ZeroConfig):
    """Chunked-parameter pipeline: ``f(W_full, x, *bargs) -> y`` scanned
    over stacked per-chunk primary shards with the double-buffered schedule
    of :func:`zero_apply_scan` (chunk c+1's gather issued under chunk c's
    compute; per-chunk qgZ reduce pipelined one step behind in backward).

    Chunks are independent — there is no carry.  Returns
    ``run(stacked, xs, *bargs) -> ys``, differentiable w.r.t. ``stacked``
    and the float leaves of ``xs``/``bargs``.  Used for the MoE
    routed-expert chunks, where the per-chunk slot buffers are rebuilt
    from the token activations inside each chunk's own gather scope
    (models/model.py).
    """
    return _chunk_runner(zero_apply_scan, f, z)


def zero_chunk_scan_inference(f: Callable, z: ZeroConfig):
    """Serving-path :func:`zero_chunk_scan`: same forward pipeline, no vjp."""
    return _chunk_runner(zero_scan_inference, f, z)


# ---------------------------------------------------------------------------
# inference variant (no gradient machinery)
# ---------------------------------------------------------------------------

def zero_scan_inference(f: Callable, z: ZeroConfig):
    """Serving-path prefetched scan: same forward schedule as
    :func:`zero_apply_scan`, no residuals, no vjp.

    ``f(W_full, h, x, *bargs) -> (h_next, y)``; returns
    ``run(stacked, h0, xs, *bargs) -> (h_final, ys)``.
    """

    def run(stacked, h0, xs, *bargs):
        n = stacked.shape[0]
        if not z.distributed or z.prefetch < 1 or n < 2:
            def body_sync(h, sx):
                p, x = sx
                W = fwd_gather(p, z) if z.distributed \
                    else p.astype(z.compute_dtype)
                return f(W, h, x, *bargs)

            return lax.scan(body_sync, h0, (stacked, xs))

        W0 = fwd_gather(stacked[0], z)

        def body(carry, sx):
            h, W = carry
            i, x = sx
            p_next = lax.dynamic_index_in_dim(
                stacked, jnp.remainder(i + 1, n), axis=0, keepdims=False)
            W_next = fwd_gather(p_next, z)
            h2, y = f(W, h, x, *bargs)
            h2, W_next = lax.optimization_barrier((h2, W_next))
            return (h2, W_next), y

        (h_final, _), ys = lax.scan(
            body, (h0, W0), (jnp.arange(n, dtype=jnp.int32), xs))
        return h_final, ys

    return run
