"""Depth-k prefetch-ring layer schedule for the ZeRO++ engine.

:func:`repro.core.zeropp.zero_apply` runs every collective synchronously on
the critical path: gather layer *i*, compute layer *i*, gather layer *i+1*,
... — the "no overlap" worst case that ``benchmarks/throughput_model.py``
models.  The paper's throughput numbers assume the DeepSpeed schedule where
the next layer's all-gather is in flight *under* the current layer's
compute.  This module generalizes that schedule to a configurable
lookahead: a ring of ``k = ZeroConfig.prefetch`` gathered weight buffers
carried through a ``lax.scan`` (see DESIGN.md §3 for buffer lifetimes):

  forward   the carry holds a ring of k gathered (qwZ-dequantized) layer
            weights; iteration *i* issues layer *i+k*'s gather into the
            ring slot it just consumed, BEFORE computing layer *i* from
            the ring head, so the gather has k iterations of compute to
            complete under (k=1 is the classic double buffer; k>1 is for
            interconnects where one layer's compute cannot cover a full
            quantized gather).
  backward  the reverse scan mirrors the ring on the hpZ fast tier
            (layer *i-k*'s gather under layer *i*'s recompute+vjp) and
            carries a second ring of k unreduced gradients so layer
            *i+k*'s qgZ reduce-scatter retires k steps behind.

``optimization_barrier`` discipline: each iteration ends by pinning the
(compute result, updated ring[s]) tuple TOGETHER.  The joint barrier
forces the in-flight collectives to complete inside the iteration (XLA
cannot sink them into a later iteration or resurrect them at their use
site) while leaving them mutually independent — exactly the structure the
latency-hiding scheduler needs to emit async-start early and async-done
late.  Nothing creates a dependency *between* a collective and the
compute; that would serialize them and reproduce the synchronous schedule.

``ZeroConfig.prefetch = 0`` selects the synchronous reference schedule
(a scan over per-layer :func:`zero_apply`); every depth >= 1 issues
identical collectives on identical values in identical per-layer order, so
losses AND gradients match the reference bit for bit at every depth
(tests/test_schedule.py sweeps prefetch ∈ {0,1,2,3} and beyond the layer
count — ``ZeroConfig.effective_prefetch`` clamps the ring to n-1 slots).

MoE stacks use the same machinery at TWO granularities (DESIGN.md §3):
the layer scan rings the next layers' shared (attn/router/shared-expert)
gathers exactly as above, with the routed-expert chunk stack riding
through ``xs`` unpeeked; inside each layer, :func:`zero_chunk_scan` runs
the expert-chunk pipeline with its own ring.  Two knobs close the MoE
holes the plain nesting leaves:

  * ``spec`` (routing-ahead dispatch) — the layer scan speculatively
    gathers layer *i+k*'s FIRST expert chunk alongside its shared buffer
    (experts are gathered in full regardless of routing), so chunk 0 no
    longer waits on the router: the last synchronous expert gather moves
    off the critical path.  The backward recompute re-gathers chunk 0
    itself — values identical, so gradients are untouched.
  * ``f_fwd``/``f_bwd`` (hpZ-aware nested recompute) — the forward saves
    each layer's expert-chunk SECONDARY shards through the outer scan's
    residuals (:func:`zero_chunk_scan` ``collect_secondary``), and the
    backward recompute rebuilds the chunk pipeline from them on the hpZ
    fast tier (:func:`zero_chunk_scan_hpz`) instead of re-gathering every
    chunk on the slow qwZ tier.  The hpZ roundtrip is exact, so outputs
    and gradients are bit-identical; only the tier the recompute bytes
    ride changes.

Cost of the uniform scan body: the forward issues k wasted gathers (the
last k iterations prefetch layers 0..k-1 again, results discarded) and the
backward k dummy reduce-scatters (of zeros) and k wasted fast-tier gathers
— O(k/n_layers) extra wire bytes, all of it off the critical path.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import collectives as cl
from repro.core.zeropp import ZeroConfig, fwd_gather, grad_reduce, zero_apply

Array = jax.Array


# ---------------------------------------------------------------------------
# pytree helpers: cotangents for mixed float/int trees
# ---------------------------------------------------------------------------

def _is_float(x) -> bool:
    return jnp.issubdtype(jnp.result_type(x), jnp.inexact)


def _split_floats(tree):
    """Partition a pytree into (float leaves, int leaves); each side keeps
    the full tree structure with ``None`` in the other side's positions."""
    floats = jax.tree.map(lambda x: x if _is_float(x) else None, tree)
    ints = jax.tree.map(lambda x: None if _is_float(x) else x, tree)
    return floats, ints


def _merge(floats, ints):
    """Inverse of :func:`_split_floats`."""
    f_leaves, treedef = jax.tree.flatten(floats, is_leaf=lambda x: x is None)
    i_leaves, _ = jax.tree.flatten(ints, is_leaf=lambda x: x is None)
    return jax.tree.unflatten(
        treedef, [f if f is not None else i
                  for f, i in zip(f_leaves, i_leaves)])


def _float0_like(x, extra_leading: Tuple[int, ...] = ()):
    """The cotangent jax expects for a non-differentiable (integer) leaf."""
    return np.zeros(extra_leading + tuple(x.shape), jax.dtypes.float0)


def _int_cotangents(tree, extra_leading: Tuple[int, ...] = ()):
    return jax.tree.map(lambda x: _float0_like(x, extra_leading), tree)


# ---------------------------------------------------------------------------
# backward-pass gather (hpZ fast tier, or the paper's second global gather)
# ---------------------------------------------------------------------------

def _bwd_gather(shard: Array, z: ZeroConfig) -> Array:
    if z.hpz:
        return cl.hpz_all_gather(shard, z.secondary_axes)
    return fwd_gather(shard, z)


def _bwd_src(stacked: Array, res_ws, z: ZeroConfig):
    """Per-layer shard stack the backward gathers from: the secondary
    (intra-node) shards saved by the forward when hpZ is on, else the
    primary shards themselves (the paper's second global gather)."""
    return res_ws if z.hpz else stacked


# ---------------------------------------------------------------------------
# ring plumbing (shared by the fwd/bwd scans)
# ---------------------------------------------------------------------------

def _ring_read(ring: Array, slot: Array) -> Array:
    return lax.dynamic_index_in_dim(ring, slot, axis=0, keepdims=False)


def _ring_write(ring: Array, buf: Array, slot: Array) -> Array:
    return lax.dynamic_update_index_in_dim(ring, buf, slot, axis=0)


def _bwd_ring_seed(src, n: int, k: int, gather: Callable) -> Array:
    """Seed the backward weight ring: slot ``i % k`` holds step *i*'s
    gathered weights for the first k reverse iterations (i = n-k..n-1)."""
    slots: List[Optional[Array]] = [None] * k
    for i in range(n - k, n):
        p = jax.tree.map(
            lambda s: lax.dynamic_index_in_dim(s, i, axis=0, keepdims=False),
            src)
        slots[i % k] = gather(p)
    return jnp.stack(slots)


def _ring_grad_tail(dWring_f: Array, dprevs: Array, n: int, k: int,
                    z: ZeroConfig) -> Array:
    """Stitch the full gradient stack back together after a reverse ring
    scan: steps 0..k-1's unreduced gradients are left in the final ring
    (slot j = step j) and reduced here; dprevs[i] is step i+k's in-scan
    reduce (the top k slots were the dummy zero-reduces)."""
    head = [grad_reduce(dWring_f[j], z)[None].astype(dprevs.dtype)
            for j in range(k)]
    return jnp.concatenate(head + [dprevs[: n - k]], axis=0)


# ---------------------------------------------------------------------------
# the prefetched scan primitive
# ---------------------------------------------------------------------------

def zero_apply_scan(f: Callable, z: ZeroConfig, *,
                    f_fwd: Optional[Callable] = None,
                    f_bwd: Optional[Callable] = None,
                    spec: Optional[Callable] = None,
                    bwd_spec: Optional[Callable] = None):
    """Scan ``f`` over stacked per-layer primary shards, ZeRO++ style.

    ``f(W_full, h, x, *bargs) -> (h_next, y)`` where

      * ``W_full``  — the layer's gathered full weights (flat),
      * ``h``       — the scan carry (activations),
      * ``x``       — this layer's slice of the per-layer inputs pytree
                      ``xs`` (pass ``xs=None`` when there are none),
      * ``bargs``   — broadcast (layer-invariant) arrays, e.g. rope tables,
      * ``y``       — per-layer output, stacked into ``ys``.

    Returns ``run(stacked, h0, xs, *bargs, W0=None) -> (h_final, ys)``,
    differentiable w.r.t. ``stacked``, ``h0``, and every float leaf of
    ``xs``/``bargs``.  ``f`` is recomputed in the backward pass (activation
    checkpointing), exactly like :func:`zero_apply`.  ``W0``, if given, is
    a pre-gathered full buffer for step 0 — the ring seed skips step 0's
    gather (used by the chunk pipeline's speculative chunk-0 path; its
    gradient path is owned by the engine, so the buffer itself gets a zero
    cotangent).

    ``z.effective_prefetch(n) >= 1`` uses the depth-k ring schedule
    (``k = min(z.prefetch, n-1)``); 0 (or local mode) the synchronous
    reference.  All depths produce bit-identical outputs and gradients.

    Three optional knobs reshape the prefetched schedule WITHOUT changing
    its math (the synchronous reference always runs plain ``f``):

      * ``spec(xs, i) -> shard`` — a per-layer speculative-gather source;
        the forward ring pre-gathers ``spec(xs, i+k)`` alongside layer
        *i+k*'s weights and hands the result to ``f_fwd`` (routing-ahead
        dispatch: the MoE chunk-0 expert shard).
      * ``f_fwd(W, W_spec, h, x, *bargs) -> (h2, y, aux)`` — the
        prefetched-forward body.  ``W_spec`` is the ring's speculative
        buffer (None when ``spec`` is None); ``aux`` is an extra residual
        pytree threaded to the backward (None when ``f_bwd`` is None).
        Required whenever ``spec`` or ``f_bwd`` is given; must be
        value-identical to ``f`` modulo the extra plumbing.
      * ``f_bwd(W, h, x, aux, *bargs) -> (h2, y)`` — the recompute body
        the backward differentiates, consuming the saved ``aux`` (the MoE
        expert-chunk secondary shards: the nested recompute then rides
        the hpZ fast tier instead of re-gathering on qwZ).
      * ``bwd_spec(auxs, i) -> shard`` — the backward mirror of ``spec``:
        a per-layer speculative-gather source drawn from the STACKED saved
        residuals.  The reverse scan pre-gathers
        ``bwd_gather(bwd_spec(auxs, i-k))`` alongside layer *i-k*'s
        weights (one extra slot ring) and hands the buffer to ``f_bwd`` as
        keyword ``W0`` — so the nested recompute's chunk 0 is seeded from
        a ring slot filled k iterations early instead of issuing its own
        synchronous fast-tier gather (the backward twin of routing-ahead
        dispatch).  Same collective on the same saved value, one
        iteration earlier: gradients stay bit-identical.  Requires
        ``f_bwd``.
    """
    if (spec is not None or f_bwd is not None) and f_fwd is None:
        raise ValueError("zero_apply_scan: spec/f_bwd require f_fwd")
    if bwd_spec is not None and f_bwd is None:
        raise ValueError("zero_apply_scan: bwd_spec requires f_bwd")

    def run_sync(stacked, h0, xs, *bargs):
        ap = zero_apply(lambda W, h, x, *b: f(W, h, x, *b), z)

        def body(h, sx):
            p, x = sx
            h2, y = ap(p, h, x, *bargs)
            return h2, y

        return lax.scan(body, h0, (stacked, xs))

    def run(stacked, h0, xs, *bargs, W0: Optional[Array] = None):
        n = stacked.shape[0]
        if z.effective_prefetch(n) < 1:
            return run_sync(stacked, h0, xs, *bargs)
        w0_meta = None if W0 is None else (W0.shape, W0.dtype)
        return _prefetched(f, z, f_fwd, f_bwd, spec, bwd_spec, w0_meta)(
            stacked, h0, xs, tuple(bargs), W0)

    return run


def _prefetched(f: Callable, z: ZeroConfig, f_fwd, f_bwd, spec, bwd_spec,
                w0_meta):
    """The depth-k ring custom_vjp core (distributed, n >= 2)."""

    @jax.custom_vjp
    def scanned(stacked, h0, xs, bargs, W0):
        out, _ = scanned_fwd(stacked, h0, xs, bargs, W0)
        return out

    def scanned_fwd(stacked, h0, xs, bargs, W0):
        n = stacked.shape[0]
        k = z.effective_prefetch(n)
        # seed the ring with layers 0..k-1 (slot j = layer j); the body
        # then reads slot i%k (layer i) and refills it with layer i+k
        seed = [W0 if (j == 0 and W0 is not None)
                else fwd_gather(stacked[j], z) for j in range(k)]
        ring0 = jnp.stack(seed)
        if spec is not None:
            sring0 = jnp.stack([fwd_gather(spec(xs, j), z)
                                for j in range(k)])

        def body(carry, sx):
            if spec is not None:
                h, ring, sring = carry
            else:
                h, ring = carry
            i, x = sx
            slot = jnp.remainder(i, k)
            nxt = jnp.remainder(i + k, n)
            # prefetch layer i+k's gather FIRST: the jaxpr issues it before
            # this layer's matmuls, and nothing makes the compute depend on
            # it.  The last k iterations re-gather layers 0..k-1
            # (discarded).
            p_next = lax.dynamic_index_in_dim(stacked, nxt, axis=0,
                                              keepdims=False)
            W_next = fwd_gather(p_next, z)
            W = _ring_read(ring, slot)
            if spec is not None:
                s_next = fwd_gather(spec(xs, nxt), z)
                W_spec = _ring_read(sring, slot)
            if f_fwd is not None:
                h2, y, aux = f_fwd(W, W_spec if spec is not None else None,
                                   h, x, *bargs)
            else:
                h2, y = f(W, h, x, *bargs)
                aux = None
            if z.hpz:
                # re-partition the gathered weights into this device's
                # secondary shard: zero extra communication (paper §3.2.1)
                res_w = cl.slice_secondary(W, z.secondary_axes)
            else:
                res_w = jnp.zeros((0,), W.dtype)  # bwd re-gathers primary
            ring2 = _ring_write(ring, W_next, slot)
            # joint pin: in-flight gathers and compute all finish inside
            # this iteration but stay mutually independent (overlappable)
            if spec is not None:
                sring2 = _ring_write(sring, s_next, slot)
                h2, ring2, sring2 = lax.optimization_barrier(
                    (h2, ring2, sring2))
                carry2 = (h2, ring2, sring2)
            else:
                h2, ring2 = lax.optimization_barrier((h2, ring2))
                carry2 = (h2, ring2)
            outs = (y, res_w, h) if f_bwd is None else (y, res_w, h, aux)
            return carry2, outs

        init = (h0, ring0, sring0) if spec is not None else (h0, ring0)
        carry_out, outs = lax.scan(
            body, init, (jnp.arange(n, dtype=jnp.int32), xs))
        if f_bwd is None:
            ys, res_ws, h_ins = outs
            auxs = None
        else:
            ys, res_ws, h_ins, auxs = outs
        return (carry_out[0], ys), (stacked, res_ws, h_ins, xs, bargs, auxs)

    def scanned_bwd(res, ct):
        stacked, res_ws, h_ins, xs, bargs, auxs = res
        ct_h, ct_ys = ct
        n = stacked.shape[0]
        k = z.effective_prefetch(n)
        src = _bwd_src(stacked, res_ws, z)

        xs_f, xs_i = _split_floats(xs)
        bargs_f, bargs_i = _split_floats(bargs)

        if f_bwd is None:
            def f_flt(W, h, x_f, b_f, x_i, aux, W0_l):
                return f(W, h, _merge(x_f, x_i), *_merge(b_f, bargs_i))
        elif bwd_spec is None:
            # the recompute body consumes the saved per-layer residual
            # (e.g. expert-chunk secondary shards) as a constant: its
            # gradient path is owned by the engine's collectives, never
            # by differentiating the gather
            def f_flt(W, h, x_f, b_f, x_i, aux, W0_l):
                return f_bwd(W, h, _merge(x_f, x_i), aux,
                             *_merge(b_f, bargs_i))
        else:
            def f_flt(W, h, x_f, b_f, x_i, aux, W0_l):
                return f_bwd(W, h, _merge(x_f, x_i), aux,
                             *_merge(b_f, bargs_i), W0=W0_l)

        Wring0 = _bwd_ring_seed(src, n, k, lambda p: _bwd_gather(p, z))
        if bwd_spec is not None:
            # backward speculative ring: slot i%k carries the pre-gathered
            # chunk-0 buffer f_bwd's nested recompute would otherwise
            # gather synchronously at its own seed
            sw_slots: List[Optional[Array]] = [None] * k
            for j in range(n - k, n):
                sw_slots[j % k] = _bwd_gather(bwd_spec(auxs, j), z)
            sWring0 = jnp.stack(sw_slots)
        zero_b = jax.tree.map(
            lambda v: jnp.zeros(v.shape, v.dtype), bargs_f)
        # dW of layer i+k rides a second ring: its reduce-scatter runs
        # inside layer i's iteration, overlapped with the recompute+vjp.
        # The first k (i = n-1..n-k) iterations reduce zeros (discarded).
        full = stacked.shape[1] * cl.axis_size(z.dp_axes)
        dWring0 = jnp.zeros((k, full), jnp.float32)
        aux_xs = auxs if f_bwd is not None \
            else jnp.zeros((n,), jnp.float32)

        def body(carry, sx):
            if bwd_spec is not None:
                g_h, Wring, dWring, bg, sWring = carry
            else:
                g_h, Wring, dWring, bg = carry
            i, x_f, x_i, h_in, ct_y, aux = sx
            slot = jnp.remainder(i, k)
            prev = jnp.remainder(i - k, n)
            # 1. reduce layer i+k's pending gradient     [no dep on 3.]
            dprev = grad_reduce(_ring_read(dWring, slot), z)
            # 2. prefetch layer i-k's backward gather    [no dep on 3.]
            p_prev = jax.tree.map(
                lambda s: lax.dynamic_index_in_dim(
                    s, prev, axis=0, keepdims=False),
                src)
            W_prev = _bwd_gather(p_prev, z)
            if bwd_spec is not None:
                # 2b. ... and layer i-k's speculative chunk-0 buffer
                s_prev = _bwd_gather(bwd_spec(auxs, prev), z)
                W0_l = _ring_read(sWring, slot)
            else:
                W0_l = None
            # 3. recompute layer i and differentiate (remat)
            W = _ring_read(Wring, slot)
            _, vjp_fn = jax.vjp(
                lambda w, hh, xf, bf: f_flt(w, hh, xf, bf, x_i, aux, W0_l),
                W, h_in, x_f, bargs_f)
            dW, dh, dx_f, db_f = vjp_fn((g_h, ct_y))
            bg = jax.tree.map(jnp.add, bg, db_f)
            dWflat = dW.reshape(-1).astype(jnp.float32)
            Wring2 = _ring_write(Wring, W_prev, slot)
            dWring2 = _ring_write(dWring, dWflat, slot)
            # joint pin: collectives (1., 2.) and compute (3.) all complete
            # inside this iteration, mutually independent
            if bwd_spec is not None:
                sWring2 = _ring_write(sWring, s_prev, slot)
                dh, Wring2, dWring2, dprev, sWring2 = \
                    lax.optimization_barrier(
                        (dh, Wring2, dWring2, dprev, sWring2))
                return (dh, Wring2, dWring2, bg, sWring2), (dprev, dx_f)
            dh, Wring2, dWring2, dprev = lax.optimization_barrier(
                (dh, Wring2, dWring2, dprev))
            return (dh, Wring2, dWring2, bg), (dprev, dx_f)

        init = (ct_h, Wring0, dWring0, zero_b, sWring0) \
            if bwd_spec is not None else (ct_h, Wring0, dWring0, zero_b)
        (dh0, _, dWring_f, bg, *_), (dprevs, dxs_f) = lax.scan(
            body, init,
            (jnp.arange(n, dtype=jnp.int32), xs_f, xs_i, h_ins, ct_ys,
             aux_xs),
            reverse=True)
        dstacked = _ring_grad_tail(dWring_f, dprevs, n, k, z)
        dxs = _merge(dxs_f, _int_cotangents(xs_i, (n,)))
        dbargs = _merge(bg, _int_cotangents(bargs_i))
        dW0 = None if w0_meta is None \
            else jnp.zeros(w0_meta[0], w0_meta[1])
        return dstacked, dh0, dxs, dbargs, dW0

    def fwd(stacked, h0, xs, bargs, W0):
        return scanned_fwd(stacked, h0, xs, bargs, W0)

    scanned.defvjp(fwd, scanned_bwd)
    return scanned


# ---------------------------------------------------------------------------
# carry-less chunk pipeline (MoE expert chunks)
# ---------------------------------------------------------------------------

def _chunk_runner(engine, f: Callable, z: ZeroConfig):
    """Adapt a carry-less per-chunk ``f(W_full, x, *bargs) -> y`` onto a
    scan engine by threading a dummy scalar carry."""
    run = engine(lambda W, h, x, *b: (h, f(W, x, *b)), z)

    def run_chunks(stacked, xs, *bargs, W0: Optional[Array] = None):
        _, ys = run(stacked, jnp.zeros((), jnp.float32), xs, *bargs, W0=W0)
        return ys

    return run_chunks


def zero_chunk_scan(f: Callable, z: ZeroConfig, *,
                    collect_secondary: bool = False):
    """Chunked-parameter pipeline: ``f(W_full, x, *bargs) -> y`` scanned
    over stacked per-chunk primary shards with the depth-k ring schedule
    of :func:`zero_apply_scan` (chunk c+k's gather issued under chunk c's
    compute; per-chunk qgZ reduces retired k steps behind in backward).

    Chunks are independent — there is no carry.  Returns
    ``run(stacked, xs, *bargs, W0=None) -> ys``, differentiable w.r.t.
    ``stacked`` and the float leaves of ``xs``/``bargs``; ``W0`` is an
    optional pre-gathered chunk-0 buffer (the routing-ahead speculative
    gather).  Used for the MoE routed-expert chunks, where the per-chunk
    slot buffers are rebuilt from the token activations inside each
    chunk's own gather scope (models/model.py).

    ``collect_secondary=True`` additionally returns the stack of per-chunk
    secondary (hpZ) shards sliced from the gathered weights —
    ``run(...) -> (ys, sec)`` — zero extra communication, to be saved
    through an outer residual and replayed by :func:`zero_chunk_scan_hpz`
    in the nested recompute.
    """
    if not collect_secondary:
        return _chunk_runner(zero_apply_scan, f, z)

    def f2(W, h, x, *b):
        y = f(W, x, *b)
        if z.hpz and z.distributed:
            sec = cl.slice_secondary(W, z.secondary_axes)
        else:
            sec = jnp.zeros((0,), W.dtype)
        return h, (y, sec)

    run = zero_apply_scan(f2, z)

    def run_chunks(stacked, xs, *bargs, W0: Optional[Array] = None):
        _, (ys, secs) = run(stacked, jnp.zeros((), jnp.float32), xs,
                            *bargs, W0=W0)
        return ys, secs

    return run_chunks


def zero_chunk_scan_inference(f: Callable, z: ZeroConfig):
    """Serving-path :func:`zero_chunk_scan`: same forward pipeline, no vjp."""
    return _chunk_runner(zero_scan_inference, f, z)


def zero_chunk_scan_hpz(f: Callable, z: ZeroConfig):
    """Nested-recompute chunk pipeline fed from saved secondary shards.

    ``run(stacked, sec, xs, *bargs, W0=None) -> ys`` — the same math as
    :func:`zero_chunk_scan`, but every chunk's full weights are rebuilt
    with an intra-node hpZ all-gather of ``sec`` (the stack saved by
    ``zero_chunk_scan(collect_secondary=True)``) instead of the primary
    qwZ-tier gather.  The hpZ roundtrip reconstructs the forward weights
    exactly, so outputs and the qgZ-reduced d(stacked) are bit-identical
    to the primary-tier pipeline; only the interconnect tier the
    recompute's wire bytes ride changes.  ``sec`` is a schedule detail,
    not a differentiable input: its cotangent is zero (the expert
    gradient flows through d(stacked), exactly as in the primary
    pipeline).  ``W0``, if given, is chunk 0's already-gathered full
    weights (the outer scan's ``bwd_spec`` ring slot): the ring seed then
    skips its own synchronous chunk-0 gather — one fewer fast-tier gather
    on the recompute's critical path, same value, zero cotangent.
    Requires ``z.hpz``; the forward uses the same depth-k ring, the
    backward the mirrored reverse ring with pipelined reduces.
    """
    if not (z.hpz and z.distributed):
        raise ValueError("zero_chunk_scan_hpz requires distributed hpZ")

    def _gather(s):
        return cl.hpz_all_gather(s, z.secondary_axes)

    def make(w0_meta):
        return _chunk_hpz_vjp(f, z, _gather, w0_meta)

    def run(stacked, sec, xs, *bargs, W0: Optional[Array] = None):
        w0_meta = None if W0 is None else (W0.shape, W0.dtype)
        return make(w0_meta)(stacked, sec, xs, tuple(bargs), W0)

    return run


def _chunk_hpz_vjp(f: Callable, z: ZeroConfig, _gather, w0_meta):
    """The hpZ chunk pipeline's custom_vjp (one instance per W0 arity)."""

    @jax.custom_vjp
    def scanned(stacked, sec, xs, bargs, W0):
        out, _ = scanned_fwd(stacked, sec, xs, bargs, W0)
        return out

    def scanned_fwd(stacked, sec, xs, bargs, W0):
        nc = sec.shape[0]
        k = z.effective_prefetch(nc)
        if k < 1:
            def body_sync(_, sx):
                s_c, x = sx
                return (), f(_gather(s_c), x, *bargs)

            _, ys = lax.scan(body_sync, (), (sec, xs))
            return ys, (stacked, sec, xs, bargs)

        seed = [W0 if (j == 0 and w0_meta is not None)
                else _gather(sec[j]) for j in range(k)]
        ring0 = jnp.stack(seed)

        def body(ring, sx):
            i, x = sx
            slot = jnp.remainder(i, k)
            s_next = lax.dynamic_index_in_dim(
                sec, jnp.remainder(i + k, nc), axis=0, keepdims=False)
            W_next = _gather(s_next)
            y = f(_ring_read(ring, slot), x, *bargs)
            ring2 = _ring_write(ring, W_next, slot)
            y, ring2 = lax.optimization_barrier((y, ring2))
            return ring2, y

        _, ys = lax.scan(body, ring0,
                         (jnp.arange(nc, dtype=jnp.int32), xs))
        return ys, (stacked, sec, xs, bargs)

    def scanned_bwd(res, ct_ys):
        stacked, sec, xs, bargs = res
        nc = sec.shape[0]
        k = z.effective_prefetch(nc)
        xs_f, xs_i = _split_floats(xs)
        bargs_f, bargs_i = _split_floats(bargs)

        def f_flt(W, x_f, b_f, x_i):
            return f(W, _merge(x_f, x_i), *_merge(b_f, bargs_i))

        zero_b = jax.tree.map(
            lambda v: jnp.zeros(v.shape, v.dtype), bargs_f)

        if k < 1:
            def body_sync(bg, sx):
                s_c, x_f, x_i, ct_y = sx
                W = _gather(s_c)
                _, vjp_fn = jax.vjp(
                    lambda w, xf, bf: f_flt(w, xf, bf, x_i),
                    W, x_f, bargs_f)
                dW, dx_f, db_f = vjp_fn(ct_y)
                bg = jax.tree.map(jnp.add, bg, db_f)
                return bg, (grad_reduce(dW.reshape(-1), z), dx_f)

            bg, (drows, dxs_f) = lax.scan(
                body_sync, zero_b, (sec, xs_f, xs_i, ct_ys), reverse=True)
            dstacked = drows
        else:
            Wring0 = _bwd_ring_seed(sec, nc, k, _gather)
            full = stacked.shape[1] * cl.axis_size(z.dp_axes)
            dWring0 = jnp.zeros((k, full), jnp.float32)

            def body(carry, sx):
                Wring, dWring, bg = carry
                i, x_f, x_i, ct_y = sx
                slot = jnp.remainder(i, k)
                dprev = grad_reduce(_ring_read(dWring, slot), z)
                s_prev = lax.dynamic_index_in_dim(
                    sec, jnp.remainder(i - k, nc), axis=0, keepdims=False)
                W_prev = _gather(s_prev)
                W = _ring_read(Wring, slot)
                _, vjp_fn = jax.vjp(
                    lambda w, xf, bf: f_flt(w, xf, bf, x_i),
                    W, x_f, bargs_f)
                dW, dx_f, db_f = vjp_fn(ct_y)
                bg = jax.tree.map(jnp.add, bg, db_f)
                dWflat = dW.reshape(-1).astype(jnp.float32)
                Wring2 = _ring_write(Wring, W_prev, slot)
                dWring2 = _ring_write(dWring, dWflat, slot)
                Wring2, dWring2, dprev = lax.optimization_barrier(
                    (Wring2, dWring2, dprev))
                return (Wring2, dWring2, bg), (dprev, dx_f)

            (_, dWring_f, bg), (dprevs, dxs_f) = lax.scan(
                body, (Wring0, dWring0, zero_b),
                (jnp.arange(nc, dtype=jnp.int32), xs_f, xs_i, ct_ys),
                reverse=True)
            dstacked = _ring_grad_tail(dWring_f, dprevs, nc, k, z)

        dxs = _merge(dxs_f, _int_cotangents(xs_i, (nc,)))
        dbargs = _merge(bg, _int_cotangents(bargs_i))
        dW0 = None if w0_meta is None \
            else jnp.zeros(w0_meta[0], w0_meta[1])
        return dstacked, jnp.zeros_like(sec), dxs, dbargs, dW0

    scanned.defvjp(scanned_fwd, scanned_bwd)
    return scanned


# ---------------------------------------------------------------------------
# inference variant (no gradient machinery)
# ---------------------------------------------------------------------------

def zero_scan_inference(f: Callable, z: ZeroConfig, *,
                        spec: Optional[Callable] = None):
    """Serving-path prefetched scan: same forward ring schedule as
    :func:`zero_apply_scan`, no residuals, no vjp.

    ``f(W_full, h, x, *bargs) -> (h_next, y)``; returns
    ``run(stacked, h0, xs, *bargs, W0=None) -> (h_final, ys)``.  With
    ``spec`` the body is called ``f(W, W_spec, h, x, *bargs)`` (W_spec is
    None on the synchronous path, where no speculative gather exists).
    """

    def call(W, W_spec, h, x, *bargs):
        if spec is not None:
            return f(W, W_spec, h, x, *bargs)
        return f(W, h, x, *bargs)

    def run(stacked, h0, xs, *bargs, W0: Optional[Array] = None):
        n = stacked.shape[0]
        k = z.effective_prefetch(n)
        if k < 1:
            def body_sync(h, sx):
                p, x = sx
                W = fwd_gather(p, z) if z.distributed \
                    else p.astype(z.compute_dtype)
                return call(W, None, h, x, *bargs)

            return lax.scan(body_sync, h0, (stacked, xs))

        seed = [W0 if (j == 0 and W0 is not None)
                else fwd_gather(stacked[j], z) for j in range(k)]
        ring0 = jnp.stack(seed)
        if spec is not None:
            sring0 = jnp.stack([fwd_gather(spec(xs, j), z)
                                for j in range(k)])

        def body(carry, sx):
            if spec is not None:
                h, ring, sring = carry
            else:
                h, ring = carry
            i, x = sx
            slot = jnp.remainder(i, k)
            nxt = jnp.remainder(i + k, n)
            p_next = lax.dynamic_index_in_dim(stacked, nxt, axis=0,
                                              keepdims=False)
            W_next = fwd_gather(p_next, z)
            W = _ring_read(ring, slot)
            if spec is not None:
                s_next = fwd_gather(spec(xs, nxt), z)
                W_spec = _ring_read(sring, slot)
                h2, y = f(W, W_spec, h, x, *bargs)
            else:
                h2, y = f(W, h, x, *bargs)
            ring2 = _ring_write(ring, W_next, slot)
            if spec is not None:
                sring2 = _ring_write(sring, s_next, slot)
                h2, ring2, sring2 = lax.optimization_barrier(
                    (h2, ring2, sring2))
                return (h2, ring2, sring2), y
            h2, ring2 = lax.optimization_barrier((h2, ring2))
            return (h2, ring2), y

        init = (h0, ring0, sring0) if spec is not None else (h0, ring0)
        carry_out, ys = lax.scan(
            body, init, (jnp.arange(n, dtype=jnp.int32), xs))
        return carry_out[0], ys

    return run
