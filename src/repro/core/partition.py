"""Flat parameter buffers: the ZeRO-3 "partitioned model state" layout.

Every logical weight group (an embedding table, one transformer layer's
weights, ...) lives inside a single flat 1-D buffer padded so that it
divides evenly into ``world × block`` — which simultaneously satisfies

  * ZeRO-3 sharding (equal shard per device),
  * qwZ  (shard length a multiple of the quant block), and
  * qgZ  (per-destination slice length a multiple of the quant block) —
    the paper's "16B-aligned quantization granularity" requirement (§4.2).

Flat 1-D global layout also makes *elastic* re-sharding trivial: a
checkpointed global buffer re-splits onto any new world size by reshape
(see train/state.py, the ZeroState subsystem).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Static layout of named tensors inside one flat buffer."""

    entries: Tuple[Tuple[str, Tuple[int, ...]], ...]  # (name, shape)
    align: int = 1  # pad total length to a multiple of this (world*block)

    @functools.cached_property
    def offsets(self) -> Dict[str, Tuple[int, int]]:
        # memoized: unpack/pack hit this per layer in the hot path, and
        # entries are frozen, so the dict is built once per instance
        # (cached_property writes the instance __dict__ directly, which
        # frozen dataclasses allow; replace()/with_align() get fresh caches)
        off, out = 0, {}
        for name, shape in self.entries:
            n = int(np.prod(shape)) if shape else 1
            out[name] = (off, n)
            off += n
        return out

    @property
    def size(self) -> int:
        return sum(int(np.prod(s)) if s else 1 for _, s in self.entries)

    @property
    def padded_size(self) -> int:
        a = self.align
        return ((self.size + a - 1) // a) * a

    def with_align(self, align: int) -> "ParamSpec":
        return dataclasses.replace(self, align=align)

    def unpack(self, flat: Array) -> Dict[str, Array]:
        """Slice a (padded) flat buffer into named, shaped tensors.

        Custom VJP: the cotangent of unpack is exactly ``pack`` (slices are
        disjoint and ordered), i.e. ONE concatenation — without this, autodiff
        builds a chain of full-buffer pad+add ops per tensor (~17 per layer),
        which both wastes HBM traffic and, under schedulers that hoist the
        pads, multiplies peak temp memory by the tensor count.
        """
        return _unpack_vjp(flat, self)

    def _unpack_raw(self, flat: Array) -> Dict[str, Array]:
        out = {}
        for name, shape in self.entries:
            off, n = self.offsets[name]
            out[name] = jax.lax.dynamic_slice_in_dim(flat, off, n).reshape(shape)
        return out

    def pack(self, tensors: Mapping[str, Array],
             dtype=jnp.float32) -> Array:
        """Concatenate named tensors into one padded flat buffer."""
        parts = []
        for name, shape in self.entries:
            t = tensors[name]
            assert tuple(t.shape) == tuple(shape), (name, t.shape, shape)
            parts.append(t.reshape(-1).astype(dtype))
        flat = jnp.concatenate(parts) if parts else jnp.zeros((0,), dtype)
        pad = self.padded_size - self.size
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return flat

    def init(self, key: Array, init_fns: Mapping[str, Callable],
             dtype=jnp.float32) -> Array:
        """Initialize a flat buffer from per-tensor initializers.

        ``init_fns`` maps name -> fn(key, shape) -> array; missing names get
        zeros (biases / norm offsets) — pass explicit fns for anything else.
        """
        keys = jax.random.split(key, max(len(self.entries), 1))
        tensors = {}
        for (name, shape), k in zip(self.entries, keys):
            fn = init_fns.get(name)
            tensors[name] = fn(k, shape) if fn else jnp.zeros(shape, dtype)
        return self.pack(tensors, dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _unpack_vjp(flat: Array, spec: "ParamSpec") -> Dict[str, Array]:
    return spec._unpack_raw(flat)


def _unpack_fwd(flat, spec):
    return spec._unpack_raw(flat), None


def _unpack_bwd(spec, _, dts):
    dtype = jax.tree.leaves(dts)[0].dtype
    dflat = spec.pack(dts, dtype=dtype)  # one concat (+ zero pad)
    return (dflat,)


_unpack_vjp.defvjp(_unpack_fwd, _unpack_bwd)


def alignment(world: int, *blocks: int) -> int:
    """Padding alignment satisfying ZeRO sharding + every quant block.

    The PER-SHARD length (total/world) must itself be a multiple of every
    quantization block (qwZ quantizes the shard; qgZ slices the gathered
    gradient into world × block-aligned pieces), so the total is padded to
    world × lcm(blocks).
    """
    a = 1
    for b in blocks:
        a = a * b // math.gcd(a, b)
    return world * a


def shard_of(flat: np.ndarray, rank: int, world: int) -> np.ndarray:
    """This rank's primary shard of a (padded) global flat buffer."""
    n = flat.shape[-1]
    assert n % world == 0
    per = n // world
    return flat[..., rank * per:(rank + 1) * per]


def reshard(global_flat: np.ndarray, new_world: int,
            block: int = 1) -> np.ndarray:
    """Re-split a global flat buffer for a different world size (elastic
    restart).  Re-pads so the new layout keeps world×block alignment."""
    n = global_flat.shape[-1]
    a = alignment(new_world, block)
    n_new = ((n + a - 1) // a) * a
    if n_new != n:
        pad = [(0, 0)] * (global_flat.ndim - 1) + [(0, n_new - n)]
        global_flat = np.pad(global_flat, pad)
    return global_flat
