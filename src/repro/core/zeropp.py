"""The ZeRO++ engine: gather-compute-reduce as one differentiable primitive.

DeepSpeed implements ZeRO-3 with engine hooks that intercept each module's
forward/backward to all-gather parameters and reduce-scatter gradients.  The
JAX-native equivalent is a ``jax.custom_vjp`` wrapper around each layer
group's apply function:

  primal / fwd : W  = fwd-gather(primary shard)      [qwZ INT8 if enabled]
                 out = f(W, *args)
                 residuals = (secondary shard of W if hpZ else primary, args)
  bwd          : W' = hpZ intra-node gather of the secondary shard
                       (or a re-run of the fwd gather when hpZ is off —
                        deterministic quantization makes W' == W exactly)
                 dW, dargs = vjp(f)(g)                [recomputes f: remat]
                 dprimary  = qgZ INT4 hierarchical all-to-all reduce-scatter
                             (or bf16 psum_scatter baseline)

This reproduces Algorithm 1 of the paper with the ZeRO++ substitutions of
§3, and makes "the secondary copy is re-partitioned from this iteration's
forward gather" (temporal consistency, §3.2.1) automatic: the residual IS a
slice of the gathered tensor.

Layer recomputation in bwd is deliberate (activation checkpointing — the
setting the paper evaluates in; it is also what forces the second
all-gather that hpZ optimizes away from the slow links).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives as cl
from repro.core.partition import alignment
from repro.core.quant import QuantConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ZeroConfig:
    """Which of the paper's optimizations are active, and on which axes.

    The default is full ZeRO++ (qwZ + hpZ + qgZ).  Setting all three to
    False gives the ZeRO-3 baseline of Algorithm 1.  ``dp_axes=()`` is
    single-device ("local") mode: gathers become identity and gradients pass
    through — used by the smoke tests.
    """

    # qwZ (§3.1)
    qwz: bool = True
    qwz_bits: int = 8
    qwz_block: int = 256
    qwz_blocked: bool = True   # False = paper's diverging non-blocked ablation
    # serving head: consume the qwZ-gathered INT8 payload directly through
    # the fused dequant-GEMM kernel (kernels/dequant_matmul.py) instead of
    # dequantizing the whole chunk first.  Only takes effect where the
    # layout is eligible (see qwz_gemm_eligible); False = always staged.
    qwz_gemm: bool = True
    # hpZ (§3.2).  ``hpz_axes=None`` -> secondary group = (intra_axis,).
    # A wider tuple (e.g. ("data","model") on the multi-pod mesh = one whole
    # pod) is the paper's "multiple compute nodes" secondary group: it costs
    # less memory (M / |group|) and still kills the *slowest*-tier traffic.
    hpz: bool = True
    hpz_axes: Optional[Tuple[str, ...]] = None
    # qgZ (§3.3)
    qgz: bool = True
    qgz_bits: int = 4
    qgz_block: int = 256
    qgz_2hop: bool = True      # False = the volume-blowup 1-hop variant (§3.3.2)
    # mesh mapping
    dp_axes: Tuple[str, ...] = ("data", "model")  # full ZeRO world
    intra_axis: str = "model"  # fast tier: hpZ secondary group, qgZ intra hop
    # schedule (core/schedule.py): layers/chunks of weight-gather lookahead
    # in the block scans — the prefetch-RING depth.  0 = fully synchronous
    # collectives on the critical path (the reference schedule); 1 = the
    # double-buffered schedule (gather for step i+1 under step i's
    # compute); k>1 = a ring of k gathered buffers, step i+k's gather in
    # flight under step i's compute and qgZ reduces retired k steps
    # behind (low-bandwidth interconnects, where one step's compute
    # cannot cover a full gather).  Every depth is bit-exact in loss AND
    # gradients; only the overlap structure differs.  Negative values are
    # rejected; depths beyond a scan's length clamp to n-1 per scan
    # (see effective_prefetch).
    prefetch: int = 1

    def __post_init__(self):
        if self.prefetch < 0:
            raise ValueError(
                f"ZeroConfig.prefetch must be >= 0 (ring depth), got "
                f"{self.prefetch}")

    def effective_prefetch(self, n: int) -> int:
        """Usable ring depth for an ``n``-step scan.

        A ring deeper than n-1 would re-gather a buffer still live in the
        ring (the modular prefetch index laps the consumer), so depth
        clamps to n-1; local mode and single-step scans are synchronous.
        """
        if not self.distributed or n < 2:
            return 0
        return min(self.prefetch, n - 1)
    # numerics
    param_dtype: jnp.dtype = jnp.bfloat16
    compute_dtype: jnp.dtype = jnp.bfloat16
    grad_dtype: jnp.dtype = jnp.float32   # optimizer-side gradients
    reduce_dtype: jnp.dtype = jnp.bfloat16  # baseline reduce-scatter wire dtype

    @property
    def distributed(self) -> bool:
        return bool(self.dp_axes)

    @property
    def inter_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.dp_axes if a != self.intra_axis)

    @property
    def secondary_axes(self) -> Tuple[str, ...]:
        """hpZ secondary-partition axes (fast tier)."""
        return self.hpz_axes if self.hpz_axes else (self.intra_axis,)

    @property
    def qwz_cfg(self) -> QuantConfig:
        return QuantConfig(bits=self.qwz_bits, block_size=self.qwz_block)

    @property
    def qgz_cfg(self) -> QuantConfig:
        return QuantConfig(bits=self.qgz_bits, block_size=self.qgz_block)

    def align(self, world: int) -> int:
        return alignment(world, self.qwz_block, self.qgz_block,
                         2)  # int4 packing needs even blocks

    @classmethod
    def baseline(cls, **kw) -> "ZeroConfig":
        """Plain ZeRO-3 (the paper's baseline)."""
        return cls(qwz=False, hpz=False, qgz=False, **kw)

    @classmethod
    def local(cls, **kw) -> "ZeroConfig":
        """Single-device mode (no collectives) for smoke tests/examples."""
        kw.setdefault("dp_axes", ())
        kw.setdefault("intra_axis", "")
        return cls(**kw)


# ---------------------------------------------------------------------------
# gather / reduce building blocks
# ---------------------------------------------------------------------------

def fwd_gather(primary: Array, z: ZeroConfig) -> Array:
    """Forward weights all-gather over the full ZeRO world.

    ``primary`` may be the fp32 master shard (trainer) or a bf16 shard
    (serving): qwZ quantizes whatever it gets; the baseline casts to the
    wire dtype (param_dtype) BEFORE gathering — the paper's fp16 gather.
    """
    if not z.distributed:
        return primary.astype(z.compute_dtype)
    if z.qwz:
        return cl.qwz_all_gather(primary, z.dp_axes, z.qwz_cfg,
                                 out_dtype=z.compute_dtype,
                                 blocked=z.qwz_blocked)
    return cl.baseline_all_gather(primary.astype(z.param_dtype), z.dp_axes,
                                  out_dtype=z.compute_dtype)


def fwd_gather_quant(primary: Array, z: ZeroConfig) -> Tuple[Array, Array]:
    """qwZ forward gather that keeps the payload quantized.

    Returns ``(payload_g int8, scales_g f32)`` for a fused consumer (the
    serving INT8 dequant-GEMM head).  Caller must have checked
    :func:`qwz_gemm_eligible`.
    """
    return cl.qwz_all_gather_quant(primary, z.dp_axes, z.qwz_cfg)


def qwz_gemm_eligible(z: ZeroConfig, rows: int, d: int) -> bool:
    """Can a (rows, d) weight chunk at flat offset 0 feed the fused INT8
    dequant-GEMM directly from its gathered qwZ payload?

    Requires INT8 blocked qwZ, and a scale layout that maps onto per-row
    scale groups: either each row holds whole quant blocks (d % block == 0,
    NB = d/block scales per row) or each block holds whole rows
    (block % d == 0 with rows % (block/d) == 0 — every row lies inside ONE
    block, so its scale is a broadcast).  Anything else (including int4,
    whose packed nibbles straddle rows) stays on the staged dequant path.
    """
    if not (z.distributed and z.qwz and z.qwz_blocked and z.qwz_gemm
            and z.qwz_bits == 8):
        return False
    b = z.qwz_block
    if (rows * d) % b:
        return False
    return d % b == 0 or (b % d == 0 and rows % (b // d) == 0)


def grad_reduce(dW: Array, z: ZeroConfig) -> Array:
    """Gradient reduce-scatter over the full ZeRO world (sums, not means)."""
    if not z.distributed:
        return dW.astype(z.grad_dtype)
    if z.qgz:
        if z.qgz_2hop:
            return cl.qgz_reduce_scatter(
                dW, z.intra_axis, z.inter_axes, z.qgz_cfg,
                out_dtype=z.grad_dtype)
        return cl.qgz_reduce_scatter_1hop(
            dW, z.dp_axes, z.qgz_cfg, out_dtype=z.grad_dtype)
    red = cl.baseline_reduce_scatter(dW.astype(z.reduce_dtype), z.dp_axes)
    return red.astype(z.grad_dtype)


# ---------------------------------------------------------------------------
# the engine primitive
# ---------------------------------------------------------------------------

def zero_apply(f: Callable, z: ZeroConfig):
    """Wrap ``f(W_full, *args) -> out`` into a ZeRO++ layer application.

    Returns ``g(primary_shard, *args) -> out`` that is differentiable w.r.t.
    both the primary shard (via the paper's collectives) and args.  ``f``
    must be differentiable and is recomputed in the backward pass
    (activation checkpointing).
    """
    if not z.distributed:
        # local mode: still remat to mirror distributed memory behaviour
        def local(primary, *args):
            return jax.checkpoint(
                lambda p, *a: f(p.astype(z.compute_dtype), *a))(primary, *args)
        return local

    @jax.custom_vjp
    def apply(primary, *args):
        return f(fwd_gather(primary, z), *args)

    def apply_fwd(primary, *args):
        W = fwd_gather(primary, z)
        out = f(W, *args)
        if z.hpz:
            # re-partition the *already gathered* weights into the secondary
            # (intra-node) shard: zero extra communication (§3.2.1).
            # The barrier ties the slice to the primal output: without it,
            # partial evaluation defers the slice into the backward pass and
            # saves the FULL gathered W as the residual instead — silently
            # reinstating the memory hpZ exists to avoid.
            res_w = cl.slice_secondary(W, z.secondary_axes)
            out, res_w = lax.optimization_barrier((out, res_w))
        else:
            res_w = primary
        return out, (res_w, args)

    def apply_bwd(res, g):
        res_w, args = res
        if z.hpz:
            W = cl.hpz_all_gather(res_w, z.secondary_axes)  # fast tier only
        else:
            W = fwd_gather(res_w, z)  # paper: 2nd global gather (qwZ'd if on)
        _, vjp_fn = jax.vjp(lambda w, *a: f(w, *a), W, *args)
        dW, *dargs = vjp_fn(g)
        dprimary = grad_reduce(dW.reshape(-1), z)
        return (dprimary, *dargs)

    apply.defvjp(apply_fwd, apply_bwd)
    return apply


def zero_apply_inference(f: Callable, z: ZeroConfig):
    """Serving-path variant: gather (qwZ weight-quantized if enabled) and
    apply, no gradient machinery."""
    if not z.distributed:
        return lambda primary, *args: f(primary.astype(z.compute_dtype), *args)

    def apply(primary, *args):
        return f(fwd_gather(primary, z), *args)
    return apply


# ---------------------------------------------------------------------------
# communication-volume accounting (paper Table 1)
# ---------------------------------------------------------------------------

def comm_volume_per_step(n_params: int, z: ZeroConfig,
                         elem_bytes: int = 2) -> dict:
    """Analytic slow-tier (cross-node) bytes per training step for a model
    with ``n_params`` parameters — reproduces Table 1 rows.

    Baseline ZeRO-3: M (fwd AG) + M (bwd AG) + M (grad RS) = 3M.
    ZeRO++       : 0.5M        + 0          + 0.25M        = 0.75M.
    """
    M = n_params * elem_bytes
    qw = z.qwz_cfg
    qg = z.qgz_cfg
    fwd = (qw.wire_bytes(n_params) if z.qwz else M)
    if z.hpz:
        bwd = 0
    else:
        bwd = (qw.wire_bytes(n_params) if z.qwz else M)
    if z.qgz:
        world_scale = 1.0  # per-device slice sum == M total across devices
        rs = int(qg.wire_bytes(n_params) * world_scale)
    else:
        rs = M
    return {"fwd_allgather": fwd, "bwd_allgather": bwd, "grad_reduce": rs,
            "total": fwd + bwd + rs, "baseline_total": 3 * M,
            "reduction_factor": 3 * M / max(fwd + bwd + rs, 1)}


# ---------------------------------------------------------------------------
# per-device wire accounting (runtime telemetry cross-check)
# ---------------------------------------------------------------------------
# Unlike comm_volume_per_step (Table-1 totals: M-relative, slow-tier-only),
# these formulas give the PER-DEVICE bytes one collective invocation puts
# on the wire, exactly as launch/jaxpr_analysis.py measures them from the
# jaxpr (all_gather: out-in; scatter: in-out; all_to_all: in·(g-1)/g),
# with fp32 scales on the wire losslessly (quant.wire_bytes): qwZ gathers
# them on a second all-gather; qgZ bitcasts them to int8 lanes and packs
# them into the payload all-to-all (collectives._pack_scales) — all_to_all
# wire is linear in message size, so the per-label byte total is identical.
# The labels match the named_scope names in core/collectives.py; the
# measured-vs-projected gate (obs/report.py) compares per-label sums.

WIRE_LABELS = ("zero.qwz_gather", "zero.baseline_gather", "zero.hpz_gather",
               "zero.qgz_reduce", "zero.qgz_reduce1hop",
               "zero.baseline_reduce")

EVENT_KINDS = ("fwd_gather", "bwd_gather", "grad_reduce")


def _group(sizes: dict, axes) -> int:
    g = 1
    for a in (axes if isinstance(axes, (tuple, list)) else (axes,)):
        g *= int(sizes[a])
    return g


def wire_label(kind: str, z: ZeroConfig) -> str:
    """The named_scope label the collective for ``kind`` runs under."""
    if kind == "fwd_gather":
        return "zero.qwz_gather" if z.qwz else "zero.baseline_gather"
    if kind == "bwd_gather":
        return "zero.hpz_gather" if z.hpz else wire_label("fwd_gather", z)
    if kind == "grad_reduce":
        if z.qgz:
            return "zero.qgz_reduce" if z.qgz_2hop else "zero.qgz_reduce1hop"
        return "zero.baseline_reduce"
    raise ValueError(f"unknown comm event kind {kind!r}")


def event_wire_bytes(kind: str, n_elems: int, z: ZeroConfig,
                     sizes: dict) -> float:
    """Per-device wire bytes for ONE collective over a global flat buffer of
    ``n_elems`` elements.  ``sizes`` maps mesh axis name -> size."""
    if not z.distributed:
        return 0.0
    n = int(n_elems)
    if kind == "fwd_gather":
        w = _group(sizes, z.dp_axes)
        if z.qwz:
            pb = z.qwz_cfg.payload_bytes
            wire = float(pb(n) - pb(n // w))
            if z.qwz_blocked:
                b = z.qwz_block
                wire += 4.0 * (n // b - (n // w) // b)
            else:
                wire += 4.0 * (w - 1)  # one fp32 scale per shard
            return wire
        eb = jnp.dtype(z.param_dtype).itemsize
        return float(eb * n - eb * (n // w))
    if kind == "bwd_gather":
        if z.hpz:
            xs = _group(sizes, z.secondary_axes)
            eb = jnp.dtype(z.compute_dtype).itemsize
            return float(eb * n - eb * (n // xs))
        return event_wire_bytes("fwd_gather", n, z, sizes)
    if kind == "grad_reduce":
        if z.qgz:
            pb = z.qgz_cfg.payload_bytes
            b = z.qgz_block
            if z.qgz_2hop:
                X = _group(sizes, (z.intra_axis,))
                Y = _group(sizes, z.inter_axes) if z.inter_axes else 1
                wire = (pb(n) + 4.0 * (n // b)) * (X - 1) / X
                if Y > 1:
                    m = n // X
                    wire += (pb(m) + 4.0 * (m // b)) * (Y - 1) / Y
                return float(wire)
            w = _group(sizes, z.dp_axes)
            return float((pb(n) + 4.0 * (n // b)) * (w - 1) / w)
        w = _group(sizes, z.dp_axes)
        eb = jnp.dtype(z.reduce_dtype).itemsize
        return float(eb * n - eb * (n // w))
    raise ValueError(f"unknown comm event kind {kind!r}")


def step_wire_by_label(events, z: ZeroConfig, sizes: dict) -> dict:
    """Fold a comm-event list (``Model.comm_events()``) into per-label
    per-device wire bytes — the projection the runtime gate checks the
    jaxpr-measured counters against."""
    out: dict = {}
    for ev in events:
        lbl = wire_label(ev["kind"], z)
        wire = event_wire_bytes(ev["kind"], ev["elems"], z, sizes)
        out[lbl] = out.get(lbl, 0.0) + wire * ev.get("count", 1)
    return out
