from repro.core import collectives, quant  # noqa: F401
