"""jax version compatibility shims.

The repo targets the modern ``jax.shard_map`` / ``jax.make_mesh(...,
axis_types=...)`` API surface, but must also run on jax 0.4.x where

  * ``shard_map`` lives in ``jax.experimental.shard_map`` and spells the
    replication-check knob ``check_rep`` instead of ``check_vma``;
  * ``jax.make_mesh`` exists but takes no ``axis_types`` argument (and
    ``jax.sharding.AxisType`` does not exist at all).

Everything in the repo imports these two names from here instead of from
``jax`` directly.  The shims are pass-throughs on new jax.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax import lax

__all__ = ["shard_map", "make_mesh", "axis_size", "auto_axis_types"]


if hasattr(lax, "axis_size"):
    axis_size = lax.axis_size
else:
    def axis_size(name) -> int:
        """``lax.axis_size`` fallback: on jax 0.4.x the static size of a
        named mesh axis comes from the axis environment frame."""
        from jax._src import core as _core
        frame = _core.axis_frame(name)
        return int(getattr(frame, "size", frame))


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f=None, /, *, mesh, in_specs, out_specs, **kw):
        """``jax.shard_map`` fallback for jax 0.4.x.

        Maps the new ``check_vma`` kwarg onto the old ``check_rep`` and
        drops kwargs the old implementation does not know.
        """
        if "check_vma" in kw:
            kw.setdefault("check_rep", kw.pop("check_vma"))
        kw = {k: v for k, v in kw.items() if k in ("check_rep", "auto")}

        def wrap(fn):
            return _shard_map_exp(fn, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, **kw)

        return wrap if f is None else wrap(f)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None, axis_types: Optional[Tuple] = None):
    """``jax.make_mesh`` that tolerates old jax (no ``axis_types`` kwarg).

    ``axis_types`` entries, when supported, should be built via
    :func:`auto_axis_types` so callers never touch ``jax.sharding.AxisType``
    directly.
    """
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_types is None:
        axis_types = auto_axis_types(len(axis_shapes))  # default: Auto
    if axis_types is not None and hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = axis_types
    try:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)
    except TypeError:  # very old signature: positional only, no axis_types
        kw.pop("axis_types", None)
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` on new jax, None on old jax."""
    at = getattr(jax.sharding, "AxisType", None)
    return (at.Auto,) * n if at is not None else None
