"""ZeRO++ collectives, expressed as per-device shard_map code.

These are the three communication primitives of the paper plus their ZeRO-3
baselines.  All functions are written for use *inside* ``jax.shard_map`` and
take mesh axis names explicitly so the same code serves the single-pod
``("data","model")`` and multi-pod ``("pod","data","model")`` meshes.

Axis convention (see DESIGN.md §2): ``intra_axis`` is the fastest
interconnect tier (the paper's intra-node NVLink; our ``'model'`` axis) and
``inter_axes`` the slower tiers (cross-node IB; our ``('pod','data')``).

  * :func:`qwz_all_gather`   — blockwise-INT8-quantized all-gather (qwZ, §3.1)
  * :func:`hpz_all_gather`   — intra-node-only all-gather of the secondary
                               partition (hpZ, §3.2)
  * :func:`qgz_reduce_scatter` — hierarchical 2-hop all-to-all quantized
                               gradient reduce-scatter with tensor-slice
                               reordering (qgZ, §3.3)
  * baselines: plain bf16/fp32 all-gather and psum_scatter (ZeRO-3, Alg. 1)
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.quant import (
    QuantConfig,
    quantize_global,
    dequantize_global,
)
# hot-path quantization goes through the kernel dispatcher: Pallas kernels on
# TPU (incl. the fused reorder+quant and dequant-reduce-quant of paper §4.2),
# bit-identical pure-jnp on CPU.
from repro.core.compat import axis_size as _axis_size
# Module import (not from-import): kernels.ops also reaches back into
# repro.core lazily, so names must resolve at call time, not import time.
from repro.kernels import ops as _kops
# Telemetry labels: every collective wrapper below runs under a
# ``zero.<op>`` named_scope.  The scope is trace-time only (zero runtime
# cost) but survives into the jaxpr ``name_stack`` — through scan bodies
# and custom_vjp transposition — so launch/jaxpr_analysis.py can attribute
# wire bytes per collective and obs/report.py can gate measured-vs-
# projected comm volume.  Keep these names in sync with
# zeropp.WIRE_LABELS and DESIGN.md §8.
from repro.obs.trace import annotate as _annotate

dequant_reduce = lambda *a, **k: _kops.dequant_reduce(*a, **k)  # noqa: E731
dequant_reduce_quant = lambda *a, **k: _kops.dequant_reduce_quant(*a, **k)  # noqa: E731
dequantize_blockwise = lambda *a, **k: _kops.dequantize_blockwise(*a, **k)  # noqa: E731
quantize_blockwise = lambda *a, **k: _kops.quantize_blockwise(*a, **k)  # noqa: E731
quantize_reordered = lambda *a, **k: _kops.quantize_reordered(*a, **k)  # noqa: E731

Array = jax.Array
Axes = Union[str, Tuple[str, ...]]


def _axes_tuple(axes: Axes) -> Tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def axis_size(axes: Axes) -> int:
    n = 1
    for a in _axes_tuple(axes):
        n *= _axis_size(a)
    return n


# ---------------------------------------------------------------------------
# Baseline ZeRO-3 collectives (Algorithm 1 of the paper)
# ---------------------------------------------------------------------------

def _pin(x: Array) -> Array:
    """optimization_barrier: stop XLA from hoisting a consumer's dtype
    convert to the producer side of a collective (observed on CPU: bf16
    gathers silently became f32 gathers = 2x wire bytes)."""
    return lax.optimization_barrier(x)


def gather_bf16(x: Array, axes: Axes, axis: int = 0) -> Array:
    """all_gather that provably moves 2-byte lanes.

    bf16 is bitcast to u16 for the gather: the CPU backend legalizes bf16
    collectives to f32 (2x wire bytes — poisons both the dry-run accounting
    and an actual CPU run), and XLA convert-hoisting can do the same on any
    backend.  Bit-level identity; free on TPU.
    """
    if x.dtype != jnp.bfloat16:
        return _pin(lax.all_gather(x, _axes_tuple(axes), axis=axis,
                                   tiled=True))
    u = lax.bitcast_convert_type(x, jnp.uint16)
    g = lax.all_gather(u, _axes_tuple(axes), axis=axis, tiled=True)
    return _pin(lax.bitcast_convert_type(g, jnp.bfloat16))


def baseline_all_gather(shard: Array, axes: Axes, out_dtype=None) -> Array:
    """Full-precision all-gather of a flat parameter shard (ZeRO-3 fwd/bwd)."""
    with _annotate("zero.baseline_gather"):
        full = gather_bf16(shard, axes)
        return full if out_dtype is None else full.astype(out_dtype)


def baseline_reduce_scatter(grad: Array, axes: Axes) -> Array:
    """Full-precision reduce-scatter of a flat local gradient (ZeRO-3)."""
    with _annotate("zero.baseline_reduce"):
        return lax.psum_scatter(grad, _axes_tuple(axes), scatter_dimension=0,
                                tiled=True)


# ---------------------------------------------------------------------------
# qwZ — quantized weight all-gather (§3.1)
# ---------------------------------------------------------------------------

def qwz_all_gather(
    shard: Array,
    axes: Axes,
    cfg: QuantConfig,
    out_dtype=jnp.bfloat16,
    blocked: bool = True,
) -> Array:
    """All-gather a flat weight shard with in-flight blockwise quantization.

    Each device quantizes its own shard once (one kernel, not one per hop),
    gathers the INT8 payload + scales, and dequantizes the concatenation.
    Communication: 0.5·M payload + scales instead of M (bf16), matching the
    paper's 2× reduction.

    ``blocked=False`` uses a single per-shard scale — the paper's Fig. 2 /
    Fig. 14 "non-blocked" ablation that destroys convergence.
    """
    n = shard.shape[0]
    with _annotate("zero.qwz_gather"):
        if blocked:
            if n % cfg.block_size:
                raise ValueError(
                    f"shard len {n} % block {cfg.block_size} != 0")
            payload, scales = quantize_blockwise(shard, cfg)
            payload_g = lax.all_gather(payload, _axes_tuple(axes), tiled=True)
            scales_g = lax.all_gather(scales, _axes_tuple(axes), tiled=True)
            return dequantize_blockwise(payload_g, scales_g, cfg, out_dtype)
        payload, scale = quantize_global(shard, cfg.bits)
        payload_g = lax.all_gather(payload, _axes_tuple(axes), tiled=True)
        scale_g = lax.all_gather(scale[None], _axes_tuple(axes))  # (world,)
        world = axis_size(axes)
        per = payload_g.shape[0] // world
        vals = dequantize_global(
            payload_g.reshape(world, per), scale_g.reshape(world, 1),
            cfg.bits, out_dtype)
        return vals.reshape(-1)


def qwz_all_gather_quant(
    shard: Array,
    axes: Axes,
    cfg: QuantConfig,
) -> Tuple[Array, Array]:
    """qwZ all-gather that STAYS quantized: (payload_g, scales_g).

    Same wire traffic as :func:`qwz_all_gather`, but the trailing dequant is
    omitted so a fused consumer (the serving INT8 dequant-GEMM head,
    kernels/dequant_matmul.py) can apply the scales inside its own tile
    loop — the gathered bf16 weight matrix never materializes in HBM.
    """
    n = shard.shape[0]
    if n % cfg.block_size:
        raise ValueError(f"shard len {n} % block {cfg.block_size} != 0")
    with _annotate("zero.qwz_gather"):
        payload, scales = quantize_blockwise(shard, cfg)
        payload_g = lax.all_gather(payload, _axes_tuple(axes), tiled=True)
        scales_g = lax.all_gather(scales, _axes_tuple(axes), tiled=True)
        return payload_g, scales_g


# ---------------------------------------------------------------------------
# hpZ — hierarchical (secondary) partition all-gather (§3.2)
# ---------------------------------------------------------------------------

def flat_rank(axes: Axes) -> Array:
    """This device's rank within the flattened (row-major) axis group."""
    rank = jnp.int32(0)
    for a in _axes_tuple(axes):
        rank = rank * _axis_size(a) + lax.axis_index(a)
    return rank


def hpz_all_gather(secondary_shard: Array, intra_axes: Axes,
                   out_dtype=None) -> Array:
    """Backward all-gather over the *fast-tier* axes only.

    The secondary partition replicates the full weights within each
    ``intra_axes`` group, so this gather moves zero bytes on the slow axes —
    the paper's "M → 0 inter-node" claim.  ``intra_axes`` is normally the
    single ``'model'`` axis (the paper's node), but may span multiple axes
    (e.g. ``('data','model')`` = a whole pod) — the paper's "extended to
    support multiple compute nodes" secondary group.
    """
    with _annotate("zero.hpz_gather"):
        full = gather_bf16(_pin(secondary_shard), intra_axes)
        return full if out_dtype is None else full.astype(out_dtype)


def slice_secondary(full: Array, intra_axes: Axes) -> Array:
    """Re-partition gathered weights into this device's secondary shard.

    Paper §3.2.1: "once the weights are consumed during the forward pass,
    they are partitioned based on the secondary partition".  Slicing the
    already-gathered tensor costs no communication.
    """
    x = axis_size(intra_axes)
    idx = flat_rank(intra_axes)
    sec_len = full.shape[0] // x
    # pin: the slice is saved as a bwd residual — without the barrier XLA
    # may store it pre-converted to the consumer dot's dtype (f32), doubling
    # both the residual memory and the hpZ re-gather bytes
    return _pin(lax.dynamic_slice_in_dim(full, idx * sec_len, sec_len))


# ---------------------------------------------------------------------------
# qgZ — quantized hierarchical all-to-all gradient reduce-scatter (§3.3)
# ---------------------------------------------------------------------------

def _quantize_slices(x: Array, cfg: QuantConfig,
                     key: Optional[Array]) -> Tuple[Array, Array]:
    """Blockwise-quantize the trailing dim of a (..., L) slice stack."""
    return quantize_blockwise(x, cfg, key)


def _pack_scales(payload: Array, scales: Array) -> Array:
    """Append the fp32 block scales to the int8 payload, trailing dim.

    The scales are bitcast to 4 int8 lanes each (lossless) and
    concatenated after the payload so ONE all-to-all moves both — same
    wire bytes as two messages (all_to_all volume is linear in message
    size), one less collective launch per hop.  Inverse:
    :func:`_unpack_scales`.
    """
    sb = lax.bitcast_convert_type(scales, jnp.int8)        # (..., NB, 4)
    sb = sb.reshape(*scales.shape[:-1], scales.shape[-1] * 4)
    return jnp.concatenate([payload, sb], axis=-1)


def _unpack_scales(msg: Array, payload_len: int) -> Tuple[Array, Array]:
    """Split a :func:`_pack_scales` message back into (payload, scales)."""
    payload = msg[..., :payload_len]
    sb = msg[..., payload_len:]
    nb = sb.shape[-1] // 4
    scales = lax.bitcast_convert_type(
        sb.reshape(*sb.shape[:-1], nb, 4), jnp.float32)
    return payload, scales


def qgz_reduce_scatter(
    grad: Array,
    intra_axis: str,
    inter_axes: Axes,
    cfg: QuantConfig,
    out_dtype=jnp.float32,
    key: Optional[Array] = None,
) -> Array:
    """Replacement for gradient reduce-scatter (paper §3.3, Figs. 5-9).

    Per-device algorithm, for a world of Y (inter) × X (intra) devices and a
    flat local gradient of n = world·L elements:

      1. reshape to slices ``(Y, X, L)`` — slice (y, x) is destined for the
         device at inter-coordinate y, intra-coordinate x — and transpose to
         ``(X, Y, L)``.  The transpose *is* the paper's tensor-slice
         reordering Eq. (1)→(2); without it the intra hop would deliver the
         wrong slices (Fig. 8).
      2. blockwise-quantize (INT4 by default) → intra-node all-to-all over
         ``intra_axis`` → dequantize → **reduce in full precision** over the
         X contributions.  Data per device shrinks from M/Z to M/(Z·X).
      3. re-quantize the partial sums → inter-node all-to-all over
         ``inter_axes`` → dequantize → final reduction over the Y node
         contributions.

    Exactly two quantize/dequantize pairs touch any value (vs. `world` pairs
    for a quantized ring), and every reduction runs in fp32 — the paper's
    accuracy-preservation argument.  Cross-slow-link volume is M/Z·(bits/16)
    = 0.25·M for INT4 vs M for bf16 reduce-scatter.

    Returns this device's fully-reduced gradient shard, length L, summed
    (not averaged) over the world.
    """
    inter_axes = _axes_tuple(inter_axes) if inter_axes else ()
    X = _axis_size(intra_axis)
    Y = axis_size(inter_axes) if inter_axes else 1
    world = X * Y
    n = grad.shape[0]
    if n % (world * cfg.block_size):
        raise ValueError(
            f"grad len {n} must be a multiple of world*block "
            f"({world}*{cfg.block_size})")
    L = n // world

    k1 = k2 = None
    if key is not None:
        k1, k2 = jax.random.split(key)

    with _annotate("zero.qgz_reduce"):
        # -- step 1: slice + reorder (Eq. 1 -> Eq. 2), fused with quant ----
        # (X, Y, L): grouped by destination intra coordinate.  On TPU the
        # transpose rides inside the quant kernel's BlockSpec index_map
        # (§4.2 "fused quantization and remapping kernel").
        slices = grad.reshape(Y, X, L)
        payload, scales = quantize_reordered(slices, cfg, k1)

        # -- step 2: intra-node hop over the fast axis ---------------------
        # scales ride the SAME all-to-all message as the payload (bitcast
        # to int8 lanes, split off on receipt): identical wire bytes, one
        # collective launch per hop instead of two
        msg = lax.all_to_all(_pack_scales(payload, scales), intra_axis,
                             split_axis=0, concat_axis=0)
        payload, scales = _unpack_scales(msg, payload.shape[-1])
        # payload[x'] is peer x''s contribution to my (Y, L) slice group

        if not inter_axes:  # single-tier world: already the final slice
            X_ = payload.shape[0]
            out = dequant_reduce(payload.reshape(X_, -1),
                                 scales.reshape(X_, -1), cfg)
            return out.reshape(Y, L)[0].astype(out_dtype)

        # fused dequant -> fp32 reduce -> requant (one kernel; §4.2 fusion)
        X_ = payload.shape[0]
        payload2, scales2 = dequant_reduce_quant(
            payload.reshape(X_, -1), scales.reshape(X_, -1), cfg, cfg, k2)
        payload2 = payload2.reshape(Y, -1)                      # (Y, Lp)
        scales2 = scales2.reshape(Y, -1)

        # -- step 3: inter-node hop over the slow axes ---------------------
        # packed payload+scales again: one message per hop
        msg2 = lax.all_to_all(_pack_scales(payload2, scales2)[:, None],
                              inter_axes, split_axis=0,
                              concat_axis=1)                    # (1, Y, .)
        payload2, scales2 = _unpack_scales(msg2[0], payload2.shape[-1])
        out = dequant_reduce(payload2, scales2, cfg)            # (L,) fp32
        return out.astype(out_dtype)


def qgz_reduce_scatter_1hop(
    grad: Array,
    axes: Axes,
    cfg: QuantConfig,
    out_dtype=jnp.float32,
    key: Optional[Array] = None,
) -> Array:
    """The paper's intermediate design (Fig. 5 right / Fig. 6): flat 1-hop
    all-to-all.  Single quantize/dequantize pair, but each node emits
    N·M/Z of cross-node traffic — kept for the benchmark that reproduces
    the paper's volume-blowup argument (§3.3.2).
    """
    world = axis_size(axes)
    n = grad.shape[0]
    if n % (world * cfg.block_size):
        raise ValueError(
            f"grad len {n} must be a multiple of world*block "
            f"({world}*{cfg.block_size})")
    L = n // world
    with _annotate("zero.qgz_reduce1hop"):
        slices = grad.reshape(world, L)
        payload, scales = _quantize_slices(slices, cfg, key)
        msg = lax.all_to_all(_pack_scales(payload, scales),
                             _axes_tuple(axes), split_axis=0, concat_axis=0)
        payload, scales = _unpack_scales(msg, payload.shape[-1])
        deq = dequantize_blockwise(payload, scales, cfg)
        return jnp.sum(deq, axis=0).astype(out_dtype)


def qgz_quantized_ring_reduce_scatter(
    grad: Array,
    axes: Axes,
    cfg: QuantConfig,
    out_dtype=jnp.float32,
) -> Array:
    """Naive quantized *ring* reduce-scatter (paper Fig. 5 left): quantize →
    send → dequantize → reduce, repeated ``world-1`` times.  Error compounds
    once per hop; used only by the convergence benchmark to reproduce the
    paper's accuracy argument, never for training.
    """
    axes_t = _axes_tuple(axes)
    world = axis_size(axes)
    n = grad.shape[0]
    L = n // world
    # ring over the flattened axis: permute accumulated chunk to the next rank
    perm = [(i, (i + 1) % world) for i in range(world)]

    # flatten multi-axis rank
    rank = jnp.int32(0)
    for a in axes_t:
        rank = rank * _axis_size(a) + lax.axis_index(a)

    def hop(i, acc):
        # acc: fp32 (L,) partial sum for slice s_r(i) = (rank - 1 - i) mod W;
        # send it on, receive the neighbour's, add our local contribution.
        q, s = quantize_blockwise(acc, cfg)
        q = lax.ppermute(q, axes_t, perm)
        s = lax.ppermute(s, axes_t, perm)
        recv = dequantize_blockwise(q, s, cfg)
        idx = jnp.mod(rank - 2 - i, world)
        mine = lax.dynamic_slice_in_dim(grad, idx * L, L)
        return recv + mine.astype(jnp.float32)

    idx0 = jnp.mod(rank - 1, world)
    acc0 = lax.dynamic_slice_in_dim(grad, idx0 * L, L).astype(jnp.float32)
    # after world-1 hops each device holds the fully-reduced slice `rank`
    with _annotate("zero.qgz_ring"):
        acc = lax.fori_loop(0, world - 1, hop, acc0)
    return acc.astype(out_dtype)
