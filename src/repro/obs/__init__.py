"""Runtime telemetry: tracing, metrics, and measured-vs-projected reporting.

Three modules, layered so the import graph stays acyclic:

  ``obs.metrics``  stdlib-only counters/gauges/histograms behind a process
                   registry.  Safe to import from anywhere (kernels.ops,
                   core.collectives) — it never imports jax or repro.
  ``obs.trace``    span/event tracer: host-side jsonl event log with
                   monotonic timestamps, optional
                   ``jax.profiler.TraceAnnotation`` spans, and
                   ``annotate()`` — the in-jit ``jax.named_scope`` labels
                   that survive into jaxpr ``name_stack``s and let
                   ``launch/jaxpr_analysis.py`` attribute wire bytes to
                   specific ZeRO collectives.
  ``obs.report``   BENCH-schema snapshot export, ``bench_diff``, and the
                   measured-vs-projected gate (comm bytes vs the analytic
                   model, overhead, overlap).

Disabled overhead is ~zero: the null tracer hands out one shared
``nullcontext``, counters live host-side only, and nothing here ever runs
inside a jitted step — per-step comm bytes come from a one-time jaxpr walk
of the compiled step, accumulated by host counters at tick boundaries.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               get_registry, set_registry)
from repro.obs.trace import (Tracer, annotate, get_tracer, set_tracer,
                             replay_counters)
from repro.obs.report import (GateFailure, bench_diff, comm_gate,
                              export_snapshot, overhead_gate,
                              projected_wire_by_label, runtime_gate)

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry",
    "get_registry", "set_registry",
    "Tracer", "annotate", "get_tracer", "set_tracer", "replay_counters",
    "GateFailure", "bench_diff", "comm_gate", "export_snapshot",
    "overhead_gate", "projected_wire_by_label", "runtime_gate",
]
