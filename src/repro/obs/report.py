"""BENCH snapshot export, snapshot diffing, and the measured-vs-projected
regression gate (DESIGN.md §8).

Three pieces:

* :func:`export_snapshot` — serialize the metrics registry into the
  repo's BENCH json schema (one top-level section key, nested plain
  dicts — the same shape ``benchmarks/snapshots/BENCH_*.json`` already
  use), optionally merged with caller-provided extras (gate results,
  run config) and written to disk.

* :func:`bench_diff` — compare two BENCH snapshots leaf-by-leaf and
  report relative drift.  Also a tiny CLI:
  ``python -m repro.obs.report diff OLD.json NEW.json [--rel-tol 0.05]``.

* The gate — :func:`comm_gate` checks recorded per-step wire bytes
  (jaxpr walk, ``wire_by_label``) against the analytic projection
  (``Model.comm_events`` folded through ``zeropp.step_wire_by_label``)
  per collective label at a strict default tolerance of 1%;
  :func:`overhead_gate` checks the telemetry-disabled step time against
  a no-telemetry baseline (medians of interleaved samples, so CI noise
  hits both sides alike); :func:`runtime_gate` combines them into one
  pass/fail report.  Tolerance policy: comm bytes are DETERMINISTIC
  (both sides count the same traced program), so 1% is generous — the
  validated repo configurations match to the byte and any real drift
  means one side's model is wrong; wall-clock comparisons are loose
  because CPU CI timing is noisy.

This module deliberately imports nothing from jax or the rest of
``repro`` at module scope — gate helpers that need the analytic model
import lazily — so it stays importable from lightweight tooling.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.obs.metrics import Registry, get_registry

__all__ = ["export_snapshot", "bench_diff", "format_diff",
           "comm_gate", "overhead_gate", "runtime_gate",
           "projected_wire_by_label", "GateFailure"]


class GateFailure(AssertionError):
    """A measured-vs-projected check exceeded its tolerance."""


# ---------------------------------------------------------------------------
# snapshot export
# ---------------------------------------------------------------------------

def export_snapshot(path: Optional[str] = None, *,
                    registry: Optional[Registry] = None,
                    section: str = "runtime",
                    extra: Optional[Mapping[str, Any]] = None
                    ) -> Dict[str, Any]:
    """Registry -> ``{section: {metrics: <flat snapshot>, **extra}}``.

    The flat metric names (``comm.zero.qwz_gather.bytes``, ...) stay flat
    under ``"metrics"`` — they are the stable diffable surface; ``extra``
    carries structured one-off payloads (gate report, run config).
    """
    reg = registry if registry is not None else get_registry()
    body: Dict[str, Any] = {"metrics": reg.snapshot()}
    if extra:
        body.update(extra)
    doc = {section: body}
    if path:
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")
    return doc


# ---------------------------------------------------------------------------
# snapshot diff
# ---------------------------------------------------------------------------

def _leaves(doc: Any, prefix: str = "") -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if isinstance(doc, dict):
        for k, v in doc.items():
            out.update(_leaves(v, f"{prefix}{k}."))
        return out
    out[prefix[:-1]] = doc
    return out


def bench_diff(old: Mapping[str, Any], new: Mapping[str, Any], *,
               rel_tol: float = 0.05
               ) -> List[Tuple[str, Any, Any, Optional[float]]]:
    """Leaf-wise diff of two BENCH docs.

    Returns rows ``(key, old, new, rel)`` for every leaf that drifted
    beyond ``rel_tol`` (numeric), changed value (non-numeric), or exists
    on only one side (the missing side is None, rel is None).
    """
    a, b = _leaves(dict(old)), _leaves(dict(new))
    rows: List[Tuple[str, Any, Any, Optional[float]]] = []
    for key in sorted(set(a) | set(b)):
        va, vb = a.get(key), b.get(key)
        if key not in a or key not in b:
            rows.append((key, va, vb, None))
            continue
        if isinstance(va, (int, float)) and isinstance(vb, (int, float)) \
                and not isinstance(va, bool) and not isinstance(vb, bool):
            denom = max(abs(va), abs(vb), 1e-12)
            rel = abs(va - vb) / denom
            if rel > rel_tol:
                rows.append((key, va, vb, rel))
        elif va != vb:
            rows.append((key, va, vb, None))
    return rows


def format_diff(rows: Sequence[Tuple[str, Any, Any, Optional[float]]]) -> str:
    if not rows:
        return "no drift"
    lines = []
    for key, va, vb, rel in rows:
        tail = f"  rel={rel:.3f}" if rel is not None else ""
        lines.append(f"  {key}: {va!r} -> {vb!r}{tail}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# measured-vs-projected gate
# ---------------------------------------------------------------------------

def projected_wire_by_label(model: Any, sizes: Mapping[str, int],
                            accum: int = 1) -> Dict[str, float]:
    """Analytic per-step per-device wire bytes by collective label, from
    the schedule's event enumeration (``Model.comm_events``)."""
    from repro.core.zeropp import step_wire_by_label
    return step_wire_by_label(model.comm_events(accum=accum), model.zcfg,
                              dict(sizes))


def comm_gate(measured: Mapping[str, float], projected: Mapping[str, float],
              *, tol: float = 0.01, ignore: Sequence[str] = ("other",)
              ) -> Dict[str, Any]:
    """Per-label relative comparison of measured (jaxpr walk) vs projected
    (analytic event model) per-step wire bytes.

    ``other`` (unlabeled collectives: loss psums etc.) is reported but not
    gated by default — it carries no parameter traffic in this codebase
    and measures 0 bytes on every validated configuration.
    """
    rows: Dict[str, Dict[str, float]] = {}
    ok = True
    for lbl in sorted(set(measured) | set(projected)):
        m = float(measured.get(lbl, 0.0))
        p = float(projected.get(lbl, 0.0))
        rel = abs(m - p) / max(m, p, 1.0)
        gated = lbl not in ignore
        passed = (rel <= tol) or not gated
        ok = ok and passed
        rows[lbl] = {"measured": m, "projected": p, "rel": rel,
                     "pass": passed}
    return {"ok": ok, "tol": tol, "labels": rows}


def overhead_gate(enabled_s: Sequence[float], disabled_s: Sequence[float],
                  *, tol: float = 0.02) -> Dict[str, Any]:
    """Telemetry overhead check: median step time with the tracer+metrics
    DISABLED must be within ``tol`` of a run that never created them —
    and, reported for context, the enabled run's median.  Samples should
    come from alternating enabled/disabled steps of the same jitted
    function so machine noise lands on both sides."""
    med_e = _median(enabled_s)
    med_d = _median(disabled_s)
    rel = (med_d - med_e) / max(med_e, 1e-12)
    # disabled-path overhead can only come from the no-op guards; a
    # negative rel (disabled faster) trivially passes
    return {"ok": rel <= tol or med_d <= med_e, "tol": tol,
            "median_enabled_s": med_e, "median_disabled_s": med_d,
            "rel_overhead": rel}


def _median(xs: Sequence[float]) -> float:
    if not xs:
        raise ValueError("no samples")
    ys = sorted(float(x) for x in xs)
    n = len(ys)
    return ys[n // 2] if n % 2 else 0.5 * (ys[n // 2 - 1] + ys[n // 2])


def runtime_gate(*, measured: Mapping[str, float],
                 projected: Mapping[str, float],
                 enabled_s: Optional[Sequence[float]] = None,
                 disabled_s: Optional[Sequence[float]] = None,
                 comm_tol: float = 0.01, overhead_tol: float = 0.02,
                 strict: bool = False) -> Dict[str, Any]:
    """Combined gate report.  ``strict=True`` raises :class:`GateFailure`
    listing every failing check instead of returning ``ok=False``."""
    report: Dict[str, Any] = {"comm": comm_gate(measured, projected,
                                                tol=comm_tol)}
    if enabled_s and disabled_s:
        report["overhead"] = overhead_gate(enabled_s, disabled_s,
                                           tol=overhead_tol)
    report["ok"] = all(sec["ok"] for k, sec in report.items()
                       if isinstance(sec, dict))
    if strict and not report["ok"]:
        bad = []
        for lbl, row in report["comm"]["labels"].items():
            if not row["pass"]:
                bad.append(f"comm[{lbl}]: measured={row['measured']:.0f} "
                           f"projected={row['projected']:.0f} "
                           f"rel={row['rel']:.4f} > {comm_tol}")
        ov = report.get("overhead")
        if ov and not ov["ok"]:
            bad.append(f"overhead: disabled median {ov['median_disabled_s']:.6f}s "
                       f"vs baseline {ov['median_enabled_s']:.6f}s "
                       f"(rel {ov['rel_overhead']:.4f} > {overhead_tol})")
        raise GateFailure("measured-vs-projected gate failed:\n  "
                          + "\n  ".join(bad))
    return report


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.obs.report")
    sub = ap.add_subparsers(dest="cmd", required=True)
    d = sub.add_parser("diff", help="compare two BENCH snapshots")
    d.add_argument("old")
    d.add_argument("new")
    d.add_argument("--rel-tol", type=float, default=0.05)
    d.add_argument("--fail-on-drift", action="store_true")
    args = ap.parse_args(argv)
    with open(args.old) as fh:
        old = json.load(fh)
    with open(args.new) as fh:
        new = json.load(fh)
    rows = bench_diff(old, new, rel_tol=args.rel_tol)
    print(format_diff(rows))
    return 1 if (rows and args.fail_on_drift) else 0


if __name__ == "__main__":
    sys.exit(main())
