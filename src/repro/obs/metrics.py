"""Process-wide metrics registry: counters, gauges, histograms.

Deliberately stdlib-only (no jax, no repro imports) so every layer of the
stack — including ``kernels/ops.py``, which sits below ``core`` — can
increment metrics without import cycles.  All instruments are host-side
Python objects; nothing here is traced or jitted, so the cost of an
increment is one dict lookup plus an int add, and the cost when a caller
holds no registry is whatever guard the caller writes (typically an
``if`` on a module global).

Naming scheme (DESIGN.md §8): dot-separated, ``<subsystem>.<noun>[.<qual>]``:

  train.step.wall_ms        histogram   per-step wall time
  train.steps / train.tokens  counter   monotone progress
  comm.<label>.bytes        counter     cumulative wire bytes per collective
                                        label (zero.qwz_gather, ...)
  comm.<label>.bytes_per_step  gauge    the per-step constant (jaxpr walk)
  kernels.dispatch.<op>.<backend>  counter  dispatch-seam routing counts
  serve.admitted/completed/expired counter  request lifecycle (exactly-once)
  serve.ttft_ms / serve.tok_latency_ms  histogram  sliding-window latency
  serve.slot_occupancy / serve.queue_depth  gauge
  elastic.ckpt.write_ms     histogram   async checkpoint wall time
  elastic.restarts / elastic.reshards  counter
  elastic.ckpt.overlap_fraction  gauge  steps_overlapped / submitted
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, Optional, Sequence, Union


class Counter:
    """Monotone counter.  ``inc`` accepts negative deltas only via reset()."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, delta: Union[int, float] = 1) -> None:
        self.value += delta

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Union[int, float, None] = None

    def set(self, value: Union[int, float]) -> None:
        self.value = value


class Histogram:
    """Sliding-window histogram: keeps the last ``window`` observations in a
    deque plus lifetime count/sum, computes exact percentiles on demand.
    The window bounds memory for long-running serve loops; at window=512
    a p99 is still exact over the last 512 observations."""

    __slots__ = ("name", "window", "samples", "count", "total",
                 "min", "max")

    def __init__(self, name: str, window: int = 512):
        self.name = name
        self.window = window
        self.samples: deque = deque(maxlen=window)
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, value: Union[int, float]) -> None:
        v = float(value)
        self.samples.append(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def percentile(self, p: float) -> Optional[float]:
        """Exact percentile over the current window (nearest-rank)."""
        if not self.samples:
            return None
        xs = sorted(self.samples)
        i = min(len(xs) - 1, max(0, int(round((p / 100.0) * (len(xs) - 1)))))
        return xs[i]

    def quantiles(self, ps: Sequence[float] = (50, 90, 99)
                  ) -> Dict[str, Optional[float]]:
        """Quantile export: {"p50": ..., "p90": ..., ...} plus the window
        sample count, sorted once for all requested quantiles.  This is
        the shape ``ServeEngine.stats()`` and the serve bench publish."""
        if not self.samples:
            return {**{f"p{g:g}": None for g in ps}, "n": 0}
        xs = sorted(self.samples)
        n = len(xs)
        out: Dict[str, Optional[float]] = {}
        for p in ps:
            i = min(n - 1, max(0, int(round((p / 100.0) * (n - 1)))))
            out[f"p{p:g}"] = xs[i]
        out["n"] = self.count
        return out

    @property
    def mean(self) -> Optional[float]:
        return (self.total / self.count) if self.count else None

    def summary(self) -> Dict[str, Optional[float]]:
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


class Registry:
    """Create-on-first-use instrument registry.  Thread-safe creation (the
    async checkpoint writer thread and the serve loop share the process
    default); individual updates are plain attribute writes — GIL-atomic
    for the int/float cases we use."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str, window: int = 512) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(name, Histogram(name, window))
        return h

    def snapshot(self) -> Dict[str, object]:
        """Flat {name: value-or-summary} dict; histograms expand to their
        summary dict.  Stable key order for diffable json."""
        out: Dict[str, object] = {}
        for n in sorted(self._counters):
            out[n] = self._counters[n].value
        for n in sorted(self._gauges):
            if self._gauges[n].value is not None:
                out[n] = self._gauges[n].value
        for n in sorted(self._hists):
            out[n] = self._hists[n].summary()
        return out

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


_default = Registry()


def get_registry() -> Registry:
    return _default


def set_registry(registry: Registry) -> Registry:
    """Swap the process default (tests); returns the previous one."""
    global _default
    old, _default = _default, registry
    return old


def count_dispatch(op: str, backend: str) -> None:
    """Kernel-dispatch seam hook (kernels/ops.py): one counter per
    (op, backend) pair.  Hot only at trace time — inside jit the Python
    body runs once per compilation, so these count *dispatches*, i.e.
    routing decisions, not per-step executions."""
    _default.counter(f"kernels.dispatch.{op}.{backend}").inc()
