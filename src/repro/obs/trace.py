"""Span/event tracer with a host-side structured jsonl log.

Two complementary mechanisms, one per timescale:

* **Host spans/events** (`Tracer.span` / `.event` / `.counter`): plain
  Python context managers stamping ``time.monotonic_ns()``, buffered and
  flushed to an append-mode jsonl file at tick boundaries (``flush()``).
  Optionally each span also opens a ``jax.profiler.TraceAnnotation`` so
  the spans line up with device activity in a profiler trace.
* **In-jit labels** (`annotate`): ``jax.named_scope`` wrappers.  These are
  trace-time only — zero runtime cost — but the labels survive into
  ``eqn.source_info.name_stack`` of the jaxpr (including through
  ``lax.scan`` bodies and ``custom_vjp`` transposition, where they appear
  wrapped as e.g. ``transpose(jvp(zero.hpz_gather))``), which is how
  ``launch/jaxpr_analysis.py`` attributes per-collective wire bytes.

Kill-safety / replay contract (elastic training): every flush ends in
``os.fsync``; a SIGKILL can at worst truncate the final line, which
``read_events`` skips.  Counter records carry the step tag, and
``replay_counters`` deduplicates per ``(name, step)`` with
last-occurrence-wins — a restarted run that re-emits steps already in the
log (resume from an earlier checkpoint) replays to the same totals as an
uninterrupted run.  The log is opened in append mode so in-process or
cross-process restarts extend, never clobber, the history.
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from typing import Any, Dict, IO, List, Optional, Tuple

import jax

_NULLCTX = contextlib.nullcontext()


def annotate(name: str):
    """In-jit label: a ``jax.named_scope`` whose name survives into the
    jaxpr ``name_stack``.  Collective wrappers in ``core/collectives.py``
    use ``zero.<op>`` names; anything outside such a scope is bucketed as
    ``other`` by the analyzer."""
    return jax.named_scope(name)


class _Span:
    """Enabled-path span: stamps monotonic ns, appends one record on exit."""

    __slots__ = ("_tracer", "_name", "_tags", "_t0", "_prof")

    def __init__(self, tracer: "Tracer", name: str, tags: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._tags = tags
        self._prof = None

    def __enter__(self):
        if self._tracer.profiler_annotations:
            self._prof = jax.profiler.TraceAnnotation(self._name)
            self._prof.__enter__()
        self._t0 = time.monotonic_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        dur = time.monotonic_ns() - self._t0
        if self._prof is not None:
            self._prof.__exit__(exc_type, exc, tb)
        rec = {"kind": "span", "name": self._name,
               "t_ns": self._t0, "dur_ns": dur}
        if self._tags:
            rec.update(self._tags)
        self._tracer._emit(rec)
        return False


class Tracer:
    """Buffered jsonl tracer.  ``enabled=False`` makes every call a no-op
    (spans return one shared ``nullcontext`` — no allocation), which is the
    disabled-overhead story the telemetry gate measures."""

    def __init__(self, path: Optional[str] = None, *, enabled: bool = True,
                 profiler_annotations: bool = False):
        self.path = path
        self.enabled = enabled
        self.profiler_annotations = profiler_annotations
        self._buf: List[str] = []
        self._fh: Optional[IO[str]] = None

    # -- recording ---------------------------------------------------------

    def span(self, name: str, **tags):
        if not self.enabled:
            return _NULLCTX
        return _Span(self, name, tags)

    def event(self, name: str, **tags) -> None:
        if not self.enabled:
            return
        rec = {"kind": "event", "name": name, "t_ns": time.monotonic_ns()}
        rec.update(tags)
        self._emit(rec)

    def counter(self, name: str, value, step: Optional[int] = None,
                **tags) -> None:
        """A replayable counter sample.  Records WITH a step tag are
        deduplicated per (name, step) on replay — emit per-step quantities
        this way so elastic restarts cannot double-count; records without
        a step are summed as-is."""
        if not self.enabled:
            return
        rec: Dict[str, Any] = {"kind": "counter", "name": name,
                               "t_ns": time.monotonic_ns(), "value": value}
        if step is not None:
            rec["step"] = step
        rec.update(tags)
        self._emit(rec)

    def _emit(self, rec: Dict[str, Any]) -> None:
        self._buf.append(json.dumps(rec, sort_keys=True))

    # -- io ----------------------------------------------------------------

    def flush(self) -> None:
        """Tick-boundary flush: one write + fsync for everything buffered.
        Called once per train step / serve tick, never from jitted code."""
        if not self._buf or self.path is None:
            self._buf.clear() if self.path is None else None
            return
        if self._fh is None:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._fh = open(self.path, "a")
        self._fh.write("\n".join(self._buf) + "\n")
        self._buf.clear()
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def close(self) -> None:
        self.flush()
        if self._fh is not None:
            self._fh.close()
            self._fh = None


_disabled = Tracer(enabled=False)
_current: Tracer = _disabled


def get_tracer() -> Tracer:
    return _current


def set_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Install the process tracer (None restores the disabled singleton);
    returns the previous one."""
    global _current
    old = _current
    _current = tracer if tracer is not None else _disabled
    return old


# -- replay ----------------------------------------------------------------

def read_events(path: str) -> List[Dict[str, Any]]:
    """All records in file order.  Tolerates a truncated final line (the
    one write a SIGKILL can shear)."""
    out: List[Dict[str, Any]] = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return out


def replay_counters(path: str, up_to_step: Optional[int] = None
                    ) -> Dict[str, float]:
    """Reduce the event log to counter totals.

    Stepped records dedupe per (name, step) with last-occurrence-wins, so
    a run that restarted from a checkpoint and re-emitted steps already in
    the log replays to the same totals as an uninterrupted run.  Unstepped
    records are summed in file order.
    """
    stepped: Dict[Tuple[str, int], float] = {}
    flat: Dict[str, float] = {}
    for rec in read_events(path):
        if rec.get("kind") != "counter":
            continue
        name = rec["name"]
        step = rec.get("step")
        value = rec.get("value", 0)
        if step is None:
            flat[name] = flat.get(name, 0) + value
        else:
            if up_to_step is not None and step > up_to_step:
                continue
            stepped[(name, step)] = value
    totals = dict(flat)
    for (name, _), value in stepped.items():
        totals[name] = totals.get(name, 0) + value
    return totals
