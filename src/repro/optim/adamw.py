"""Sharded AdamW: the ZeRO-3 partitioned optimizer.

Every optimizer tensor lives on the *primary* parameter shard only — the
"K·M/P" row of the paper's Fig. 4 memory analysis.  Gradients arrive as
fp32 primary shards (already summed over the world by qgZ / reduce-scatter);
global-norm clipping needs one scalar psum because every device owns a
disjoint shard.

Memory layout choices (per-parameter bytes on each device's shard):
  * There is no separate bf16 parameter copy: the fp32 master IS the
    parameter buffer, and the ZeRO++ forward gather quantizes (qwZ) or
    casts (baseline) straight from it.  Saves 2 bytes/param vs the usual
    master+param split.
  * ``moments_dtype`` controls m/v storage.  fp32 (default, 4+4 B) for
    small models; bf16 (2+2 B) for the ≥70B configs where fp32 moments
    alone would not fit v5e's 16 GB HBM.  Update math is always fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array
PyTree = Dict[str, Array]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Union[float, Callable[[Array], Array]] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moments_dtype: jnp.dtype = jnp.float32   # fp32 | bf16 (large models)


def init_opt_state(params: PyTree,
                   cfg: AdamWConfig = AdamWConfig()) -> Dict[str, PyTree]:
    """params: fp32 master buffers (these ARE the trained parameters)."""
    zeros = lambda t: jax.tree.map(
        lambda p: jnp.zeros(p.shape, cfg.moments_dtype), t)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def global_grad_norm(grads: PyTree, dp_axes: Tuple[str, ...]) -> Array:
    local = sum(jnp.sum(g.astype(jnp.float32) ** 2)
                for g in jax.tree.leaves(grads))
    if dp_axes:
        local = lax.psum(local, dp_axes)  # shards are disjoint -> psum = global
    return jnp.sqrt(local)


def apply_update(
    grads: PyTree,
    params: PyTree,
    opt: Dict[str, PyTree],
    cfg: AdamWConfig,
    dp_axes: Tuple[str, ...] = (),
) -> Tuple[PyTree, Dict[str, PyTree], Dict[str, Array]]:
    """One AdamW step on the primary shards.

    Returns (new_params (fp32), new_opt, stats).
    """
    count = opt["count"] + 1
    lr = cfg.lr(count) if callable(cfg.lr) else jnp.float32(cfg.lr)

    gnorm = global_grad_norm(grads, dp_axes)
    scale = jnp.where(gnorm > cfg.grad_clip,
                      cfg.grad_clip / (gnorm + 1e-12), 1.0) \
        if cfg.grad_clip else jnp.float32(1.0)

    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * w
        w = w - lr * step
        return (m32.astype(cfg.moments_dtype), v32.astype(cfg.moments_dtype),
                w)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(opt["m"])
    flat_v = tdef.flatten_up_to(opt["v"])
    flat_w = tdef.flatten_up_to(params)
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v,
                                                 flat_w)]
    new_m = tdef.unflatten([o[0] for o in out])
    new_v = tdef.unflatten([o[1] for o in out])
    new_w = tdef.unflatten([o[2] for o in out])
    new_opt = {"m": new_m, "v": new_v, "count": count}
    return new_w, new_opt, {"grad_norm": gnorm, "lr": lr}
