"""LR schedules (callable on the fp32 step count)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.1):
    def sched(count):
        c = count.astype(jnp.float32)
        warm = peak * c / max(warmup, 1)
        prog = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(c < warmup, warm, cos)
    return sched


def constant(lr: float):
    return lambda count: jnp.float32(lr)
