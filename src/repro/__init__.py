"""repro — ZeRO++ (Wang et al., 2023) reproduced as a JAX/TPU training framework."""
__version__ = "0.1.0"
