"""Architecture config (see assignment block + cited source)."""
from repro.configs.base import ArchConfig


# --- paper's own GPT-style configs (for benchmarks) --------------------------
CONFIG_GPT_350M = ArchConfig(
    name="gpt-350m", family="dense", n_layers=24, d_model=1024, vocab=50304,
    pattern=("attn",), n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096,
    note="paper §5.4 convergence model")
gpt_350m = CONFIG_GPT_350M

CONFIG_GPT_18B = ArchConfig(
    name="gpt-18b", family="dense", n_layers=40, d_model=6144, vocab=50304,
    pattern=("attn",), n_heads=48, n_kv_heads=48, head_dim=128, d_ff=24576,
    note="paper §5.2 scalability model")
gpt_18b = CONFIG_GPT_18B
