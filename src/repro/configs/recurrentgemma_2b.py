"""Architecture config (see assignment block + cited source)."""
from repro.configs.base import ArchConfig


# --- hybrid -----------------------------------------------------------------
# RG-LRU + local attention, 1 attn : 2 recurrent [arXiv:2402.19427]
CONFIG_RECURRENTGEMMA_2B = ArchConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    vocab=256000, pattern=("rec", "rec", "local"), n_heads=10, n_kv_heads=1,
    head_dim=256, d_ff=7680, act="gelu", window=2048, rnn_width=2560,
    conv_width=4, long_context=True,
    note="window-bounded KV + O(1) recurrent state -> long_500k capable")
recurrentgemma_2b = CONFIG_RECURRENTGEMMA_2B
