"""Architecture config (see assignment block + cited source)."""
from repro.configs.base import ArchConfig


# GQA, RoPE [arXiv:2402.19173]
CONFIG_STARCODER2_3B = ArchConfig(
    name="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
    vocab=49152, pattern=("attn",), n_heads=24, n_kv_heads=2, head_dim=128,
    d_ff=12288, act="gelu", rope_theta=1e6)
starcoder2_3b = CONFIG_STARCODER2_3B
