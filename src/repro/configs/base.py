"""ArchConfig: static description of every supported architecture, plus the
assigned input-shape suite (train_4k / prefill_32k / decode_32k / long_500k).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    vocab: int
    # block pattern, tiled over n_layers.  kinds:
    #   attn   — global causal GQA + dense MLP
    #   local  — sliding-window GQA + dense MLP
    #   moe    — global causal GQA + MoE MLP
    #   ssd    — Mamba-2 block (no separate MLP)
    #   rec    — RG-LRU recurrent block + dense MLP
    pattern: Tuple[str, ...] = ("attn",)
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False              # Qwen2-VL 3-stream rotary
    logit_softcap: float = 0.0
    window: int = 0                  # sliding window for "local" layers
    # mlp
    d_ff: int = 0
    act: str = "silu"
    # moe
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_ff: int = 0                  # per-routed-expert hidden size
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # expert weights are gathered expert_chunks at a time (one zero_apply per
    # chunk): bounds the gathered-buffer working set — the analogue of
    # DeepSpeed's per-module gather granularity for fine-grained MoE.
    expert_chunks: int = 1
    # the unembedding is stored TRANSPOSED (V, d) and split into this many
    # vocab-row groups, gathered one at a time with a streaming log-sum-exp
    # across chunks: big-vocab heads (2.5 GB gathered for 152k x 8192)
    # otherwise dominate peak memory.  0 = auto (target <= 512 MB/chunk).
    unemb_chunks: int = 0
    # ssm (mamba-2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 128
    conv_width: int = 4
    # rg-lru
    rnn_width: int = 0
    # io
    embed_inputs: bool = False       # audio/vlm: frontend stub supplies embeddings
    pos_streams: int = 0             # 3 => M-RoPE position ids from the stub
    # capabilities
    long_context: bool = False       # may run the long_500k shape
    note: str = ""

    def __post_init__(self):
        assert self.n_layers >= len(self.pattern) or self.n_layers > 0

    @property
    def d_head(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def d_rnn(self) -> int:
        return self.rnn_width or self.d_model

    def reduced(self, **overrides) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        scale: Dict = dict(
            n_layers=max(len(self.pattern), 2) if len(self.pattern) > 1
            else min(self.n_layers, 2),
            d_model=64,
            vocab=128,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else 0,
            d_ff=96 if self.d_ff else 0,
            window=min(self.window, 8) if self.window else 0,
            n_experts=8 if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared=min(self.n_shared, 1),
            moe_ff=32 if self.moe_ff else 0,
            expert_chunks=2 if self.n_experts else 1,  # exercise chunked path
            unemb_chunks=2,                 # exercise streaming-LSE head
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=8 if self.ssm_state else 64,
            ssm_expand=2,
            ssm_chunk=4,
            rnn_width=64 if self.rnn_width else 0,
            name=self.name + "-reduced",
        )
        scale.update(overrides)
        return dataclasses.replace(self, **scale)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One cell of the (arch × shape) matrix."""
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_supported(arch: ArchConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and not arch.long_context:
        return False, ("pure full-attention architecture: 500k-token decode "
                       "requires sub-quadratic attention (see DESIGN.md §4)")
    return True, ""
