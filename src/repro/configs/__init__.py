"""Architecture registry: the 10 assigned architectures + paper GPT configs.

One module per assigned architecture (exact dims from the assignment block;
head_dim/pattern details from the cited model cards).  ``get_config`` is the
lookup used by --arch flags everywhere.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, shape_supported
from repro.configs.mamba2_130m import mamba2_130m
from repro.configs.recurrentgemma_2b import recurrentgemma_2b
from repro.configs.deepseek_moe_16b import deepseek_moe_16b
from repro.configs.qwen3_moe_235b_a22b import qwen3_moe_235b_a22b
from repro.configs.musicgen_large import musicgen_large
from repro.configs.qwen2_vl_72b import qwen2_vl_72b
from repro.configs.qwen1_5_110b import qwen1_5_110b
from repro.configs.qwen3_0_6b import qwen3_0_6b
from repro.configs.starcoder2_3b import starcoder2_3b
from repro.configs.gemma3_4b import gemma3_4b
from repro.configs.gpt_zeropp import gpt_350m, gpt_18b

_R: Dict[str, ArchConfig] = {c.name: c for c in [
    mamba2_130m, recurrentgemma_2b, deepseek_moe_16b, qwen3_moe_235b_a22b,
    musicgen_large, qwen2_vl_72b, qwen1_5_110b, qwen3_0_6b, starcoder2_3b,
    gemma3_4b, gpt_350m, gpt_18b,
]}


def get_config(name: str) -> ArchConfig:
    key = name.replace("_", "-")
    if key not in _R:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_R)}")
    return _R[key]


def list_archs(assigned_only: bool = False):
    out = sorted(_R)
    if assigned_only:
        out = [a for a in out if not a.startswith("gpt-")]
    return out


ASSIGNED = list_archs(assigned_only=True)
