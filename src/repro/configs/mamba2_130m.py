"""Architecture config (see assignment block + cited source)."""
from repro.configs.base import ArchConfig


# --- ssm ------------------------------------------------------------------
# SSD (state-space duality) [arXiv:2405.21060]
CONFIG_MAMBA2_130M = ArchConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768, vocab=50280,
    pattern=("ssd",), ssm_state=128, ssm_headdim=64, ssm_expand=2,
    ssm_groups=1, ssm_chunk=128, long_context=True,
    note="attention-free; decode state is O(1) in context length")
mamba2_130m = CONFIG_MAMBA2_130M
