"""Architecture config (see assignment block + cited source)."""
from repro.configs.base import ArchConfig


# --- audio ------------------------------------------------------------------
# decoder-only over EnCodec tokens [arXiv:2306.05284]; frontend stubbed
CONFIG_MUSICGEN_LARGE = ArchConfig(
    name="musicgen-large", family="audio", n_layers=48, d_model=2048,
    vocab=2048, pattern=("attn",), n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, embed_inputs=True,
    note="backbone only; EnCodec frame embeddings provided by input stub")
musicgen_large = CONFIG_MUSICGEN_LARGE
