"""Architecture config (see assignment block + cited source)."""
from repro.configs.base import ArchConfig


# --- vlm --------------------------------------------------------------------
# M-RoPE, dynamic resolution [arXiv:2409.12191]; patch frontend stubbed
CONFIG_QWEN2_VL_72B = ArchConfig(
    name="qwen2-vl-72b", family="vlm", n_layers=80, d_model=8192,
    vocab=152064, pattern=("attn",), n_heads=64, n_kv_heads=8, head_dim=128,
    qkv_bias=True, mrope=True, pos_streams=3, d_ff=29568, rope_theta=1e6,
    embed_inputs=True,
    note="backbone only; patch embeddings + (t,h,w) positions from stub")
qwen2_vl_72b = CONFIG_QWEN2_VL_72B
