"""Architecture config (see assignment block + cited source)."""
from repro.configs.base import ArchConfig


# 5:1 local:global, 128k context [hf:google/gemma-3-4b]
CONFIG_GEMMA3_4B = ArchConfig(
    name="gemma3-4b", family="dense", n_layers=34, d_model=2560,
    vocab=262144, pattern=("local",) * 5 + ("attn",), n_heads=8,
    n_kv_heads=4, head_dim=256, qk_norm=True, d_ff=10240, act="gelu",
    window=1024, rope_theta=1e6, long_context=True,
    note="5:1 local:global -> decode KV dominated by 1k-window ring buffers")
gemma3_4b = CONFIG_GEMMA3_4B
