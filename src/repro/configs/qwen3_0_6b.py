"""Architecture config (see assignment block + cited source)."""
from repro.configs.base import ArchConfig


# qk_norm, GQA [hf:Qwen/Qwen3-0.6B]
CONFIG_QWEN3_0_6B = ArchConfig(
    name="qwen3-0.6b", family="dense", n_layers=28, d_model=1024,
    vocab=151936, pattern=("attn",), n_heads=16, n_kv_heads=8, head_dim=128,
    qk_norm=True, d_ff=3072, rope_theta=1e6)
qwen3_0_6b = CONFIG_QWEN3_0_6B
