"""Architecture config (see assignment block + cited source)."""
from repro.configs.base import ArchConfig


# --- moe --------------------------------------------------------------------
# 2 shared + 64 routed top-6, fine-grained [arXiv:2401.06066]
CONFIG_DEEPSEEK_MOE_16B = ArchConfig(
    name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    vocab=102400, pattern=("moe",), n_heads=16, n_kv_heads=16, head_dim=128,
    n_experts=64, top_k=6, n_shared=2, moe_ff=1408, d_ff=1408,
    expert_chunks=4)
deepseek_moe_16b = CONFIG_DEEPSEEK_MOE_16B
