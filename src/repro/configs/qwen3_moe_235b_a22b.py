"""Architecture config (see assignment block + cited source)."""
from repro.configs.base import ArchConfig


# 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B scaled per assignment]
CONFIG_QWEN3_MOE_235B_A22B = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    vocab=151936, pattern=("moe",), n_heads=64, n_kv_heads=4, head_dim=128,
    qk_norm=True, n_experts=128, top_k=8, n_shared=0, moe_ff=1536, d_ff=1536,
    rope_theta=1e6, expert_chunks=8)
qwen3_moe_235b_a22b = CONFIG_QWEN3_MOE_235B_A22B
