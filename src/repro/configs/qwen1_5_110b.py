"""Architecture config (see assignment block + cited source)."""
from repro.configs.base import ArchConfig


# --- dense ------------------------------------------------------------------
# QKV bias [hf:Qwen/Qwen1.5-110B]
CONFIG_QWEN1_5_110B = ArchConfig(
    name="qwen1.5-110b", family="dense", n_layers=80, d_model=8192,
    vocab=152064, pattern=("attn",), n_heads=64, n_kv_heads=8, head_dim=128,
    qkv_bias=True, d_ff=49152, rope_theta=1e6)
qwen1_5_110b = CONFIG_QWEN1_5_110B
