"""HBM ledger: charge every persistent byte a policy implies, honestly.

The long-standing gap this closes: the depth-k prefetch ring of
``core/schedule.py`` keeps a ring of ``k`` gathered weight buffers in the
scan carry AND materializes one more copy at the read (``_ring_read``
returns a dynamic-index copy of the consumed slot), so a depth-k schedule
holds **k+1** live gathered buffers per scan — while the old analytic
memory model (``benchmarks/memory_model.py``) charged zero ring bytes and
``launch/dryrun.py`` only reported whatever the jaxpr walk happened to
see.  The resolver trades ring depth against this ledger's headroom
instead of OOMing at boot.

Line items (per device):

  master_params      fp32 master shard — IS the parameter buffer (4 B/param)
  adam_moments       two Adam moment shards (fp32: 8 B, bf16: 4 B /param)
  grad_shards        fp32 reduced-gradient shard live at the update
  hpz_secondary      bf16 secondary copy per hpZ group (2·M / |secondary|)
  ring_weights_*     (k+1) live gathered buffers per ring'd scan  <-- the gap
  ring_grads_bwd     backward's k-slot unreduced-gradient ring
  gathered_transient largest single-shot gathered buffer (embed/rem/unemb)
  activations        residual-stream saves under remat (coarse, documented)
  kv_pool            serve: the engine's paged KV slabs
  params_bf16        serve: the inference weight shard

Everything is analytic (no tracing, no devices) so the resolver can sweep
depths in microseconds; ``tests/test_tune.py`` pins the ring charge to a
hand-counted oracle and ``testing/checks.py`` cross-checks the buffer
counts against the live scan carries for prefetch 0..3.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

GB = 1 << 30
# v5e per-chip HBM — the default budget; keep in sync with
# launch/dryrun.py's hardware model.
HBM_BYTES = 16 * GB

_COMPUTE_BYTES = 2   # gathered weights / grads ride in bf16 (compute dtype)


@dataclasses.dataclass(frozen=True)
class LedgerLine:
    name: str
    bytes: int
    detail: str


@dataclasses.dataclass(frozen=True)
class HBMLedger:
    """An itemized per-device HBM bill against a budget."""

    lines: Tuple[LedgerLine, ...]
    budget_bytes: int
    # (scan name, live gathered-buffer count) — the (k+1) contract; the
    # live-buffer regression check compares these against the traced scan
    # carries, not just the byte totals.
    ring_buffers: Tuple[Tuple[str, int], ...] = ()

    @property
    def total(self) -> int:
        return sum(l.bytes for l in self.lines)

    @property
    def headroom(self) -> int:
        return self.budget_bytes - self.total

    @property
    def fits(self) -> bool:
        return self.total <= self.budget_bytes

    def line(self, name: str) -> int:
        for l in self.lines:
            if l.name == name:
                return l.bytes
        return 0

    def explain(self) -> str:
        out = ["HBM ledger (per device):"]
        for l in self.lines:
            out.append(f"  {l.name:<20s} {l.bytes / GB:7.3f} GiB  {l.detail}")
        verdict = "fits" if self.fits else "OVER BUDGET"
        out.append(f"  {'total':<20s} {self.total / GB:7.3f} GiB  "
                   f"of {self.budget_bytes / GB:.1f} GiB budget -> {verdict} "
                   f"(headroom {self.headroom / GB:+.3f} GiB)")
        return "\n".join(out)

    def as_dict(self) -> Dict:
        return {
            "budget_bytes": self.budget_bytes,
            "total_bytes": self.total,
            "headroom_bytes": self.headroom,
            "fits": self.fits,
            "ring_buffers": dict(self.ring_buffers),
            "lines": {l.name: l.bytes for l in self.lines},
        }


def _group_size(mesh_sizes: Mapping[str, int], axes: Sequence[str]) -> int:
    g = 1
    for a in axes:
        g *= int(mesh_sizes.get(a, 1))
    return g


def ring_lines(model) -> Tuple[List[LedgerLine], List[Tuple[str, int]]]:
    """The prefetch-ring charge: (k+1) live gathered buffers per scan.

    k ring slots live in the scan carry plus the one copy ``_ring_read``
    materializes for the consuming layer; prefetch=0 (synchronous) still
    holds the single gathered buffer it is computing with.  Backward adds
    a k-slot ring of unreduced per-layer gradients (compute dtype) on top
    of its own weight ring — charged separately so ``explain`` shows which
    phase owns the bytes.
    """
    z = model.zcfg
    lines: List[LedgerLine] = []
    rings: List[Tuple[str, int]] = []

    k = z.effective_prefetch(model.n_periods)
    P = model.period_spec.padded_size
    lines.append(LedgerLine(
        "ring_weights_layers", (k + 1) * _COMPUTE_BYTES * P,
        f"(k+1)={k + 1} live gathered layer buffers x {P:,} params bf16 "
        f"(k={k} ring slots + 1 read copy; layer scan)"))
    rings.append(("layers", k + 1))
    if k:
        lines.append(LedgerLine(
            "ring_grads_bwd", k * _COMPUTE_BYTES * P,
            f"backward k={k} unreduced per-layer gradient slots x "
            f"{P:,} params bf16"))

    if model.is_moe:
        kc = z.effective_prefetch(model.cfg.expert_chunks)
        E = model.expert_spec.padded_size
        lines.append(LedgerLine(
            "ring_weights_experts", (kc + 1) * _COMPUTE_BYTES * E,
            f"(k+1)={kc + 1} live gathered expert-chunk buffers x "
            f"{E:,} params bf16 (nested chunk scan)"))
        rings.append(("expert_chunks", kc + 1))
        if kc:
            lines.append(LedgerLine(
                "ring_grads_experts_bwd", kc * _COMPUTE_BYTES * E,
                f"backward kc={kc} unreduced expert-chunk gradient slots"))
    return lines, rings


def _transient_line(model) -> LedgerLine:
    """Largest single-shot (un-ring'd) gathered buffer."""
    singles = {"unemb_chunk": model.unemb_spec.padded_size,
               "head": model.head_spec.padded_size}
    if model.embed_spec is not None:
        singles["embed"] = model.embed_spec.padded_size
    if model.rem_spec is not None:
        singles["rem"] = model.rem_spec.padded_size
    worst = max(singles, key=lambda k: singles[k])
    return LedgerLine(
        "gathered_transient", _COMPUTE_BYTES * singles[worst],
        f"largest one-shot gathered buffer = {worst} "
        f"({singles[worst]:,} params bf16)")


def train_ledger(model, mesh_sizes: Mapping[str, int],
                 moments_itemsize: int = 4,
                 tokens_per_device: int = 2048,
                 accum: int = 1,
                 budget_bytes: int = HBM_BYTES) -> HBMLedger:
    """Per-device training HBM bill for ``model`` on a mesh of
    ``mesh_sizes`` ({axis: size}).

    ``moments_itemsize`` is the per-moment element size (4 = fp32, 2 =
    bf16); ``tokens_per_device`` the MICRObatch tokens one device holds
    activations for (already divided by ``accum``).
    """
    z = model.zcfg
    world = _group_size(mesh_sizes, mesh_sizes.keys())
    N = model.n_params()
    lines: List[LedgerLine] = [
        LedgerLine("master_params", 4 * N // world,
                   f"fp32 master shard: 4 B x {N / 1e9:.2f}B params "
                   f"/ {world} devices"),
        LedgerLine("adam_moments", 2 * moments_itemsize * N // world,
                   f"2 moment shards x {moments_itemsize} B/param"),
        LedgerLine("grad_shards", 4 * N // world,
                   "fp32 reduced-gradient shard live at the optimizer "
                   "update"),
    ]
    if z.hpz:
        sec = _group_size(mesh_sizes, z.secondary_axes)
        lines.append(LedgerLine(
            "hpz_secondary", _COMPUTE_BYTES * N // max(sec, 1),
            f"bf16 secondary copy over {z.secondary_axes} "
            f"(group size {sec})"))
    rlines, rings = ring_lines(model)
    lines += rlines
    lines.append(_transient_line(model))
    d = model.cfg.d_model
    layers = model.cfg.n_layers
    act = _COMPUTE_BYTES * tokens_per_device * d * (layers + 2)
    lines.append(LedgerLine(
        "activations", act,
        f"residual-stream saves under remat: {tokens_per_device} tok x "
        f"d_model {d} x ({layers}+2) layers bf16 x accum=1 microbatch "
        f"(accum={accum} shrinks tokens, not this term)"))
    return HBMLedger(tuple(lines), budget_bytes, tuple(rings))


def serve_ledger(model, mesh_sizes: Mapping[str, int],
                 n_slots: int, kv_len: int,
                 cache_itemsize: int = 2,
                 budget_bytes: int = HBM_BYTES,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 kv_axes: Sequence[str] = ()) -> HBMLedger:
    """Per-device serving HBM bill: bf16 weight shard + KV pool + rings.

    With ``page_size``/``n_pages`` set, the KV line charges the *paged*
    arena instead of the whole-slot slab: ``n_pages`` pages of
    ``page_size`` positions each, plus the host-side page table
    (``n_slots x kv_len/page_size`` int32 slot rows — charged even though
    it lives off-device, because the jitted step stages a copy per call).
    The arena shards only its within-page token dim over ``kv_axes`` and
    is replicated across every other mesh axis, so the per-device divisor
    is the kv-axes world, not the full world (matches
    ``serve.PagedKVPool`` / ``train/serve.paged_cache_specs``).
    """
    import numpy as np

    world = _group_size(mesh_sizes, mesh_sizes.keys())
    N = model.n_params()
    lines: List[LedgerLine] = [
        LedgerLine("params_bf16", _COMPUTE_BYTES * N // world,
                   f"bf16 inference weight shard / {world} devices"),
    ]
    import jax
    if page_size is not None:
        if kv_len % page_size:
            raise ValueError(f"kv_len {kv_len} % page_size {page_size} != 0")
        pages_per_slot = kv_len // page_size
        if n_pages is None:
            n_pages = n_slots * pages_per_slot
        page_bytes = sum(int(np.prod(l.shape)) * cache_itemsize
                         for l in jax.tree.leaves(
                             model.cache_shapes(1, page_size)))
        kv_world = _group_size(mesh_sizes, kv_axes)
        table_bytes = n_slots * pages_per_slot * 4
        lines.append(LedgerLine(
            "kv_pool",
            (n_pages * page_bytes) // kv_world + table_bytes,
            f"{n_pages} pages x {page_size} positions KV / {kv_world} "
            f"kv-axis devices + {n_slots}x{pages_per_slot} int32 page "
            f"table"))
    else:
        kv = model.cache_shapes(n_slots, kv_len)
        kv_bytes = sum(int(np.prod(l.shape)) * cache_itemsize
                       for l in jax.tree.leaves(kv))
        lines.append(LedgerLine(
            "kv_pool", kv_bytes // world,
            f"{n_slots} slots x {kv_len} positions KV / {world} devices"))
    rlines, rings = ring_lines(model)
    # inference scans ring the forward gathers only — no backward grads
    rlines = [l for l in rlines if "grads" not in l.name]
    rings = list(rings)
    lines += rlines
    lines.append(_transient_line(model))
    return HBMLedger(tuple(lines), budget_bytes, tuple(rings))
