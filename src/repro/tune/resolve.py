"""The single owner of ZeRO++ configuration resolution.

Every consumer — ``train/policy.py`` presets, ``launch/train.py --tune``,
``ServeEngine``, ``launch/dryrun.py`` — funnels through :func:`resolve`,
which turns (ArchConfig, mesh, probe profile, HBM budget) into one frozen
:class:`ResolvedPolicy`.  Decision order (DESIGN.md §9):

  1. variant    — the paper's ablation table sets the qwZ/hpZ/qgZ switches.
  2. hpZ        — preset placement (large-model secondary widening / off on
                  single-pod), then the probe veto: no measurably slower
                  inter tier => nothing for hpZ's memory to buy back.
  3. blocks     — qwZ/qgZ block sizes from the measured slow-tier
                  bandwidth (scarcer wire bytes => coarser blocks, fewer
                  scale bytes; plentiful bandwidth => finer blocks for
                  tighter quantization error).
  4. overrides  — explicit caller overrides win, always (ablations, tests).
  5. moments / accum — the preset memory rules (bf16 moments and
                  microbatching for large/active-heavy models).
  6. prefetch   — ``break_even_depth`` fed with the *measured* per-tier
                  latency/bandwidth, then walked DOWN until the HBM ledger
                  (which charges the (k+1) ring buffers) fits the budget.
                  Tighter budget can only lower depth — never raise it.
  7. backend    — kernel backend from the platform seam (pallas on TPU).

``mode="off"`` reproduces the static preset table bit-for-bit (no probe,
no ledger feedback) — that is what ``train/policy.make_policy`` wraps, so
every existing caller keeps byte-identical configs.  The tuner only ever
*selects* values the bit-exact depth-sweep checks already prove correct.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.zeropp import ZeroConfig
from repro.tune import memory as memory_lib
from repro.tune.probe import ProbeProfile, probe_mesh, static_profile

LARGE_PARAMS = 32e9

MODES = ("off", "static", "probe")

# Block-size thresholds (step 3): below _COARSE_BW the slow tier is so
# scarce that halving scale overhead (4 B per block) wins; above _FINE_BW
# wire bytes are cheap and finer blocks buy quantization accuracy.
_COARSE_BW = 16e9
_FINE_BW = 100e9


def count_params(arch) -> int:
    """Analytic parameter count (no sharding, no devices)."""
    from repro.models.model import Model
    return Model(arch, ZeroConfig.local(), world=1).n_params()


@dataclasses.dataclass(frozen=True)
class ResolvedPolicy:
    """One frozen, self-describing answer to 'how do we run this cell'."""

    zcfg: ZeroConfig
    moments_dtype: jnp.dtype
    n_params: int
    train_accum: int
    kernel_backend: str
    mode: str                       # off | static | probe
    note: str                       # preset note (make_policy parity)
    decisions: Tuple[str, ...]      # human-readable, in decision order
    ledger: Optional[memory_lib.HBMLedger] = None
    profile: Optional[ProbeProfile] = None

    def explain(self) -> str:
        out = [f"resolved policy (mode={self.mode}, "
               f"profile={self.profile.source if self.profile else 'none'}, "
               f"kernel_backend={self.kernel_backend}):"]
        for i, d in enumerate(self.decisions, 1):
            out.append(f"  {i}. {d}")
        if self.ledger is not None:
            out.append(self.ledger.explain())
        return "\n".join(out)

    def as_dict(self) -> Dict:
        """Flat summary for obs metrics / BENCH snapshots."""
        z = self.zcfg
        d = {
            "mode": self.mode,
            "kernel_backend": self.kernel_backend,
            "n_params": self.n_params,
            "train_accum": self.train_accum,
            "moments_dtype": jnp.dtype(self.moments_dtype).name,
            "qwz": z.qwz, "hpz": z.hpz, "qgz": z.qgz,
            "qwz_block": z.qwz_block, "qgz_block": z.qgz_block,
            "hpz_axes": list(z.secondary_axes) if z.hpz else None,
            "prefetch": z.prefetch,
            "profile_source": self.profile.source if self.profile else None,
            "decisions": list(self.decisions),
        }
        if self.ledger is not None:
            d["ledger"] = self.ledger.as_dict()
        return d


def _resolve_profile(mode: str, mesh, mesh_axes: Sequence[str],
                     mesh_sizes: Optional[Mapping[str, int]],
                     profile: Optional[ProbeProfile]) -> Optional[ProbeProfile]:
    if profile is not None:
        if mesh_sizes:
            return profile.for_mesh(tuple(mesh_axes),
                                    tuple(mesh_sizes[a] for a in mesh_axes))
        return profile
    if mode == "off":
        return None
    if mode == "probe":
        if mesh is None:
            raise ValueError("mode='probe' needs the live mesh")
        return probe_mesh(mesh)
    if mode == "static":
        shape = tuple(mesh_sizes[a] for a in mesh_axes) if mesh_sizes \
            else None
        return static_profile(tuple(mesh_axes), shape)
    raise ValueError(f"mode must be one of {MODES}, got {mode!r}")


def _break_even_depth(n_dev_params: float, tokens_dev: int, variant: str,
                      n_layers: int, prof: ProbeProfile,
                      intra_axis: str, inter_axes: Sequence[str]) -> int:
    """Depth from the ring step-time model with probed coefficients."""
    try:
        from benchmarks.throughput_model import break_even_depth
    except ImportError:     # repro deployed without the benchmarks tree
        return 1
    return break_even_depth(
        int(n_dev_params), tokens_dev, variant,
        slow_bw=prof.slow_bw(inter_axes),
        n_layers=max(n_layers, 2),
        latency=prof.coll_latency(),
        fast_bw=prof.fast_bw(intra_axis))


def resolve(
    arch,
    mesh_axes: Sequence[str],
    variant: str = "zeropp",       # zeropp | baseline | qwz | hpz | qgz
    *,
    mode: str = "off",
    mesh=None,
    mesh_sizes: Optional[Mapping[str, int]] = None,
    profile: Optional[ProbeProfile] = None,
    hbm_budget_bytes: int = memory_lib.HBM_BYTES,
    tokens_per_device: int = 2048,
    workload: str = "train",       # train | serve
    n_slots: int = 8,
    kv_len: int = 2048,
    overrides: Optional[Dict] = None,
) -> ResolvedPolicy:
    """Resolve every ZeRO++ knob for an (arch, mesh) cell — see module
    docstring for the decision order.

    ``mesh_sizes`` ({axis: size}) enables the HBM ledger (and the
    depth-vs-headroom trade); without it the resolver still runs but only
    the probe-informed decisions apply.  ``overrides`` are explicit
    ZeroConfig field overrides and always win.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    overrides = dict(overrides or {})
    mesh_axes = tuple(mesh_axes)
    if mesh_sizes is None and mesh is not None:
        mesh_sizes = dict(zip(mesh.axis_names,
                              (int(s) for s in mesh.devices.shape)))
    prof = _resolve_profile(mode, mesh, mesh_axes, mesh_sizes, profile)
    decisions = []

    n = count_params(arch)
    large = n >= LARGE_PARAMS
    multi_pod = "pod" in mesh_axes

    # -- 1. variant table ---------------------------------------------------
    on = dict(qwz=variant in ("zeropp", "qwz"),
              hpz=variant in ("zeropp", "hpz"),
              qgz=variant in ("zeropp", "qgz"))
    decisions.append(f"variant={variant}: qwz={on['qwz']} hpz={on['hpz']} "
                     f"qgz={on['qgz']} (paper ablation table)")

    # -- 2. hpZ placement ---------------------------------------------------
    hpz_axes: Optional[Tuple[str, ...]] = None
    note = ""
    if on["hpz"] and large:
        if multi_pod:
            hpz_axes = ("data", "model")   # secondary group = one pod
            note = (f"{n/1e9:.0f}B params: node-sized secondary copy "
                    f"(2M/16) exceeds 16 GB HBM; secondary group widened to "
                    f"one pod (2M/256) — kills cross-pod weight traffic")
        else:
            on["hpz"] = False
            note = (f"{n/1e9:.0f}B params on single-pod mesh: hpZ off "
                    f"(no slower tier to trade memory against; paper's "
                    f"Table 4 shows the same memory wall for MiCS)")
    if note:
        decisions.append(f"hpz preset: {note}")
    intra_axis = "model"
    if on["hpz"] and prof is not None:
        sec_axes = hpz_axes or (intra_axis,)
        inter = tuple(a for a in mesh_axes if a not in sec_axes)
        fast, slow = prof.fast_bw(intra_axis), prof.slow_bw(inter)
        if not inter or slow >= fast:
            on["hpz"] = False
            hpz_axes = None
            decisions.append(
                f"hpz probe veto: no inter tier slower than the fast tier "
                f"(slow {slow/1e9:.1f} GB/s >= fast {fast/1e9:.1f} GB/s) — "
                f"secondary copy would buy nothing")
        else:
            decisions.append(
                f"hpz on over {sec_axes}: probed inter tier "
                f"{slow/1e9:.1f} GB/s << fast {fast/1e9:.1f} GB/s")

    # -- 3. quant block sizes ----------------------------------------------
    qwz_block = qgz_block = 256
    if prof is not None:
        inter = tuple(a for a in mesh_axes if a != intra_axis)
        slow = prof.slow_bw(inter or mesh_axes)
        if slow < _COARSE_BW:
            qwz_block = qgz_block = 512
            decisions.append(
                f"blocks=512: slow tier {slow/1e9:.1f} GB/s < "
                f"{_COARSE_BW/1e9:.0f} GB/s — halve the per-block fp32 "
                f"scale overhead on the wire")
        elif slow >= _FINE_BW:
            qwz_block = qgz_block = 128
            decisions.append(
                f"blocks=128: slow tier {slow/1e9:.1f} GB/s >= "
                f"{_FINE_BW/1e9:.0f} GB/s — wire bytes are cheap, buy "
                f"quantization accuracy")
        else:
            decisions.append(
                f"blocks=256 (default): slow tier {slow/1e9:.1f} GB/s in "
                f"the balanced regime")

    kw = dict(
        qwz=on["qwz"], hpz=on["hpz"], qgz=on["qgz"],
        hpz_axes=hpz_axes,
        dp_axes=mesh_axes,
        intra_axis=intra_axis,
    )
    if prof is not None:
        kw.update(qwz_block=qwz_block, qgz_block=qgz_block)

    # -- 4. explicit overrides win -----------------------------------------
    if overrides:
        decisions.append(f"caller overrides: {sorted(overrides)}")
        kw.update(overrides)
    zcfg = ZeroConfig(**kw)

    # -- 5. moments dtype + accumulation (preset memory rules) -------------
    moments = jnp.bfloat16 if large else jnp.float32
    # microbatching keeps the >=70B-ACTIVE train cells inside v5e's 16 GB
    # (activation residuals scale with tokens/device x d_model).  Keyed on
    # ACTIVE params: a 235B MoE with 22B active has dense-4B-scale
    # activations and fits at accum=1 — and accum multiplies weight-gather
    # volume, so never use more than memory requires (§Perf cell C:
    # accum=4 cost 4.1x collective time for the same math).
    from repro.models.model import Model
    n_active = Model(arch, zcfg, world=1).n_active_params()
    accum = 2 if n_active >= 70e9 else 1
    if mode != "off":
        decisions.append(
            f"moments={'bf16' if large else 'fp32'}, accum={accum} "
            f"(preset memory rules: {n/1e9:.1f}B total, "
            f"{n_active/1e9:.1f}B active)")

    # -- 6. prefetch depth: break-even, then walk down into the budget -----
    ledger = None
    if prof is not None and mesh_sizes:
        world = 1
        for a in mesh_axes:
            world *= int(mesh_sizes[a])
        model = Model(arch, zcfg, world=world)
        micro_tokens = max(tokens_per_device // max(accum, 1), 1)

        def _ledger(depth: int) -> memory_lib.HBMLedger:
            m = model.with_prefetch(depth)
            if workload == "serve":
                return memory_lib.serve_ledger(
                    m, mesh_sizes, n_slots=n_slots, kv_len=kv_len,
                    budget_bytes=hbm_budget_bytes)
            return memory_lib.train_ledger(
                m, mesh_sizes, moments_itemsize=jnp.dtype(moments).itemsize,
                tokens_per_device=micro_tokens, accum=accum,
                budget_bytes=hbm_budget_bytes)

        if "prefetch" in overrides:
            depth = zcfg.prefetch
            decisions.append(f"prefetch={depth}: pinned by caller override")
        else:
            inter = tuple(a for a in mesh_axes if a != intra_axis)
            tok = n_slots if workload == "serve" else tokens_per_device
            depth = _break_even_depth(n / world, tok, variant,
                                      model.n_periods, prof, intra_axis,
                                      inter)
            decisions.append(
                f"prefetch break-even depth={depth}: ring model with "
                f"probed slow {prof.slow_bw(inter)/1e9:.1f} GB/s, "
                f"latency {prof.coll_latency()*1e6:.0f} us, "
                f"{model.n_periods} scan steps, {tok} tokens/dev")
            while depth > 0 and not _ledger(depth).fits:
                depth -= 1
            led = _ledger(depth)
            if depth != zcfg.prefetch or not led.fits:
                decisions.append(
                    f"prefetch={depth} after HBM ledger walk-down: "
                    f"(k+1) ring buffers charged against "
                    f"{hbm_budget_bytes / memory_lib.GB:.1f} GiB budget "
                    f"({'fits' if led.fits else 'still over at depth 0'})")
            zcfg = dataclasses.replace(zcfg, prefetch=depth)
        ledger = _ledger(zcfg.prefetch)

    # -- 7. kernel backend --------------------------------------------------
    from repro.kernels import platform
    kernel_backend = platform.resolve(None)
    if mode != "off":
        decisions.append(f"kernel_backend={kernel_backend} "
                         f"(platform seam, kernels/platform.py)")

    return ResolvedPolicy(
        zcfg=zcfg, moments_dtype=moments, n_params=n, train_accum=accum,
        kernel_backend=kernel_backend, mode=mode, note=note,
        decisions=tuple(decisions), ledger=ledger, profile=prof)
