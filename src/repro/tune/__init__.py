"""Boot-time self-tuning comm policy (DESIGN.md §9).

One owner for every ZeRO++ knob: probe the live mesh
(:mod:`repro.tune.probe`), charge HBM honestly — including the (k+1)
prefetch-ring buffers (:mod:`repro.tune.memory`) — and resolve the
configuration through a single deterministic decision list
(:mod:`repro.tune.resolve`).
"""
from repro.tune.memory import (GB, HBM_BYTES, HBMLedger, LedgerLine,
                               ring_lines, serve_ledger, train_ledger)
from repro.tune.probe import (STATIC_PROFILE_PATH, ProbeProfile, TierProfile,
                              probe_mesh, static_profile)
from repro.tune.resolve import (LARGE_PARAMS, MODES, ResolvedPolicy,
                                count_params, resolve)

__all__ = [
    "GB", "HBM_BYTES", "HBMLedger", "LedgerLine", "ring_lines",
    "serve_ledger", "train_ledger",
    "STATIC_PROFILE_PATH", "ProbeProfile", "TierProfile", "probe_mesh",
    "static_profile",
    "LARGE_PARAMS", "MODES", "ResolvedPolicy", "count_params", "resolve",
]
