"""Boot-time mesh probe: measure what the interconnect actually delivers.

ZeRO++'s knobs (qwZ/qgZ block sizes, hpZ placement, prefetch-ring depth)
only pay off when the tier bandwidths justify them, and those bandwidths
are a property of the *deployment*, not the code — frontier practice picks
them from measurements, not defaults.  This module is the measurement:

  * :func:`probe_mesh` times small REAL collectives per mesh axis (an
    all-gather and an all-to-all at 2-3 sizes each) on the live mesh and
    fits a per-tier ``t = latency + bytes / bandwidth`` model.
  * :func:`static_profile` loads the committed ``profiles/static_v5e.json``
    instead of timing — the deterministic ``--tune=static`` mode CI uses
    (timing on shared CI hosts is noise; the resolver must be reproducible).

The fitted :class:`ProbeProfile` is the only input the resolver
(``repro.tune.resolve``) accepts for interconnect numbers: nothing else in
the repo hard-codes a bandwidth into a *decision* (the analytic benchmark
constants remain as defaults for the paper-figure projections).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Optional, Sequence, Tuple

_PROFILE_DIR = os.path.join(os.path.dirname(__file__), "profiles")
STATIC_PROFILE_PATH = os.path.join(_PROFILE_DIR, "static_v5e.json")

# Fit clamps: a probe on simulated host devices can produce degenerate
# timings (zero-variance, negative slope); the resolver must still get a
# usable positive model out.
_MIN_BW = 1e6      # 1 MB/s floor
_MAX_BW = 1e15     # effectively-free tier (degenerate size-1 axes)


@dataclasses.dataclass(frozen=True)
class TierProfile:
    """Fitted alpha/beta collective cost model for one mesh-axis tier."""

    latency_s: float       # alpha: fixed per-collective cost
    bandwidth_Bps: float   # 1/beta: per-device wire bytes per second

    def time_s(self, wire_bytes: float) -> float:
        return self.latency_s + wire_bytes / self.bandwidth_Bps


@dataclasses.dataclass(frozen=True)
class ProbeProfile:
    """Per-tier collective cost model for one mesh.

    ``tiers`` maps each mesh axis name to its fitted :class:`TierProfile`.
    ``source`` records provenance ("probe" = timed on the live mesh,
    "static" = the committed CI profile) so resolved policies are
    self-describing.
    """

    source: str
    mesh_axes: Tuple[str, ...]
    mesh_shape: Tuple[int, ...]
    tiers: Dict[str, TierProfile]

    # -- resolver-facing queries -------------------------------------------
    def fast_bw(self, intra_axis: str = "model") -> float:
        """Bandwidth of the fast (intra) tier."""
        t = self.tiers.get(intra_axis)
        return t.bandwidth_Bps if t else _MAX_BW

    def slow_bw(self, inter_axes: Sequence[str] = ()) -> float:
        """Bandwidth of the slowest tier a collective over ``inter_axes``
        touches (the bottleneck link); all tiers when axes are omitted."""
        axes = tuple(inter_axes) or tuple(self.tiers)
        bws = [self.tiers[a].bandwidth_Bps for a in axes if a in self.tiers]
        return min(bws) if bws else _MAX_BW

    def coll_latency(self, axes: Sequence[str] = ()) -> float:
        """Per-collective fixed cost over ``axes`` (worst tier)."""
        names = tuple(axes) or tuple(self.tiers)
        lats = [self.tiers[a].latency_s for a in names if a in self.tiers]
        return max(lats) if lats else 0.0

    # -- (de)serialization --------------------------------------------------
    def to_json(self) -> Dict:
        return {
            "source": self.source,
            "mesh_axes": list(self.mesh_axes),
            "mesh_shape": list(self.mesh_shape),
            "tiers": {a: {"latency_s": t.latency_s,
                          "bandwidth_Bps": t.bandwidth_Bps}
                      for a, t in self.tiers.items()},
        }

    @classmethod
    def from_json(cls, d: Dict) -> "ProbeProfile":
        return cls(
            source=d["source"],
            mesh_axes=tuple(d["mesh_axes"]),
            mesh_shape=tuple(d["mesh_shape"]),
            tiers={a: TierProfile(float(t["latency_s"]),
                                  float(t["bandwidth_Bps"]))
                   for a, t in d["tiers"].items()},
        )

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "ProbeProfile":
        with open(path) as f:
            return cls.from_json(json.load(f))

    def for_mesh(self, mesh_axes: Sequence[str],
                 mesh_shape: Sequence[int]) -> "ProbeProfile":
        """Re-key this profile onto another mesh's axes.

        Axes present in ``tiers`` keep their numbers; unknown axis names
        fall back to the 'data' tier (the mid interconnect) so a test mesh
        with exotic axis names still resolves.  Size-1 axes carry no
        traffic and get the free tier.
        """
        fallback = self.tiers.get("data") or next(iter(self.tiers.values()))
        tiers = {}
        for a, g in zip(mesh_axes, mesh_shape):
            if g <= 1:
                tiers[a] = TierProfile(0.0, _MAX_BW)
            else:
                tiers[a] = self.tiers.get(a, fallback)
        return ProbeProfile(self.source, tuple(mesh_axes), tuple(mesh_shape),
                            tiers)


def static_profile(mesh_axes: Sequence[str] = ("pod", "data", "model"),
                   mesh_shape: Optional[Sequence[int]] = None,
                   path: str = STATIC_PROFILE_PATH) -> ProbeProfile:
    """The committed deterministic profile, re-keyed for ``mesh_axes``."""
    base = ProbeProfile.load(path)
    if mesh_shape is None:
        # unknown sizes: assume every named axis is populated (size 2 is
        # enough to keep it off the free tier)
        mesh_shape = tuple(2 for _ in mesh_axes)
    return base.for_mesh(tuple(mesh_axes), tuple(mesh_shape))


# ---------------------------------------------------------------------------
# live probe
# ---------------------------------------------------------------------------

def _fit(points: Sequence[Tuple[float, float]]) -> Tuple[float, float]:
    """Least-squares ``t = alpha + bytes/bw`` over (wire_bytes, seconds)."""
    n = len(points)
    mx = sum(p[0] for p in points) / n
    mt = sum(p[1] for p in points) / n
    var = sum((x - mx) ** 2 for x, _ in points)
    slope = (sum((x - mx) * (t - mt) for x, t in points) / var) if var else 0.0
    slope = max(slope, 1.0 / _MAX_BW)
    alpha = max(mt - slope * mx, 0.0)
    bw = min(max(1.0 / slope, _MIN_BW), _MAX_BW)
    return alpha, bw


def _time_collective(mesh, axis: str, n_local: int, iters: int,
                     kind: str) -> float:
    """Best-of-``iters`` wall time of one small collective over ``axis``."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map

    g = int(dict(zip(mesh.axis_names, mesh.devices.shape))[axis])
    if kind == "gather":
        def body(x):
            return lax.all_gather(x, axis, tiled=True)
        x = jnp.ones((g * n_local,), jnp.bfloat16)
        in_specs, out_specs = P(axis), P()
    else:  # all_to_all: local (g, n_local) block, same wire volume as gather
        def body(x):
            return lax.all_to_all(x, axis, split_axis=0, concat_axis=0)
        x = jnp.ones((g * g, n_local), jnp.bfloat16)
        in_specs, out_specs = P(axis), P(axis)
    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs))
    jax.block_until_ready(fn(x))   # compile + warm up outside the clock
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        best = min(best, time.perf_counter() - t0)
    return best


def probe_mesh(mesh, sizes: Sequence[int] = (1 << 13, 1 << 15, 1 << 17),
               iters: int = 2) -> ProbeProfile:
    """Time small real collectives per mesh axis and fit per-tier costs.

    For every axis of size > 1 this times an all-gather and an all-to-all
    at each of ``sizes`` local elements (bf16) and least-squares-fits
    ``t = latency + wire_bytes / bandwidth``.  Size-1 axes carry no
    traffic and get the free tier.  Cheap by construction: the largest
    default message is 256 KiB per device.
    """
    names = tuple(mesh.axis_names)
    shape = tuple(int(s) for s in mesh.devices.shape)
    tiers: Dict[str, TierProfile] = {}
    for axis, g in zip(names, shape):
        if g <= 1:
            tiers[axis] = TierProfile(0.0, _MAX_BW)
            continue
        pts = []
        for n_local in sizes:
            wire = 2.0 * n_local * (g - 1)   # bf16, per device, both kinds
            pts.append((wire, _time_collective(mesh, axis, n_local, iters,
                                               "gather")))
            pts.append((wire, _time_collective(mesh, axis, n_local, iters,
                                               "a2a")))
        alpha, bw = _fit(pts)
        tiers[axis] = TierProfile(alpha, bw)
    return ProbeProfile("probe", names, shape, tiers)
