"""Multi-device correctness checks, executed via testing.subproc.

Each ``check_*`` function builds a small mesh out of however many host
devices the subprocess was launched with, runs ZeRO++ collectives, and
asserts against single-collective oracles.  They are plain callables so the
benchmark harness can reuse them.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import collectives as cl
from repro.core.compat import make_mesh, shard_map, auto_axis_types
from repro.core.quant import QuantConfig, quantize_blockwise, dequantize_blockwise


def _mesh2(data: int = None, model: int = 2):
    n = jax.device_count()
    data = data or n // model
    assert data * model == n, f"need data*model == {n}"
    return make_mesh((data, model), ("data", "model"),
                     axis_types=auto_axis_types(2))


def _mesh3(pod: int = 2, model: int = 2):
    n = jax.device_count()
    data = n // (pod * model)
    assert pod * data * model == n
    return make_mesh((pod, data, model), ("pod", "data", "model"),
                     axis_types=auto_axis_types(3))


# ---------------------------------------------------------------------------
# qgZ == reduce-scatter oracle (up to INT4 quantization error)
# ---------------------------------------------------------------------------

def _qgz_vs_oracle(mesh, intra_axis, inter_axes, all_axes, bits, block, n_per_dev):
    world = int(np.prod(list(mesh.shape.values())))
    rng = np.random.default_rng(0)
    g = rng.normal(size=(world * n_per_dev,)).astype(np.float32)
    cfg = QuantConfig(bits=bits, block_size=block)

    f_qgz = jax.jit(shard_map(
        lambda x: cl.qgz_reduce_scatter(x, intra_axis, inter_axes, cfg),
        mesh=mesh, in_specs=P(all_axes), out_specs=P(all_axes)))
    f_ora = jax.jit(shard_map(
        lambda x: cl.baseline_reduce_scatter(x.astype(jnp.float32), all_axes),
        mesh=mesh, in_specs=P(all_axes), out_specs=P(all_axes)))

    got = np.asarray(f_qgz(jnp.asarray(g)))
    want = np.asarray(f_ora(jnp.asarray(g)))

    # error bound: each of the two quant steps contributes <= scale/2 per
    # element; intra stage sums X quantized slices, inter stage sums Y
    # requantized partials whose magnitude grew by ~X.
    X = mesh.shape[intra_axis]
    Y = world // X
    amax = np.abs(g).max()
    qmax = 7.0 if bits == 4 else 127.0
    bound = (X * (amax / qmax) / 2) + Y * (X * amax / qmax) / 2
    err = np.abs(got - want).max()
    assert err <= bound * 1.1 + 1e-6, f"qgz err {err} > bound {bound}"
    # correlation ~1 (placement breakage would give ~0); exact placement is
    # separately proven by check_qgz_exact_when_representable
    c = np.corrcoef(got, want)[0, 1]
    assert c > 0.97, f"qgz placement broken, corr={c}"
    return err


def check_qgz_matches_reduce_scatter():
    mesh = _mesh2(model=2)
    _qgz_vs_oracle(mesh, "model", ("data",), ("data", "model"), 4, 64, 64 * 8)
    _qgz_vs_oracle(mesh, "model", ("data",), ("data", "model"), 8, 32, 32 * 8)


def check_qgz_multipod():
    mesh = _mesh3(pod=2, model=2)
    _qgz_vs_oracle(mesh, "model", ("pod", "data"), ("pod", "data", "model"),
                   4, 64, 64 * 8)


def check_qgz_exact_when_representable():
    """Placement/reordering correctness isolated from quantization error.

    Every device's local gradient is (rank+1)·P for a shared integer pattern
    P whose per-block absmax is exactly 7.  Then every block seen by either
    quantization stage is (integer)·P, its scale is that integer, and
    quantization is the identity — so qgZ must match reduce-scatter EXACTLY.
    Any slice-reordering bug scrambles P and fails loudly.
    """
    mesh = _mesh2(model=2)
    world = jax.device_count()
    cfg = QuantConfig(bits=4, block_size=32)
    n_per_dev = world * cfg.block_size  # L = block_size per destination
    rng = np.random.default_rng(1)
    pattern = rng.integers(-7, 8, size=(n_per_dev,)).astype(np.float32)
    pattern.reshape(-1, cfg.block_size)[:, 0] = 7.0  # pin block absmax
    ranks = (np.arange(world, dtype=np.float32) + 1.0)[:, None]
    g = (ranks * pattern[None, :]).reshape(-1)  # device d shard = (d+1)*P

    f_qgz = jax.jit(shard_map(
        lambda x: cl.qgz_reduce_scatter(x, "model", ("data",), cfg),
        mesh=mesh, in_specs=P(("data", "model")), out_specs=P(("data", "model"))))
    f_ora = jax.jit(shard_map(
        lambda x: cl.baseline_reduce_scatter(x, ("data", "model")),
        mesh=mesh, in_specs=P(("data", "model")), out_specs=P(("data", "model"))))
    got = np.asarray(f_qgz(jnp.asarray(g)))
    want = np.asarray(f_ora(jnp.asarray(g)))
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-3)


def check_qgz_1hop_and_ring():
    mesh = _mesh2(model=2)
    world = jax.device_count()
    cfg = QuantConfig(bits=8, block_size=32)
    rng = np.random.default_rng(2)
    g = rng.normal(size=(world * 32 * world,)).astype(np.float32)
    spec = P(("data", "model"))
    f1 = jax.jit(shard_map(lambda x: cl.qgz_reduce_scatter_1hop(x, ("data", "model"), cfg),
                           mesh=mesh, in_specs=spec, out_specs=spec))
    fr = jax.jit(shard_map(lambda x: cl.qgz_quantized_ring_reduce_scatter(x, ("data", "model"), cfg),
                           mesh=mesh, in_specs=spec, out_specs=spec))
    fo = jax.jit(shard_map(lambda x: cl.baseline_reduce_scatter(x, ("data", "model")),
                           mesh=mesh, in_specs=spec, out_specs=spec))
    want = np.asarray(fo(jnp.asarray(g)))
    got1 = np.asarray(f1(jnp.asarray(g)))
    gotr = np.asarray(fr(jnp.asarray(g)))
    amax = np.abs(g).max()
    assert np.abs(got1 - want).max() < world * amax / 127, "1-hop wrong"
    # ring compounds error once per hop -> looser bound, but placement exact
    assert np.corrcoef(gotr, want)[0, 1] > 0.99, "ring placement broken"
    e1 = np.abs(got1 - want).max()
    er = np.abs(gotr - want).max()
    assert er >= e1 * 0.5, (
        f"expected ring error ({er}) to be no better than 1-hop ({e1})")


# ---------------------------------------------------------------------------
# qwZ / hpZ
# ---------------------------------------------------------------------------

def check_qwz_all_gather():
    mesh = _mesh2(model=2)
    world = jax.device_count()
    cfg = QuantConfig(bits=8, block_size=64)
    rng = np.random.default_rng(3)
    w = (rng.normal(size=(world * 256,)) * 0.02).astype(np.float32)
    spec = P(("data", "model"))
    f = jax.jit(shard_map(
        lambda x: cl.qwz_all_gather(x, ("data", "model"), cfg, out_dtype=jnp.float32),
        mesh=mesh, in_specs=spec, out_specs=P(None), check_vma=False))
    got = np.asarray(f(jnp.asarray(w)))
    scale_bound = np.abs(w).max() / 127.0
    assert got.shape == w.shape
    assert np.abs(got - w).max() <= scale_bound * 0.51 + 1e-8
    # blocked must beat non-blocked on heterogeneous-scale data (Fig. 2)
    w2 = w.copy()
    w2[: world * 8] *= 100.0  # outlier block
    fn = jax.jit(shard_map(
        lambda x: cl.qwz_all_gather(x, ("data", "model"), cfg,
                                    out_dtype=jnp.float32, blocked=False),
        mesh=mesh, in_specs=spec, out_specs=P(None), check_vma=False))
    eb = np.abs(np.asarray(f(jnp.asarray(w2))) - w2).max()
    en = np.abs(np.asarray(fn(jnp.asarray(w2))) - w2).max()
    assert eb < en, f"blocked ({eb}) should beat non-blocked ({en})"


def check_hpz_roundtrip():
    """fwd global gather -> slice secondary -> intra-only gather == original."""
    mesh = _mesh2(model=2)
    world = jax.device_count()
    rng = np.random.default_rng(4)
    w = rng.normal(size=(world * 64,)).astype(np.float32)
    spec = P(("data", "model"))

    def f(shard):
        full = cl.baseline_all_gather(shard, ("data", "model"))
        sec = cl.slice_secondary(full, "model")
        full2 = cl.hpz_all_gather(sec, "model")
        return full2

    got = np.asarray(jax.jit(shard_map(f, mesh=mesh, in_specs=spec,
                                       out_specs=P(None),
                                       check_vma=False))(jnp.asarray(w)))
    np.testing.assert_allclose(got, w, rtol=0, atol=0)


ALL_CHECKS = [n for n in dir() if n.startswith("check_")]


# ---------------------------------------------------------------------------
# ZeRO++ engine: distributed grads == single-device grads
# ---------------------------------------------------------------------------

def _engine_setup():
    from repro.core.zeropp import ZeroConfig, zero_apply
    from repro.core.partition import ParamSpec

    d_in, d_h = 16, 32
    spec = ParamSpec((("w1", (d_in, d_h)), ("w2", (d_h, d_in))))

    def layer_f(wflat, x):
        w = spec.unpack(wflat.astype(jnp.float32))
        h = jnp.tanh(x @ w["w1"])
        return x + h @ w["w2"]

    def loss_of(apply_fn, pshard, x, n_global):
        h = apply_fn(pshard, x)
        return jnp.sum(h ** 2) / n_global

    return spec, layer_f, loss_of


def _engine_grads(mesh, zcfg, w_flat, x, spec, layer_f, loss_of):
    from repro.core.zeropp import zero_apply
    world = int(np.prod(list(mesh.shape.values())))
    n_global = x.shape[0] * x.shape[1]

    def step(pshard, xs):
        ap = zero_apply(layer_f, zcfg)
        def lf(p):
            return loss_of(ap, p, xs, n_global)
        l, g = jax.value_and_grad(lf)(pshard)
        return lax.psum(l, zcfg.dp_axes), g

    fstep = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(P(("data", "model")), P(("data", "model"), None, None)),
        out_specs=(P(), P(("data", "model")))))
    return fstep(w_flat, x)


def check_engine_baseline_matches_local():
    """ZeRO-3 baseline engine grads == single-device jax.grad exactly
    (fp32 end-to-end, bf16 reduce disabled via reduce_dtype=f32)."""
    from repro.core.zeropp import ZeroConfig
    mesh = _mesh2(model=2)
    world = jax.device_count()
    spec, layer_f, loss_of = _engine_setup()
    align = world * 2
    padded = ((spec.size + align - 1) // align) * align
    spec = spec.with_align(align)

    rng = np.random.default_rng(0)
    w = (rng.normal(size=(padded,)) * 0.3).astype(np.float32)
    x = rng.normal(size=(world, 4, 16)).astype(np.float32)

    zcfg = ZeroConfig.baseline(param_dtype=jnp.float32,
                               compute_dtype=jnp.float32,
                               reduce_dtype=jnp.float32)
    l_d, g_d = _engine_grads(mesh, zcfg, jnp.asarray(w), jnp.asarray(x),
                             spec, layer_f, loss_of)

    # single-device oracle
    def local_loss(wf):
        h = layer_f(wf, jnp.asarray(x.reshape(-1, 16)))
        return jnp.sum(h ** 2) / (world * 4)
    l_o, g_o = jax.value_and_grad(local_loss)(jnp.asarray(w))
    np.testing.assert_allclose(float(l_d), float(l_o), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g_d), np.asarray(g_o),
                               rtol=2e-4, atol=2e-5)


def check_engine_zeropp_close_to_local():
    """Full ZeRO++ (qwZ int8 + hpZ + qgZ int4) grads are close to exact
    grads: relative L2 error small, structure preserved."""
    from repro.core.zeropp import ZeroConfig
    mesh = _mesh2(model=2)
    world = jax.device_count()
    spec, layer_f, loss_of = _engine_setup()
    align = world * 64
    padded = ((spec.size + align - 1) // align) * align
    spec = spec.with_align(align)

    rng = np.random.default_rng(1)
    w = (rng.normal(size=(padded,)) * 0.3).astype(np.float32)
    x = rng.normal(size=(world, 4, 16)).astype(np.float32)

    zcfg = ZeroConfig(qwz_block=64, qgz_block=64,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    l_d, g_d = _engine_grads(mesh, zcfg, jnp.asarray(w), jnp.asarray(x),
                             spec, layer_f, loss_of)

    def local_loss(wf):
        h = layer_f(wf, jnp.asarray(x.reshape(-1, 16)))
        return jnp.sum(h ** 2) / (world * 4)
    l_o, g_o = jax.value_and_grad(local_loss)(jnp.asarray(w))

    # loss uses int8-quantized weights -> close but not exact
    assert abs(float(l_d) - float(l_o)) / abs(float(l_o)) < 0.05
    gd, go = np.asarray(g_d), np.asarray(g_o)
    rel = np.linalg.norm(gd - go) / (np.linalg.norm(go) + 1e-9)
    assert rel < 0.2, f"zero++ grad rel err {rel}"
    # direction must agree strongly (what matters for SGD)
    cos = (gd * go).sum() / (np.linalg.norm(gd) * np.linalg.norm(go) + 1e-9)
    assert cos > 0.98, f"cosine {cos}"


def check_engine_hpz_consistency():
    """hpZ on vs off (with qwZ+qgZ off) must give IDENTICAL loss and grads:
    the secondary gather must reconstruct exactly the forward weights."""
    from repro.core.zeropp import ZeroConfig
    mesh = _mesh2(model=2)
    world = jax.device_count()
    spec, layer_f, loss_of = _engine_setup()
    align = world * 2
    padded = ((spec.size + align - 1) // align) * align
    rng = np.random.default_rng(2)
    w = (rng.normal(size=(padded,)) * 0.3).astype(np.float32)
    x = rng.normal(size=(world, 4, 16)).astype(np.float32)

    common = dict(qwz=False, qgz=False, param_dtype=jnp.float32,
                  compute_dtype=jnp.float32, reduce_dtype=jnp.float32)
    l1, g1 = _engine_grads(mesh, ZeroConfig(hpz=True, **common),
                           jnp.asarray(w), jnp.asarray(x), spec, layer_f, loss_of)
    l2, g2 = _engine_grads(mesh, ZeroConfig(hpz=False, **common),
                           jnp.asarray(w), jnp.asarray(x), spec, layer_f, loss_of)
    np.testing.assert_allclose(float(l1), float(l2), rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# system-level checks: trainer / serve / checkpoint / dry-run machinery
# ---------------------------------------------------------------------------

def _train_setup(mesh_shape=(4, 2), arch_name="gpt-350m", variant="zeropp",
                 batch=16, seq=64, accum=1):
    from repro.launch.train import build_everything
    return build_everything(arch_name, mesh_shape, variant, True, batch,
                            seq, 3e-3, accum=accum)


def _run_steps(mesh, arch, model, opt_cfg, ts, lm, steps, batch, start=0,
               params=None, opt=None):
    import jax
    from repro.data.synthetic import make_batch
    from repro.train.trainer import init_state, place_batch
    if params is None:
        params, opt = init_state(model, mesh, opt_cfg, jax.random.PRNGKey(0))
    losses = []
    for i in range(start, start + steps):
        host = make_batch(arch, lm, i, batch)
        b = place_batch(host, mesh, ts.in_specs[2])
        params, opt, metrics = ts.fn(params, opt, b)
        losses.append(float(metrics["loss"]))
    return params, opt, losses


def check_trainer_loss_decreases():
    """ZeRO++ end-to-end training on 8 simulated devices learns."""
    env = _train_setup()
    mesh, arch, model, opt_cfg, ts, lm = env
    _, _, losses = _run_steps(mesh, arch, model, opt_cfg, ts, lm, 8, 16)
    assert losses[-1] < losses[0] * 0.9, losses


def check_trainer_zeropp_tracks_baseline():
    """ZeRO++ loss curve stays close to the ZeRO-3 baseline curve (paper
    Fig. 14 in miniature)."""
    lcurves = {}
    for variant in ("baseline", "zeropp"):
        mesh, arch, model, opt_cfg, ts, lm = _train_setup(variant=variant)
        _, _, losses = _run_steps(mesh, arch, model, opt_cfg, ts, lm, 8, 16)
        lcurves[variant] = losses
    import numpy as np
    b = np.array(lcurves["baseline"])
    z = np.array(lcurves["zeropp"])
    rel = np.abs(b - z) / np.abs(b)
    assert rel.max() < 0.05, (b, z)


def check_trainer_grad_accumulation():
    """accum=2 with half microbatches ~= single step with full batch."""
    import numpy as np
    mesh, arch, model, opt_cfg, ts1, lm = _train_setup(
        variant="baseline", batch=16, accum=1)
    _, _, l1 = _run_steps(mesh, arch, model, opt_cfg, ts1, lm, 4, 16)

    from repro.train import trainer as trainer_lib
    ts2 = trainer_lib.build_train_step(model, mesh, opt_cfg, accum=2,
                                       global_batch=8)
    import jax
    from repro.data.synthetic import make_batch
    from repro.train.trainer import init_state, place_batch
    params, opt = init_state(model, mesh, opt_cfg, jax.random.PRNGKey(0))
    l2 = []
    for i in range(4):
        host = make_batch(arch, lm, i, 16)
        host = {k: v.reshape((2, 8) + v.shape[1:]) for k, v in host.items()}
        b = place_batch(host, mesh, ts2.in_specs[2])
        params, opt, metrics = ts2.fn(params, opt, b)
        l2.append(float(metrics["loss"]))
    rel = np.abs(np.array(l1) - np.array(l2)) / np.abs(np.array(l1))
    assert rel.max() < 0.02, (l1, l2)


def check_checkpoint_elastic_restart():
    """Save on world=8, restore on world=4: training continues and the
    restored loss matches the uninterrupted curve closely."""
    import tempfile
    import numpy as np
    import jax
    from repro.launch.train import restore_ckpt, save_ckpt
    from repro.train.state import ZeroState

    d = tempfile.mkdtemp(prefix="ckpt_elastic_")
    mesh8, arch, model8, opt_cfg, ts8, lm = _train_setup(mesh_shape=(4, 2))
    p8, o8, l_first = _run_steps(mesh8, arch, model8, opt_cfg, ts8, lm, 3, 16)
    save_ckpt(d, 3, ZeroState(model8, mesh8, opt_cfg, params=p8, opt=o8),
              {"world": 8})
    # uninterrupted reference: continue to step 5 on the same mesh
    _, _, l_ref = _run_steps(mesh8, arch, model8, opt_cfg, ts8, lm, 2, 16,
                             start=3, params=p8, opt=o8)

    # elastic: restore on a 2x2 mesh (uses 4 of the 8 devices)
    mesh4, arch4, model4, opt_cfg4, ts4, lm4 = _train_setup(mesh_shape=(2, 2))
    got = restore_ckpt(d, model4, mesh4, opt_cfg4)
    assert got is not None
    step_i, p4, o4, meta = got
    assert step_i == 3 and meta["world"] == 8
    _, _, l_new = _run_steps(mesh4, arch4, model4, opt_cfg4, ts4, lm4, 2, 16,
                             start=3, params=p4, opt=o4)
    rel = np.abs(np.array(l_ref) - np.array(l_new)) / np.abs(np.array(l_ref))
    assert rel.max() < 0.02, (l_ref, l_new)


# ---------------------------------------------------------------------------
# ZeroState subsystem: per-shard / quantized / elastic checkpointing
# ---------------------------------------------------------------------------

def _logical_equal(got: "np.ndarray", want: "np.ndarray"):
    """Bit-exact over the common (logical + shorter padding) trailing
    prefix; anything past it must be zero padding on both sides."""
    import numpy as np
    n = min(got.shape[-1], want.shape[-1])
    np.testing.assert_array_equal(got[..., :n], want[..., :n])
    if got.shape[-1] > n:
        assert not np.asarray(got[..., n:]).any()
    if want.shape[-1] > n:
        assert not np.asarray(want[..., n:]).any()


def check_state_elastic_restore():
    """Per-shard fp32 save at world=8, elastic restore at world=4 AND
    world=2 (three different paddings/alignments):

      * restored buffers are bit-exact against the saved state over the
        logical region, with zero padding beyond it;
      * one train step from the checkpoint is BIT-EXACT against the same
        step from a direct in-memory reshard of the world-8 state (the
        checkpoint roundtrip adds nothing);
      * the loss curve continues the uninterrupted world-8 curve closely
        (worlds differ, so reduction orders — not the state — differ).
    """
    import tempfile
    import numpy as np
    import jax
    from repro.data.synthetic import make_batch
    from repro.train.state import ZeroState, read_manifest
    from repro.train.trainer import place_batch

    d = tempfile.mkdtemp(prefix="ckpt_state_elastic_")
    mesh8, arch, model8, opt_cfg, ts8, lm = _train_setup(mesh_shape=(4, 2))
    p8, o8, _ = _run_steps(mesh8, arch, model8, opt_cfg, ts8, lm, 3, 16)
    p8_host = jax.device_get(p8)      # GLOBAL host state: the oracle input
    o8_host = jax.device_get(o8)
    path = ZeroState(model8, mesh8, opt_cfg, params=p8, opt=o8).save(
        d, 3, meta={"world": 8})
    man = read_manifest(path)
    assert man["world"] == 8 and man["format"] == "fp32"
    assert man["step"] == 3 and "blocks" in man["param_layout"]
    # uninterrupted reference (donates p8/o8 — everything saved above)
    _, _, l_ref = _run_steps(mesh8, arch, model8, opt_cfg, ts8, lm, 2, 16,
                             start=3, params=p8, opt=o8)

    for mesh_shape in ((2, 2), (1, 2)):
        meshW, archW, modelW, opt_cfgW, tsW, lmW = _train_setup(
            mesh_shape=mesh_shape)
        stW = ZeroState.restore(modelW, meshW, opt_cfgW, d)
        assert stW is not None and stW.step == 3
        assert stW.meta["world"] == 8
        for k, arr in stW.params.items():
            _logical_equal(np.asarray(jax.device_get(arr)), p8_host[k])
        for mom in ("m", "v"):
            for k, arr in stW.opt[mom].items():
                _logical_equal(np.asarray(jax.device_get(arr)),
                               o8_host[mom][k])

        # oracle: the same world-8 state resharded in memory (no files)
        stD = ZeroState(modelW, meshW, opt_cfgW).place_global(p8_host,
                                                              o8_host)
        host = make_batch(archW, lmW, 3, 16)
        bW = place_batch(host, meshW, tsW.in_specs[2])
        pa, oa, ma = tsW.fn(stW.params, stW.opt, bW)
        pb, ob, mb = tsW.fn(stD.params, stD.opt, bW)
        assert float(ma["loss"]) == float(mb["loss"]), mesh_shape
        for k in pa:
            np.testing.assert_array_equal(np.asarray(jax.device_get(pa[k])),
                                          np.asarray(jax.device_get(pb[k])))

        # loss continuity vs the uninterrupted world-8 curve
        host2 = make_batch(archW, lmW, 4, 16)
        b2 = place_batch(host2, meshW, tsW.in_specs[2])
        _, _, m2 = tsW.fn(pa, oa, b2)
        l_new = [float(ma["loss"]), float(m2["loss"])]
        rel = np.abs(np.array(l_ref) - np.array(l_new)) \
            / np.abs(np.array(l_ref))
        assert rel.max() < 0.02, (mesh_shape, l_ref, l_new)


def check_state_quantized_roundtrip():
    """INT8 block-quantized per-shard checkpoints: the roundtrip error of
    every buffer is inside the blockwise QuantConfig bound (absmax/127 per
    block, + fp16 scale storage), the files are ~4x smaller than fp32, and
    an elastic 8->4 restore from the quantized payload continues training
    with losses close to the fp32-restored run."""
    import os
    import tempfile
    import numpy as np
    import jax
    from repro.train.state import ZeroState, read_manifest

    d8 = tempfile.mkdtemp(prefix="ckpt_state_q8_")
    d32 = tempfile.mkdtemp(prefix="ckpt_state_f32_")
    mesh8, arch, model8, opt_cfg, ts8, lm = _train_setup(mesh_shape=(4, 2))
    p8, o8, _ = _run_steps(mesh8, arch, model8, opt_cfg, ts8, lm, 2, 16)
    p8_host = jax.device_get(p8)
    st8 = ZeroState(model8, mesh8, opt_cfg, params=p8, opt=o8)
    path8 = st8.save(d8, 2, fmt="int8", meta={"world": 8})
    path32 = st8.save(d32, 2, fmt="fp32", meta={"world": 8})
    man = read_manifest(path8)
    block = man["quant_block"]
    assert man["format"] == "int8_blockwise" and block
    assert all(v["quantized"] for k, v in man["layout"].items()
               if not v["replicated"])

    def _dir_bytes(p):
        return sum(os.path.getsize(os.path.join(p, f))
                   for f in os.listdir(p))
    sz8, sz32 = _dir_bytes(path8), _dir_bytes(path32)
    assert sz8 < 0.35 * sz32, (sz8, sz32)

    # elastic restore of the quantized payload onto world=4
    mesh4, arch4, model4, opt_cfg4, ts4, lm4 = _train_setup(mesh_shape=(2, 2))
    st4 = ZeroState.restore(model4, mesh4, opt_cfg4, d8)
    assert st4 is not None and st4.step == 2
    for k, arr in st4.params.items():
        got = np.asarray(jax.device_get(arr))
        want = p8_host[k]
        n = min(got.shape[-1], want.shape[-1])
        assert n % block == 0, (k, n, block)
        wb = want[..., :n].reshape(*want.shape[:-1], n // block, block)
        # per-block bound: scale/2 rounding + fp16 scale storage (2^-11
        # relative on a value of magnitude <= 127*scale => +0.062*scale)
        bound = np.abs(wb).max(axis=-1, keepdims=True) / 127.0 * 0.6 + 1e-8
        err = np.abs(got[..., :n].reshape(wb.shape) - wb)
        assert (err <= bound).all(), \
            (k, float(err.max()), float(bound.max()))

    # training continues; losses track the exact-fp32 restore closely
    st4f = ZeroState.restore(model4, mesh4, opt_cfg4, d32)
    _, _, l_q = _run_steps(mesh4, arch4, model4, opt_cfg4, ts4, lm4, 2, 16,
                           start=2, params=st4.params, opt=st4.opt)
    _, _, l_f = _run_steps(mesh4, arch4, model4, opt_cfg4, ts4, lm4, 2, 16,
                           start=2, params=st4f.params, opt=st4f.opt)
    rel = np.abs(np.array(l_q) - np.array(l_f)) / np.abs(np.array(l_f))
    assert rel.max() < 0.05, (l_q, l_f)


def check_state_serving_load():
    """bf16 params-only serving load path: a params-only INT8 checkpoint
    saved at world=8 loads onto a world=4 mesh as bf16 with the serving
    shardings, matching bf16(dequantized global) exactly."""
    import tempfile
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.train.state import (ZeroState, load_global, load_serving_params,
                                   fit_to)

    d = tempfile.mkdtemp(prefix="ckpt_state_serve_")
    mesh8, arch, model8, opt_cfg, ts8, lm = _train_setup(mesh_shape=(4, 2))
    from repro.train.trainer import init_state
    p8, _ = init_state(model8, mesh8, opt_cfg, jax.random.PRNGKey(5))
    st = ZeroState(model8, mesh8, opt_cfg, params=p8)   # params-only
    path = st.save(d, 0, fmt="int8")

    mesh4, arch4, model4, opt_cfg4, ts4, lm4 = _train_setup(mesh_shape=(2, 2))
    params = load_serving_params(model4, mesh4, d, dtype=jnp.bfloat16)
    _, tree, _ = load_global(path)
    want_shapes = model4.param_shapes()
    bf16 = np.dtype(jnp.bfloat16)
    for k, arr in params.items():
        assert arr.dtype == jnp.bfloat16, (k, arr.dtype)
        assert tuple(arr.shape) == tuple(want_shapes[k])
        want = fit_to(np.asarray(tree["params"][k]),
                      want_shapes[k]).astype(bf16)
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(arr)).view(np.uint16),
            want.view(np.uint16))


def check_serve_prefill_decode_consistency(arch_name="qwen3-0.6b"):
    """prefill(P) + decode steps == prefill(P+n) teacher forcing."""
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.core.zeropp import ZeroConfig
    from repro.models.model import Model
    from repro.train import serve as serve_lib
    from repro.train.policy import make_policy

    mesh = _mesh2(model=2)
    world = jax.device_count()
    arch = get_config(arch_name).reduced()
    # f32 compute: this check proves PATH equivalence (prefill+decode ==
    # teacher forcing); bf16 reduction-order noise is not the subject
    pol = make_policy(arch, tuple(mesh.axis_names),
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    model = Model(arch, pol.zcfg, world=world)
    params = model.init_params(jax.random.PRNGKey(1), dtype=jnp.float32)
    from repro.train.state import param_specs
    p_specs = param_specs(model, tuple(mesh.axis_names))
    params = {k: jax.device_put(v, NamedSharding(mesh, p_specs[k]))
              for k, v in params.items()}

    B, Pn, extra = 2, 14, 2
    cap = Pn + extra
    rng = np.random.default_rng(3)
    toks = rng.integers(0, arch.vocab, size=(B, cap)).astype(np.int32)

    batch_axes, kv_axes = ("data",), ("model",)
    ps = serve_lib.build_prefill_step(model, mesh, batch_axes, ("model",))
    ds = serve_lib.build_decode_step(model, mesh, batch_axes, kv_axes,
                                     donate=False)

    def put_batch(d, specs):
        return {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
                for k, v in d.items()}

    # reference: prefill over the full P+extra prompt
    ref_logits, _ = ps.fn(params, put_batch(
        {"tokens": toks}, ps.in_specs[1]))
    ref = np.asarray(ref_logits)

    # prefill P, then decode the remaining tokens one at a time
    logits, caches = ps.fn(params, put_batch(
        {"tokens": toks[:, :Pn]}, ps.in_specs[1]))
    caches = serve_lib.pad_prefill_caches(model, caches, cap)
    c_specs = serve_lib.cache_specs(model, batch_axes, kv_axes)
    caches = jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        caches, c_specs)
    got = None
    for t in range(Pn, cap):
        b = put_batch({"tokens": toks[:, t:t + 1]}, ds.in_specs[2])
        # per-sequence cache_pos vector (all rows at the same position here)
        got, caches = ds.fn(params, caches, b, jnp.full((B,), t, jnp.int32))
    got = np.asarray(got)
    err = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
    assert err < 2e-2, f"prefill/decode mismatch rel {err}"
    # argmax token must agree
    assert (got.argmax(-1) == ref.argmax(-1)).all()


def check_serve_consistency_ssm():
    check_serve_prefill_decode_consistency("mamba2-130m")


def check_serve_consistency_hybrid():
    check_serve_prefill_decode_consistency("recurrentgemma-2b")


def check_serve_consistency_moe():
    check_serve_prefill_decode_consistency("deepseek-moe-16b")


def check_dryrun_smoke_cell():
    """Exercise the dry-run machinery end-to-end on the tiny 2x2x2 mesh:
    lower, compile, memory/cost analysis, loop-aware collective parse."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch import dryrun as dr
    from repro.launch.mesh import make_test_mesh
    from repro.models.model import Model
    from repro.optim.adamw import AdamWConfig
    from repro.train import trainer as trainer_lib
    from repro.train.policy import make_policy

    mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    axes = tuple(mesh.axis_names)
    arch = get_config("qwen3-0.6b").reduced()
    pol = make_policy(arch, axes)
    model = Model(arch, pol.zcfg, world=8)
    opt_cfg = AdamWConfig(moments_dtype=pol.moments_dtype)
    ts = trainer_lib.build_train_step(model, mesh, opt_cfg, donate=False,
                                      global_batch=8)
    p_sh, o_sh = trainer_lib.state_shapes(model, opt_cfg)
    params = dr._abstract(p_sh, mesh, ts.in_specs[0])
    opt = dr._abstract(o_sh, mesh, ts.in_specs[1])
    import dataclasses as dc
    shape = dc.replace(
        __import__("repro.configs.base", fromlist=["SHAPES"]).SHAPES["train_4k"],
        seq_len=32, global_batch=8)
    batch = dr._abstract(dr.train_batch_shapes(model, shape), mesh,
                         ts.in_specs[2])
    lowered = ts.fn.lower(params, opt, batch)
    info = {"world": 8, "n_params": model.n_params(),
            "n_active": model.n_active_params(), "tokens_per_step": 8 * 32}
    info = dr.analyze(lowered, info, multi_pod=True)
    assert info["memory"].get("peak_bytes_per_device", 0) > 0
    assert info["cost"]["flops"] > 0
    assert info["collectives"]["count"] > 0
    assert info["collectives"]["wire_bytes"] > 0
    r = info["roofline"]
    assert r["dominant"] in ("compute_s", "memory_s", "collective_s")
    # analytic floor: at least the forward matmul flops must be counted
    floor = 2 * model.n_active_params() * (8 * 32) / 8
    assert info["cost"]["flops"] >= floor, (info["cost"], floor)


# ---------------------------------------------------------------------------
# prefetched schedule (core/schedule.py): equality, ordering, HLO overlap
# ---------------------------------------------------------------------------

def _prefetch_env(prefetch: int, variant: str = "zeropp", batch: int = 16,
                  arch_name: str = "gpt-350m", n_layers: int = 0):
    import jax
    from repro.configs import get_config
    from repro.data.synthetic import SyntheticLM
    from repro.models.model import Model
    from repro.optim.adamw import AdamWConfig
    from repro.optim.schedule import warmup_cosine
    from repro.train import trainer as trainer_lib
    from repro.train.policy import make_policy

    mesh = _mesh2(model=2)
    axes = tuple(mesh.axis_names)
    # n_layers>0 deepens the stack beyond the 2-layer reduced default so
    # ring depths >= 2 are real (effective_prefetch clamps to n-1)
    arch = get_config(arch_name).reduced(
        **({"n_layers": n_layers} if n_layers else {}))
    pol = make_policy(arch, axes, variant, prefetch=prefetch)
    model = Model(arch, pol.zcfg, world=jax.device_count())
    opt_cfg = AdamWConfig(lr=warmup_cosine(3e-3, 10, 10_000),
                          moments_dtype=pol.moments_dtype)
    ts = trainer_lib.build_train_step(model, mesh, opt_cfg,
                                      global_batch=batch)
    lm = SyntheticLM(vocab=arch.vocab, seq_len=64, seed=7)
    return mesh, arch, model, opt_cfg, ts, lm


def _abstract_tree(tree, mesh, specs):
    """ShapeDtypeStructs with shardings (dryrun._abstract, duplicated here
    because importing launch.dryrun pins XLA_FLAGS to 512 devices)."""
    from jax.sharding import NamedSharding

    def mk(leaf, spec):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(mk, tree, specs)


def _prefetch_abstract_args(pf: int, arch_name: str = "gpt-350m",
                            n_layers: int = 0):
    """(ts, abstract (params, opt, batch)) for a prefetch setting."""
    from repro.train import trainer as trainer_lib
    mesh, arch, model, opt_cfg, ts, lm = _prefetch_env(
        pf, arch_name=arch_name, n_layers=n_layers)
    p_sh, o_sh = trainer_lib.state_shapes(model, opt_cfg)
    params = _abstract_tree(p_sh, mesh, ts.in_specs[0])
    opt = _abstract_tree(o_sh, mesh, ts.in_specs[1])
    bsh = {"tokens": jax.ShapeDtypeStruct((16, 64), jnp.int32),
           "targets": jax.ShapeDtypeStruct((16, 64), jnp.int32)}
    batch = _abstract_tree(bsh, mesh, ts.in_specs[2])
    return ts, (params, opt, batch)


def check_prefetch_matches_sync():
    """prefetch=1 (double-buffered overlap schedule) and prefetch=0
    (synchronous) must produce IDENTICAL loss curves on the smoke model:
    the schedule reorders collectives relative to compute, not the math.

    Covers both the hpZ backward branch (zeropp) and the re-gather-primary
    branch (baseline, hpz=False) of the prefetched custom vjp."""
    for variant in ("zeropp", "baseline"):
        curves = {}
        for pf in (0, 1):
            mesh, arch, model, opt_cfg, ts, lm = _prefetch_env(
                pf, variant=variant)
            _, _, losses = _run_steps(mesh, arch, model, opt_cfg, ts, lm,
                                      4, 16)
            curves[pf] = losses
        assert curves[0] == curves[1], (variant, curves[0], curves[1])


def _scan_bodies(jaxpr, out=None, seen=None):
    """All scan body jaxprs reachable from ``jaxpr`` (recursive)."""
    from repro.launch.jaxpr_analysis import _sub_jaxprs
    out = [] if out is None else out
    seen = set() if seen is None else seen
    if id(jaxpr) in seen:
        return out
    seen.add(id(jaxpr))
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "scan":
            out.append(eqn.params["jaxpr"].jaxpr)
        for sub, _ in _sub_jaxprs(eqn):
            _scan_bodies(sub, out, seen)
    return out


def _contains_dot(eqn, depth=0) -> bool:
    from repro.launch.jaxpr_analysis import _sub_jaxprs
    if eqn.primitive.name in ("dot_general", "conv_general_dilated"):
        return True
    if depth > 8:
        return False
    return any(_contains_dot(e, depth + 1)
               for sub, _ in _sub_jaxprs(eqn) for e in sub.eqns)


def _gather_dot_relation(body):
    """(first_gather_idx, first_dot_idx, any_gather_feeds_dot) for one scan
    body jaxpr, or None if it lacks gathers or dots."""
    eqns = body.eqns
    gathers = [i for i, e in enumerate(eqns)
               if e.primitive.name == "all_gather"]
    dots = [i for i, e in enumerate(eqns) if _contains_dot(e)]
    if not gathers or not dots:
        return None
    tainted = set()
    for g in gathers:
        tainted.update(id(v) for v in eqns[g].outvars)
    feeds = False
    for i, e in enumerate(eqns):
        if any(id(v) in tainted for v in e.invars):
            if _contains_dot(e):
                feeds = True
            tainted.update(id(v) for v in e.outvars)
    return min(gathers), min(dots), feeds


def _prefill_scan_relations(pf: int):
    from jax.sharding import NamedSharding
    from repro.train import serve as serve_lib

    mesh, arch, model, opt_cfg, ts, lm = _prefetch_env(pf)
    ps = serve_lib.build_prefill_step(model, mesh, ("data",), ("model",))
    p_sh = {k: jax.ShapeDtypeStruct(s, jnp.bfloat16)
            for k, s in model.param_shapes().items()}

    def mk(leaf, spec):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    params = jax.tree.map(mk, p_sh, ps.in_specs[0])
    batch = jax.tree.map(
        mk, {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32)},
        ps.in_specs[1])
    cj = jax.make_jaxpr(ps.fn)(params, batch)
    return [r for r in map(_gather_dot_relation, _scan_bodies(cj.jaxpr))
            if r]


def check_prefetch_jaxpr_ordering():
    """Double-buffering in the traced program, two granularities:

    * prefill (directly traced, trace order == jaxpr order): with
      prefetch=1 the block scan issues layer i+1's gather BEFORE layer i's
      matmuls, and no matmul consumes it; with prefetch=0 the gather feeds
      the matmuls (synchronous).
    * train step (AD partial-eval may reorder jaxpr text, so only the
      dependence property is meaningful): prefetch=1 yields independent
      (overlappable) gather bodies for BOTH the forward and backward block
      scans; prefetch=0 yields none.
    """
    # --- prefill: ordering + independence -------------------------------
    rels = {pf: _prefill_scan_relations(pf) for pf in (0, 1)}
    assert rels[0] and all(feeds for _, _, feeds in rels[0]), rels[0]
    free = [(g, d) for g, d, feeds in rels[1] if not feeds]
    assert free, f"no double-buffered prefill scan body: {rels[1]}"
    assert all(g < d for g, d in free), \
        f"prefetch gather not issued before the matmuls: {free}"

    # --- train step: independence in fwd AND bwd scans ------------------
    trels = {}
    for pf in (0, 1):
        ts, args = _prefetch_abstract_args(pf)
        cj = jax.make_jaxpr(ts.fn)(*args)
        trels[pf] = [r for r in map(_gather_dot_relation,
                                    _scan_bodies(cj.jaxpr)) if r]
    assert trels[0] and all(feeds for _, _, feeds in trels[0]), trels[0]
    tfree = [r for r in trels[1] if not r[2]]
    assert len(tfree) >= 2, \
        f"expected fwd+bwd double-buffered scan bodies, got {trels[1]}"


def check_prefetch_overlap_fraction():
    """Compiled-HLO verification (the acceptance criterion): with
    prefetch=1 the block-scan collectives are schedulable under compute
    (overlap_fraction > 0); with prefetch=0 nothing is."""
    from repro.launch.hlo_analysis import analyze_overlap

    ov = {}
    for pf in (0, 1):
        ts, args = _prefetch_abstract_args(pf)
        txt = ts.fn.lower(*args).compile().as_text()
        ov[pf] = analyze_overlap(txt)
    # 0.8 pins the measured value benchmarks/throughput_model.py projects
    # from (MEASURED_OVERLAP = 0.89): if the schedule regresses, this
    # fails before the benchmark silently misreports the prefetch win
    assert ov[1]["overlap_fraction"] > 0.8, ov[1]
    # fwd qwZ gather (payload+scales) + bwd hpZ gather + qgZ a2a pipeline
    assert ov[1]["overlappable_collectives"] >= 5, ov[1]
    assert ov[0]["overlap_fraction"] == 0.0, ov[0]
    assert ov[0]["overlappable_collectives"] == 0, ov[0]


def _stack_loss_and_grads(pf: int, arch_name: str, n_layers: int = 0):
    """(psum loss, grad pytree as numpy) for a tiny stack at one prefetch
    setting — fresh init, fixed seed, one fixed batch."""
    import jax
    from repro.data.synthetic import make_batch
    from repro.train.trainer import init_state, place_batch

    mesh, arch, model, opt_cfg, ts, lm = _prefetch_env(
        pf, arch_name=arch_name, n_layers=n_layers)
    params, _ = init_state(model, mesh, opt_cfg, jax.random.PRNGKey(0))
    host = make_batch(arch, lm, 0, 16)
    b = place_batch(host, mesh, ts.in_specs[2])
    z = model.zcfg

    def gf(p, batch):
        def lf(pp):
            loss, _ = model.loss_fn(pp, batch, ts.run_spec, ts.world)
            return loss

        l, g = jax.value_and_grad(lf)(p)
        return lax.psum(l, z.dp_axes), g

    sm = shard_map(gf, mesh=mesh,
                   in_specs=(ts.in_specs[0], ts.in_specs[2]),
                   out_specs=(P(), ts.in_specs[0]), check_vma=False)
    loss, grads = jax.jit(sm)(params, b)
    return float(loss), {k: np.asarray(v) for k, v in grads.items()}


def _moe_loss_and_grads(pf: int):
    return _stack_loss_and_grads(pf, "deepseek-moe-16b")


def _assert_depth_sweep(arch_name: str, depths, n_layers: int = 4):
    """Losses AND gradients at every ring depth must be bit-identical to
    the synchronous (prefetch=0) reference."""
    l0, g0 = _stack_loss_and_grads(0, arch_name, n_layers)
    for pf in depths:
        l, g = _stack_loss_and_grads(pf, arch_name, n_layers)
        assert l == l0, (arch_name, pf, l, l0)
        for k in g0:
            assert np.array_equal(g0[k], g[k]), (
                f"{arch_name} prefetch={pf}: grad {k} differs from the "
                f"synchronous reference, max abs diff "
                f"{np.abs(g0[k].astype(np.float64) - g[k].astype(np.float64)).max()}")


def check_prefetch_depth_sweep():
    """Dense 4-layer stack: the depth-k ring is bit-exact to the
    synchronous reference at every depth — including 8 > n_layers, which
    must clamp to the ring's n-1 maximum rather than lap itself."""
    _assert_depth_sweep("gpt-350m", (1, 2, 3, 8))


def check_moe_prefetch_depth_sweep():
    """MoE 4-layer stack (chunk + layer rings, routing-ahead speculative
    chunk-0 gather, hpZ-residual nested recompute): bit-exact to the
    synchronous reference at every ring depth, including one beyond the
    layer count (clamp case)."""
    _assert_depth_sweep("deepseek-moe-16b", (1, 2, 3, 8))


def check_ring_overlap_depth():
    """The ring acceptance check, from compiled HLO on 4-layer stacks:

      * prefetch=2 yields strictly higher depth-credited
        (effective_overlap) overlap than prefetch=1 on BOTH the dense and
        the MoE stack at the canonical low-bandwidth operating point
        (hlo_analysis.RING_OPERATING_POINT), with the structural fraction
        no lower and ring slack 2 visible in the HLO;
      * the MoE nested-remat expert re-gather is no longer exposed: every
        loop body holding collectives also holds compute (the gather-only
        loop the old qwZ-tier recompute left behind is gone), and the
        structural MoE fraction clears the pre-hpZ-recompute 0.63.
    """
    from repro.launch.hlo_analysis import (RING_OPERATING_POINT,
                                           analyze_overlap,
                                           effective_overlap)

    for arch in ("gpt-350m", "deepseek-moe-16b"):
        ov = {}
        for pf in (1, 2):
            ts, args = _prefetch_abstract_args(pf, arch_name=arch,
                                               n_layers=4)
            txt = ts.fn.lower(*args).compile().as_text()
            ov[pf] = analyze_overlap(txt)
        assert ov[2]["overlap_fraction"] >= ov[1]["overlap_fraction"], \
            (arch, ov[1]["overlap_fraction"], ov[2]["overlap_fraction"])
        e1 = effective_overlap(ov[1], **RING_OPERATING_POINT)
        e2 = effective_overlap(ov[2], **RING_OPERATING_POINT)
        f1 = e1["effective_overlap_fraction"]
        f2 = e2["effective_overlap_fraction"]
        assert f2 > f1 > 0.0, (arch, f1, f2)
        slack2 = max(l["max_slack_iters"] for l in ov[2]["per_loop"].values())
        assert slack2 >= 2, (arch, slack2)
        for pf in (1, 2):
            for name, loop in ov[pf]["per_loop"].items():
                assert loop["has_compute"], (
                    f"{arch} prefetch={pf}: loop {name} holds collectives "
                    f"with no compute to hide behind (exposed re-gather)")
        if arch == "deepseek-moe-16b":
            assert ov[1]["overlap_fraction"] > 0.7, ov[1]["overlap_fraction"]


def check_moe_prefetch_matches_sync():
    """MoE stack (deepseek-style shared+routed experts, chunked): the
    chunk/layer double-buffered schedule (prefetch=1) and the synchronous
    reference (prefetch=0) must produce BIT-IDENTICAL loss curves AND
    gradients — the schedule reorders collectives against compute at two
    granularities, never the math."""
    curves = {}
    for pf in (0, 1):
        mesh, arch, model, opt_cfg, ts, lm = _prefetch_env(
            pf, arch_name="deepseek-moe-16b")
        _, _, losses = _run_steps(mesh, arch, model, opt_cfg, ts, lm, 4, 16)
        curves[pf] = losses
    assert curves[0] == curves[1], (curves[0], curves[1])

    l0, g0 = _moe_loss_and_grads(0)
    l1, g1 = _moe_loss_and_grads(1)
    assert l0 == l1, (l0, l1)
    for k in g0:
        assert np.array_equal(g0[k], g1[k]), (
            f"grad {k} differs between schedules: max abs diff "
            f"{np.abs(g0[k].astype(np.float64) - g1[k].astype(np.float64)).max()}")


def check_moe_prefetch_overlap_fraction():
    """Compiled-HLO verification of the MoE schedule (acceptance
    criterion): with prefetch=1 the layer-scan shared gathers AND the
    nested expert-chunk gathers/reduces are schedulable under compute
    (overlap_fraction > 0.7 — the hpZ-residual recompute removed the
    exposed backward expert re-gather loop, so every in-loop collective
    body now holds compute); with prefetch=0 every in-loop collective
    stays on the critical path."""
    from repro.launch.hlo_analysis import analyze_overlap

    ov = {}
    for pf in (0, 1):
        ts, args = _prefetch_abstract_args(pf, arch_name="deepseek-moe-16b")
        txt = ts.fn.lower(*args).compile().as_text()
        ov[pf] = analyze_overlap(txt)
    assert ov[1]["overlap_fraction"] > 0.7, ov[1]
    # nested chunk loops must be seen as loops (layer scan + chunk scans)
    assert len(ov[1]["per_loop"]) >= 2, ov[1]["per_loop"]
    # the nested-remat expert re-gather no longer shows up as a
    # gather-only loop of exposed slow-tier bytes
    for name, loop in ov[1]["per_loop"].items():
        assert loop["has_compute"], (name, loop)
    assert ov[0]["overlap_fraction"] == 0.0, ov[0]
    assert ov[0]["overlappable_collectives"] == 0, ov[0]


def check_qgz_1hop_rejects_misaligned():
    """qgz_reduce_scatter_1hop must raise (not silently truncate) when the
    gradient length is not a multiple of world*block."""
    mesh = _mesh2(model=2)
    world = jax.device_count()
    cfg = QuantConfig(bits=8, block_size=32)
    spec = P(("data", "model"))
    n_bad = world * (world * 32 + 8)  # local len not divisible by world*32
    g = jnp.ones((n_bad,), jnp.float32)
    f = jax.jit(shard_map(
        lambda x: cl.qgz_reduce_scatter_1hop(x, ("data", "model"), cfg),
        mesh=mesh, in_specs=spec, out_specs=spec))
    try:
        f(g)
    except ValueError as e:
        assert "multiple of world*block" in str(e), e
        return
    raise AssertionError("qgz_reduce_scatter_1hop accepted misaligned input")


def check_serve_engine_continuous_batching():
    """Continuous-batching engine on an 8-device (2,4) mesh, batch-sharded
    slots, INT8 per-shard checkpoint boot: greedy engine output for every
    request (mixed prompt lengths, staggered admission over 4 slots) must
    equal running that request alone through the raw prefill+decode steps
    with the SAME restored weights."""
    import tempfile
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.serve import ServeEngine, steps
    from repro.train.policy import make_policy
    from repro.train.state import ZeroState, param_specs

    mesh = _mesh2(model=4)                      # (data=2, model=4)
    world = jax.device_count()
    arch = get_config("qwen3-0.6b").reduced()
    pol = make_policy(arch, tuple(mesh.axis_names))
    model = Model(arch, pol.zcfg, world=world)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    p_specs = param_specs(model, tuple(mesh.axis_names))
    params = {k: jax.device_put(v, NamedSharding(mesh, p_specs[k]))
              for k, v in params.items()}

    with tempfile.TemporaryDirectory(prefix="zeropp_serve8_") as d:
        st = ZeroState(model, mesh, opt_cfg=None, params=params,
                       meta={"arch": arch.name})
        st.save(d, 0, fmt="int8")
        kv_len = 32
        eng = ServeEngine.from_checkpoint(
            model, mesh, d, n_slots=4, kv_len=kv_len,
            batch_axes=("data",), kv_axes=("model",))

    jobs = [(5, 6), (11, 4), (8, 5), (16, 3), (3, 7), (9, 4)]  # 6 req, 4 slots
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, arch.vocab, p).astype(np.int32)
               for p, _ in jobs]
    uids = [eng.submit(pr, max_new_tokens=n)
            for pr, (_, n) in zip(prompts, jobs)]
    res = eng.run(max_steps=200)
    # slot recycling really happened: more requests than slots
    assert len(set(eng.slot_history.values())) <= 4
    assert len(eng.slot_history) == len(jobs)

    # oracle: each request alone through the raw steps, same INT8 weights
    ps = steps.build_prefill_step(model, mesh, (), ())
    ds = steps.build_decode_step(model, mesh, (), ("model",), donate=False)
    for uid, pr, (P_, n) in zip(uids, prompts, jobs):
        logits, caches = ps.fn(eng.params, {"tokens": pr[None, :]})
        caches = steps.pad_prefill_caches(model, caches, kv_len)
        want = [int(jnp.argmax(logits[0, -1]))]
        for i in range(1, n):
            logits, caches = ds.fn(
                eng.params, caches,
                {"tokens": jnp.array([[want[-1]]], jnp.int32)},
                jnp.full((1,), P_ + i - 1, jnp.int32))
            want.append(int(jnp.argmax(logits[0, -1])))
        assert res[uid] == want, (uid, res[uid], want)


def check_serve_engine_paged():
    """Paged engine on a sharded mesh ((n//4, 4); runs at 4 AND 8 devices),
    INT8 per-shard checkpoint boot: the page-table engine (chunked prefill,
    prefix cache, page-granularity admission) must emit token streams
    identical to the whole-slot slab engine on the same request set — and
    a second wave resubmitting shared-prefix prompts must actually HIT the
    prefix cache while staying identical."""
    import tempfile
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.serve import ServeEngine
    from repro.train.policy import make_policy
    from repro.train.state import ZeroState, param_specs

    mesh = _mesh2(model=4)                      # (n//4, model=4)
    world = jax.device_count()
    arch = get_config("qwen3-0.6b").reduced()
    pol = make_policy(arch, tuple(mesh.axis_names))
    model = Model(arch, pol.zcfg, world=world)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    p_specs = param_specs(model, tuple(mesh.axis_names))
    params = {k: jax.device_put(v, NamedSharding(mesh, p_specs[k]))
              for k, v in params.items()}

    kv_len = 32
    with tempfile.TemporaryDirectory(prefix="zeropp_paged_") as d:
        st = ZeroState(model, mesh, opt_cfg=None, params=params,
                       meta={"arch": arch.name})
        st.save(d, 0, fmt="int8")
        paged = ServeEngine.from_checkpoint(
            model, mesh, d, n_slots=4, kv_len=kv_len,
            kv_axes=("model",), pool="paged", page_size=8, chunk_size=8)
        slab = ServeEngine.from_checkpoint(
            model, mesh, d, n_slots=4, kv_len=kv_len, kv_axes=("model",))

    # 6 requests over 4 slots; prompts span 1..3 chunks and three of them
    # share a full-page 16-token prefix
    rng = np.random.default_rng(7)
    shared = rng.integers(0, arch.vocab, 16).astype(np.int32)
    jobs = [
        (rng.integers(0, arch.vocab, 5).astype(np.int32), 6),
        (np.concatenate([shared, rng.integers(0, arch.vocab, 3)
                         .astype(np.int32)]), 4),
        (rng.integers(0, arch.vocab, 11).astype(np.int32), 4),
        (np.concatenate([shared, rng.integers(0, arch.vocab, 6)
                         .astype(np.int32)]), 3),
        (rng.integers(0, arch.vocab, 21).astype(np.int32), 3),
        (np.concatenate([shared, rng.integers(0, arch.vocab, 1)
                         .astype(np.int32)]), 5),
    ]

    def run(eng):
        uids = [eng.submit(pr, max_new_tokens=n) for pr, n in jobs]
        res = eng.run(max_steps=300)
        return [res[u] for u in uids]

    want = run(slab)
    got = run(paged)
    assert got == want, (got, want)
    u = paged.pool.utilization()
    # the 2nd/3rd shared-prefix requests land after the 1st registered it
    assert u["prefix_hits"] >= 1 and u["prefix_tokens_reused"] >= 16, u
    assert paged.pool.n_free == 4 and (paged.pool.refcount == 0).all()


def check_serve_engine_speculative():
    """Speculative decoding on a sharded mesh: (a) an INDEPENDENT drafter
    (same arch, different init — a bad drafter) still yields token streams
    identical to plain paged greedy decode; (b) self-draft (perfect
    drafter) accepts > 1 token per verify step."""
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.serve import ServeEngine
    from repro.train.policy import make_policy
    from repro.train.state import param_specs

    mesh = _mesh2(model=4)
    world = jax.device_count()
    arch = get_config("qwen3-0.6b").reduced()
    pol = make_policy(arch, tuple(mesh.axis_names),
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    model = Model(arch, pol.zcfg, world=world)
    p_specs = param_specs(model, tuple(mesh.axis_names))

    def put(p):
        return {k: jax.device_put(v, NamedSharding(mesh, p_specs[k]))
                for k, v in p.items()}

    params = put(model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32))
    drafter = put(model.init_params(jax.random.PRNGKey(1), dtype=jnp.float32))

    kv_len, jobs = 32, [(5, 6), (11, 4), (8, 5), (3, 7)]
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, arch.vocab, p).astype(np.int32)
               for p, _ in jobs]

    def run(**kw):
        eng = ServeEngine(model, mesh, params, n_slots=4, kv_len=kv_len,
                          kv_axes=("model",), pool="paged", page_size=8,
                          chunk_size=8, cache_dtype=jnp.float32, **kw)
        uids = [eng.submit(pr, max_new_tokens=n)
                for pr, (_, n) in zip(prompts, jobs)]
        res = eng.run(max_steps=300)
        return [res[u] for u in uids], eng

    want, _ = run()
    got_bad, eng_bad = run(draft=(model, drafter), spec_tokens=4)
    assert got_bad == want, (got_bad, want)
    got_self, eng_self = run(draft=(model, params), spec_tokens=4)
    assert got_self == want, (got_self, want)
    acc = eng_self.stats()["spec_accepted"]
    assert acc["mean"] is not None and acc["mean"] > 1.0, acc
    # the bad drafter is still correct, just slower (fewer accepted)
    bad = eng_bad.stats()["spec_accepted"]
    assert bad["mean"] <= acc["mean"], (bad, acc)


# ---------------------------------------------------------------------------
# elastic runtime: async checkpoints, faults, live resharding (DESIGN.md §6)
# ---------------------------------------------------------------------------

def check_elastic_async_overlap():
    """The async writer genuinely overlaps: with every shard write slowed,
    train steps still complete WHILE a write is in flight, every submitted
    snapshot commits, and the committed manifest carries checksums."""
    import os
    import tempfile
    from repro.testing.faults import SlowIO
    from repro.train.elastic import ElasticConfig, Supervisor
    from repro.train.state import latest_checkpoint, read_manifest

    d = tempfile.mkdtemp(prefix="elastic_overlap_")
    slow = SlowIO(0.5)
    out = Supervisor(ElasticConfig(steps=8, ckpt_dir=d, ckpt_every=2),
                     io_hooks=slow).run_supervised()
    ws = out["writer_stats"]
    assert out["status"] == "complete" and out["final_step"] == 8
    assert ws["submitted"] == 4 and ws["completed"] == 4, ws
    assert ws["failed"] == 0 and ws["abandoned"] == 0, ws
    assert ws["steps_overlapped"] > 0, ws     # steps ran during writes
    assert slow.calls == 4
    path = latest_checkpoint(d)
    assert path is not None and os.path.basename(path) == "ckpt_8"
    man = read_manifest(path)
    assert man["step"] == 8 and man["checksums"], man.keys()


def check_elastic_kill_resume():
    """Worker death at step 5 (checkpoints every 2): the supervisor
    restarts, resumes from the step-4 async checkpoint, and every
    post-resume loss is BIT-IDENTICAL to the uninterrupted oracle run."""
    import tempfile
    from repro.testing.faults import StepFaults
    from repro.train.elastic import ElasticConfig, Supervisor

    oracle = Supervisor(ElasticConfig(steps=8)).run_supervised()
    d = tempfile.mkdtemp(prefix="elastic_kill_")
    sup = Supervisor(ElasticConfig(steps=8, ckpt_dir=d, ckpt_every=2),
                     faults=StepFaults({5: "die"}))
    out = sup.run_supervised()
    assert out["status"] == "complete" and out["final_step"] == 8
    assert out["restarts"] == 1 and out["fired"] == [(5, "die")]
    assert set(out["losses"]) == set(range(8))
    for i in range(8):          # includes replayed steps 4..7: bit-exact
        assert out["losses"][i] == oracle["losses"][i], \
            (i, out["losses"][i], oracle["losses"][i])


def check_elastic_live_reshard():
    """Live 8 -> 4 -> 8 resharding mid-run with NO checkpoint dir: the
    state moves through host memory only.  Steps before the first reshard
    are bit-exact vs the fixed-world oracle; the whole curve stays within
    rel 2e-2 (different worlds reduce in different orders)."""
    import numpy as np
    from repro.train.elastic import ElasticConfig, Supervisor

    oracle = Supervisor(ElasticConfig(steps=9)).run_supervised()
    sup = Supervisor(ElasticConfig(steps=9),
                     reshard_plan={3: (2, 2), 6: (4, 2)})
    out = sup.run_supervised()
    assert out["resharded"] == [(3, 8, 4), (6, 4, 8)], out["resharded"]
    for i in range(3):                       # same world so far: bit-exact
        assert out["losses"][i] == oracle["losses"][i], i
    l_ref = np.array([oracle["losses"][i] for i in range(9)])
    l_new = np.array([out["losses"][i] for i in range(9)])
    rel = np.abs(l_ref - l_new) / np.abs(l_ref)
    assert rel.max() < 0.02, (l_ref, l_new)


def check_elastic_crash_during_write():
    """A REAL SIGKILL lands mid async write (each shard write slowed to
    5s): the staging dir is left behind WITHOUT a manifest, so
    ``latest_checkpoint`` still selects the previous committed step; the
    relaunch resumes from it, sweeps the debris on the re-save, and
    completes."""
    import os
    import signal
    import tempfile
    from repro.testing.faults import kill_on_marker, run_train
    from repro.train.state import MANIFEST, latest_checkpoint

    d = tempfile.mkdtemp(prefix="elastic_crash_")
    args = ["--elastic", "--reduced", "--mesh", "4x2", "--steps", "8",
            "--ckpt-dir", d, "--ckpt-every", "2", "--fault-slow-write", "5"]
    rc, lines = kill_on_marker(args, "committed step 2",
                               sig=signal.SIGKILL, delay=1.5)
    assert rc != 0
    staging = os.path.join(d, "ckpt_4.tmp")
    assert os.path.isdir(staging), os.listdir(d)      # genuine debris
    assert not os.path.exists(os.path.join(staging, MANIFEST))
    latest = latest_checkpoint(d)
    assert latest is not None and os.path.basename(latest) == "ckpt_2", \
        os.listdir(d)

    lines2 = run_train(["--elastic", "--reduced", "--mesh", "4x2",
                        "--steps", "8", "--ckpt-dir", d, "--ckpt-every",
                        "2"])
    txt = "\n".join(lines2)
    assert "resumed from step 2" in txt, txt[-2000:]
    assert "status=complete" in txt and "final_step=8" in txt
    assert os.path.basename(latest_checkpoint(d)) == "ckpt_8"
    assert not os.path.isdir(staging)       # re-save swept the stale dir


def check_elastic_sigterm_grace():
    """Graceful preemption, both ways in: a REAL SIGTERM mid-run and an
    injected in-process preempt.  Both must drain the in-flight write,
    cut a final synchronous checkpoint, exit cleanly, and resume."""
    import os
    import signal
    import tempfile
    from repro.testing.faults import StepFaults, kill_on_marker, run_train
    from repro.train.elastic import ElasticConfig, Supervisor
    from repro.train.state import latest_checkpoint, read_manifest

    # real signal, subprocess
    d = tempfile.mkdtemp(prefix="elastic_term_")
    args = ["--elastic", "--reduced", "--mesh", "4x2", "--steps", "12",
            "--ckpt-dir", d, "--ckpt-every", "2", "--grace", "30"]
    rc, lines = kill_on_marker(args, "step 4 loss", sig=signal.SIGTERM)
    txt = "\n".join(lines)
    assert rc == 0, txt[-2000:]
    assert "preemption requested" in txt and "preempted at step" in txt
    assert "status=preempted" in txt
    path = latest_checkpoint(d)
    assert path is not None
    stop = read_manifest(path)["step"]
    assert 4 < stop < 12                   # stopped early, but checkpointed
    txt2 = "\n".join(run_train(
        ["--elastic", "--reduced", "--mesh", "4x2", "--steps", "12",
         "--ckpt-dir", d, "--ckpt-every", "2"]))
    assert f"resumed from step {stop}" in txt2, txt2[-2000:]
    assert "status=complete" in txt2 and "final_step=12" in txt2

    # injected preempt, in-process
    d2 = tempfile.mkdtemp(prefix="elastic_term2_")
    out = Supervisor(ElasticConfig(steps=12, ckpt_dir=d2, ckpt_every=2),
                     faults=StepFaults({5: "preempt"})).run_supervised()
    assert out["status"] == "preempted" and out["final_step"] == 5
    assert os.path.basename(latest_checkpoint(d2)) == "ckpt_5"
    out2 = Supervisor(ElasticConfig(steps=12, ckpt_dir=d2,
                                    ckpt_every=2)).run_supervised()
    assert out2["status"] == "complete" and out2["final_step"] == 12


def check_elastic_corrupt_fallback():
    """Quarantine-and-fall-back: with the two newest checkpoints damaged
    (bit-rot in one, truncation in the other), ``restore_resilient``
    quarantines both and restores the oldest intact one; when EVERY
    checkpoint is damaged it returns None instead of raising."""
    import os
    import tempfile
    from repro.testing.faults import corrupt_shard, truncate_shard
    from repro.train.elastic import ElasticConfig, Supervisor
    from repro.train.state import ZeroState

    d = tempfile.mkdtemp(prefix="elastic_corrupt_")
    Supervisor(ElasticConfig(steps=6, ckpt_dir=d,
                             ckpt_every=2)).run_supervised()
    corrupt_shard(os.path.join(d, "ckpt_6"))     # crc catches bit-rot
    truncate_shard(os.path.join(d, "ckpt_4"))    # short read
    mesh, arch, model, opt_cfg, ts, lm = _train_setup()
    st = ZeroState.restore_resilient(model, mesh, opt_cfg, d)
    assert st is not None and int(st.step) == 2
    assert os.path.isdir(os.path.join(d, "ckpt_6.corrupt"))
    assert os.path.isdir(os.path.join(d, "ckpt_4.corrupt"))
    corrupt_shard(os.path.join(d, "ckpt_2"))
    assert ZeroState.restore_resilient(model, mesh, opt_cfg, d) is None
    # and the supervisor on an all-quarantined dir starts from scratch
    out = Supervisor(ElasticConfig(steps=2, ckpt_dir=d,
                                   ckpt_every=2)).run_supervised()
    assert out["status"] == "complete" and 0 in out["losses"]


def check_elastic_flaky_io_retry():
    """Transient write errors: the first two shard writes fail with
    OSError; with retries=3 the async writer absorbs them (retry with
    exponential backoff) and every snapshot still commits."""
    import os
    import tempfile
    from repro.testing.faults import FlakyIO
    from repro.train.elastic import ElasticConfig, Supervisor
    from repro.train.state import latest_checkpoint

    d = tempfile.mkdtemp(prefix="elastic_flaky_")
    flaky = FlakyIO(2)
    out = Supervisor(ElasticConfig(steps=4, ckpt_dir=d, ckpt_every=2,
                                   retries=3, backoff=0.01),
                     io_hooks=flaky).run_supervised()
    ws = out["writer_stats"]
    assert out["status"] == "complete"
    assert ws["completed"] == 2 and ws["failed"] == 0, ws
    assert flaky.remaining == 0 and flaky.calls >= 3   # 2 fails + retries
    assert os.path.basename(latest_checkpoint(d)) == "ckpt_4"


# ---------------------------------------------------------------------------
# kernel backend seam (kernels/ops.py + kernels/platform.py) — DESIGN.md §7
# ---------------------------------------------------------------------------

def check_kernel_backend_depth_sweep():
    """The prefetch ring composes with kernel-backed quant: with the
    backend forced to `interpret` (the real Pallas kernel bodies, run
    through the interpreter), the dense depth sweep stays bit-identical
    in losses AND gradients to the synchronous reference — same assertion
    as check_prefetch_depth_sweep, different quant implementation."""
    from repro.kernels import ops
    with ops.use_backend("interpret"):
        assert ops.backend() == "interpret"
        _assert_depth_sweep("gpt-350m", (1, 2, 3))


def check_kernel_backend_serve_engine():
    """The serve-engine bit-identity check (engine output == raw
    per-request prefill+decode, INT8 checkpoint boot) passes unchanged
    with the kernel backend forced to `interpret` — covering the fused
    INT8 dequant-GEMM serving head, which both sides dispatch through
    kernels/ops.py."""
    from repro.kernels import ops
    with ops.use_backend("interpret"):
        check_serve_engine_continuous_batching()


def check_kernel_backend_train_bitexact():
    """Switching the quant backend must not move the training trajectory:
    `interpret` (Pallas kernel bodies) and `xla` (pure-jnp reference)
    loss curves are bit-identical — the kernels ARE the reference math
    (quantize/dequant/fused-reduce parity is exact, not approximate)."""
    from repro.kernels import ops
    curves = {}
    for be in ("xla", "interpret"):
        with ops.use_backend(be):
            mesh, arch, model, opt_cfg, ts, lm = _prefetch_env(1)
            _, _, losses = _run_steps(mesh, arch, model, opt_cfg, ts, lm,
                                      3, 16)
            curves[be] = losses
    assert curves["xla"] == curves["interpret"], curves


def check_qwz_gemm_head_matches_staged():
    """The fused INT8 dequant-GEMM serving head (qwz_gemm=True: the decode
    GEMM eats the gathered INT8 payload, scales applied in the k-tile
    loop) must produce the same logits as the staged
    gather-dequant-einsum head (qwz_gemm=False) — tight allclose (fp32
    accumulation-order only) and identical argmax, under both the xla
    and interpret backends."""
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.configs import get_config
    from repro.kernels import ops
    from repro.models.model import Model
    from repro.train import serve as serve_lib
    from repro.train.policy import make_policy
    from repro.train.state import param_specs

    mesh = _mesh2(model=2)
    world = jax.device_count()
    arch = get_config("qwen3-0.6b").reduced()
    rng = np.random.default_rng(5)
    toks = rng.integers(0, arch.vocab, size=(2, 12)).astype(np.int32)

    outs = {}
    for fused in (True, False):
        pol = make_policy(arch, tuple(mesh.axis_names), qwz_gemm=fused)
        model = Model(arch, pol.zcfg, world=world)
        params = model.init_params(jax.random.PRNGKey(2), dtype=jnp.float32)
        p_specs = param_specs(model, tuple(mesh.axis_names))
        params = {k: jax.device_put(v, NamedSharding(mesh, p_specs[k]))
                  for k, v in params.items()}
        for be in ("xla", "interpret"):
            with ops.use_backend(be):
                ps = serve_lib.build_prefill_step(model, mesh, (),
                                                  ("model",))
                batch = {"tokens": jax.device_put(
                    toks, NamedSharding(mesh, ps.in_specs[1]["tokens"]))}
                logits, _ = ps.fn(params, batch)
                outs[(fused, be)] = np.asarray(logits)

    want = outs[(False, "xla")]                  # the staged reference head
    for k, got in outs.items():
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5,
                                   err_msg=str(k))
        assert (got.argmax(-1) == want.argmax(-1)).all(), k


# ---------------------------------------------------------------------------
# observability: measured-vs-projected comm, telemetry replay, runtime gate
# (DESIGN.md §8, obs/)
# ---------------------------------------------------------------------------

def _obs_crosscheck(variant: str, arch_name: str, n_layers: int = 4):
    """Per-label wire bytes from the traced step's jaxpr must match the
    analytic event model to 1% (in practice: to the byte) at every ring
    depth.  Labels come from the ``zero.*`` named scopes in
    core/collectives.py; the projection from ``Model.comm_events`` folded
    through ``zeropp.step_wire_by_label``."""
    from repro.launch.jaxpr_analysis import analyze_jaxpr
    from repro.obs.report import GateFailure, runtime_gate
    from repro.core.zeropp import step_wire_by_label
    from repro.train import trainer as trainer_lib

    for pf in (0, 1, 2):
        mesh, arch, model, opt_cfg, ts, lm = _prefetch_env(
            pf, variant=variant, arch_name=arch_name, n_layers=n_layers)
        p_sh, o_sh = trainer_lib.state_shapes(model, opt_cfg)
        params = _abstract_tree(p_sh, mesh, ts.in_specs[0])
        opt = _abstract_tree(o_sh, mesh, ts.in_specs[1])
        bsh = {"tokens": jax.ShapeDtypeStruct((16, 64), jnp.int32),
               "targets": jax.ShapeDtypeStruct((16, 64), jnp.int32)}
        batch = _abstract_tree(bsh, mesh, ts.in_specs[2])
        cj = jax.make_jaxpr(ts.fn)(params, opt, batch)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        measured = analyze_jaxpr(cj, sizes)["collectives"]["wire_by_label"]
        projected = step_wire_by_label(model.comm_events(), model.zcfg,
                                       sizes)
        # unlabeled collectives (loss psums) carry no parameter traffic
        assert measured.get("other", 0.0) == 0.0, measured
        try:
            runtime_gate(measured=measured, projected=projected,
                         strict=True)
        except GateFailure as e:
            raise AssertionError(
                f"{variant}/{arch_name} pf={pf}: {e}") from e


def check_obs_comm_crosscheck():
    """Dense stack, zeropp + baseline, prefetch depths 0/1/2 (satellite:
    runtime counters vs comm_volume analytics must agree per label)."""
    _obs_crosscheck("zeropp", "gpt-350m")
    _obs_crosscheck("baseline", "gpt-350m")


def check_obs_comm_crosscheck_moe():
    """MoE stack (chunked experts, spec ring, hpZ recompute gathers):
    the event enumeration must track the real schedule at every depth —
    this is where qgZ scale bytes and per-chunk recompute gathers are
    easiest to drop on either side."""
    _obs_crosscheck("zeropp", "deepseek-moe-16b")


def check_obs_telemetry_failure_replay():
    """Telemetry under failure: a run killed at step 5 and restarted from
    the step-4 checkpoint re-emits steps 4.. into the SAME append-mode
    jsonl; ``replay_counters`` must dedupe the re-emitted steps so the
    interrupted log replays to totals identical to an uninterrupted
    oracle — and, truncated at the kill step, to the oracle's prefix."""
    import os
    import tempfile
    from repro.obs.trace import read_events, replay_counters
    from repro.testing.faults import StepFaults
    from repro.train.elastic import ElasticConfig, Supervisor

    d_o = tempfile.mkdtemp(prefix="obs_oracle_")
    oracle = Supervisor(
        ElasticConfig(steps=8, metrics_dir=d_o)).run_supervised()
    assert oracle["status"] == "complete"
    log_o = os.path.join(d_o, "events.jsonl")
    tot_o = replay_counters(log_o)
    assert tot_o["train.steps"] == 8, tot_o

    d_i = tempfile.mkdtemp(prefix="obs_interrupted_")
    ck = tempfile.mkdtemp(prefix="obs_ckpt_")
    out = Supervisor(
        ElasticConfig(steps=8, ckpt_dir=ck, ckpt_every=2,
                      metrics_dir=d_i),
        faults=StepFaults({5: "die"})).run_supervised()
    assert out["restarts"] == 1 and out["final_step"] == 8
    log_i = os.path.join(d_i, "events.jsonl")
    tot_i = replay_counters(log_i)

    # steps 4,5 were emitted twice (pre-kill + replay) yet count once
    raw_step_recs = [r for r in read_events(log_i)
                     if r.get("kind") == "counter"
                     and r["name"] == "train.steps"]
    assert len(raw_step_recs) > 8, len(raw_step_recs)
    for key in ("train.steps", "train.tokens", "train.loss"):
        assert tot_i[key] == tot_o[key], (key, tot_i[key], tot_o[key])

    # prefix property: truncating the replay at the kill step matches the
    # oracle truncated at the same step
    pre_i = replay_counters(log_i, up_to_step=4)
    pre_o = replay_counters(log_o, up_to_step=4)
    for key in ("train.steps", "train.tokens", "train.loss"):
        assert pre_i[key] == pre_o[key], (key, pre_i, pre_o)

    # restart itself was recorded exactly once
    evs = [r["name"] for r in read_events(log_i)
           if r.get("kind") == "event"]
    assert evs.count("elastic.restart") == 1, evs


def check_obs_runtime_gate():
    """The full measured-vs-projected gate on a REAL train run, plus the
    disabled-telemetry overhead bound: alternate plain steps with steps
    under the no-op tracer + guard and compare medians (alternation puts
    machine noise on both sides)."""
    import os
    import tempfile
    import time as _time
    from repro.obs.metrics import Registry, set_registry
    from repro.obs.report import runtime_gate
    from repro.obs.trace import Tracer, set_tracer
    from repro.launch.jaxpr_analysis import analyze_jaxpr
    from repro.core.zeropp import step_wire_by_label
    from repro.data.synthetic import make_batch
    from repro.train.state import ZeroState
    from repro.train.trainer import place_batch

    mesh, arch, model, opt_cfg, ts, lm = _prefetch_env(1)
    st = ZeroState(model, mesh, opt_cfg).init(jax.random.PRNGKey(0))
    params, opt = st.params, st.opt
    reg = Registry()
    old_reg = set_registry(reg)
    d = tempfile.mkdtemp(prefix="obs_gate_")
    tracer = Tracer(os.path.join(d, "events.jsonl"))
    off = Tracer(enabled=False)
    old_tr = set_tracer(tracer)
    try:
        host = make_batch(arch, lm, 0, 16)
        batch = place_batch(host, mesh, ts.in_specs[2])
        cj = jax.make_jaxpr(ts.fn)(params, opt, batch)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        comm = analyze_jaxpr(cj, sizes)["collectives"]["wire_by_label"]
        params, opt, m = ts.fn(params, opt, batch)       # compile once
        jax.block_until_ready(m["loss"])

        # -- overhead: alternate plain steps with telemetry-DISABLED
        # steps (no-op span + False guard — the production off path);
        # medians over interleaved samples cancel machine drift
        plain_s, off_s = [], []
        telemetry = False
        for i in range(1, 17):
            host = make_batch(arch, lm, i, 16)
            batch = place_batch(host, mesh, ts.in_specs[2])
            t0 = _time.monotonic()
            if i % 2:
                with off.span("train.step", step=i):
                    params, opt, m = ts.fn(params, opt, batch)
                    jax.block_until_ready(m["loss"])
                if telemetry:       # pragma: no cover — the off guard
                    reg.counter("train.steps").inc()
                off_s.append(_time.monotonic() - t0)
            else:
                params, opt, m = ts.fn(params, opt, batch)
                jax.block_until_ready(m["loss"])
                plain_s.append(_time.monotonic() - t0)

        # -- a couple of fully-ENABLED steps: counters must accumulate
        # exactly measured-per-step * n_steps
        n_enabled = 2
        for i in range(17, 17 + n_enabled):
            host = make_batch(arch, lm, i, 16)
            batch = place_batch(host, mesh, ts.in_specs[2])
            with tracer.span("train.step", step=i):
                params, opt, m = ts.fn(params, opt, batch)
                jax.block_until_ready(m["loss"])
            for lbl, b in comm.items():
                reg.counter(f"comm.{lbl}.bytes").inc(b)
            tracer.counter("train.steps", 1, step=i)
            tracer.flush()
        for lbl, b in comm.items():
            got = reg.counter(f"comm.{lbl}.bytes").value
            assert got == b * n_enabled, (lbl, got, b, n_enabled)

        projected = step_wire_by_label(model.comm_events(), model.zcfg,
                                       sizes)
        report = runtime_gate(measured=comm, projected=projected,
                              enabled_s=plain_s, disabled_s=off_s,
                              overhead_tol=0.02, strict=True)
        assert report["ok"], report
    finally:
        set_registry(old_reg)
        set_tracer(old_tr)
        tracer.close()


# ---------------------------------------------------------------------------
# tuner (repro/tune): (k+1) HBM ledger vs the live schedule, boot path
# (DESIGN.md §9)
# ---------------------------------------------------------------------------

def _scan_carry_ring_depths(jaxpr, width, out=None, seen=None):
    """Max leading dim per dtype over scan CARRY avals shaped (d, width)
    reachable from ``jaxpr`` (recursive) — the prefetch rings.

    The forward ring rides the scan carry as a stacked (k, P) buffer (P =
    padded per-layer flat size); xs/consts never have that shape, so the
    (d, width) carry filter isolates the rings exactly.
    """
    from repro.launch.jaxpr_analysis import _sub_jaxprs
    out = {} if out is None else out
    seen = set() if seen is None else seen
    if id(jaxpr) in seen:
        return out
    seen.add(id(jaxpr))
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "scan":
            nc = eqn.params["num_consts"]
            ncar = eqn.params["num_carry"]
            for v in eqn.invars[nc:nc + ncar]:
                a = v.aval
                if getattr(a, "ndim", 0) == 2 and a.shape[1] == width:
                    key = str(a.dtype)
                    out[key] = max(out.get(key, 0), int(a.shape[0]))
        for sub, _ in _sub_jaxprs(eqn):
            _scan_carry_ring_depths(sub, width, out, seen)
    return out


def check_tune_ledger_live_buffers():
    """ISSUE 9 acceptance: the ledger's (k+1) ring charge must match the
    MEASURED live gathered-buffer count of the traced train step for
    prefetch 0..3 — counted from the scan carries, not assumed.

    measured = (bf16 (k, P) carry ring leading dim) + 1: k slots ride the
    carry and ``_ring_read`` materializes one more copy for the consuming
    layer; prefetch=0 has no ring carry but still computes with a single
    gathered buffer.  The backward pass carries a second, fp32 (k, P)
    ring of unreduced gradients — its depth must match ring_grads_bwd.
    """
    from repro.train import trainer as trainer_lib
    from repro.tune import train_ledger

    for pf in (0, 1, 2, 3):
        # 6 layers so effective_prefetch(n_periods) == pf for every depth
        mesh, arch, model, opt_cfg, ts, lm = _prefetch_env(pf, n_layers=6)
        k_eff = model.zcfg.effective_prefetch(model.n_periods)
        assert k_eff == pf, (k_eff, pf, model.n_periods)

        p_sh, o_sh = trainer_lib.state_shapes(model, opt_cfg)
        params = _abstract_tree(p_sh, mesh, ts.in_specs[0])
        opt = _abstract_tree(o_sh, mesh, ts.in_specs[1])
        bsh = {"tokens": jax.ShapeDtypeStruct((16, 64), jnp.int32),
               "targets": jax.ShapeDtypeStruct((16, 64), jnp.int32)}
        batch = _abstract_tree(bsh, mesh, ts.in_specs[2])
        cj = jax.make_jaxpr(ts.fn)(params, opt, batch)

        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        led = train_ledger(model, sizes)
        ring = dict(led.ring_buffers)
        assert ring["layers"] == pf + 1, (pf, ring)

        P = model.period_spec.padded_size
        depths = _scan_carry_ring_depths(cj.jaxpr, P)
        measured_w = depths.get("bfloat16", 0) + 1   # slots + read copy
        assert measured_w == ring["layers"], (pf, depths, ring)
        measured_g = depths.get("float32", 0)        # bwd unreduced grads
        assert measured_g == pf, (pf, depths)
        assert led.line("ring_grads_bwd") == pf * 2 * P, led.as_dict()


def check_tune_static_resolve_boot():
    """--tune=static boots through repro.tune end to end on a live mesh:
    build_everything carries the frozen ResolvedPolicy, the boot-path
    resolution equals a direct ``resolve`` call with the same inputs
    (deterministic by the committed-profile contract), the ledger's ring
    count honors the policy's own effective depth, and the tuned step
    trains to finite loss."""
    from repro.launch.train import build_everything
    from repro.tune import GB, resolve

    built = build_everything("gpt-350m", (4, 2), "zeropp", reduced=True,
                             batch=16, seq=64, lr=3e-3, tune="static",
                             hbm_gb=16.0)
    pol = built.policy
    assert pol is not None and pol.mode == "static", pol
    assert pol.ledger is not None and pol.ledger.fits, pol.ledger.as_dict()
    again = resolve(built.arch, ("data", "model"), "zeropp", mode="static",
                    mesh_sizes={"data": 4, "model": 2},
                    hbm_budget_bytes=16 * GB,
                    tokens_per_device=16 * 64 // 8)
    assert pol == again, (pol, again)
    k_eff = pol.zcfg.effective_prefetch(built.model.n_periods)
    assert dict(pol.ledger.ring_buffers)["layers"] == k_eff + 1
    mesh, arch, model, opt_cfg, ts, lm = built
    _, _, losses = _run_steps(mesh, arch, model, opt_cfg, ts, lm, 2, 16)
    assert np.isfinite(losses).all(), losses
