"""Run multi-device checks in a subprocess with simulated host devices.

jax pins the device count at first backend init, and the main pytest process
must keep seeing exactly one CPU device (see dryrun.py's device-count note).
Multi-device semantics are therefore exercised by spawning a fresh python
with ``--xla_force_host_platform_device_count=N`` and invoking a named check
function from :mod:`repro.testing.checks`.
"""
from __future__ import annotations

import os
import subprocess
import sys
from typing import Optional, Sequence

_SNIPPET = """
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
from repro.testing import checks
fns = {fns!r}
for fn in fns:
    getattr(checks, fn)()
    print("PASS", fn)
"""


def run_checks(fn_names: Sequence[str], n_devices: int = 8,
               timeout: int = 600, extra_env: Optional[dict] = None) -> str:
    """Run named functions from repro.testing.checks under N host devices.

    Raises AssertionError with the subprocess output on failure; returns the
    combined stdout on success.
    """
    env = dict(os.environ)
    src_dir = os.path.join(os.path.dirname(__file__), "..", "..")
    env["PYTHONPATH"] = os.path.abspath(src_dir) + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    if extra_env:
        env.update(extra_env)
    code = _SNIPPET.format(n=n_devices, fns=list(fn_names))
    proc = subprocess.run(
        [sys.executable, "-c", code],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    out = proc.stdout + proc.stderr
    if proc.returncode != 0:
        raise AssertionError(
            f"multi-device check {fn_names} failed (rc={proc.returncode}):\n{out}")
    for fn in fn_names:
        assert f"PASS {fn}" in out, f"missing PASS marker for {fn}:\n{out}"
    return out
