"""Deterministic fault injection for the elastic training runtime.

Two layers:

  * **In-process** — :class:`StepFaults` injects worker death / preemption
    at exact step numbers into ``train/elastic.Supervisor``, and the
    ``IOHooks`` implementations (:class:`FlakyIO`, :class:`SlowIO`,
    :class:`CrashBeforeManifest`) plug into ``ZeroState.save``'s commit
    protocol to simulate transient write errors, slow storage, and a crash
    between the shard write and the manifest commit.  File mutators
    (:func:`truncate_shard`, :func:`corrupt_shard`) damage a committed
    checkpoint the way real storage does.
  * **Subprocess** — :func:`spawn_train` / :func:`kill_on_marker` run
    ``repro.launch.train --elastic`` under forced 8-device XLA (same env
    recipe as testing/subproc.py) and deliver REAL signals (SIGKILL mid
    slowed write, SIGTERM with a grace deadline) keyed on stdout markers,
    because an in-process "crash" cannot skip ``finally`` cleanup — only a
    real kill leaves genuine staging debris behind.

Everything here is test-only; production code never imports this module.
"""
from __future__ import annotations

import dataclasses
import os
import re
import signal
import subprocess
import sys
import threading
import time
from typing import Dict, List, Optional, Tuple

# the exception the supervisor handles is owned by the runtime, so
# production code never has to import this harness to catch it
from repro.train.elastic import WorkerDeath  # noqa: F401

__all__ = [
    "WorkerDeath", "StepFaults", "FlakyIO", "SlowIO",
    "CrashBeforeManifest", "ChainedHooks", "truncate_file", "corrupt_file",
    "truncate_shard", "corrupt_shard", "make_stale_staging", "spawn_train",
    "run_train", "kill_on_marker", "parse_losses",
]


# ---------------------------------------------------------------------------
# step-boundary fault plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepFaults:
    """step -> action map consulted by the supervisor at each step
    boundary.  Actions: ``"die"`` (raise WorkerDeath), ``"preempt"``
    (request a graceful preemption).  Each fires exactly once."""

    actions: Dict[int, str]
    fired: List[Tuple[int, str]] = dataclasses.field(default_factory=list)

    def take(self, step: int) -> Optional[str]:
        action = self.actions.pop(step, None)
        if action is not None:
            self.fired.append((step, action))
        return action


# ---------------------------------------------------------------------------
# IOHooks implementations (the ZeroState.save seam)
# ---------------------------------------------------------------------------

class FlakyIO:
    """First ``n_failures`` shard writes raise OSError — a transient
    storage error the save path must absorb via retry-with-backoff."""

    def __init__(self, n_failures: int):
        self.remaining = int(n_failures)
        self.calls = 0

    def post_shard(self, path: str) -> None:
        self.calls += 1
        if self.remaining > 0:
            self.remaining -= 1
            raise OSError(
                f"injected transient write error on {os.path.basename(path)}"
                f" ({self.remaining} more to come)")


class SlowIO:
    """Sleeps ``delay`` seconds after each shard write: a slow writer for
    overlap measurement and backpressure / abandon-window tests."""

    def __init__(self, delay: float):
        self.delay = float(delay)
        self.calls = 0

    def post_shard(self, path: str) -> None:
        self.calls += 1
        time.sleep(self.delay)


class CrashBeforeManifest:
    """Aborts every save between the shard write and the manifest commit —
    the staged shards exist but the checkpoint is never published."""

    def pre_manifest(self, staging: str) -> None:
        raise OSError("injected crash before manifest commit")


class ChainedHooks:
    """Compose several hook objects; each stage runs them in order."""

    def __init__(self, hooks):
        self.hooks = [h for h in hooks if h is not None]

    def _fan(self, name: str, *args) -> None:
        for h in self.hooks:
            fn = getattr(h, name, None)
            if fn is not None:
                fn(*args)

    def post_shard(self, path: str) -> None:
        self._fan("post_shard", path)

    def pre_manifest(self, staging: str) -> None:
        self._fan("pre_manifest", staging)

    def pre_publish(self, staging: str, final: str) -> None:
        self._fan("pre_publish", staging, final)


# ---------------------------------------------------------------------------
# on-disk damage to committed checkpoints
# ---------------------------------------------------------------------------

def truncate_file(path: str, frac: float = 0.5) -> str:
    """Cut a file to ``frac`` of its size — a write interrupted mid-way."""
    size = os.path.getsize(path)
    with open(path, "rb+") as f:
        f.truncate(max(1, int(size * frac)))
    return path

def corrupt_file(path: str, offset: Optional[int] = None,
                 nbytes: int = 16) -> str:
    """Flip bytes mid-file (silent bit-rot: size unchanged, crc breaks)."""
    size = os.path.getsize(path)
    if offset is None:
        offset = size // 2
    with open(path, "rb+") as f:
        f.seek(offset)
        chunk = f.read(nbytes)
        f.seek(offset)
        f.write(bytes(b ^ 0xFF for b in chunk))
    return path


def _first_shard(ckpt_path: str) -> str:
    names = sorted(n for n in os.listdir(ckpt_path)
                   if n.startswith("shard_") and n.endswith(".npz"))
    assert names, f"no shard files in {ckpt_path}"
    return os.path.join(ckpt_path, names[0])


def truncate_shard(ckpt_path: str, frac: float = 0.5) -> str:
    return truncate_file(_first_shard(ckpt_path), frac)


def corrupt_shard(ckpt_path: str) -> str:
    return corrupt_file(_first_shard(ckpt_path))


def make_stale_staging(ckpt_dir: str, step: int) -> str:
    """Fabricate the debris a crash mid-write leaves: a ``ckpt_<step>.tmp``
    staging dir holding a partial shard and no manifest."""
    staging = os.path.join(ckpt_dir, f"ckpt_{step}.tmp")
    os.makedirs(staging, exist_ok=True)
    with open(os.path.join(staging, "shard_00000.npz"), "wb") as f:
        f.write(b"PK\x03\x04 partial garbage")
    return staging


# ---------------------------------------------------------------------------
# subprocess harness: real processes, real signals
# ---------------------------------------------------------------------------

_LOSS_RE = re.compile(r"\[elastic\] step (\d+) loss ([-+0-9.eE]+)")


def _train_env(n_devices: int) -> Dict[str, str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    env["PYTHONPATH"] = os.path.join(root, "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    return env


def spawn_train(args: List[str], n_devices: int = 8) -> subprocess.Popen:
    """Launch ``python -m repro.launch.train <args>`` with line-buffered
    merged stdout, under a forced ``n_devices``-device CPU topology."""
    return subprocess.Popen(
        [sys.executable, "-u", "-m", "repro.launch.train", *args],
        env=_train_env(n_devices), stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True, bufsize=1)


def run_train(args: List[str], n_devices: int = 8,
              timeout: float = 600.0) -> List[str]:
    """Run a launch to completion; returns stdout lines, asserts rc 0."""
    proc = spawn_train(args, n_devices)
    out, _ = proc.communicate(timeout=timeout)
    lines = out.splitlines()
    assert proc.returncode == 0, \
        f"train exited rc={proc.returncode}:\n" + "\n".join(lines[-40:])
    return lines


def kill_on_marker(args: List[str], marker: str,
                   sig: int = signal.SIGKILL, delay: float = 0.0,
                   n_devices: int = 8, timeout: float = 600.0,
                   ) -> Tuple[int, List[str]]:
    """Launch a training subprocess, watch stdout for ``marker``, then
    (after ``delay`` seconds) deliver ``sig``.  Returns (rc, lines)."""
    proc = spawn_train(args, n_devices)
    lines: List[str] = []
    seen = threading.Event()

    def reader():
        for line in proc.stdout:
            lines.append(line.rstrip("\n"))
            if marker in line:
                seen.set()
        seen.set()   # EOF: stop waiting even if the marker never appeared

    t = threading.Thread(target=reader, daemon=True)
    t.start()
    assert seen.wait(timeout), \
        f"marker {marker!r} never appeared:\n" + "\n".join(lines[-40:])
    if proc.poll() is None:
        if delay:
            time.sleep(delay)
        try:
            os.kill(proc.pid, sig)
        except ProcessLookupError:
            pass
    rc = proc.wait(timeout=timeout)
    t.join(timeout=30)
    assert marker in "\n".join(lines), \
        f"process exited before marker {marker!r}:\n" + "\n".join(lines[-40:])
    return rc, lines


def parse_losses(lines: List[str]) -> Dict[int, float]:
    """Per-step losses from supervisor markers; a later occurrence of the
    same step (post-resume recompute) overwrites the earlier one."""
    out: Dict[int, float] = {}
    for line in lines:
        m = _LOSS_RE.search(line)
        if m:
            out[int(m.group(1))] = float(m.group(2))
    return out
