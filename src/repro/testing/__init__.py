from repro.testing import checks, subproc  # noqa: F401
