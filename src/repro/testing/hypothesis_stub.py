"""Drop-in stand-ins for ``hypothesis`` when it is not installed.

``@given(...)`` tests become pytest skips; every other test in the module
still runs.  Strategy expressions (``st.integers(...)``) evaluate to inert
placeholders so module-level decorators don't raise at import time.
"""
from __future__ import annotations

import pytest


class _Strategies:
    def __getattr__(self, name):
        return lambda *a, **k: None


st = _Strategies()


def given(*args, **kwargs):
    def deco(fn):
        @pytest.mark.skip(reason="property test: hypothesis not installed")
        def _skipped():
            pass
        _skipped.__name__ = fn.__name__
        _skipped.__doc__ = fn.__doc__
        return _skipped
    return deco


def settings(*args, **kwargs):
    return lambda fn: fn
