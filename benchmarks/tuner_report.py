"""Boot-time tuner report: static resolution across archs x meshes.

Sweeps ``repro.tune.resolve`` in ``--tune=static`` mode (the committed
deterministic profile — no devices, no probing, microseconds per cell)
over representative archs and mesh shapes, and emits one BENCH json with,
per cell:

  * every resolved ZeRO++ knob (prefetch, qwZ/hpZ/qgZ + block sizes,
    moments dtype, accum, kernel backend) and the decision trail;
  * the (k+1)-ring HBM ledger (total, ring bytes, headroom, fits);
  * the throughput model's break-even ring depth evaluated with the
    profile's probed coefficients (``throughput_model.ring_coeffs``).

The sweep is deterministic by the static-profile contract, so the
snapshot ``snapshots/BENCH_tuner.json`` is committed and ``main()``
compares the fresh sweep against it exactly — any drift in resolver
behaviour fails the benchmark run (and CI's tune-smoke).  Refresh the
snapshot deliberately with ``--write-snapshot`` after an intentional
resolver change.

Run: PYTHONPATH=src python -m benchmarks.tuner_report [--write-snapshot]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

GB = 1 << 30

SNAPSHOT = os.path.join(os.path.dirname(__file__), "snapshots",
                        "BENCH_tuner.json")

# arch x mesh cells: a dense smoke model, the paper-scale dense stacks,
# and the MoE config (expert-chunk ring + total-vs-active param split)
CELLS = [
    ("gpt-350m", {"data": 4, "model": 2}),
    ("gpt-18b", {"data": 16, "model": 16}),
    ("qwen1.5-110b", {"pod": 2, "data": 16, "model": 16}),
    ("deepseek-moe-16b", {"data": 16, "model": 16}),
]

TOKENS_PER_DEVICE = 2048
HBM_BUDGET = 16 * GB          # v5e


def _cell(arch_name: str, sizes: Dict[str, int]) -> Dict:
    from repro.configs import get_config
    from repro.tune import resolve
    from benchmarks import throughput_model as tm

    arch = get_config(arch_name)
    axes = tuple(sizes)
    rp = resolve(arch, axes, "zeropp", mode="static", mesh_sizes=sizes,
                 hbm_budget_bytes=HBM_BUDGET,
                 tokens_per_device=TOKENS_PER_DEVICE)
    d = rp.as_dict()
    led = d.get("ledger", {})
    ring = sum(b for n, b in led.get("lines", {}).items()
               if n.startswith("ring_"))
    world = 1
    for s in sizes.values():
        world *= s
    coeffs = tm.ring_coeffs(rp.profile)
    be = tm.break_even_depth(rp.n_params // world, TOKENS_PER_DEVICE,
                             "zeropp", n_layers=arch.n_layers, **coeffs)
    return {
        "mesh": dict(sizes),
        "policy": {k: d[k] for k in
                   ("mode", "kernel_backend", "n_params", "train_accum",
                    "moments_dtype", "qwz", "hpz", "qgz", "qwz_block",
                    "qgz_block", "hpz_axes", "prefetch",
                    "profile_source")},
        "decisions": d["decisions"],
        "ledger": {"total_bytes": led.get("total_bytes"),
                   "ring_bytes": ring,
                   "headroom_bytes": led.get("headroom_bytes"),
                   "fits": led.get("fits"),
                   "ring_buffers": led.get("ring_buffers")},
        "break_even_depth": be,
        "probed_coeffs": {k: float(v) for k, v in coeffs.items()},
    }


def sweep() -> Dict:
    cells = {}
    for arch_name, sizes in CELLS:
        mesh_tag = "x".join(str(sizes[a]) for a in sizes)
        cells[f"{arch_name}@{mesh_tag}"] = _cell(arch_name, sizes)
    return {"tuner": {
        "cells": cells,
        "config": {"mode": "static", "hbm_budget_bytes": HBM_BUDGET,
                   "tokens_per_device": TOKENS_PER_DEVICE,
                   "variant": "zeropp"},
    }}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--write-snapshot", action="store_true",
                    help=f"refresh {SNAPSHOT}")
    args, _ = ap.parse_known_args()

    doc = sweep()
    print("BENCH " + json.dumps(doc))
    print(f"\n{'cell':<28} {'pf':>2} {'qwZ':>4} {'hpZ':>4} {'qgZ':>4} "
          f"{'ledger_gb':>9} {'ring_gb':>8} {'fits':>5} {'breakeven':>9}")
    for name, c in doc["tuner"]["cells"].items():
        p, led = c["policy"], c["ledger"]
        print(f"{name:<28} {p['prefetch']:>2} {str(p['qwz']):>4} "
              f"{str(p['hpz']):>4} {str(p['qgz']):>4} "
              f"{led['total_bytes'] / GB:>9.2f} "
              f"{led['ring_bytes'] / GB:>8.3f} {str(led['fits']):>5} "
              f"{c['break_even_depth']:>9}")

    if args.write_snapshot:
        with open(SNAPSHOT, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {SNAPSHOT}")
    elif os.path.exists(SNAPSHOT):
        with open(SNAPSHOT) as fh:
            want = json.load(fh)
        # static resolution is deterministic by contract: exact equality
        assert doc == want, (
            "tuner sweep drifted from committed snapshot — intentional "
            "resolver changes must refresh it via --write-snapshot")
        print(f"snapshot check OK ({SNAPSHOT})")
    return doc


if __name__ == "__main__":
    main()
