"""Paper Fig. 14 / Table 5: convergence of ZeRO++ vs baseline vs
non-blocked quantization.

Trains the reduced GPT config on the deterministic synthetic LM (8
simulated devices, identical data order across variants) and compares loss
curves:
  * zeropp (blocked INT8/INT4) must track the ZeRO-3 baseline closely;
  * zeropp with NON-blocked (single-scale) weight quantization must be
    clearly worse / unstable — the paper's divergence result.

``--elastic`` instead compares an INTERRUPTED run (worker death mid-run,
resume from the latest async checkpoint via the elastic supervisor)
against the uninterrupted oracle: the replayed curve must be bit-exact.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.data.synthetic import SyntheticLM, make_batch
from repro.configs import get_config
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import warmup_cosine
from repro.train import trainer as trainer_lib
from repro.train.policy import make_policy
from repro.train.trainer import init_state, place_batch
from repro.core.compat import make_mesh

STEPS = int(os.environ.get("CONV_STEPS", "40"))
arch = get_config("gpt-350m").reduced()
mesh = make_mesh((4, 2), ("data", "model"))
lm = SyntheticLM(vocab=arch.vocab, seq_len=64, seed=11)
out = {"entropy_bound": lm.entropy_bound}
for name, variant, overrides in [
    ("baseline", "baseline", {}),
    ("zeropp", "zeropp", {}),
    ("zeropp_nonblocked", "zeropp", {"qwz_blocked": False}),
]:
    pol = make_policy(arch, tuple(mesh.axis_names), variant, **overrides)
    model = Model(arch, pol.zcfg, world=8)
    opt_cfg = AdamWConfig(lr=warmup_cosine(3e-3, 10, 10000),
                          moments_dtype=pol.moments_dtype)
    ts = trainer_lib.build_train_step(model, mesh, opt_cfg,
                                      global_batch=16)
    params, opt = init_state(model, mesh, opt_cfg, jax.random.PRNGKey(0))
    losses = []
    for i in range(STEPS):
        b = place_batch(make_batch(arch, lm, i, 16), mesh, ts.in_specs[2])
        params, opt, m = ts.fn(params, opt, b)
        losses.append(float(m["loss"]))
    out[name] = losses
print("RESULT " + json.dumps(out))
"""


_ELASTIC_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import tempfile
from repro.testing.faults import StepFaults
from repro.train.elastic import ElasticConfig, Supervisor

STEPS = int(os.environ.get("CONV_STEPS", "24"))
DIE_AT = STEPS // 2
oracle = Supervisor(ElasticConfig(steps=STEPS, log=False)).run_supervised()
d = tempfile.mkdtemp(prefix="conv_elastic_")
hit = Supervisor(ElasticConfig(steps=STEPS, ckpt_dir=d, ckpt_every=4,
                               log=False),
                 faults=StepFaults({DIE_AT: "die"})).run_supervised()
out = {"die_at": DIE_AT, "restarts": hit["restarts"],
       "writer_stats": hit["writer_stats"],
       "oracle": [oracle["losses"][i] for i in range(STEPS)],
       "interrupted": [hit["losses"][i] for i in range(STEPS)]}
print("RESULT " + json.dumps(out))
"""


def _run_snippet(snippet: str, steps: int):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    env["CONV_STEPS"] = str(steps)
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=3600)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"convergence run failed:\n{r.stdout}\n{r.stderr}")


def run(steps: int = 40):
    return _run_snippet(_SNIPPET, steps)


def run_elastic(steps: int = 24):
    """Interrupted-vs-uninterrupted: a worker death mid-run (resume from
    the latest async checkpoint) must not perturb convergence AT ALL —
    fp32 state + deterministic data makes the replayed curve bit-exact."""
    return _run_snippet(_ELASTIC_SNIPPET, steps)


def main_elastic(steps: int = 24):
    out = run_elastic(steps)
    o, h = out["oracle"], out["interrupted"]
    print(f"# elastic: worker death at step {out['die_at']} "
          f"(restarts={out['restarts']}) vs uninterrupted")
    print("step,uninterrupted,interrupted")
    for i in range(0, len(o), max(1, len(o) // 8)):
        print(f"{i},{o[i]!r},{h[i]!r}")
    diff = max(abs(a - b) for a, b in zip(o, h))
    print(f"max_abs_loss_diff,{diff!r}")
    print(f"bit_exact,{diff == 0.0}")
    ws = out["writer_stats"]
    print(f"async_writes,{ws['completed']} "
          f"steps_overlapped,{ws['steps_overlapped']}")
    return out


def main(steps: int = 40):
    out = run(steps)
    b = out["baseline"]
    z = out["zeropp"]
    n = out["zeropp_nonblocked"]
    print("# Fig 14 / Table 5 analogue (reduced GPT, synthetic LM)")
    print("step,baseline,zeropp,zeropp_nonblocked")
    for i in range(0, len(b), max(1, len(b) // 10)):
        print(f"{i},{b[i]:.4f},{z[i]:.4f},{n[i]:.4f}")
    print(f"final,{b[-1]:.4f},{z[-1]:.4f},{n[-1]:.4f}")
    gap = abs(z[-1] - b[-1]) / b[-1]
    print(f"zeropp_final_gap,{gap*100:.2f}%")
    print(f"nonblocked_final_gap,{(n[-1]-b[-1])/b[-1]*100:.2f}%")
    print(f"entropy_bound,{out['entropy_bound']:.4f}")
    return out


if __name__ == "__main__":
    if "--elastic" in sys.argv:
        main_elastic()
    else:
        main()
