"""Paper Table 3: impact of optimized / fused quantization kernels.

Two measurements on CPU:
  * wall-clock of the quantization pipeline run STAGED (three separate jit
    calls — dequant, reduce, requant each materializing its output, the
    PyTorch-op-sequence analogue) vs FUSED (single jit of the fused op the
    Pallas kernel implements) — the end-to-end fusion effect XLA can see.
  * the analytic HBM-traffic ratio of the same two schedules (the paper's
    "reduces total memory traffic by 9x" claim for dequant+reduce+quant).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig, dequantize_blockwise, \
    quantize_blockwise
from repro.kernels import ref as kref


def traffic_ratio(n_contrib: int, n_elems: int, bits: int, block: int):
    """Bytes touched: staged (materialize fp32 between stages) vs fused."""
    pay = n_contrib * (n_elems // (8 // bits))
    scales = n_contrib * (n_elems // block) * 4
    f32 = n_contrib * n_elems * 4
    out_pay = n_elems // (8 // bits)
    out_scales = (n_elems // block) * 4
    staged = (pay + scales + f32) + (f32 + n_elems * 4) \
        + (n_elems * 4 + out_pay + out_scales)
    fused = pay + scales + out_pay + out_scales
    return staged, fused, staged / fused


def _time(fn, *args, reps=20):
    fn(*args)  # compile + warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def main():
    cfg = QuantConfig(bits=4, block_size=256)
    N, C = 8, 1 << 20  # 8 contributions x 1M elements
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((N, C)).astype(np.float32))
    p, s = quantize_blockwise(x, cfg)

    stage_deq = jax.jit(lambda p, s: dequantize_blockwise(p, s, cfg))
    stage_red = jax.jit(lambda d: jnp.sum(d, axis=0))
    stage_q = jax.jit(lambda a: quantize_blockwise(a, cfg))
    fused = jax.jit(lambda p, s: kref.dequant_reduce_quant_ref(p, s, cfg, cfg))

    def staged(p, s):
        d = stage_deq(p, s)
        a = stage_red(d)
        return stage_q(a)

    t_staged = _time(staged, p, s)
    t_fused = _time(fused, p, s)
    st, fu, ratio = traffic_ratio(N, C, 4, 256)

    print("# Table 3 analogue: fused dequant+reduce+requant (qgZ inner op)")
    print("schedule,wall_us,traffic_bytes")
    print(f"staged,{t_staged*1e6:.0f},{st}")
    print(f"fused,{t_fused*1e6:.0f},{fu}")
    print(f"speedup,{t_staged/t_fused:.2f}x,traffic_ratio={ratio:.1f}x")

    # quantize throughput: blocked quant of a big weight tensor
    w = jnp.asarray(rng.standard_normal((1, 1 << 22)).astype(np.float32))
    qf = jax.jit(lambda w: quantize_blockwise(w, QuantConfig(bits=8,
                                                             block_size=256)))
    t_q = _time(qf, w)
    gbps = w.size * 4 / t_q / 1e9
    print(f"quantize_int8_gbps,{gbps:.1f}")
    return {"staged_us": t_staged * 1e6, "fused_us": t_fused * 1e6,
            "traffic_ratio": ratio}


if __name__ == "__main__":
    main()
