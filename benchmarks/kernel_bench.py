"""Paper Table 3: impact of optimized / fused quantization kernels.

Measures, PER KERNEL BACKEND (kernels/ops.py: xla reference, interpret =
real Pallas kernel bodies through the interpreter, pallas when on TPU):

  * fused_reduce_quant — the qgZ inner op (paper §4.2 "reduces total
    memory traffic by 9x").  STAGED = three separate jit calls through the
    backend's unfused ops (dequant materializing fp32, reduce, requant),
    the PyTorch-op-sequence analogue; FUSED = the single
    ops.dequant_reduce_quant call.
  * dequant_gemm — the serving head.  STAGED = dequantize the whole INT8
    weight matrix to bf16 then einsum; FUSED = ops.dequant_matmul (scales
    applied inside the k-tile loop, no bf16 weight matrix in HBM).
  * quantize_int8_gbps — blocked quant throughput of a big weight tensor.

Plus backend-independent ANALYTIC HBM-traffic ratios for both fusions.
Emits one BENCH json line (snapshot: benchmarks/snapshots/BENCH_kernels.json).
"""
from __future__ import annotations

import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig, quantize_blockwise
from repro.kernels import ops, platform


def traffic_ratio(n_contrib: int, n_elems: int, bits: int, block: int):
    """qgZ inner op, bytes touched: staged (fp32 materialized between every
    stage) vs fused (inputs + outputs only)."""
    pay = n_contrib * (n_elems // (8 // bits))
    scales = n_contrib * (n_elems // block) * 4
    f32 = n_contrib * n_elems * 4
    out_pay = n_elems // (8 // bits)
    out_scales = (n_elems // block) * 4
    staged = (pay + scales + f32) + (f32 + n_elems * 4) \
        + (n_elems * 4 + out_pay + out_scales)
    fused = pay + scales + out_pay + out_scales
    return staged, fused, staged / fused


def gemm_traffic_ratio(T: int, N: int, K: int, block: int):
    """Serving head, bytes touched: staged (INT8 in, bf16 weight matrix
    written then re-read by the GEMM) vs fused (INT8 straight to the MXU).
    Activations/outputs are identical on both sides and included."""
    pay = N * K                       # int8
    scales = (N * K // block) * 4
    x = T * K * 4
    out = T * N * 4
    w_bf16 = N * K * 2
    staged = (pay + scales + w_bf16) + (w_bf16 + x + out)
    fused = pay + scales + x + out
    return staged, fused, staged / fused


def _time(fn, *args, reps=10):
    jax.block_until_ready(fn(*args))  # compile + warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _bench_backend(p, s, cfg, x_act, w_pay, w_scales, wq, reps):
    """All three measurements under the CURRENTLY forced backend."""
    out = {}

    # qgZ inner fusion: staged = the backend's own unfused ops, 3 jits
    stage_deq = jax.jit(lambda p, s: ops.dequantize_blockwise(p, s, cfg))
    stage_red = jax.jit(lambda d: jnp.sum(d, axis=0))
    stage_q = jax.jit(lambda a: ops.quantize_blockwise(a, cfg))
    fused = jax.jit(lambda p, s: ops.dequant_reduce_quant(p, s, cfg, cfg))

    def staged(p, s):
        return stage_q(stage_red(stage_deq(p, s)))

    t_staged = _time(staged, p, s, reps=reps)
    t_fused = _time(fused, p, s, reps=reps)
    out["fused_reduce_quant"] = {
        "staged_us": t_staged * 1e6, "fused_us": t_fused * 1e6,
        "speedup": t_staged / t_fused}

    # serving head GEMM: staged = whole-matrix dequant + einsum, 2 jits
    kb = w_pay.size // w_scales.size
    gcfg = QuantConfig(bits=8, block_size=kb)
    g_deq = jax.jit(lambda p, s: ops.dequantize_blockwise(p, s, gcfg,
                                                          jnp.bfloat16))
    g_mm = jax.jit(lambda x, w: jnp.einsum(
        "tk,nk->tn", x, w, preferred_element_type=jnp.float32))
    g_fused = jax.jit(lambda x, p, s: ops.dequant_matmul(x, p, s))

    def g_staged(x, p, s):
        return g_mm(x, g_deq(p, s))

    t_gs = _time(g_staged, x_act, w_pay, w_scales, reps=reps)
    t_gf = _time(g_fused, x_act, w_pay, w_scales, reps=reps)
    out["dequant_gemm"] = {
        "staged_us": t_gs * 1e6, "fused_us": t_gf * 1e6,
        "speedup": t_gs / t_gf}

    # blocked-quant throughput
    qf = jax.jit(lambda w: ops.quantize_blockwise(
        w, QuantConfig(bits=8, block_size=256)))
    t_q = _time(qf, wq, reps=reps)
    out["quantize_int8_gbps"] = wq.size * 4 / t_q / 1e9
    return out


def main(smoke: bool = False):
    cfg = QuantConfig(bits=4, block_size=256)
    N, C = (4, 1 << 16) if smoke else (8, 1 << 20)
    T, NR, K = (16, 256, 1024) if smoke else (64, 2048, 4096)
    gemm_block = 256
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((N, C)).astype(np.float32))
    p, s = quantize_blockwise(x, cfg)
    x_act = jnp.asarray(rng.standard_normal((T, K)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((NR, K)).astype(np.float32))
    w_pay, w_scales = quantize_blockwise(
        w, QuantConfig(bits=8, block_size=gemm_block))
    wq = jnp.asarray(rng.standard_normal(
        (1, 1 << (18 if smoke else 22))).astype(np.float32))
    reps = 3 if smoke else 10

    backends = ["xla", "interpret"]
    if platform.is_tpu():
        backends.append("pallas")
    per_backend = {}
    for be in backends:
        with ops.use_backend(be):
            per_backend[be] = _bench_backend(p, s, cfg, x_act, w_pay,
                                             w_scales, wq, reps)

    st, fu, ratio = traffic_ratio(N, C, 4, 256)
    gst, gfu, gratio = gemm_traffic_ratio(T, NR, K, gemm_block)
    traffic = {
        "fused_reduce_quant": {"staged_bytes": st, "fused_bytes": fu,
                               "ratio": ratio},
        "dequant_gemm": {"staged_bytes": gst, "fused_bytes": gfu,
                         "ratio": gratio},
    }

    print("# Table 3 analogue: fused vs staged quantized hot-path kernels")
    print("backend,op,staged_us,fused_us,speedup")
    for be, r in per_backend.items():
        for op_name in ("fused_reduce_quant", "dequant_gemm"):
            d = r[op_name]
            print(f"{be},{op_name},{d['staged_us']:.0f},{d['fused_us']:.0f},"
                  f"{d['speedup']:.2f}x")
        print(f"{be},quantize_int8_gbps,{r['quantize_int8_gbps']:.1f}")
    print(f"analytic_traffic_ratio,fused_reduce_quant,{ratio:.1f}x")
    print(f"analytic_traffic_ratio,dequant_gemm,{gratio:.2f}x")

    res = {"backends": per_backend, "traffic": traffic,
           "shapes": {"reduce_quant": [N, C], "gemm": [T, NR, K],
                      "smoke": smoke}}
    print("BENCH " + json.dumps({"kernels": res}))
    return res


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
