"""Paper Fig. 4 + Table 4: per-device memory — DP vs ZeRO-3 vs hpZ vs MiCS.

Analytic reproduction of the paper's memory analysis with this repo's
actual byte layout (fp32 master IS the parameter buffer: K = 4 master +
4+4 moments = 12 B/param fp32, or 4+2+2 = 8 B/param with bf16 moments),
plus the paper's Table 4 OOM argument evaluated against v5e's 16 GB.
"""
from __future__ import annotations

GB = 1 << 30


def per_device_bytes(n_params: float, world: int, secondary: int,
                     scheme: str, k_bytes: float = 12.0) -> float:
    """Persistent model-state bytes per device (no activations)."""
    M2 = 2.0 * n_params            # bf16 weights
    opt = k_bytes * n_params       # master + moments (fp32 path)
    if scheme == "dp":             # replicate everything
        return M2 + opt
    if scheme == "zero3":
        return (M2 + opt) / world
    if scheme == "hpz":            # + secondary bf16 copy per group
        return (M2 + opt) / world + M2 / secondary
    if scheme == "mics":           # ALL state replicated per group
        return (M2 + opt) / secondary
    raise ValueError(scheme)


def main():
    print("# Fig 4 analogue: 100B model, world=1024, secondary group=16")
    print("scheme,bytes_per_device_gb,vs_zero3")
    n, world, sec = 100e9, 1024, 16
    z3 = per_device_bytes(n, world, sec, "zero3")
    for scheme in ("dp", "zero3", "hpz", "mics"):
        b = per_device_bytes(n, world, sec, scheme)
        print(f"{scheme},{b/GB:.2f},{b/z3:.1f}x")

    print("# Table 4 analogue: hpZ vs MiCS fit on one node group (16 chips)")
    print("model,scheme,bytes_gb,fits_16gb_hbm(+4gb_act)")
    for name, n in (("7.5B", 7.5e9), ("18B", 18e9)):
        for scheme in ("zero3", "hpz", "mics"):
            b = per_device_bytes(n, 64, 16, scheme)
            fits = (b + 4 * GB) <= 16 * GB
            print(f"{name},{scheme},{b/GB:.2f},{fits}")

    print("# this repo's large-model policy (v5e 16GB): 235B on 256 chips")
    n = 235e9
    for k, tag in ((12.0, "fp32_moments"), (8.0, "bf16_moments")):
        for scheme, sec in (("zero3", 16), ("hpz", 16), ("hpz", 256)):
            b = per_device_bytes(n, 256, sec, scheme, k)
            print(f"235B,{scheme}(sec={sec},{tag}),{b/GB:.2f},"
                  f"{b <= 12 * GB}")


if __name__ == "__main__":
    main()
