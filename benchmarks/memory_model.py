"""Paper Fig. 4 + Table 4: per-device memory — DP vs ZeRO-3 vs hpZ vs MiCS.

Analytic reproduction of the paper's memory analysis with this repo's
actual byte layout (fp32 master IS the parameter buffer: K = 4 master +
4+4 moments = 12 B/param fp32, or 4+2+2 = 8 B/param with bf16 moments),
plus the paper's Table 4 OOM argument evaluated against v5e's 16 GB.

The prefetch-ring term: a run with weight-gather lookahead ``k`` keeps
(k+1) fully-gathered layer buffers live per device — k ring slots in the
scan carry plus the dynamic-index read copy — and the backward pass adds
k unreduced per-layer gradient slots (both in the bf16 compute dtype).
``per_device_bytes`` charges this when given ``layer_params``/``prefetch``;
the legacy call shape (both omitted) keeps the old persistent-state-only
number so BENCH snapshots produced before the ring term existed still
compare cleanly.  ``repro.tune.memory`` is the authoritative per-line
ledger; this module is the closed-form scheme comparison.
"""
from __future__ import annotations

from typing import Optional

GB = 1 << 30

_COMPUTE_BYTES = 2.0     # bf16 compute dtype: gathered weights + grads


def ring_bytes(layer_params: float, prefetch: int,
               compute_bytes: float = _COMPUTE_BYTES) -> float:
    """Live prefetch-ring bytes per device: (k+1) gathered weight buffers
    plus k backward unreduced-gradient slots, each ``layer_params`` big."""
    k = max(int(prefetch), 0)
    return compute_bytes * layer_params * ((k + 1) + k)


def per_device_bytes(n_params: float, world: int, secondary: int,
                     scheme: str, k_bytes: float = 12.0,
                     layer_params: float = 0.0,
                     prefetch: Optional[int] = None) -> float:
    """Persistent model-state bytes per device (no activations).

    ``layer_params`` + ``prefetch`` add the (k+1)-ring live-buffer term;
    omitting them (the legacy signature) reproduces the historical
    under-reported number — compat path for old BENCH snapshots.
    """
    M2 = 2.0 * n_params            # bf16 weights
    opt = k_bytes * n_params       # master + moments (fp32 path)
    if scheme == "dp":             # replicate everything
        base = M2 + opt
    elif scheme == "zero3":
        base = (M2 + opt) / world
    elif scheme == "hpz":          # + secondary bf16 copy per group
        base = (M2 + opt) / world + M2 / secondary
    elif scheme == "mics":         # ALL state replicated per group
        base = (M2 + opt) / secondary
    else:
        raise ValueError(scheme)
    if prefetch is not None and layer_params > 0:
        base += ring_bytes(layer_params, prefetch)
    return base


def main():
    print("# Fig 4 analogue: 100B model, world=1024, secondary group=16")
    print("scheme,bytes_per_device_gb,vs_zero3")
    n, world, sec = 100e9, 1024, 16
    z3 = per_device_bytes(n, world, sec, "zero3")
    for scheme in ("dp", "zero3", "hpz", "mics"):
        b = per_device_bytes(n, world, sec, scheme)
        print(f"{scheme},{b/GB:.2f},{b/z3:.1f}x")

    print("# Table 4 analogue: hpZ vs MiCS fit on one node group (16 chips)")
    print("model,scheme,bytes_gb,fits_16gb_hbm(+4gb_act)")
    for name, n in (("7.5B", 7.5e9), ("18B", 18e9)):
        for scheme in ("zero3", "hpz", "mics"):
            b = per_device_bytes(n, 64, 16, scheme)
            fits = (b + 4 * GB) <= 16 * GB
            print(f"{name},{scheme},{b/GB:.2f},{fits}")

    print("# this repo's large-model policy (v5e 16GB): 235B on 256 chips")
    n = 235e9
    for k, tag in ((12.0, "fp32_moments"), (8.0, "bf16_moments")):
        for scheme, sec in (("zero3", 16), ("hpz", 16), ("hpz", 256)):
            b = per_device_bytes(n, 256, sec, scheme, k)
            print(f"235B,{scheme}(sec={sec},{tag}),{b/GB:.2f},"
                  f"{b <= 12 * GB}")

    print("# prefetch-ring live buffers (the long under-reported term):")
    print("# 100B/80 layers on 256 chips, zero3 + ring at depth k")
    n, world, layers = 100e9, 256, 80
    lp = n / layers
    base = per_device_bytes(n, world, 16, "zero3", 8.0)
    for k in (0, 1, 2, 3):
        tot = per_device_bytes(n, world, 16, "zero3", 8.0,
                               layer_params=lp, prefetch=k)
        print(f"k={k},ring_gb={(tot-base)/GB:.2f},total_gb={tot/GB:.2f}")

    # cross-check the closed form against the authoritative per-line
    # ledger when the src tree is importable (repo checkout, CI)
    try:
        from repro.configs import get_config
        from repro.core.zeropp import ZeroConfig
        from repro.models.model import Model
        from repro.tune.memory import ring_lines
    except ImportError:
        return
    print("# ledger cross-check (repro.tune.memory.ring_lines):")
    arch = get_config("gpt-350m").reduced()
    for k in (0, 1, 2, 3):
        z = ZeroConfig(dp_axes=("data", "model"), prefetch=k)
        model = Model(arch, z, world=8)
        lines, _ = ring_lines(model)
        led = sum(l.bytes for l in lines)
        # the ledger charges the EFFECTIVE depth (clamped to n_periods-1:
        # a deeper ring would lap itself) — clamp the closed form to match
        k_eff = z.effective_prefetch(model.n_periods)
        closed = ring_bytes(model.period_spec.padded_size, k_eff)
        match = abs(led - closed) <= 1e-9 * max(led, 1)
        print(f"k={k},k_eff={k_eff},ledger={led},"
              f"closed_form={closed:.0f},match={match}")


if __name__ == "__main__":
    main()
