"""Runtime telemetry report: a real 8-device train run + serve ticks with
the obs/ subsystem on, exported in the BENCH schema.

One subprocess (simulated devices, see testing/subproc.py note) runs:

  1. a short telemetry-on training run through ``launch/train.train_loop``
     (``--metrics-dir`` path): jsonl event log, per-step wall-time
     histogram, per-label comm counters from the one-time jaxpr walk, and
     the measured-vs-projected gate in ASSERT mode — the run fails if the
     recorded per-step comm bytes drift from the analytic projection by
     more than 1% on any collective label;
  2. a serving burst through :class:`ServeEngine` (3 requests, 2 slots,
     slot recycling) so the snapshot carries the serve metrics surface
     (TTFT / per-token latency percentiles, occupancy, lifecycle counts).

The merged registry snapshot + gate report is printed as one BENCH json
line.  ``--write-snapshot`` refreshes ``snapshots/BENCH_runtime.json``
(committed so ``repro.obs.report diff`` has a baseline; wall-time leaves
drift run-to-run — the stable surface is the comm bytes, counter totals,
and gate verdict).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict

_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import argparse
import json
import tempfile
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

md = tempfile.mkdtemp(prefix="runtime_report_")
from repro.launch.train import train_loop
args = argparse.Namespace(
    arch="gpt-350m", reduced=True, mesh="4x2", variant="zeropp",
    steps=4, batch=16, seq=64, accum=1, lr=3e-3, seed=0,
    ckpt_dir=None, ckpt_every=0, ckpt_format="fp32", log_every=0,
    simulate_failure_at=None, metrics_dir=md, trace_steps=1,
    obs_gate=True)
out = train_loop(args)           # raises GateFailure on >1% comm drift
gate = out["gate"]

from repro.configs import get_config
from repro.core.compat import make_mesh
from repro.models.model import Model
from repro.serve import ServeEngine
from repro.train.policy import make_policy
from repro.train.state import param_specs

mesh = make_mesh((2, 4), ("data", "model"))
arch = get_config("qwen3-0.6b").reduced()
pol = make_policy(arch, tuple(mesh.axis_names))
model = Model(arch, pol.zcfg, world=8)
params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
p_specs = param_specs(model, tuple(mesh.axis_names))
params = {k: jax.device_put(v, NamedSharding(mesh, p_specs[k]))
          for k, v in params.items()}
engine = ServeEngine(model, mesh, params, n_slots=2, kv_len=32,
                     batch_axes=(), kv_axes=("model",))
rng = np.random.default_rng(0)
for i, P in enumerate((4, 7, 5)):
    engine.submit(rng.integers(0, arch.vocab, P).astype(np.int32),
                  max_new_tokens=4, seed=i)
engine.run(max_steps=200)
stats = engine.stats()
assert stats["completed"] == 3 and stats["expired"] == 0, stats

from repro.obs.report import export_snapshot
doc = export_snapshot(extra={
    "gate": gate,
    "serve": stats,
    "config": {"train": {"arch": "gpt-350m", "variant": "zeropp",
                         "mesh": [4, 2], "steps": 4},
               "serve": {"arch": "qwen3-0.6b", "mesh": [2, 4],
                         "slots": 2, "requests": 3}}})
print("RESULT " + json.dumps(doc))
"""

SNAPSHOT = os.path.join(os.path.dirname(__file__), "snapshots",
                        "BENCH_runtime.json")


def measure() -> Dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SNIPPET], env=env,
                       capture_output=True, text=True, timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(
            f"runtime report subprocess failed:\n{r.stdout}\n{r.stderr}")
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line in:\n{r.stdout}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--write-snapshot", action="store_true",
                    help=f"refresh {SNAPSHOT}")
    args, _ = ap.parse_known_args()

    doc = measure()
    rt = doc["runtime"]
    print("BENCH " + json.dumps(doc))

    gate = rt["gate"]
    assert gate["ok"], gate           # belt-and-braces: subprocess asserted
    print("\n# measured vs projected per-device wire bytes (train step)")
    print(f"{'label':<26} {'measured':>12} {'projected':>12} {'rel':>8}")
    for lbl, row in sorted(gate["comm"]["labels"].items()):
        print(f"{lbl:<26} {row['measured']:>12.0f} "
              f"{row['projected']:>12.0f} {row['rel']:>8.4f}")
    met = rt["metrics"]
    wall = met.get("train.step.wall_ms", {})
    print(f"\ntrain: steps={met.get('train.steps')} "
          f"tokens={met.get('train.tokens')} "
          f"step p50={wall.get('p50', 0):.0f}ms")
    sv = rt["serve"]
    print(f"serve: completed={sv['completed']}/{sv['admitted']} "
          f"expired={sv['expired']} steps={sv['steps']} "
          f"ttft p50={sv['ttft_ms']['p50']:.0f}ms "
          f"tok/s={sv['tok_per_s'] and round(sv['tok_per_s'], 1)}")
    disp = {k: v for k, v in met.items()
            if k.startswith("kernels.dispatch.")}
    print(f"kernel dispatches: {disp}")

    if args.write_snapshot:
        with open(SNAPSHOT, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {SNAPSHOT}")
    return doc


if __name__ == "__main__":
    main()
