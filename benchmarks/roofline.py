"""§Roofline table renderer: reads results/dryrun/*.json into the
EXPERIMENTS.md table (per arch × shape: three roofline terms, dominant
bottleneck, MODEL_FLOPS/HLO ratio, memory fit).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str = "results/dryrun", mesh: str = "16x16",
         variant: str = "zeropp") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("mesh") != mesh or d.get("variant") != variant:
            continue
        rows.append(d)
    rows.sort(key=lambda d: (d["arch"], SHAPE_ORDER.index(d["shape"])
                             if d["shape"] in SHAPE_ORDER else 9))
    return rows


def fmt_ms(s: float) -> str:
    return f"{s*1e3:.1f}"


def render(rows: List[Dict], markdown: bool = False) -> str:
    hdr = ("arch,shape,params_B,peak_GiB,fits,compute_ms,memory_ms,"
           "coll_ici_ms,coll_dci_ms,dominant,useful_ratio,mfu_bound")
    lines = [hdr]
    for d in rows:
        if d.get("skipped"):
            lines.append(f"{d['arch']},{d['shape']},,,SKIP({d['why'][:40]})"
                         ",,,,,,,")
            continue
        r = d["roofline"]
        m = d["memory"]
        lines.append(
            f"{d['arch']},{d['shape']},{d['n_params']/1e9:.2f},"
            f"{m.get('peak_bytes_per_device', 0)/2**30:.2f},"
            f"{m.get('fits_16gb')},"
            f"{fmt_ms(r['compute_s'])},{fmt_ms(r['memory_s'])},"
            f"{fmt_ms(r['collective_ici_s'])},{fmt_ms(r['collective_dci_s'])},"
            f"{r['dominant'].replace('_s','')},"
            f"{r['useful_flops_ratio']:.2f},{r['mfu_bound']:.3f}")
    if markdown:
        out = []
        for i, l in enumerate(lines):
            out.append("| " + l.replace(",", " | ") + " |")
            if i == 0:
                out.append("|" + "---|" * (l.count(",") + 1))
        return "\n".join(out)
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--variant", default="zeropp")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load(args.out_dir, args.mesh, args.variant)
    print(f"# Roofline table ({args.mesh}, {args.variant}): "
          f"{len(rows)} cells")
    print(render(rows, args.markdown))


if __name__ == "__main__":
    main()
