"""Wall-clock overlap hook: measured schedule overlap vs ring depth.

For each (stack, prefetch depth) cell the compiled 8-device train step is
analyzed three ways (launch/hlo_analysis):

  * structural ``overlap_fraction`` — which in-loop wire bytes CAN move
    under compute (dependence analysis; depth-blind once > 0);
  * ``async_pairs_enclosing_compute`` — the latency-hiding scheduler's
    own evidence, when the backend emits async collectives (0 on the CPU
    smoke backend; the hook exists so an accelerator run records the real
    number next to the projection);
  * ``effective_overlap_fraction`` — ring-depth-credited overlap at a
    low-bandwidth operating point (a gather issued d layers early is
    credited against d layers of compute; see hlo_analysis.effective_overlap).

Alongside, the depth-k step-time projection from
``benchmarks/throughput_model.py`` (break-even depth per interconnect).
Emits one BENCH json line so the perf trajectory records the measured
numbers; ``python benchmarks/overlap_bench.py`` also prints a table.

Runs the measurement in a subprocess with simulated devices (see
testing/subproc.py note).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict

_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
from repro.testing.checks import _prefetch_abstract_args
from repro.launch.hlo_analysis import (RING_OPERATING_POINT as OP,
                                       analyze_overlap, effective_overlap)

out = {"operating_point": OP}
for key, arch in (("dense", "gpt-350m"), ("moe", "deepseek-moe-16b")):
    out[key] = {}
    for pf in (0, 1, 2):
        ts, args = _prefetch_abstract_args(pf, arch_name=arch, n_layers=4)
        txt = ts.fn.lower(*args).compile().as_text()
        ov = analyze_overlap(txt)
        eff = effective_overlap(ov, peak_flops=OP["peak_flops"],
                                tier_bw=OP["tier_bw"],
                                coll_latency_s=OP["coll_latency_s"])
        out[key][str(pf)] = {
            "overlap_fraction": ov["overlap_fraction"],
            "effective_overlap_fraction":
                eff["effective_overlap_fraction"],
            "async_pairs": ov["async_pairs"],
            "async_pairs_enclosing_compute":
                ov["async_pairs_enclosing_compute"],
            "max_slack_iters": max(
                (l["max_slack_iters"] for l in ov["per_loop"].values()),
                default=1),
            "in_loop_wire_bytes": ov["in_loop_wire_bytes"],
            "loops_without_compute": sum(
                1 for l in ov["per_loop"].values()
                if not l["has_compute"]),
        }
print("RESULT " + json.dumps(out))
"""


def measure() -> Dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SNIPPET], env=env,
                       capture_output=True, text=True, timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(f"overlap bench failed:\n{r.stdout}\n{r.stderr}")
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line in:\n{r.stdout}")


def projection() -> Dict:
    try:
        from benchmarks.throughput_model import (
            SLOW_BWS, break_even_depth, model_tflops, step_time_ring)
    except ModuleNotFoundError:  # run as a script, not a package
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
        from throughput_model import (SLOW_BWS, break_even_depth,
                                      model_tflops, step_time_ring)
    n_dev = 18e9 / 384
    proj = {}
    for bw_name, bw in SLOW_BWS.items():
        proj[bw_name] = {
            "break_even_depth": break_even_depth(n_dev, 2048, "zeropp", bw),
            "tflops_by_depth": {
                str(d): model_tflops(
                    n_dev, 2048,
                    step_time_ring(n_dev, 2048, "zeropp", bw, d))
                for d in (0, 1, 2, 4)},
        }
    return proj


def main():
    measured = measure()
    res = {"measured": measured, "projection": projection(),
           "operating_point": measured.pop("operating_point", None)}
    print("BENCH " + json.dumps({"overlap": res}))
    print(f"\n{'stack':<6} {'pf':>3} {'struct':>8} {'effective':>10} "
          f"{'slack':>6} {'async':>6} {'bare loops':>10}")
    for stack, by_pf in res["measured"].items():
        for pf, m in sorted(by_pf.items()):
            print(f"{stack:<6} {pf:>3} {m['overlap_fraction']:>8.3f} "
                  f"{m['effective_overlap_fraction']:>10.5f} "
                  f"{m['max_slack_iters']:>6} "
                  f"{m['async_pairs_enclosing_compute']:>6} "
                  f"{m['loops_without_compute']:>10}")
    print("\nbreak-even ring depth (18B zeropp):",
          {k: v["break_even_depth"]
           for k, v in res["projection"].items()})


if __name__ == "__main__":
    main()
