"""Paper Figs. 11-13 / Table 2: throughput vs interconnect bandwidth.

The cluster is CPU-only, so absolute wall-clock throughput cannot be
measured; instead the roofline step-time model is driven by the MEASURED
per-variant wire bytes (benchmarks/comm_volume or the dry-run JSONs) and
swept over slow-tier bandwidths — the analogue of the paper's 1-8
InfiniBand connections.  Reported: model TFLOPs/GPU-equivalent and the
ZeRO++/baseline speedup at each bandwidth, for the paper's batch regimes
(2K and 1K tokens per device).

Two step-time models:

  synchronous (the worst case this repo started from, prefetch=0):
    t_step = t_compute + t_slow_comm + t_fast_comm

  overlapped (the prefetched schedule of core/schedule.py, prefetch=1),
  parameterized by the measured ``overlap_fraction`` — the wire-byte share
  of collectives the HLO dependence analysis proves schedulable under
  compute (launch/hlo_analysis.analyze_overlap):
    t_hidden  = f · (t_slow + t_fast)     rides under the matmuls
    t_exposed = (1-f) · (t_slow + t_fast) still on the critical path
    t_step    = max(t_compute, t_hidden) + t_exposed

  t_compute = 8·N·tokens_dev / peak   (fwd 2 + bwd 4 + remat 2)
  t_comm    = bytes / bw
"""
from __future__ import annotations

from typing import Dict, List

PEAK = 197e12          # bf16 flop/s per chip
FAST_BW = 300e9        # intra-node NVLink/NVSwitch per-GPU (DGX-2 era)
# paper sweeps 1..8 IB connections (100Gb/s = 12.5GB/s each)
SLOW_BWS = {f"{n}IB": n * 12.5e9 for n in (1, 2, 4, 8)}

# overlap_fraction measured from the compiled train step on the 8-device
# CPU mesh (gpt-350m reduced, zeropp variant, prefetch=1): the block-scan
# qwZ gathers, hpZ backward gathers and the pipelined qgZ reduce are all
# overlappable; only the streaming-LSE unembedding gathers stay exposed.
# Reproduce with: make bench-smoke (or checks.check_prefetch_overlap_fraction)
MEASURED_OVERLAP = 0.89

# same measurement for the MoE chunk/layer schedule (deepseek-moe-16b
# reduced, zeropp, prefetch=1): the layer scan's shared-param gathers, the
# nested expert-chunk gathers, the pipelined reduces AND — since the
# hpZ-aware nested recompute (secondary shards threaded through the outer
# residuals, core/schedule.py f_bwd) removed the gather-only qwZ re-gather
# loop from backward — the recompute's chunk gathers are all overlappable;
# exposed remainder = the streaming-LSE unembedding.
# Reproduce with: make moe-smoke (checks.check_moe_prefetch_overlap_fraction)
MEASURED_MOE_OVERLAP = 0.80

# per-collective launch + wire latency for the depth-k ring model: the
# fixed cost a gather pays regardless of its size (NCCL launch, network
# round-trip).  On slow interconnects this is what prefetch depth > 1
# amortizes — bandwidth is a per-iteration steady-state cost no ring can
# beat, but latency is per-collective and hides under k iterations.
COLL_LATENCY = 20e-6
# collectives issued per layer per step under full ZeRO++ (qwZ payload +
# scales gathers fwd, hpZ gather bwd, qgZ reduce hops — each hop moves
# payload AND bitcast scales as ONE all-to-all message since the scale
# packing change in core/collectives.py, so the hops count one launch)
COLLS_PER_LAYER = 4


def comm_bytes_per_step(n_params: int, variant: str) -> Dict[str, float]:
    """Slow/fast-tier wire bytes for one step (M = 2·n_params bf16 bytes).

    Matches the paper's Table 1 accounting: slow tier carries qwZ INT8
    (0.5M), hpZ moves bwd gather to the fast tier, qgZ INT4 2-hop carries
    0.25M slow + 0.5M fast.
    """
    M = 2.0 * n_params
    if variant == "baseline":
        return {"slow": 3.0 * M, "fast": 0.0}
    if variant == "qwz":
        return {"slow": 0.5 * M + 0.5 * M + M, "fast": 0.0}
    if variant == "hpz":
        return {"slow": 2.0 * M, "fast": M}
    if variant == "qgz":
        return {"slow": 2.0 * M + 0.25 * M, "fast": 0.25 * M}
    if variant == "zeropp":
        return {"slow": 0.5 * M + 0.25 * M, "fast": M + 0.25 * M}
    raise ValueError(variant)


def step_time(n_params: int, tokens_dev: int, variant: str,
              slow_bw: float, fast_bw: float = FAST_BW) -> float:
    c = 8.0 * n_params * tokens_dev / PEAK
    b = comm_bytes_per_step(n_params, variant)
    return c + b["slow"] / slow_bw + b["fast"] / fast_bw


def step_time_overlap(n_params: int, tokens_dev: int, variant: str,
                      slow_bw: float,
                      overlap: float = MEASURED_OVERLAP,
                      fast_bw: float = FAST_BW) -> float:
    """Prefetched-schedule step time: ``overlap`` of the comm rides under
    compute, the rest stays exposed (see module docstring)."""
    c = 8.0 * n_params * tokens_dev / PEAK
    b = comm_bytes_per_step(n_params, variant)
    t_comm = b["slow"] / slow_bw + b["fast"] / fast_bw
    return max(c, overlap * t_comm) + (1.0 - overlap) * t_comm


def model_tflops(n_params: int, tokens_dev: int, t: float) -> float:
    """Paper metric: model flops (6·N·D) per second per device."""
    return 6.0 * n_params * tokens_dev / t / 1e12


# ---------------------------------------------------------------------------
# depth-k prefetch-ring step-time model (core/schedule.py)
# ---------------------------------------------------------------------------
#
# The structural model above charges (1-f) of the comm as exposed and lets
# f ride under compute unconditionally.  The ring model refines the f part
# per layer: a gather issued `depth` layers ahead has a window of
# depth·t_layer to complete in, so per layer the exposed residue is
#
#   exposed_l = max(0,
#                   c_bw,                      # bandwidth over one window —
#                     - t_layer                #   steady state, depth-blind
#                   c_bw + n_coll·alpha        # latency + bandwidth over a
#                     - depth·t_layer)         #   depth-deep window
#
# i.e. depth can never beat the per-layer bandwidth steady state (one
# gather is issued per layer regardless of k) but it amortizes the
# per-collective latency — exactly the small-transfer / slow-interconnect
# regime (decode batches, 1-2 IB links) where one layer's compute cannot
# cover a gather.

def step_time_ring(n_params: int, tokens_dev: int, variant: str,
                   slow_bw: float, depth: int, n_layers: int = 48,
                   overlap: float = MEASURED_OVERLAP,
                   latency: float = COLL_LATENCY,
                   colls_per_layer: int = COLLS_PER_LAYER,
                   fast_bw: float = FAST_BW) -> float:
    """Step time under a depth-``depth`` prefetch ring (depth=0 is the
    synchronous schedule; depth=1 the classic double buffer).

    ``slow_bw``/``fast_bw``/``latency`` default to the analytic constants
    for the paper-figure sweeps; the boot-time tuner (repro.tune) feeds
    the *measured* coefficients from its mesh probe instead."""
    c = 8.0 * n_params * tokens_dev / PEAK
    b = comm_bytes_per_step(n_params, variant)
    t_comm = b["slow"] / slow_bw + b["fast"] / fast_bw
    t_lat = colls_per_layer * latency * n_layers
    if depth < 1:
        return c + t_comm + t_lat
    t_layer = c / n_layers
    # the overlappable share f of both bandwidth AND latency rides inside
    # the depth-deep window; the structurally exposed (1-f) share keeps
    # its full comm + latency cost regardless of depth (at overlap=0 every
    # depth collapses to the synchronous time — the ring hides nothing)
    c_bw = overlap * t_comm / n_layers          # hideable bw time / layer
    t_l = overlap * colls_per_layer * latency   # hideable latency / layer
    exposed_l = max(0.0, c_bw - t_layer, c_bw + t_l - depth * t_layer)
    return (c + n_layers * exposed_l
            + (1.0 - overlap) * (t_comm + t_lat))


def break_even_depth(n_params: int, tokens_dev: int, variant: str,
                     slow_bw: float, n_layers: int = 48,
                     overlap: float = MEASURED_OVERLAP,
                     latency: float = COLL_LATENCY,
                     colls_per_layer: int = COLLS_PER_LAYER,
                     fast_bw: float = FAST_BW) -> int:
    """Smallest ring depth after which deepening stops paying (capped at
    n_layers-1, the ring's hard clamp)."""
    d = 1
    while d < n_layers - 1:
        t_now = step_time_ring(n_params, tokens_dev, variant, slow_bw, d,
                               n_layers, overlap, latency, colls_per_layer,
                               fast_bw)
        t_next = step_time_ring(n_params, tokens_dev, variant, slow_bw,
                                d + 1, n_layers, overlap, latency,
                                colls_per_layer, fast_bw)
        if t_next >= t_now - 1e-12:
            return d
        d += 1
    return d


def ring_coeffs(profile, intra_axis: str = "model") -> Dict[str, float]:
    """Map a ``repro.tune.probe.ProbeProfile`` onto this model's
    coefficients — the kwargs :func:`step_time_ring` /
    :func:`break_even_depth` accept in place of the analytic constants."""
    inter = tuple(a for a in profile.mesh_axes if a != intra_axis)
    return {
        "slow_bw": profile.slow_bw(inter or profile.mesh_axes),
        "fast_bw": profile.fast_bw(intra_axis),
        "latency": profile.coll_latency(),
    }


# ---------------------------------------------------------------------------
# MoE step-time model (the chunk/layer prefetched expert path)
# ---------------------------------------------------------------------------
#
# ZeRO gathers are parameter-complete: the expert stack moves ALL E experts'
# weights per layer even though each token's FLOPs touch only top_k of them.
# Compute therefore scales with ACTIVE params while communication scales
# with TOTAL params — the worst communication-per-FLOP regime, and exactly
# where hiding the wire bytes behind compute pays most.  The chunk/layer
# schedule nests the chunk pipeline inside the layer engine's remat; with
# hpZ the chunk SECONDARY shards thread through the outer residuals and
# the recompute re-gathers ride the fast tier (core/schedule.py
# zero_chunk_scan_hpz — already inside the fast-tier M of Table 1), so
# only hpZ-less variants still pay a forward-tier expert re-gather.

def moe_comm_bytes_per_step(n_shared: int, n_expert: int, variant: str
                            ) -> Dict[str, float]:
    """Slow/fast-tier wire bytes for one MoE train step."""
    b = dict(comm_bytes_per_step(n_shared + n_expert, variant))
    M_e = 2.0 * n_expert
    qw = variant in ("zeropp", "qwz")
    if variant not in ("zeropp", "hpz"):
        # nested-remat re-gather of the expert chunks stays on the
        # forward (qwZ) tier when there is no secondary copy to replay
        b["slow"] += (0.5 if qw else 1.0) * M_e
    return b


def moe_step_time(n_shared: int, n_expert: int, n_active: int,
                  tokens_dev: int, variant: str, slow_bw: float) -> float:
    """Synchronous (prefetch=0) MoE step time."""
    c = 8.0 * n_active * tokens_dev / PEAK
    b = moe_comm_bytes_per_step(n_shared, n_expert, variant)
    return c + b["slow"] / slow_bw + b["fast"] / FAST_BW


def moe_step_time_overlap(n_shared: int, n_expert: int, n_active: int,
                          tokens_dev: int, variant: str, slow_bw: float,
                          overlap: float = MEASURED_MOE_OVERLAP) -> float:
    """Chunk/layer prefetched (prefetch=1) MoE step time."""
    c = 8.0 * n_active * tokens_dev / PEAK
    b = moe_comm_bytes_per_step(n_shared, n_expert, variant)
    t_comm = b["slow"] / slow_bw + b["fast"] / FAST_BW
    return max(c, overlap * t_comm) + (1.0 - overlap) * t_comm


def deepseek_moe_16b_splits(n_gpus: int = 64):
    """(n_shared, n_expert, n_active) parameters per device, derived from
    the registered deepseek-moe-16b config so the projection tracks it."""
    from repro.configs import get_config
    c = get_config("deepseek-moe-16b")
    per_expert = 3 * c.d_model * c.moe_ff
    attn = 2 * c.d_model * (c.n_heads + c.n_kv_heads) * c.d_head
    shared = 2 * c.vocab * c.d_model + c.n_layers * (
        attn + c.d_model * c.n_experts
        + 3 * c.d_model * c.moe_ff * c.n_shared)
    expert = c.n_layers * c.n_experts * per_expert
    active = shared + c.n_layers * c.top_k * per_expert
    return shared / n_gpus, expert / n_gpus, active / n_gpus


def main():
    # paper Table 2 model sizes (18B..138B) at 2K/1K tokens per GPU
    sizes = {"18B": 18e9, "49B": 49e9, "91B": 91e9, "138B": 138e9}
    print("# Table 2 analogue: model TFLOPs per chip and speedup")
    print("model,tokens_dev,bandwidth,baseline_tflops,zeropp_tflops,speedup")
    for name, n in sizes.items():
        n_dev = n / 384  # paper: 384 GPUs; params per device for comm = M
        for tokens in (2048, 1024):
            for bw_name, bw in SLOW_BWS.items():
                tb = step_time(n / 384, tokens, "baseline", bw)
                tz = step_time(n / 384, tokens, "zeropp", bw)
                fb = model_tflops(n / 384, tokens, tb)
                fz = model_tflops(n / 384, tokens, tz)
                print(f"{name},{tokens},{bw_name},{fb:.2f},{fz:.2f},"
                      f"{tz and tb / tz:.2f}x")

    print("# Fig 13 analogue: per-technique speedup, 18B, 128 GPUs")
    print("variant,bandwidth,tflops,speedup_vs_baseline")
    n_dev = 18e9 / 128
    for bw_name, bw in SLOW_BWS.items():
        tb = step_time(n_dev, 2048, "baseline", bw)
        for variant in ("baseline", "qwz", "hpz", "qgz", "zeropp"):
            t = step_time(n_dev, 2048, variant, bw)
            print(f"{variant},{bw_name},"
                  f"{model_tflops(n_dev, 2048, t):.2f},{tb / t:.2f}x")

    print("# Fig 12 analogue: democratization (low-bw ZeRO++ vs high-bw baseline)")
    for name, n in (("18B", 18e9), ("138B", 138e9)):
        tz = step_time(n / 384, 2048, "zeropp", SLOW_BWS["2IB"])
        tb = step_time(n / 384, 2048, "baseline", SLOW_BWS["8IB"])
        print(f"{name}: zeropp@2IB {model_tflops(n/384, 2048, tz):.2f} TF "
              f"vs baseline@8IB {model_tflops(n/384, 2048, tb):.2f} TF "
              f"-> ratio {tb/tz:.2f}")

    print(f"# MoE projection (deepseek-moe-16b, 64 GPUs): chunk/layer "
          f"schedule, f={MEASURED_MOE_OVERLAP:.2f} measured")
    print("tokens_dev,bandwidth,variant,comm_compute_ratio,sync_tflops,"
          "overlap_tflops,prefetch_speedup")
    n_sh, n_ex, n_ac = deepseek_moe_16b_splits()
    for tokens in (2048, 1024):
        for bw_name, bw in SLOW_BWS.items():
            for variant in ("baseline", "zeropp"):
                ts_ = moe_step_time(n_sh, n_ex, n_ac, tokens, variant, bw)
                to = moe_step_time_overlap(n_sh, n_ex, n_ac, tokens,
                                           variant, bw)
                b = moe_comm_bytes_per_step(n_sh, n_ex, variant)
                c = 8.0 * n_ac * tokens / PEAK
                ratio = (b["slow"] / bw + b["fast"] / FAST_BW) / c
                fs = model_tflops(n_ac, tokens, ts_)
                fo = model_tflops(n_ac, tokens, to)
                print(f"{tokens},{bw_name},{variant},{ratio:.2f},"
                      f"{fs:.2f},{fo:.2f},{ts_ / to:.2f}x")

    print("# Ring-depth projection: step time vs prefetch depth "
          "(18B, 2K tokens/dev; latency-amortization regime)")
    print("bandwidth,variant,break_even_depth,"
          + ",".join(f"d{d}_tflops" for d in (0, 1, 2, 4)))
    n_dev = 18e9 / 384
    for bw_name, bw in SLOW_BWS.items():
        for variant in ("baseline", "zeropp"):
            cols = []
            for d in (0, 1, 2, 4):
                t = step_time_ring(n_dev, 2048, variant, bw, d)
                cols.append(f"{model_tflops(n_dev, 2048, t):.2f}")
            be = break_even_depth(n_dev, 2048, variant, bw)
            print(f"{bw_name},{variant},{be}," + ",".join(cols))

    print(f"# Prefetch projection: overlapped (f={MEASURED_OVERLAP:.2f} "
          f"measured, see core/schedule.py) vs synchronous schedule")
    print("model,tokens_dev,bandwidth,variant,sync_tflops,overlap_tflops,"
          "prefetch_speedup,ideal_speedup")
    for name, n in sizes.items():
        for tokens in (2048, 1024):
            for bw_name, bw in SLOW_BWS.items():
                for variant in ("baseline", "zeropp"):
                    ts = step_time(n / 384, tokens, variant, bw)
                    to = step_time_overlap(n / 384, tokens, variant, bw)
                    ti = step_time_overlap(n / 384, tokens, variant, bw,
                                           overlap=1.0)
                    fs = model_tflops(n / 384, tokens, ts)
                    fo = model_tflops(n / 384, tokens, to)
                    print(f"{name},{tokens},{bw_name},{variant},"
                          f"{fs:.2f},{fo:.2f},{ts / to:.2f}x,{ts / ti:.2f}x")


if __name__ == "__main__":
    main()
