"""Benchmark entry point: one section per paper table/figure.

  comm_volume      — Table 1  (analytic + measured wire bytes)
  throughput_model — Figs 11-13 / Table 2 (roofline model over bandwidth)
  kernel_bench     — Table 3  (fused vs staged quantization pipeline)
  memory_model     — Fig 4 / Table 4 (DP vs ZeRO-3 vs hpZ vs MiCS)
  convergence      — Fig 14 / Table 5 (loss curves per variant)
  overlap_bench    — measured schedule overlap vs ring depth (BENCH json:
                     structural + depth-credited fractions, async pairs,
                     break-even depth projection; 8-dev subprocess)
  roofline         — §Roofline table from the dry-run JSONs (if present)

Run everything: PYTHONPATH=src python -m benchmarks.run
Select sections: PYTHONPATH=src python -m benchmarks.run comm_volume ...
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (comm_volume, convergence, kernel_bench,
                            memory_model, overlap_bench, roofline,
                            throughput_model)
    sections = {
        "comm_volume": comm_volume.main,
        "throughput_model": throughput_model.main,
        "kernel_bench": kernel_bench.main,
        "memory_model": memory_model.main,
        "convergence": convergence.main,
        "overlap_bench": overlap_bench.main,
    }
    pick = [a for a in sys.argv[1:] if a in sections] or list(sections)
    failures = []
    for name in pick:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            sections[name]()
            print(f"[{name} done in {time.time()-t0:.0f}s]")
        except Exception:
            failures.append(name)
            traceback.print_exc()

    if not sys.argv[1:] or "roofline" in sys.argv[1:]:
        print("\n===== roofline =====")
        try:
            from benchmarks import roofline as rl
            rows = rl.load()
            print(rl.render(rows))
        except Exception:
            traceback.print_exc()

    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")


if __name__ == "__main__":
    main()
