"""Benchmark entry point: one section per paper table/figure.

  comm_volume      — Table 1  (analytic + measured wire bytes)
  throughput_model — Figs 11-13 / Table 2 (roofline model over bandwidth)
  kernel_bench     — Table 3  (fused vs staged quantization pipeline)
  memory_model     — Fig 4 / Table 4 (DP vs ZeRO-3 vs hpZ vs MiCS)
  convergence      — Fig 14 / Table 5 (loss curves per variant)
  overlap_bench    — measured schedule overlap vs ring depth (BENCH json:
                     structural + depth-credited fractions, async pairs,
                     break-even depth projection; 8-dev subprocess)
  runtime_report   — telemetry-on train + serve run (obs/): BENCH snapshot
                     with the measured-vs-projected comm gate in assert
                     mode, serve latency percentiles, dispatch counts
  tuner_report     — static boot-time resolution sweep (repro.tune):
                     resolved knobs + (k+1)-ring HBM ledger + break-even
                     depth per arch x mesh, checked against the committed
                     deterministic snapshot
  serve_bench      — decode tok/s + TTFT vs occupancy, and the paged-pool
                     multi-tenant trace (equal-HBM admission, prefix-cache
                     TTFT, speculative acceptance) with its structural
                     facts gated against the committed BENCH_serve.json
  roofline         — §Roofline table from the dry-run JSONs (if present)

Any section that raises marks the whole run failed (nonzero exit) — no
silently swallowed crashes.

Run everything: PYTHONPATH=src python -m benchmarks.run
Select sections: PYTHONPATH=src python -m benchmarks.run comm_volume ...
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (comm_volume, convergence, kernel_bench,
                            memory_model, overlap_bench, roofline,
                            runtime_report, serve_bench, throughput_model,
                            tuner_report)
    sections = {
        "comm_volume": comm_volume.main,
        "throughput_model": throughput_model.main,
        "kernel_bench": kernel_bench.main,
        "memory_model": memory_model.main,
        "convergence": convergence.main,
        "overlap_bench": overlap_bench.main,
        "runtime_report": runtime_report.main,
        "tuner_report": tuner_report.main,
        "serve_bench": serve_bench.main,
    }
    pick = [a for a in sys.argv[1:] if a in sections] or list(sections)
    failures = []
    for name in pick:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            sections[name]()
            print(f"[{name} done in {time.time()-t0:.0f}s]")
        except Exception:
            failures.append(name)
            traceback.print_exc()

    if not sys.argv[1:] or "roofline" in sys.argv[1:]:
        print("\n===== roofline =====")
        try:
            rows = roofline.load()
            print(roofline.render(rows))
        except Exception:
            # a crashed section must fail the run, not scroll past — this
            # used to print the traceback and exit 0
            failures.append("roofline")
            traceback.print_exc()

    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")


if __name__ == "__main__":
    main()
