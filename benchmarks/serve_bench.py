"""Serving throughput: decode tokens/sec and time-to-first-token vs batch
occupancy, baseline (bf16 gathers) vs qwZ (INT8 gathers) — plus the
paged-pool multi-tenant trace.

The engine's decode step is timed on the simulated 4-device CPU mesh at
several slot occupancies (1, half, full): tokens/sec = occupied slots /
median step wall-clock, so the plot shows how continuous batching
amortizes the per-step weight gathers.  TTFT is one prefill + first-token
sample at the smallest bucket.  CPU wall-clock is NOT accelerator
wall-clock — the comparison across variants and occupancies is the
signal, not the absolute numbers (Table-1 wire volumes + the measured
overlap fraction in throughput_model.py project the hardware picture).

The TRACE section replays a deterministic multi-tenant request trace
(mixed lengths, staged arrivals, two tenants sharing a 16-token system
prefix) against a slab pool and a paged pool holding the SAME number of
KV positions (equal HBM), and reports:

  * peak concurrent sequences each pool admits (the paged pool must hold
    >= 2x — pages admit at page granularity, slots at whole-sequence);
  * prefix-cache hits + chunked-prefill TTFT cold vs warm (the warm
    prefill runs strictly fewer chunks);
  * speculative decoding accepted-tokens-per-verify (self-draft);
  * p50/p99 TTFT and aggregate tok/s over the trace (wall-clock:
    reported, never snapshotted).

The structural fields (peaks, hits, chunk counts, accepted mean — all
deterministic host-side scheduling facts) are committed as
``snapshots/BENCH_serve.json``; ``--smoke`` gates against them and the
invariants above, ``--write-snapshot`` refreshes the file.

Runs in a subprocess with simulated devices (see testing/subproc.py note).
Emits a BENCH json line; ``python benchmarks/serve_bench.py`` prints a
table.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict

_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, time
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.configs import get_config
from repro.core.compat import make_mesh
from repro.models.model import Model
from repro.serve import ServeEngine
from repro.train.policy import make_policy
from repro.train.state import param_specs

N_SLOTS, KV = 8, 64
mesh = make_mesh((2, 2), ("data", "model"))
arch = get_config("qwen3-0.6b").reduced()
out = {}
for variant in ("baseline", "qwz"):
    pol = make_policy(arch, mesh.axis_names, variant)
    model = Model(arch, pol.zcfg, world=4)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    sp = param_specs(model, tuple(mesh.axis_names))
    params = {k: jax.device_put(v, NamedSharding(mesh, sp[k]))
              for k, v in params.items()}
    res = {"occupancy": {}}
    rng = np.random.default_rng(0)

    # TTFT: submit one request, time until its first streamed token
    eng = ServeEngine(model, mesh, params, n_slots=N_SLOTS, kv_len=KV)
    first = []
    eng.submit(rng.integers(0, arch.vocab, 8), max_new_tokens=1,
               on_token=lambda u, t: first.append(time.perf_counter()))
    t0 = time.perf_counter(); eng.step()       # includes prefill compile
    eng.run(max_steps=10)
    t0 = time.perf_counter()
    eng.submit(rng.integers(0, arch.vocab, 8), max_new_tokens=1,
               on_token=lambda u, t: first.append(time.perf_counter()))
    eng.step(); eng.run(max_steps=10)
    res["ttft_s"] = first[-1] - t0             # warm-compile TTFT

    for occ in (1, N_SLOTS // 2, N_SLOTS):
        eng = ServeEngine(model, mesh, params, n_slots=N_SLOTS, kv_len=KV)
        for r in range(occ):
            eng.submit(rng.integers(0, arch.vocab, 8), max_new_tokens=40)
        eng.step()                              # admissions + compile
        times = []
        while not eng.done:
            t = time.perf_counter()
            emitted = eng.step()
            times.append((time.perf_counter() - t, len(emitted)))
            if len(times) >= 24:
                break
        times = times[2:]                       # drop warmup steps
        med = sorted(t for t, _ in times)[len(times) // 2]
        res["occupancy"][occ] = {"step_s": med,
                                 "decode_tok_per_s": occ / med}
    out[variant] = res
print("RESULT " + json.dumps(out))
"""


_TRACE_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, time
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.configs import get_config
from repro.core.compat import make_mesh
from repro.models.model import Model
from repro.serve import ServeEngine
from repro.train.policy import make_policy
from repro.train.state import param_specs

KV, PAGE, CHUNK = 64, 8, 8
mesh = make_mesh((2, 2), ("data", "model"))
arch = get_config("qwen3-0.6b").reduced()
pol = make_policy(arch, mesh.axis_names, param_dtype=jnp.float32,
                  compute_dtype=jnp.float32)
model = Model(arch, pol.zcfg, world=4)
params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
sp = param_specs(model, tuple(mesh.axis_names))
params = {k: jax.device_put(v, NamedSharding(mesh, sp[k]))
          for k, v in params.items()}

def paged_engine(**kw):
    return ServeEngine(model, mesh, params, kv_len=KV, pool="paged",
                       page_size=PAGE, chunk_size=CHUNK, **kw)

# deterministic multi-tenant trace: 2 tenants with a 16-token shared
# system prefix, unique suffixes of mixed length, staged arrivals (one
# warm-up request per tenant registers the prefix, then a 14-request
# flood reuses it)
rng = np.random.default_rng(0)
prefixes = [rng.integers(0, arch.vocab, 16).astype(np.int32)
            for _ in range(2)]
def make_req(i):
    suffix = rng.integers(0, arch.vocab, 3 + (i % 5)).astype(np.int32)
    return np.concatenate([prefixes[i % 2], suffix]), 6 + (i % 3)
WARM = [make_req(i) for i in range(2)]
FLOOD = [make_req(i) for i in range(2, 16)]

def run_trace(eng, paged):
    peak, toks = 0, 0
    t0 = time.perf_counter()
    for pr, n in WARM:
        eng.submit(pr, max_new_tokens=n)
    while not eng.done:
        toks += len(eng.step())
    for pr, n in FLOOD:
        eng.submit(pr, max_new_tokens=n)
    while not eng.done:
        toks += len(eng.step())
        conc = eng.n_active + (len(eng._prefilling) if paged else 0)
        peak = max(peak, conc)
    wall = time.perf_counter() - t0
    return peak, toks, wall

out = {}
# equal HBM: 4 slots x 64 positions slab == 32 pages x 8 positions paged
slab = ServeEngine(model, mesh, params, n_slots=4, kv_len=KV)
s_peak, s_toks, s_wall = run_trace(slab, False)
s_stats = slab.stats()
paged = paged_engine(n_slots=16, n_pages=32)
p_peak, p_toks, p_wall = run_trace(paged, True)
p_stats = paged.stats()
pool = p_stats["pool"]
out["equal_hbm"] = {
    "kv_positions": 4 * KV,
    "slab_slots": 4, "paged_pages": 32, "page_size": PAGE,
    "slab_peak_concurrent": s_peak, "paged_peak_concurrent": p_peak,
    "admission_ratio": p_peak / max(1, s_peak),
    "completed": {"slab": s_stats["completed"],
                  "paged": p_stats["completed"]},
    "prefix_hits": pool["prefix_hits"],
    "prefix_tokens_reused": pool["prefix_tokens_reused"],
}
out["wall"] = {   # wall-clock: reported, never snapshotted
    "slab": {"tok_per_s": s_toks / s_wall,
             "ttft_ms": s_stats["ttft_ms"],
             "tok_latency_ms": s_stats["tok_latency_ms"]},
    "paged": {"tok_per_s": p_toks / p_wall,
              "ttft_ms": p_stats["ttft_ms"],
              "tok_latency_ms": p_stats["tok_latency_ms"]},
}

# chunked-prefill TTFT, cold vs warm: the warm resubmission of a 3-chunk
# prompt matches 2 chunks of prefix pages and prefills only the last
eng = paged_engine(n_slots=2)
prompt = np.concatenate([prefixes[0],
                         rng.integers(0, arch.vocab, 8).astype(np.int32)])
t0 = time.perf_counter(); eng.submit(prompt, max_new_tokens=2)
eng.run(max_steps=50)
cold_ms = (time.perf_counter() - t0) * 1e3
cold_chunks = eng.stats()["prefill_chunks"]
t0 = time.perf_counter(); eng.submit(prompt, max_new_tokens=2)
eng.run(max_steps=50)
warm_ms = (time.perf_counter() - t0) * 1e3
warm_chunks = eng.stats()["prefill_chunks"] - cold_chunks
out["prefix_ttft"] = {"cold_chunks": cold_chunks,
                      "warm_chunks": warm_chunks,
                      "hits": eng.stats()["pool"]["prefix_hits"]}
out["wall"]["prefix_ttft_ms"] = {"cold": cold_ms, "warm": warm_ms}

# speculative decoding (self-draft: the drafter sets the stride, the
# acceptance distribution is a deterministic host-side fact)
spec = paged_engine(n_slots=8, draft=(model, params), spec_tokens=4)
for pr, n in FLOOD[:6]:
    spec.submit(pr, max_new_tokens=n)
spec.run(max_steps=200)
acc = spec.stats()["spec_accepted"]
out["spec"] = {"accepted_mean": acc["mean"], "rounds": acc["n"],
               "completed": spec.stats()["completed"]}
print("RESULT " + json.dumps(out))
"""

SNAPSHOT = os.path.join(os.path.dirname(__file__), "snapshots",
                        "BENCH_serve.json")


def _run_snippet(snippet: str) -> Dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"serve bench failed:\n{r.stdout}\n{r.stderr}")
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line in:\n{r.stdout}")


def measure() -> Dict:
    return _run_snippet(_SNIPPET)


def measure_trace() -> Dict:
    return _run_snippet(_TRACE_SNIPPET)


def _structural(trace: Dict) -> Dict:
    """The deterministic scheduling facts — everything but wall-clock."""
    return {k: v for k, v in trace.items() if k != "wall"}


def _gate(trace: Dict) -> None:
    """Invariants the paged pool must deliver (raise on violation)."""
    eq = trace["equal_hbm"]
    assert eq["admission_ratio"] >= 2.0, (
        f"paged pool admitted only {eq['paged_peak_concurrent']} vs slab "
        f"{eq['slab_peak_concurrent']} at equal HBM")
    assert eq["prefix_hits"] >= 1 and eq["prefix_tokens_reused"] >= 16, eq
    assert eq["completed"]["paged"] == eq["completed"]["slab"] == 16, eq
    pt = trace["prefix_ttft"]
    assert pt["warm_chunks"] < pt["cold_chunks"] and pt["hits"] >= 1, pt
    assert trace["spec"]["accepted_mean"] > 1.0, trace["spec"]


def main(smoke: bool = False, write_snapshot: bool = False):
    out = {}
    if not smoke:
        res = measure()
        out["serve"] = res
    trace = measure_trace()
    out["serve_trace"] = trace
    print("BENCH " + json.dumps(out))

    if not smoke:
        res = out["serve"]
        print(f"\n{'variant':<10} {'ttft_ms':>9}  " +
              "  ".join(f"occ={o:>2} tok/s" for o in
                        sorted(int(k) for k in res['baseline']['occupancy'])))
        for variant, r in res.items():
            occ = {int(k): v for k, v in r["occupancy"].items()}
            row = "  ".join(f"{occ[o]['decode_tok_per_s']:>12.1f}"
                            for o in sorted(occ))
            print(f"{variant:<10} {r['ttft_s'] * 1e3:>9.1f}  {row}")

    eq = trace["equal_hbm"]
    w = trace["wall"]
    print(f"\n# multi-tenant trace (equal HBM: {eq['kv_positions']} KV "
          f"positions)")
    print(f"peak concurrent: slab={eq['slab_peak_concurrent']} "
          f"paged={eq['paged_peak_concurrent']} "
          f"(x{eq['admission_ratio']:.1f})")
    print(f"prefix cache: {eq['prefix_hits']} hits, "
          f"{eq['prefix_tokens_reused']} tokens reused; "
          f"cold {trace['prefix_ttft']['cold_chunks']} chunks -> warm "
          f"{trace['prefix_ttft']['warm_chunks']}")
    for kind in ("slab", "paged"):
        t = w[kind]["ttft_ms"]
        print(f"{kind:<6} tok/s={w[kind]['tok_per_s']:.1f} "
              f"ttft p50={t['p50']:.0f}ms p99={t['p99']:.0f}ms")
    print(f"speculative: {trace['spec']['accepted_mean']:.2f} accepted/"
          f"verify over {trace['spec']['rounds']} rounds")

    _gate(trace)
    if write_snapshot:
        with open(SNAPSHOT, "w") as fh:
            json.dump(_structural(trace), fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {SNAPSHOT}")
    elif smoke:
        with open(SNAPSHOT) as fh:
            want = json.load(fh)
        got = json.loads(json.dumps(_structural(trace)))
        assert got == want, (
            f"serve trace drifted from {SNAPSHOT}:\n{got}\nvs\n{want}")
        print("snapshot match: structural trace facts unchanged")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="trace only + gates + snapshot comparison")
    ap.add_argument("--write-snapshot", action="store_true",
                    help=f"refresh {SNAPSHOT}")
    a, _ = ap.parse_known_args()
    main(smoke=a.smoke, write_snapshot=a.write_snapshot)
