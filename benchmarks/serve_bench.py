"""Serving throughput: decode tokens/sec and time-to-first-token vs batch
occupancy, baseline (bf16 gathers) vs qwZ (INT8 gathers).

The engine's decode step is timed on the simulated 4-device CPU mesh at
several slot occupancies (1, half, full): tokens/sec = occupied slots /
median step wall-clock, so the plot shows how continuous batching
amortizes the per-step weight gathers.  TTFT is one prefill + first-token
sample at the smallest bucket.  CPU wall-clock is NOT accelerator
wall-clock — the comparison across variants and occupancies is the
signal, not the absolute numbers (Table-1 wire volumes + the measured
overlap fraction in throughput_model.py project the hardware picture).

Runs in a subprocess with simulated devices (see testing/subproc.py note).
Emits a BENCH json line; ``python benchmarks/serve_bench.py`` prints a
table.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict

_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, time
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.configs import get_config
from repro.core.compat import make_mesh
from repro.models.model import Model
from repro.serve import ServeEngine
from repro.train.policy import make_policy
from repro.train.state import param_specs

N_SLOTS, KV = 8, 64
mesh = make_mesh((2, 2), ("data", "model"))
arch = get_config("qwen3-0.6b").reduced()
out = {}
for variant in ("baseline", "qwz"):
    pol = make_policy(arch, mesh.axis_names, variant)
    model = Model(arch, pol.zcfg, world=4)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    sp = param_specs(model, tuple(mesh.axis_names))
    params = {k: jax.device_put(v, NamedSharding(mesh, sp[k]))
              for k, v in params.items()}
    res = {"occupancy": {}}
    rng = np.random.default_rng(0)

    # TTFT: submit one request, time until its first streamed token
    eng = ServeEngine(model, mesh, params, n_slots=N_SLOTS, kv_len=KV)
    first = []
    eng.submit(rng.integers(0, arch.vocab, 8), max_new_tokens=1,
               on_token=lambda u, t: first.append(time.perf_counter()))
    t0 = time.perf_counter(); eng.step()       # includes prefill compile
    eng.run(max_steps=10)
    t0 = time.perf_counter()
    eng.submit(rng.integers(0, arch.vocab, 8), max_new_tokens=1,
               on_token=lambda u, t: first.append(time.perf_counter()))
    eng.step(); eng.run(max_steps=10)
    res["ttft_s"] = first[-1] - t0             # warm-compile TTFT

    for occ in (1, N_SLOTS // 2, N_SLOTS):
        eng = ServeEngine(model, mesh, params, n_slots=N_SLOTS, kv_len=KV)
        for r in range(occ):
            eng.submit(rng.integers(0, arch.vocab, 8), max_new_tokens=40)
        eng.step()                              # admissions + compile
        times = []
        while not eng.done:
            t = time.perf_counter()
            emitted = eng.step()
            times.append((time.perf_counter() - t, len(emitted)))
            if len(times) >= 24:
                break
        times = times[2:]                       # drop warmup steps
        med = sorted(t for t, _ in times)[len(times) // 2]
        res["occupancy"][occ] = {"step_s": med,
                                 "decode_tok_per_s": occ / med}
    out[variant] = res
print("RESULT " + json.dumps(out))
"""


def measure() -> Dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SNIPPET], env=env,
                       capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"serve bench failed:\n{r.stdout}\n{r.stderr}")
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line in:\n{r.stdout}")


def main():
    res = measure()
    print("BENCH " + json.dumps({"serve": res}))
    print(f"\n{'variant':<10} {'ttft_ms':>9}  " +
          "  ".join(f"occ={o:>2} tok/s" for o in
                    sorted(int(k) for k in res['baseline']['occupancy'])))
    for variant, r in res.items():
        occ = {int(k): v for k, v in r["occupancy"].items()}
        row = "  ".join(f"{occ[o]['decode_tok_per_s']:>12.1f}"
                        for o in sorted(occ))
        print(f"{variant:<10} {r['ttft_s'] * 1e3:>9.1f}  {row}")


if __name__ == "__main__":
    main()
