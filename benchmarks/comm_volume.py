"""Paper Table 1: per-step communication volume, ZeRO-3 vs ZeRO++.

Two measurements:
  * analytic — ZeroConfig.comm_volume_per_step (the paper's 3M -> 0.75M)
  * measured — wire bytes from the traced step's jaxpr (true dtypes,
    exact mesh axis names), split by interconnect tier, for every variant.

The measured numbers come from a subprocess with 8 simulated devices (2x2x2
pod/data/model mesh), so "slow tier" = groups crossing the model ring.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict

_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from repro.configs import get_config
from repro.launch import dryrun as dr
from repro.launch.mesh import make_test_mesh
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig
from repro.train import trainer as trainer_lib
from repro.train.policy import make_policy
import dataclasses as dc
from repro.configs.base import ShapeConfig

out = {}
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
axes = tuple(mesh.axis_names)
arch = get_config("gpt-350m").reduced(
    n_layers=4, d_model=256, vocab=512, n_heads=4, n_kv_heads=4,
    head_dim=64, d_ff=1024)
for variant in ("baseline", "zeropp", "qwz", "hpz", "qgz"):
    pol = make_policy(arch, axes, variant)
    model = Model(arch, pol.zcfg, world=8)
    opt_cfg = AdamWConfig(moments_dtype=pol.moments_dtype)
    ts = trainer_lib.build_train_step(model, mesh, opt_cfg, donate=False,
                                      global_batch=8)
    p_sh, o_sh = trainer_lib.state_shapes(model, opt_cfg)
    params = dr._abstract(p_sh, mesh, ts.in_specs[0])
    opt = dr._abstract(o_sh, mesh, ts.in_specs[1])
    shape = ShapeConfig("t", "train", 64, 8)
    batch = dr._abstract(dr.train_batch_shapes(model, shape), mesh,
                         ts.in_specs[2])
    res = dr._jaxpr_info(ts.fn, (params, opt, batch), mesh)
    from repro.core.zeropp import step_wire_by_label
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out[variant] = {
        "n_params": model.n_params(),
        "wire": res["collectives"]["per_tier_wire"],
        "per_op": {k: v["wire_bytes"]
                   for k, v in res["collectives"]["per_op"].items()},
        "wire_by_label": res["collectives"]["wire_by_label"],
        "projected_by_label": step_wire_by_label(
            model.comm_events(), model.zcfg, sizes),
    }
print("RESULT " + json.dumps(out))
"""


def measured() -> Dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SNIPPET], env=env,
                       capture_output=True, text=True, timeout=1800)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"comm_volume subprocess failed:\n{r.stdout}\n{r.stderr}")


def analytic_table() -> Dict:
    from repro.core.zeropp import ZeroConfig, comm_volume_per_step
    M = 100_000_000  # 100M params
    rows = {}
    for name, z in [
        ("zero3", ZeroConfig.baseline()),
        ("zeropp", ZeroConfig()),
        ("qwz", ZeroConfig(hpz=False, qgz=False)),
        ("hpz", ZeroConfig(qwz=False, qgz=False)),
        ("qgz", ZeroConfig(qwz=False, hpz=False)),
    ]:
        rows[name] = comm_volume_per_step(M, z)
    return rows


def main(csv=True):
    rows = analytic_table()
    base = rows["zero3"]["total"]
    print("# Table 1 (analytic, M=100M params, bf16)")
    print("variant,fwd_allgather,bwd_allgather,grad_reduce,total,reduction")
    for name, r in rows.items():
        print(f"{name},{r['fwd_allgather']},{r['bwd_allgather']},"
              f"{r['grad_reduce']},{r['total']},"
              f"{base / max(r['total'], 1):.2f}x")

    print("# Table 1 (measured wire bytes from compiled HLO, 8 devices)")
    m = measured()
    base_slow = None
    print("variant,slow_tier_bytes,fast_tier_bytes,reduction_slow")
    for variant in ("baseline", "zeropp", "qwz", "hpz", "qgz"):
        w = m[variant]["wire"]
        slow = w["pod"] + w["data"]
        fast = w["model"]
        if variant == "baseline":
            base_slow = slow
        print(f"{variant},{slow:.0f},{fast:.0f},"
              f"{base_slow / max(slow, 1):.2f}x")

    # measured (jaxpr named-scope buckets) vs projected (the analytic
    # event model behind the runtime gate, obs/report.py) per collective
    # label — both sides count the same traced program, so they must
    # agree to 1% (in practice: to the byte) or one model is wrong
    print("# measured vs projected per-device wire bytes by label")
    print("variant,label,measured,projected,rel")
    worst = 0.0
    for variant in ("baseline", "zeropp", "qwz", "hpz", "qgz"):
        mb = m[variant]["wire_by_label"]
        pb = m[variant]["projected_by_label"]
        for lbl in sorted(set(mb) | set(pb)):
            if lbl == "other":
                continue
            mv, pv = mb.get(lbl, 0.0), pb.get(lbl, 0.0)
            rel = abs(mv - pv) / max(mv, pv, 1.0)
            worst = max(worst, rel)
            print(f"{variant},{lbl},{mv:.0f},{pv:.0f},{rel:.4f}")
    if worst > 0.01:
        raise AssertionError(
            f"measured vs projected comm bytes disagree (worst rel "
            f"{worst:.4f} > 0.01) — see table above")
    print(f"# measured==projected within 1% (worst rel {worst:.6f})")
    return m


if __name__ == "__main__":
    main()
